//! Cluster substrate: nodes, NUMA topology, resources, pods.
//!
//! Models the paper's five-node testbed (§V-A) at the granularity the
//! scheduling algorithms observe: allocatable CPUs per socket, memory,
//! per-socket memory bandwidth, NIC bandwidth, and pod placements.

pub mod node;
pub mod pod;
pub mod resources;
pub mod spec;

pub use node::{NodeClass, NodeId, NodeRole, NodeSpec};
pub use pod::{HostfileEntry, JobId, Pod, PodId, PodPhase, PodRole};
pub use resources::{gib, CpuSet, Resources};
pub use spec::{CapacityClass, ClusterSpec, HeterogeneityMix, ALL_MIXES};
