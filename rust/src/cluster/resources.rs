//! Resource quantities (CPU in millicores, memory in bytes) and cpusets —
//! the Kubernetes resource model subset the paper's algorithms operate on.

use std::collections::BTreeSet;
use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A request/limit pair component: CPU millicores + memory bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    /// CPU in millicores (1000 = one core), matching the K8s quantity model.
    pub cpu_milli: u64,
    /// Memory in bytes.
    pub mem_bytes: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu_milli: 0, mem_bytes: 0 };

    pub fn new(cpu_milli: u64, mem_bytes: u64) -> Resources {
        Resources { cpu_milli, mem_bytes }
    }

    /// Full cores, rounding down (the static CPU-manager only grants
    /// exclusive cpusets to integer-CPU containers).
    pub fn whole_cores(&self) -> u32 {
        (self.cpu_milli / 1000) as u32
    }

    /// True iff the CPU quantity is an integer number of cores.
    pub fn is_integer_cpu(&self) -> bool {
        self.cpu_milli % 1000 == 0
    }

    pub fn fits_within(&self, other: &Resources) -> bool {
        self.cpu_milli <= other.cpu_milli && self.mem_bytes <= other.mem_bytes
    }

    /// Scale by a rational factor (used by Algorithm 2's per-worker
    /// R(cpu/Nt * nTasks, mem/Nt * nTasks) division).
    pub fn scaled(&self, num: u64, den: u64) -> Resources {
        assert!(den > 0);
        Resources {
            cpu_milli: self.cpu_milli * num / den,
            mem_bytes: self.mem_bytes * num / den,
        }
    }

    pub fn saturating_sub(&self, other: &Resources) -> Resources {
        Resources {
            cpu_milli: self.cpu_milli.saturating_sub(other.cpu_milli),
            mem_bytes: self.mem_bytes.saturating_sub(other.mem_bytes),
        }
    }

    /// Scalar used for sorting groups by "resource requests" (Algorithm 3's
    /// sortGroupByResourceRequests): CPU-dominant, memory as tiebreak.
    pub fn sort_key(&self) -> (u64, u64) {
        (self.cpu_milli, self.mem_bytes)
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            cpu_milli: self.cpu_milli + o.cpu_milli,
            mem_bytes: self.mem_bytes + o.mem_bytes,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        self.cpu_milli += o.cpu_milli;
        self.mem_bytes += o.mem_bytes;
    }
}

impl Sub for Resources {
    type Output = Resources;
    fn sub(self, o: Resources) -> Resources {
        Resources {
            cpu_milli: self.cpu_milli - o.cpu_milli,
            mem_bytes: self.mem_bytes - o.mem_bytes,
        }
    }
}

impl SubAssign for Resources {
    fn sub_assign(&mut self, o: Resources) {
        self.cpu_milli -= o.cpu_milli;
        self.mem_bytes -= o.mem_bytes;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}m/{:.1}GiB",
            self.cpu_milli,
            self.mem_bytes as f64 / (1u64 << 30) as f64
        )
    }
}

/// Convenience: gibibytes to bytes.
pub const fn gib(n: u64) -> u64 {
    n << 30
}

/// A set of physical CPU ids (node-local numbering).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CpuSet(pub BTreeSet<u32>);

impl CpuSet {
    pub fn empty() -> CpuSet {
        CpuSet(BTreeSet::new())
    }

    pub fn from_range(lo: u32, hi: u32) -> CpuSet {
        CpuSet((lo..hi).collect())
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn contains(&self, cpu: u32) -> bool {
        self.0.contains(&cpu)
    }

    pub fn insert(&mut self, cpu: u32) -> bool {
        self.0.insert(cpu)
    }

    pub fn union(&self, other: &CpuSet) -> CpuSet {
        CpuSet(self.0.union(&other.0).copied().collect())
    }

    pub fn intersect(&self, other: &CpuSet) -> CpuSet {
        CpuSet(self.0.intersection(&other.0).copied().collect())
    }

    pub fn difference(&self, other: &CpuSet) -> CpuSet {
        CpuSet(self.0.difference(&other.0).copied().collect())
    }

    pub fn is_disjoint(&self, other: &CpuSet) -> bool {
        self.0.is_disjoint(&other.0)
    }

    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.0.iter().copied()
    }

    /// Take up to `n` lowest-numbered CPUs out of this set.
    pub fn take(&mut self, n: usize) -> CpuSet {
        let taken: BTreeSet<u32> = self.0.iter().copied().take(n).collect();
        for c in &taken {
            self.0.remove(c);
        }
        CpuSet(taken)
    }
}

impl fmt::Display for CpuSet {
    /// Linux cpuset-style ranges, e.g. "0-3,8,10-11".
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let cpus: Vec<u32> = self.0.iter().copied().collect();
        let mut parts: Vec<String> = Vec::new();
        let mut i = 0;
        while i < cpus.len() {
            let start = cpus[i];
            let mut end = start;
            while i + 1 < cpus.len() && cpus[i + 1] == end + 1 {
                i += 1;
                end = cpus[i];
            }
            parts.push(if start == end {
                format!("{start}")
            } else {
                format!("{start}-{end}")
            });
            i += 1;
        }
        write!(f, "{}", parts.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resources_arithmetic() {
        let a = Resources::new(4000, gib(8));
        let b = Resources::new(1000, gib(2));
        assert_eq!(a + b, Resources::new(5000, gib(10)));
        assert_eq!(a - b, Resources::new(3000, gib(6)));
        assert!(b.fits_within(&a));
        assert!(!a.fits_within(&b));
    }

    #[test]
    fn scaled_matches_algorithm2_division() {
        // Job: 16 cpus / 32 GiB total, Nt=16 tasks; worker with 4 tasks gets
        // R/Nt * 4 = 4 cpus / 8 GiB.
        let job = Resources::new(16_000, gib(32));
        let worker = job.scaled(4, 16);
        assert_eq!(worker, Resources::new(4000, gib(8)));
    }

    #[test]
    fn whole_cores_and_integer_check() {
        assert_eq!(Resources::new(2500, 0).whole_cores(), 2);
        assert!(!Resources::new(2500, 0).is_integer_cpu());
        assert!(Resources::new(2000, 0).is_integer_cpu());
    }

    #[test]
    fn cpuset_take_and_disjoint() {
        let mut pool = CpuSet::from_range(0, 8);
        let a = pool.take(3);
        assert_eq!(a.len(), 3);
        assert_eq!(pool.len(), 5);
        assert!(a.is_disjoint(&pool));
        assert!(a.contains(0) && a.contains(2) && !a.contains(3));
    }

    #[test]
    fn cpuset_display_ranges() {
        let mut s = CpuSet::empty();
        for c in [0, 1, 2, 3, 8, 10, 11] {
            s.insert(c);
        }
        assert_eq!(s.to_string(), "0-3,8,10-11");
        assert_eq!(CpuSet::empty().to_string(), "");
    }

    #[test]
    fn cpuset_set_ops() {
        let a = CpuSet::from_range(0, 4);
        let b = CpuSet::from_range(2, 6);
        assert_eq!(a.intersect(&b).len(), 2);
        assert_eq!(a.union(&b).len(), 6);
        assert_eq!(a.difference(&b).len(), 2);
    }
}
