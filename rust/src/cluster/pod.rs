//! Pods — the deployable unit the paper's algorithms schedule.
//!
//! One MPI job becomes one launcher pod plus `N_w` worker pods (Algorithm 2
//! decides each worker's task count and resources); the scheduler binds
//! workers to nodes, and the kubelet assigns cpusets per its policy.

use super::node::NodeId;
use super::resources::{CpuSet, Resources};

/// Cluster-unique pod id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PodId(pub u64);

/// Cluster-unique job id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodRole {
    /// The `mpirun` host; placed on the control-plane node (paper §V-B).
    Launcher,
    /// Worker `index` of the job (0-based).
    Worker { index: u32 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    /// Node selected by the scheduler, kubelet admission done.
    Bound,
    Running,
    Succeeded,
}

/// A pod wrapping one container (the paper's deployments are
/// one-container-per-pod).
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: PodId,
    pub job: JobId,
    pub name: String,
    pub role: PodRole,
    /// MPI processes running inside this container ("slots" in the
    /// generated hostfile). 0 for the launcher.
    pub ntasks: u32,
    pub requests: Resources,
    pub limits: Resources,
    /// Task-group id assigned by the task-group plugin (Algorithm 3).
    pub group: Option<usize>,
    pub phase: PodPhase,
    /// Binding decided by the scheduler.
    pub node: Option<NodeId>,
    /// Exclusive cpuset granted by the static CPU manager (None = shared
    /// pool under `cpu-manager-policy=none`).
    pub cpuset: Option<CpuSet>,
    /// Whether the granted cpuset spans more than one NUMA domain.
    pub spans_numa: bool,
}

impl Pod {
    pub fn new(id: PodId, job: JobId, name: String, role: PodRole) -> Pod {
        Pod {
            id,
            job,
            name,
            role,
            ntasks: 0,
            requests: Resources::ZERO,
            limits: Resources::ZERO,
            group: None,
            phase: PodPhase::Pending,
            node: None,
            cpuset: None,
            spans_numa: false,
        }
    }

    pub fn is_worker(&self) -> bool {
        matches!(self.role, PodRole::Worker { .. })
    }

    pub fn worker_index(&self) -> Option<u32> {
        match self.role {
            PodRole::Worker { index } => Some(index),
            PodRole::Launcher => None,
        }
    }
}

/// One line of the generated MPI hostfile: `<hostname> slots=<n>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostfileEntry {
    pub hostname: String,
    pub slots: u32,
}

impl std::fmt::Display for HostfileEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} slots={}", self.hostname, self.slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pod_roles() {
        let l = Pod::new(PodId(0), JobId(1), "j1-launcher".into(), PodRole::Launcher);
        let w = Pod::new(PodId(1), JobId(1), "j1-worker-2".into(), PodRole::Worker { index: 2 });
        assert!(!l.is_worker());
        assert!(w.is_worker());
        assert_eq!(w.worker_index(), Some(2));
        assert_eq!(l.worker_index(), None);
        assert_eq!(l.phase, PodPhase::Pending);
    }

    #[test]
    fn hostfile_entry_format() {
        let e = HostfileEntry { hostname: "job1-worker-0".into(), slots: 4 };
        assert_eq!(e.to_string(), "job1-worker-0 slots=4");
    }
}
