//! Cluster specifications — the testbed builder.

use super::node::{NodeId, NodeRole, NodeSpec};

/// Static description of a cluster (the simulator's "hardware").
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
}

impl ClusterSpec {
    /// The paper's five-node testbed: one control-plane node (which also
    /// runs the MPI launchers) plus four worker nodes.
    pub fn paper() -> ClusterSpec {
        let mut nodes = vec![NodeSpec::paper_control_plane("master")];
        for i in 0..4 {
            nodes.push(NodeSpec::paper_worker(&format!("node{}", i + 1)));
        }
        ClusterSpec { nodes }
    }

    /// A scaled variant with `n` worker nodes (future-work §VI larger-scale
    /// scenarios and the scalability ablation bench).
    pub fn with_workers(n: usize) -> ClusterSpec {
        let mut nodes = vec![NodeSpec::paper_control_plane("master")];
        for i in 0..n {
            nodes.push(NodeSpec::paper_worker(&format!("node{}", i + 1)));
        }
        ClusterSpec { nodes }
    }

    pub fn node(&self, id: NodeId) -> &NodeSpec {
        &self.nodes[id.0]
    }

    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.nodes.len()).map(NodeId)
    }

    pub fn worker_ids(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|&id| self.node(id).role == NodeRole::Worker)
            .collect()
    }

    pub fn control_plane_id(&self) -> NodeId {
        self.node_ids()
            .find(|&id| self.node(id).role == NodeRole::ControlPlane)
            .expect("cluster has no control-plane node")
    }

    pub fn worker_count(&self) -> usize {
        self.worker_ids().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_shape() {
        let c = ClusterSpec::paper();
        assert_eq!(c.nodes.len(), 5);
        assert_eq!(c.worker_count(), 4);
        assert_eq!(c.control_plane_id(), NodeId(0));
        assert_eq!(c.node(NodeId(1)).name, "node1");
        // Total schedulable CPU for MPI workloads: 4 × 32 cores.
        let total: u64 = c
            .worker_ids()
            .iter()
            .map(|&id| c.node(id).allocatable().cpu_milli)
            .sum();
        assert_eq!(total, 128_000);
    }

    #[test]
    fn scaled_cluster() {
        let c = ClusterSpec::with_workers(8);
        assert_eq!(c.worker_count(), 8);
        assert_eq!(c.nodes.len(), 9);
    }
}
