//! Cluster specifications — the testbed builder, homogeneous or
//! heterogeneous.
//!
//! [`ClusterSpec::paper`] / [`ClusterSpec::with_workers`] build the
//! paper's homogeneous testbed; [`ClusterSpec::heterogeneous`] builds a
//! cluster from validated [`NodeClass`] groups, and [`HeterogeneityMix`]
//! names the preset fat/thin/balanced mixes the scaling sweeps iterate
//! over.

use anyhow::{bail, Result};

use super::node::{NodeClass, NodeId, NodeRole, NodeSpec};
use super::resources::Resources;

/// A maximal group of nodes sharing one capacity shape (role +
/// allocatable resources) — the bucket granularity of the scheduler's
/// indexed placement engine ([`crate::scheduler::placement`]). Feasibility
/// is identical for every node of a class, so the engine keeps one
/// free-capacity bucket per class instead of scanning every node per pod.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CapacityClass {
    pub role: NodeRole,
    pub allocatable: Resources,
    /// Member nodes, ascending by id.
    pub nodes: Vec<NodeId>,
}

/// Static description of a cluster (the simulator's "hardware").
///
/// # Examples
///
/// ```
/// use kube_fgs::cluster::{ClusterSpec, HeterogeneityMix, NodeClass};
///
/// // The paper's homogeneous testbed, scaled to 8 workers.
/// let c = ClusterSpec::with_workers(8);
/// assert_eq!(c.worker_count(), 8);
/// assert!(!c.is_heterogeneous());
///
/// // A heterogeneous fat/thin mix of the same size: 2 fat (64-core) +
/// // 6 thin (16-core) workers.
/// let het = ClusterSpec::mixed(8, HeterogeneityMix::FatThin);
/// assert_eq!(het.worker_count(), 8);
/// assert!(het.is_heterogeneous());
/// assert_eq!(het.min_worker_cores(), 16);
/// assert_eq!(het.max_worker_cores(), 64);
///
/// // Explicit classes are validated: a zero-count class is rejected.
/// assert!(ClusterSpec::heterogeneous(&[NodeClass::fat(0)]).is_err());
/// ```
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
}

/// Preset heterogeneity mixes for the scaling sweeps (`kube-fgs scaling
/// --mixes ...`, config key `cluster.mix`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HeterogeneityMix {
    /// All workers are the paper's balanced shape (the homogeneous
    /// baseline every earlier experiment ran on).
    Uniform,
    /// ~25% fat (64-core, 10-GbE) + ~75% thin (16-core) workers.
    FatThin,
    /// ~25% fat + ~50% balanced + ~25% thin workers.
    Tiered,
}

/// All mixes, in sweep order.
pub const ALL_MIXES: [HeterogeneityMix; 3] =
    [HeterogeneityMix::Uniform, HeterogeneityMix::FatThin, HeterogeneityMix::Tiered];

impl HeterogeneityMix {
    pub fn name(&self) -> &'static str {
        match self {
            HeterogeneityMix::Uniform => "uniform",
            HeterogeneityMix::FatThin => "fat_thin",
            HeterogeneityMix::Tiered => "tiered",
        }
    }

    /// Parse a CLI/config spelling (case-insensitive, `-` tolerated).
    pub fn parse(s: &str) -> Option<HeterogeneityMix> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "uniform" | "homogeneous" => Some(HeterogeneityMix::Uniform),
            "fat_thin" | "fatthin" => Some(HeterogeneityMix::FatThin),
            "tiered" | "mixed" => Some(HeterogeneityMix::Tiered),
            _ => None,
        }
    }

    /// The node-class composition of this mix at `workers` total worker
    /// nodes. Small clusters degrade gracefully: every named class gets at
    /// least one node where the share would round to zero, and classes
    /// whose share *is* zero are dropped.
    pub fn classes(&self, workers: usize) -> Vec<NodeClass> {
        let classes = match self {
            HeterogeneityMix::Uniform => vec![NodeClass::balanced(workers)],
            HeterogeneityMix::FatThin => {
                let fat = (workers / 4).max(1).min(workers);
                vec![NodeClass::fat(fat), NodeClass::thin(workers - fat)]
            }
            HeterogeneityMix::Tiered => {
                let fat = (workers / 4).max(1).min(workers);
                let thin = (workers / 4).max(1).min(workers - fat);
                vec![
                    NodeClass::fat(fat),
                    NodeClass::balanced(workers - fat - thin),
                    NodeClass::thin(thin),
                ]
            }
        };
        classes.into_iter().filter(|c| c.count > 0).collect()
    }
}

impl std::fmt::Display for HeterogeneityMix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl ClusterSpec {
    /// The paper's five-node testbed: one control-plane node (which also
    /// runs the MPI launchers) plus four worker nodes.
    pub fn paper() -> ClusterSpec {
        let mut nodes = vec![NodeSpec::paper_control_plane("master")];
        for i in 0..4 {
            nodes.push(NodeSpec::paper_worker(&format!("node{}", i + 1)));
        }
        ClusterSpec { nodes }
    }

    /// A scaled variant with `n` worker nodes (future-work §VI larger-scale
    /// scenarios and the scalability ablation bench).
    pub fn with_workers(n: usize) -> ClusterSpec {
        let mut nodes = vec![NodeSpec::paper_control_plane("master")];
        for i in 0..n {
            nodes.push(NodeSpec::paper_worker(&format!("node{}", i + 1)));
        }
        ClusterSpec { nodes }
    }

    /// A heterogeneous cluster: one control-plane node plus each class's
    /// worker nodes, in class order. Every class is validated
    /// ([`NodeClass::validate`]); an empty class list is rejected.
    pub fn heterogeneous(classes: &[NodeClass]) -> Result<ClusterSpec> {
        if classes.is_empty() {
            bail!("heterogeneous cluster needs at least one node class");
        }
        let mut nodes = vec![NodeSpec::paper_control_plane("master")];
        for class in classes {
            class.validate()?;
            for i in 0..class.count {
                nodes.push(class.node_spec(&format!("{}-{}", class.name, i + 1)));
            }
        }
        Ok(ClusterSpec { nodes })
    }

    /// A preset heterogeneity mix at `workers` total worker nodes (the
    /// scaling-sweep axis). Panics on `workers == 0`; callers validate.
    pub fn mixed(workers: usize, mix: HeterogeneityMix) -> ClusterSpec {
        assert!(workers > 0, "cluster needs at least one worker");
        ClusterSpec::heterogeneous(&mix.classes(workers))
            .expect("preset mixes always validate")
    }

    pub fn node(&self, id: NodeId) -> &NodeSpec {
        &self.nodes[id.0]
    }

    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.nodes.len()).map(NodeId)
    }

    pub fn worker_ids(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|&id| self.node(id).role == NodeRole::Worker)
            .collect()
    }

    pub fn control_plane_id(&self) -> NodeId {
        self.node_ids()
            .find(|&id| self.node(id).role == NodeRole::ControlPlane)
            .expect("cluster has no control-plane node")
    }

    pub fn worker_count(&self) -> usize {
        self.worker_ids().len()
    }

    /// True when the worker nodes are not all the same shape (the planner
    /// and scheduler enable class-aware decisions on such clusters).
    pub fn is_heterogeneous(&self) -> bool {
        let mut cores = self
            .nodes
            .iter()
            .filter(|n| n.role == NodeRole::Worker)
            .map(NodeSpec::allocatable_cores);
        match cores.next() {
            Some(first) => cores.any(|c| c != first),
            None => false,
        }
    }

    /// Allocatable cores of the *smallest* worker class — the planner
    /// sizes workers to fit it so thin nodes stay usable.
    pub fn min_worker_cores(&self) -> u32 {
        self.nodes
            .iter()
            .filter(|n| n.role == NodeRole::Worker)
            .map(NodeSpec::allocatable_cores)
            .min()
            .unwrap_or(0)
    }

    /// Allocatable cores of the *largest* worker class.
    pub fn max_worker_cores(&self) -> u32 {
        self.nodes
            .iter()
            .filter(|n| n.role == NodeRole::Worker)
            .map(NodeSpec::allocatable_cores)
            .max()
            .unwrap_or(0)
    }

    /// Partition the nodes into [`CapacityClass`]es: maximal groups
    /// sharing (role, allocatable). On the paper's homogeneous clusters
    /// this yields two classes (control plane + workers); heterogeneous
    /// clusters get one class per distinct worker shape.
    pub fn capacity_classes(&self) -> Vec<CapacityClass> {
        let mut classes: Vec<CapacityClass> = Vec::new();
        for id in self.node_ids() {
            let node = self.node(id);
            let (role, allocatable) = (node.role, node.allocatable());
            match classes
                .iter_mut()
                .find(|c| c.role == role && c.allocatable == allocatable)
            {
                Some(c) => c.nodes.push(id),
                None => classes.push(CapacityClass { role, allocatable, nodes: vec![id] }),
            }
        }
        classes
    }

    /// Total allocatable worker cores (the utilization denominator).
    pub fn total_worker_cores(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.role == NodeRole::Worker)
            .map(|n| n.allocatable_cores() as u64)
            .sum()
    }

    /// Partition the workers into at most `shards` scheduler domains for
    /// the sharded multi-scheduler runner ([`crate::simulator::shard`]),
    /// Volcano-style: a whole worker [`CapacityClass`] is never split
    /// across domains, so the effective domain count is
    /// `min(shards, worker classes)`. On a homogeneous cluster (one
    /// worker class) any `shards` collapses to a single domain — the
    /// whole cluster, returned as-is — which is exactly why uniform
    /// configs are *shard-invariant*: the sharded runner delegates to the
    /// plain single-scheduler path there, bit for bit. Heterogeneous
    /// clusters deal their classes round-robin by class index; every
    /// multi-domain entry is a self-contained [`ClusterSpec`] (its own
    /// control-plane node plus its classes' workers, re-indexed in
    /// original node order).
    pub fn shard_domains(&self, shards: usize) -> Vec<ClusterSpec> {
        let worker_classes: Vec<CapacityClass> = self
            .capacity_classes()
            .into_iter()
            .filter(|c| c.role == NodeRole::Worker)
            .collect();
        let effective = shards.max(1).min(worker_classes.len().max(1));
        if effective <= 1 {
            return vec![self.clone()];
        }
        let control = self.node(self.control_plane_id()).clone();
        let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); effective];
        for (i, class) in worker_classes.iter().enumerate() {
            members[i % effective].extend(class.nodes.iter().copied());
        }
        members
            .into_iter()
            .map(|mut ids| {
                ids.sort();
                let mut nodes = vec![control.clone()];
                nodes.extend(ids.into_iter().map(|id| self.node(id).clone()));
                ClusterSpec { nodes }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_shape() {
        let c = ClusterSpec::paper();
        assert_eq!(c.nodes.len(), 5);
        assert_eq!(c.worker_count(), 4);
        assert_eq!(c.control_plane_id(), NodeId(0));
        assert_eq!(c.node(NodeId(1)).name, "node1");
        // Total schedulable CPU for MPI workloads: 4 × 32 cores.
        let total: u64 = c
            .worker_ids()
            .iter()
            .map(|&id| c.node(id).allocatable().cpu_milli)
            .sum();
        assert_eq!(total, 128_000);
        assert!(!c.is_heterogeneous());
        assert_eq!(c.total_worker_cores(), 128);
    }

    #[test]
    fn scaled_cluster() {
        let c = ClusterSpec::with_workers(8);
        assert_eq!(c.worker_count(), 8);
        assert_eq!(c.nodes.len(), 9);
    }

    #[test]
    fn heterogeneous_builds_and_validates() {
        let c = ClusterSpec::heterogeneous(&[NodeClass::fat(2), NodeClass::thin(6)]).unwrap();
        assert_eq!(c.worker_count(), 8);
        assert!(c.is_heterogeneous());
        assert_eq!(c.min_worker_cores(), 16);
        assert_eq!(c.max_worker_cores(), 64);
        assert_eq!(c.total_worker_cores(), 2 * 64 + 6 * 16);
        // Node names carry their class.
        assert!(c.node(NodeId(1)).name.starts_with("fat-"));
        assert!(c.node(NodeId(3)).name.starts_with("thin-"));
        // Rejections: empty list, zero-count class, zero-capacity class.
        assert!(ClusterSpec::heterogeneous(&[]).is_err());
        assert!(ClusterSpec::heterogeneous(&[NodeClass::thin(0)]).is_err());
        let mut bad = NodeClass::balanced(2);
        bad.reserved_cores = bad.total_cores();
        assert!(ClusterSpec::heterogeneous(&[bad]).is_err());
    }

    #[test]
    fn mixes_cover_requested_worker_count() {
        for mix in ALL_MIXES {
            for workers in [1usize, 2, 3, 4, 8, 16, 33, 128] {
                let c = ClusterSpec::mixed(workers, mix);
                assert_eq!(c.worker_count(), workers, "{mix} at {workers}");
                let total: usize = mix.classes(workers).iter().map(|cl| cl.count).sum();
                assert_eq!(total, workers, "{mix} at {workers}");
            }
        }
        assert!(!ClusterSpec::mixed(8, HeterogeneityMix::Uniform).is_heterogeneous());
        assert!(ClusterSpec::mixed(8, HeterogeneityMix::FatThin).is_heterogeneous());
        assert!(ClusterSpec::mixed(8, HeterogeneityMix::Tiered).is_heterogeneous());
    }

    #[test]
    fn capacity_classes_partition_by_role_and_shape() {
        // Homogeneous: control plane + one worker class covering all four.
        let c = ClusterSpec::paper();
        let classes = c.capacity_classes();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].role, NodeRole::ControlPlane);
        assert_eq!(classes[1].role, NodeRole::Worker);
        assert_eq!(classes[1].nodes.len(), 4);
        // Heterogeneous fat/thin: three classes, nodes ascending, every
        // node in exactly one class.
        let het = ClusterSpec::heterogeneous(&[NodeClass::fat(2), NodeClass::thin(6)]).unwrap();
        let classes = het.capacity_classes();
        assert_eq!(classes.len(), 3);
        let mut all: Vec<usize> =
            classes.iter().flat_map(|cl| cl.nodes.iter().map(|n| n.0)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..het.nodes.len()).collect::<Vec<_>>());
        for cl in &classes {
            assert!(cl.nodes.windows(2).all(|w| w[0] < w[1]), "nodes ascending");
            for &n in &cl.nodes {
                assert_eq!(het.node(n).allocatable(), cl.allocatable);
                assert_eq!(het.node(n).role, cl.role);
            }
        }
    }

    #[test]
    fn shard_domains_collapse_on_homogeneous_clusters() {
        // One worker class: any shard request yields the whole cluster,
        // untouched — the invariant the sharded runner's delegation (and
        // the shard-determinism property test) relies on.
        let c = ClusterSpec::with_workers(8);
        for shards in [1usize, 2, 4, 16] {
            let domains = c.shard_domains(shards);
            assert_eq!(domains.len(), 1, "shards={shards}");
            assert_eq!(domains[0].nodes.len(), c.nodes.len());
        }
        assert_eq!(c.shard_domains(0).len(), 1, "shards=0 clamps to 1");
    }

    #[test]
    fn shard_domains_partition_worker_classes() {
        // Tiered = three worker classes; two domains must split them
        // without ever splitting a class, covering every worker once.
        let c = ClusterSpec::mixed(16, HeterogeneityMix::Tiered);
        let domains = c.shard_domains(2);
        assert_eq!(domains.len(), 2);
        let mut total_workers = 0usize;
        for d in &domains {
            assert_eq!(d.control_plane_id(), NodeId(0), "own control plane first");
            assert!(d.worker_count() > 0, "no empty domain");
            total_workers += d.worker_count();
        }
        assert_eq!(total_workers, c.worker_count(), "every worker in exactly one domain");
        // A class never straddles domains: each distinct worker shape
        // appears in exactly one domain.
        for d in &domains {
            for other in &domains {
                if std::ptr::eq(d, other) {
                    continue;
                }
                for &w in &d.worker_ids() {
                    let shape = d.node(w).allocatable();
                    assert!(
                        other.worker_ids().iter().all(|&o| other.node(o).allocatable() != shape),
                        "worker class split across domains"
                    );
                }
            }
        }
        // Requesting more shards than classes clamps to the class count.
        assert_eq!(c.shard_domains(8).len(), 3);
    }

    #[test]
    fn mix_names_round_trip() {
        for mix in ALL_MIXES {
            assert_eq!(HeterogeneityMix::parse(mix.name()), Some(mix));
        }
        assert_eq!(HeterogeneityMix::parse("FAT-THIN"), Some(HeterogeneityMix::FatThin));
        assert_eq!(HeterogeneityMix::parse("homogeneous"), Some(HeterogeneityMix::Uniform));
        assert_eq!(HeterogeneityMix::parse("nope"), None);
    }
}
