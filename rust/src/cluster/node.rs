//! Node and NUMA topology model — the testbed substrate.
//!
//! The paper's hosts are 2 × Intel Xeon E5-2697v4 (18 cores/socket,
//! hyperthreading disabled), 256 GB RAM, 1-GbE. Four cores per node are
//! reserved for system + Kubernetes components, leaving 32 allocatable
//! (16 per socket). [`NodeSpec::paper_worker`] encodes exactly that.

use super::resources::{gib, CpuSet, Resources};

/// Index into the cluster's node table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRole {
    /// Hosts the control plane and the MPI launchers (paper §V-B).
    ControlPlane,
    /// Runs MPI worker pods.
    Worker,
}

/// Static description of one host.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub role: NodeRole,
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// Cores reserved for system + kube components (spread evenly over
    /// sockets, lowest-numbered cores first — mirrors kubelet's
    /// `--reserved-cpus` behaviour).
    pub reserved_cores: u32,
    pub mem_bytes: u64,
    /// Peak per-socket memory bandwidth, bytes/s (E5-2697v4: ~76.8 GB/s
    /// DDR4-2400 × 4 channels).
    pub membw_per_socket: f64,
    /// NIC bandwidth, bytes/s (1 GbE = 125 MB/s).
    pub nic_bw: f64,
}

impl NodeSpec {
    /// The paper's worker-node configuration.
    pub fn paper_worker(name: &str) -> NodeSpec {
        NodeSpec {
            name: name.to_string(),
            role: NodeRole::Worker,
            sockets: 2,
            cores_per_socket: 18,
            reserved_cores: 4,
            mem_bytes: gib(256),
            membw_per_socket: 76.8e9,
            nic_bw: 125.0e6,
        }
    }

    /// The paper's control-plane node (same hardware, different role).
    pub fn paper_control_plane(name: &str) -> NodeSpec {
        NodeSpec { role: NodeRole::ControlPlane, ..NodeSpec::paper_worker(name) }
    }

    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }

    /// Allocatable cores after the system reservation.
    pub fn allocatable_cores(&self) -> u32 {
        self.total_cores() - self.reserved_cores
    }

    /// Allocatable resources (the scheduler's capacity view).
    pub fn allocatable(&self) -> Resources {
        Resources::new(
            self.allocatable_cores() as u64 * 1000,
            // Reserve 8 GiB for system/kube, like the CPU reservation.
            self.mem_bytes - gib(8),
        )
    }

    /// Socket that owns a given physical CPU id.
    pub fn socket_of(&self, cpu: u32) -> u32 {
        cpu / self.cores_per_socket
    }

    /// Allocatable CPU ids of one socket (reservation takes the
    /// lowest-numbered cores of each socket, evenly split).
    pub fn allocatable_cpus_of_socket(&self, socket: u32) -> CpuSet {
        assert!(socket < self.sockets);
        let reserved_per_socket = self.reserved_cores / self.sockets;
        let lo = socket * self.cores_per_socket + reserved_per_socket;
        let hi = (socket + 1) * self.cores_per_socket;
        CpuSet::from_range(lo, hi)
    }

    /// All allocatable CPU ids.
    pub fn allocatable_cpus(&self) -> CpuSet {
        let mut s = CpuSet::empty();
        for sk in 0..self.sockets {
            s = s.union(&self.allocatable_cpus_of_socket(sk));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worker_topology() {
        let n = NodeSpec::paper_worker("w0");
        assert_eq!(n.total_cores(), 36);
        assert_eq!(n.allocatable_cores(), 32);
        assert_eq!(n.allocatable().cpu_milli, 32_000);
        // 16 allocatable per socket.
        assert_eq!(n.allocatable_cpus_of_socket(0).len(), 16);
        assert_eq!(n.allocatable_cpus_of_socket(1).len(), 16);
    }

    #[test]
    fn reservation_takes_low_cores_per_socket() {
        let n = NodeSpec::paper_worker("w0");
        let s0 = n.allocatable_cpus_of_socket(0);
        let s1 = n.allocatable_cpus_of_socket(1);
        // Cores 0,1 (socket 0) and 18,19 (socket 1) are reserved.
        assert!(!s0.contains(0) && !s0.contains(1) && s0.contains(2));
        assert!(!s1.contains(18) && !s1.contains(19) && s1.contains(20));
    }

    #[test]
    fn socket_of_boundaries() {
        let n = NodeSpec::paper_worker("w0");
        assert_eq!(n.socket_of(0), 0);
        assert_eq!(n.socket_of(17), 0);
        assert_eq!(n.socket_of(18), 1);
        assert_eq!(n.socket_of(35), 1);
    }

    #[test]
    fn allocatable_cpus_disjoint_across_sockets() {
        let n = NodeSpec::paper_worker("w0");
        let s0 = n.allocatable_cpus_of_socket(0);
        let s1 = n.allocatable_cpus_of_socket(1);
        assert!(s0.is_disjoint(&s1));
        assert_eq!(n.allocatable_cpus().len(), 32);
    }
}
