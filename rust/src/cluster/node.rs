//! Node and NUMA topology model — the testbed substrate.
//!
//! The paper's hosts are 2 × Intel Xeon E5-2697v4 (18 cores/socket,
//! hyperthreading disabled), 256 GB RAM, 1-GbE. Four cores per node are
//! reserved for system + Kubernetes components, leaving 32 allocatable
//! (16 per socket). [`NodeSpec::paper_worker`] encodes exactly that.
//!
//! Heterogeneous clusters are described by [`NodeClass`]: a homogeneous
//! group of worker nodes sharing one hardware shape (socket count, cores,
//! memory, bandwidths). The scaling sweeps mix *fat* (4-socket, 10-GbE),
//! *balanced* (the paper shape), and *thin* (1-socket) classes.

use anyhow::{bail, Result};

use super::resources::{gib, CpuSet, Resources};

/// Index into the cluster's node table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRole {
    /// Hosts the control plane and the MPI launchers (paper §V-B).
    ControlPlane,
    /// Runs MPI worker pods.
    Worker,
}

/// Static description of one host.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub role: NodeRole,
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// Cores reserved for system + kube components (spread evenly over
    /// sockets, lowest-numbered cores first — mirrors kubelet's
    /// `--reserved-cpus` behaviour).
    pub reserved_cores: u32,
    pub mem_bytes: u64,
    /// Peak per-socket memory bandwidth, bytes/s (E5-2697v4: ~76.8 GB/s
    /// DDR4-2400 × 4 channels).
    pub membw_per_socket: f64,
    /// NIC bandwidth, bytes/s (1 GbE = 125 MB/s).
    pub nic_bw: f64,
}

impl NodeSpec {
    /// The paper's worker-node configuration.
    pub fn paper_worker(name: &str) -> NodeSpec {
        NodeSpec {
            name: name.to_string(),
            role: NodeRole::Worker,
            sockets: 2,
            cores_per_socket: 18,
            reserved_cores: 4,
            mem_bytes: gib(256),
            membw_per_socket: 76.8e9,
            nic_bw: 125.0e6,
        }
    }

    /// The paper's control-plane node (same hardware, different role).
    pub fn paper_control_plane(name: &str) -> NodeSpec {
        NodeSpec { role: NodeRole::ControlPlane, ..NodeSpec::paper_worker(name) }
    }

    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }

    /// Allocatable cores after the system reservation.
    pub fn allocatable_cores(&self) -> u32 {
        self.total_cores() - self.reserved_cores
    }

    /// Allocatable resources (the scheduler's capacity view).
    pub fn allocatable(&self) -> Resources {
        Resources::new(
            self.allocatable_cores() as u64 * 1000,
            // Reserve 8 GiB for system/kube, like the CPU reservation.
            self.mem_bytes - gib(8),
        )
    }

    /// Socket that owns a given physical CPU id.
    pub fn socket_of(&self, cpu: u32) -> u32 {
        cpu / self.cores_per_socket
    }

    /// Allocatable CPU ids of one socket (reservation takes the
    /// lowest-numbered cores of each socket, evenly split).
    pub fn allocatable_cpus_of_socket(&self, socket: u32) -> CpuSet {
        assert!(socket < self.sockets);
        let reserved_per_socket = self.reserved_cores / self.sockets;
        let lo = socket * self.cores_per_socket + reserved_per_socket;
        let hi = (socket + 1) * self.cores_per_socket;
        CpuSet::from_range(lo, hi)
    }

    /// All allocatable CPU ids.
    pub fn allocatable_cpus(&self) -> CpuSet {
        let mut s = CpuSet::empty();
        for sk in 0..self.sockets {
            s = s.union(&self.allocatable_cpus_of_socket(sk));
        }
        s
    }
}

/// A homogeneous group of worker nodes sharing one hardware shape — the
/// unit of cluster heterogeneity. Three presets cover the scaling sweeps:
/// [`NodeClass::balanced`] (the paper's host), [`NodeClass::fat`]
/// (4-socket, 512 GiB, 10-GbE), and [`NodeClass::thin`] (1-socket,
/// 128 GiB, 1-GbE).
#[derive(Debug, Clone)]
pub struct NodeClass {
    pub name: String,
    /// Number of worker nodes of this class in the cluster.
    pub count: usize,
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// Cores reserved for system + kube components; must spread evenly
    /// over the sockets (mirrors `--reserved-cpus`).
    pub reserved_cores: u32,
    pub mem_bytes: u64,
    pub membw_per_socket: f64,
    pub nic_bw: f64,
}

impl NodeClass {
    /// The paper's worker shape: 2 × 18 cores, 4 reserved, 256 GiB, 1-GbE.
    pub fn balanced(count: usize) -> NodeClass {
        NodeClass {
            name: "balanced".to_string(),
            count,
            sockets: 2,
            cores_per_socket: 18,
            reserved_cores: 4,
            mem_bytes: gib(256),
            membw_per_socket: 76.8e9,
            nic_bw: 125.0e6,
        }
    }

    /// A fat node: 4 × 18 cores (64 allocatable), 512 GiB, 10-GbE.
    pub fn fat(count: usize) -> NodeClass {
        NodeClass {
            name: "fat".to_string(),
            count,
            sockets: 4,
            cores_per_socket: 18,
            reserved_cores: 8,
            mem_bytes: gib(512),
            membw_per_socket: 76.8e9,
            nic_bw: 1.25e9,
        }
    }

    /// A thin node: 1 × 18 cores (16 allocatable), 128 GiB, 1-GbE.
    pub fn thin(count: usize) -> NodeClass {
        NodeClass {
            name: "thin".to_string(),
            count,
            sockets: 1,
            cores_per_socket: 18,
            reserved_cores: 2,
            mem_bytes: gib(128),
            membw_per_socket: 76.8e9,
            nic_bw: 125.0e6,
        }
    }

    /// Look up a preset class by name (`balanced` | `fat` | `thin`,
    /// case-insensitive) — the config-file `cluster.classes[].class` key.
    pub fn parse(name: &str, count: usize) -> Option<NodeClass> {
        match name.to_ascii_lowercase().as_str() {
            "balanced" | "paper" => Some(NodeClass::balanced(count)),
            "fat" => Some(NodeClass::fat(count)),
            "thin" => Some(NodeClass::thin(count)),
            _ => None,
        }
    }

    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }

    /// Allocatable cores of one node of this class.
    pub fn allocatable_cores(&self) -> u32 {
        self.total_cores().saturating_sub(self.reserved_cores)
    }

    /// Reject degenerate shapes: a class must contribute at least one node
    /// with schedulable CPU and memory, and its reservation must split
    /// evenly over the sockets (the CPU-manager free pools assume it).
    pub fn validate(&self) -> Result<()> {
        if self.count == 0 {
            bail!("node class {:?}: count must be >= 1", self.name);
        }
        if self.sockets == 0 || self.cores_per_socket == 0 {
            bail!("node class {:?}: zero-capacity topology", self.name);
        }
        if self.reserved_cores >= self.total_cores() {
            bail!(
                "node class {:?}: reservation ({}) leaves no allocatable cores",
                self.name,
                self.reserved_cores
            );
        }
        if self.reserved_cores % self.sockets != 0 {
            bail!(
                "node class {:?}: reserved cores ({}) must split evenly over {} sockets",
                self.name,
                self.reserved_cores,
                self.sockets
            );
        }
        // NodeSpec::allocatable reserves 8 GiB for system/kube.
        if self.mem_bytes <= gib(8) {
            bail!("node class {:?}: memory must exceed the 8 GiB reservation", self.name);
        }
        if self.membw_per_socket <= 0.0 || self.nic_bw <= 0.0 {
            bail!("node class {:?}: bandwidths must be positive", self.name);
        }
        Ok(())
    }

    /// Materialize one worker node of this class.
    pub fn node_spec(&self, name: &str) -> NodeSpec {
        NodeSpec {
            name: name.to_string(),
            role: NodeRole::Worker,
            sockets: self.sockets,
            cores_per_socket: self.cores_per_socket,
            reserved_cores: self.reserved_cores,
            mem_bytes: self.mem_bytes,
            membw_per_socket: self.membw_per_socket,
            nic_bw: self.nic_bw,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worker_topology() {
        let n = NodeSpec::paper_worker("w0");
        assert_eq!(n.total_cores(), 36);
        assert_eq!(n.allocatable_cores(), 32);
        assert_eq!(n.allocatable().cpu_milli, 32_000);
        // 16 allocatable per socket.
        assert_eq!(n.allocatable_cpus_of_socket(0).len(), 16);
        assert_eq!(n.allocatable_cpus_of_socket(1).len(), 16);
    }

    #[test]
    fn reservation_takes_low_cores_per_socket() {
        let n = NodeSpec::paper_worker("w0");
        let s0 = n.allocatable_cpus_of_socket(0);
        let s1 = n.allocatable_cpus_of_socket(1);
        // Cores 0,1 (socket 0) and 18,19 (socket 1) are reserved.
        assert!(!s0.contains(0) && !s0.contains(1) && s0.contains(2));
        assert!(!s1.contains(18) && !s1.contains(19) && s1.contains(20));
    }

    #[test]
    fn socket_of_boundaries() {
        let n = NodeSpec::paper_worker("w0");
        assert_eq!(n.socket_of(0), 0);
        assert_eq!(n.socket_of(17), 0);
        assert_eq!(n.socket_of(18), 1);
        assert_eq!(n.socket_of(35), 1);
    }

    #[test]
    fn node_class_presets_have_expected_capacity() {
        let fat = NodeClass::fat(2);
        assert_eq!(fat.allocatable_cores(), 64);
        assert_eq!(fat.node_spec("f0").allocatable().cpu_milli, 64_000);
        let thin = NodeClass::thin(2);
        assert_eq!(thin.allocatable_cores(), 16);
        assert_eq!(thin.node_spec("t0").sockets, 1);
        let balanced = NodeClass::balanced(2);
        assert_eq!(balanced.allocatable_cores(), 32);
        // The balanced preset is exactly the paper worker.
        let paper = NodeSpec::paper_worker("b0");
        let from_class = balanced.node_spec("b0");
        assert_eq!(from_class.sockets, paper.sockets);
        assert_eq!(from_class.cores_per_socket, paper.cores_per_socket);
        assert_eq!(from_class.reserved_cores, paper.reserved_cores);
        assert_eq!(from_class.mem_bytes, paper.mem_bytes);
    }

    #[test]
    fn node_class_parse_round_trips() {
        for name in ["balanced", "fat", "thin"] {
            let c = NodeClass::parse(name, 3).unwrap();
            assert_eq!(c.name, name);
            assert_eq!(c.count, 3);
            assert!(c.validate().is_ok());
        }
        assert!(NodeClass::parse("FAT", 1).is_some());
        assert!(NodeClass::parse("gpu", 1).is_none());
    }

    #[test]
    fn node_class_validation_rejects_degenerate_shapes() {
        assert!(NodeClass::fat(0).validate().is_err(), "zero count");
        let mut zero_cores = NodeClass::thin(1);
        zero_cores.cores_per_socket = 0;
        assert!(zero_cores.validate().is_err(), "zero-capacity class");
        let mut all_reserved = NodeClass::thin(1);
        all_reserved.reserved_cores = all_reserved.total_cores();
        assert!(all_reserved.validate().is_err(), "reservation eats everything");
        let mut uneven = NodeClass::balanced(1);
        uneven.reserved_cores = 3; // 3 % 2 sockets != 0
        assert!(uneven.validate().is_err(), "uneven reservation split");
        let mut tiny_mem = NodeClass::thin(1);
        tiny_mem.mem_bytes = gib(4);
        assert!(tiny_mem.validate().is_err(), "memory below the 8 GiB reserve");
    }

    #[test]
    fn thin_and_fat_socket_topology_is_consistent() {
        // 1-socket thin node: all allocatable CPUs in socket 0.
        let thin = NodeClass::thin(1).node_spec("t");
        assert_eq!(thin.allocatable_cores(), 16);
        assert_eq!(thin.allocatable_cpus_of_socket(0).len(), 16);
        assert_eq!(thin.allocatable_cpus().len(), 16);
        // 4-socket fat node: 16 allocatable per socket, disjoint.
        let fat = NodeClass::fat(1).node_spec("f");
        assert_eq!(fat.allocatable_cores(), 64);
        for s in 0..4 {
            assert_eq!(fat.allocatable_cpus_of_socket(s).len(), 16);
        }
        assert_eq!(fat.allocatable_cpus().len(), 64);
        assert_eq!(fat.socket_of(0), 0);
        assert_eq!(fat.socket_of(71), 3);
    }

    #[test]
    fn allocatable_cpus_disjoint_across_sockets() {
        let n = NodeSpec::paper_worker("w0");
        let s0 = n.allocatable_cpus_of_socket(0);
        let s1 = n.allocatable_cpus_of_socket(1);
        assert!(s0.is_disjoint(&s1));
        assert_eq!(n.allocatable_cpus().len(), 32);
    }
}
