//! Figure writer: renders every paper figure as a standalone SVG file
//! (`kube-fgs figures --out <dir>`), using the same experiment drivers as
//! the text tables so the two surfaces can never disagree.

use std::path::Path;

use anyhow::{Context, Result};

use crate::experiments;
use crate::metrics::ExperimentMetrics;
use crate::simulator::JobRecord;
use crate::workload::{exp2_trace, Benchmark, ALL_BENCHMARKS};

use super::svg::{bar_chart, gantt_chart, line_chart, GanttRow, Series};

fn write(dir: &Path, name: &str, content: &str) -> Result<()> {
    let path = dir.join(name);
    std::fs::write(&path, content).with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Render Figs. 4–9 (and the Fig. 7 Gantt panels) into `dir`.
pub fn write_all(dir: &Path, seed: u64) -> Result<()> {
    std::fs::create_dir_all(dir)?;

    // --- Experiment 1: Figs. 4 and 5 ---
    let exp1 = experiments::exp1_all_scenarios(seed);
    let cats1: Vec<&str> = exp1.iter().map(|(s, _)| s.name()).collect();
    write(
        dir,
        "fig4_dgemm_runtime.svg",
        &bar_chart(
            "Fig. 4 — Average job running time of 10 EP-DGEMM jobs",
            &cats1,
            &[Series {
                name: "EP-DGEMM".into(),
                values: exp1.iter().map(|(_, m)| m.avg_running[&Benchmark::EpDgemm]).collect(),
            }],
            "seconds",
        ),
    )?;
    write(
        dir,
        "fig5_dgemm_response.svg",
        &bar_chart(
            "Fig. 5 — Overall response time of scheduling 10 EP-DGEMM jobs",
            &cats1,
            &[Series {
                name: "overall response".into(),
                values: exp1.iter().map(|(_, m)| m.overall_response).collect(),
            }],
            "seconds",
        ),
    )?;

    // --- Experiment 2: Figs. 6 and 7 ---
    let exp2 = experiments::exp2_all_scenarios(seed);
    let cats2: Vec<&str> = exp2.iter().map(|(s, _)| s.name()).collect();
    let series6: Vec<Series> = ALL_BENCHMARKS
        .iter()
        .map(|&b| Series {
            name: b.name().into(),
            values: exp2.iter().map(|(_, m)| m.avg_running[&b]).collect(),
        })
        .collect();
    write(
        dir,
        "fig6_mixed_running.svg",
        &bar_chart(
            "Fig. 6 — Average job running time per benchmark (20 mixed jobs)",
            &cats2,
            &series6,
            "seconds",
        ),
    )?;
    write(
        dir,
        "fig6_overall_response.svg",
        &bar_chart(
            "Fig. 6 — Overall response time (20 mixed jobs)",
            &cats2,
            &[Series {
                name: "overall response".into(),
                values: exp2.iter().map(|(_, m)| m.overall_response).collect(),
            }],
            "seconds",
        ),
    )?;
    write(
        dir,
        "fig7_makespan.svg",
        &bar_chart(
            "Fig. 7 — Makespan (20 mixed jobs)",
            &cats2,
            &[Series {
                name: "makespan".into(),
                values: exp2.iter().map(|(_, m)| m.makespan).collect(),
            }],
            "seconds",
        ),
    )?;
    for (scenario, _) in &exp2 {
        let out = experiments::run_scenario(*scenario, &exp2_trace(seed), seed, None);
        let m = ExperimentMetrics::from(&out);
        let rows: Vec<GanttRow> = m
            .per_job
            .iter()
            .map(|r| GanttRow {
                label: format!("{}-{}", r.benchmark.name(), r.id.0),
                submit: r.submit_time,
                start: r.start_time,
                finish: r.finish_time,
            })
            .collect();
        write(
            dir,
            &format!("fig7_gantt_{}.svg", scenario.name().to_lowercase()),
            &gantt_chart(
                &format!("Fig. 7 — scheduling process, {scenario}"),
                &rows,
            ),
        )?;
    }

    // --- Experiment 3: Figs. 8 and 9 ---
    let exp3 = experiments::exp3_all_scenarios(seed);
    let job_labels: Vec<String> = exp3[0]
        .1
        .per_job
        .iter()
        .map(|r| format!("{}-{}", r.benchmark.name(), r.id.0))
        .collect();
    let cats3: Vec<&str> = job_labels.iter().map(String::as_str).collect();
    let per_job_series = |metric: fn(&JobRecord) -> f64| -> Vec<Series> {
        exp3.iter()
            .map(|(s, m)| Series {
                name: s.name().into(),
                values: m.per_job.iter().map(metric).collect(),
            })
            .collect()
    };
    write(
        dir,
        "fig8_framework_runtime.svg",
        &bar_chart(
            "Fig. 8 — Job running time with different frameworks",
            &cats3,
            &per_job_series(JobRecord::running),
            "seconds",
        ),
    )?;
    write(
        dir,
        "fig9_framework_response.svg",
        &bar_chart(
            "Fig. 9 — Job response time with different frameworks",
            &cats3,
            &per_job_series(JobRecord::response),
            "seconds",
        ),
    )?;

    // Table III as CSV alongside the figures.
    let rows: Vec<Vec<String>> = exp3
        .iter()
        .map(|(s, m)| vec![s.name().to_string(), format!("{:.0}", m.makespan)])
        .collect();
    write(dir, "table3_makespan.csv", &super::csv(&["scenario", "makespan_s"], &rows))?;

    // --- Queue-policy ablation (FIFO / strict / SJF / EASY backfill) ---
    let qres = experiments::queue_ablation(
        seed,
        experiments::QUEUE_ABLATION_JOBS,
        experiments::QUEUE_ABLATION_INTERVAL,
    );
    let qcats: Vec<&str> = qres.iter().map(|(q, _)| q.name()).collect();
    write(
        dir,
        "queue_policy_response.svg",
        &bar_chart(
            "Queue-policy ablation — overall response (200 mixed jobs, CM_G_TG)",
            &qcats,
            &[Series {
                name: "overall response".into(),
                values: qres.iter().map(|(_, m)| m.overall_response).collect(),
            }],
            "seconds",
        ),
    )?;
    let qrows: Vec<Vec<String>> = qres
        .iter()
        .map(|(q, m)| {
            vec![
                q.name().to_string(),
                format!("{:.0}", m.overall_response),
                format!("{:.0}", m.makespan),
                format!("{:.0}", m.avg_wait),
            ]
        })
        .collect();
    write(
        dir,
        "queue_policy_ablation.csv",
        &super::csv(&["queue_policy", "overall_response_s", "makespan_s", "avg_wait_s"], &qrows),
    )?;

    // --- Fairness ablation (two-tenant trace: batch + high-prio prod) ---
    let fres = experiments::fairness_ablation(
        seed,
        experiments::FAIRNESS_JOBS,
        experiments::FAIRNESS_INTERVAL,
    );
    let fcats: Vec<&str> = fres.iter().map(|r| r.label).collect();
    let tenant_series = |tenant: crate::workload::TenantId, name: &str| -> Series {
        Series {
            name: name.into(),
            values: fres
                .iter()
                .map(|r| r.tenant(tenant).map(|s| s.mean_response).unwrap_or(0.0))
                .collect(),
        }
    };
    write(
        dir,
        "fairness_tenant_response.svg",
        &bar_chart(
            "Fairness ablation — per-tenant mean response (200 two-tenant jobs, CM_G_TG)",
            &fcats,
            &[
                tenant_series(crate::workload::PROD_TENANT, "prod (high prio)"),
                tenant_series(crate::workload::BATCH_TENANT, "batch"),
            ],
            "seconds",
        ),
    )?;
    let frows: Vec<Vec<String>> =
        fres.iter().map(experiments::FairnessRow::report_cells).collect();
    write(
        dir,
        "fairness_ablation.csv",
        &super::csv(
            &[
                "config",
                "overall_response_s",
                "prod_mean_response_s",
                "batch_mean_response_s",
                "jain_index",
                "preemptions",
            ],
            &frows,
        ),
    )?;
    Ok(())
}

/// Render the elasticity ablation: one bar chart per headline metric
/// (response, makespan, utilization) over the rigid / moldable /
/// malleable modes, plus the CSV record (`kube-fgs elasticity --out
/// <dir>`; CI uploads these on pushes to main).
pub fn write_elasticity(dir: &Path, rows: &[experiments::ElasticityRow]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let erows: Vec<Vec<String>> =
        rows.iter().map(experiments::ElasticityRow::report_cells).collect();
    write(
        dir,
        "elasticity.csv",
        &super::csv(
            &[
                "mode",
                "overall_response_s",
                "makespan_s",
                "avg_wait_s",
                "utilization",
                "preemptions",
                "resizes",
            ],
            &erows,
        ),
    )?;
    let cats: Vec<&str> = rows.iter().map(|r| r.label).collect();
    let metrics: [(&str, &str, &str, fn(&experiments::ElasticityRow) -> f64); 3] = [
        (
            "response",
            "Elasticity ablation — overall response (elastic trace)",
            "seconds",
            |r| r.metrics.overall_response,
        ),
        (
            "makespan",
            "Elasticity ablation — makespan (elastic trace)",
            "seconds",
            |r| r.metrics.makespan,
        ),
        (
            "utilization",
            "Elasticity ablation — cluster utilization (elastic trace)",
            "fraction of worker cores",
            |r| r.utilization,
        ),
    ];
    for (slug, title, unit, metric) in metrics {
        write(
            dir,
            &format!("elasticity_{slug}.svg"),
            &bar_chart(
                title,
                &cats,
                &[Series { name: slug.into(), values: rows.iter().map(metric).collect() }],
                unit,
            ),
        )?;
    }
    Ok(())
}

/// Render the scaling sweep: per mix × metric, one line chart with a
/// polyline per queue policy over the cluster sizes, plus the CSV record
/// (`kube-fgs scaling --out <dir>`; CI uploads these on pushes to main).
pub fn write_scaling(dir: &Path, points: &[experiments::ScalingPoint]) -> Result<()> {
    use std::collections::BTreeSet;
    std::fs::create_dir_all(dir)?;
    write(dir, "scaling_sweep.csv", &experiments::scaling_csv(points))?;

    let mixes: Vec<crate::cluster::HeterogeneityMix> = {
        let mut seen = BTreeSet::new();
        points.iter().filter(|p| seen.insert(p.mix.name())).map(|p| p.mix).collect()
    };
    let metrics: [(&str, &str, fn(&experiments::ScalingPoint) -> f64); 3] = [
        ("response", "overall response (s)", |p| p.metrics.overall_response),
        ("makespan", "makespan (s)", |p| p.metrics.makespan),
        ("utilization", "utilization", |p| p.utilization),
    ];
    for mix in mixes {
        let of_mix: Vec<&experiments::ScalingPoint> =
            points.iter().filter(|p| p.mix == mix).collect();
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = of_mix.iter().map(|p| p.workers).collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        let policies: Vec<crate::scheduler::QueuePolicyKind> = {
            let mut seen = BTreeSet::new();
            of_mix.iter().filter(|p| seen.insert(p.queue.name())).map(|p| p.queue).collect()
        };
        let xs: Vec<f64> = sizes.iter().map(|&w| w as f64).collect();
        for (slug, label, metric) in metrics {
            let series: Vec<Series> = policies
                .iter()
                .map(|&q| Series {
                    name: q.name().to_string(),
                    values: sizes
                        .iter()
                        .map(|&w| {
                            of_mix
                                .iter()
                                .find(|p| p.workers == w && p.queue == q)
                                .map(|&p| metric(p))
                                .unwrap_or(0.0)
                        })
                        .collect(),
                })
                .collect();
            write(
                dir,
                &format!("scaling_{slug}_{}.svg", mix.name()),
                &line_chart(
                    &format!("Scaling sweep — {label}, {} mix (CM_G_TG placement)", mix.name()),
                    &xs,
                    &series,
                    "worker nodes",
                    label,
                ),
            )?;
        }
    }
    Ok(())
}

/// Render the serve saturation sweep: one line chart per latency metric
/// (p50/p95/p99 response, SLO-violation fraction) with a polyline per
/// policy over the traffic multipliers, plus the CSV record
/// (`kube-fgs serve --out <dir>`; CI uploads the JSON artifact on pushes
/// to main).
pub fn write_serve(dir: &Path, points: &[experiments::ServePoint]) -> Result<()> {
    use std::collections::BTreeSet;
    std::fs::create_dir_all(dir)?;
    write(dir, "serve_sweep.csv", &experiments::serve_csv(points))?;

    let scenarios: Vec<crate::scenario::Scenario> = {
        let mut seen = BTreeSet::new();
        points.iter().filter(|p| seen.insert(p.scenario.name())).map(|p| p.scenario).collect()
    };
    let multipliers: Vec<f64> = {
        let mut m: Vec<f64> = points.iter().map(|p| p.multiplier).collect();
        m.sort_by(|a, b| a.total_cmp(b));
        m.dedup();
        m
    };
    let metrics: [(&str, &str, fn(&experiments::ServePoint) -> f64); 4] = [
        ("p50", "p50 response (s)", |p| p.slo.overall.p50),
        ("p95", "p95 response (s)", |p| p.slo.overall.p95),
        ("p99", "p99 response (s)", |p| p.slo.overall.p99),
        ("violations", "SLO-violation fraction", |p| p.slo.violation_fraction()),
    ];
    for (slug, label, metric) in metrics {
        let series: Vec<Series> = scenarios
            .iter()
            .map(|&sc| Series {
                name: sc.name().to_string(),
                values: multipliers
                    .iter()
                    .map(|&m| {
                        points
                            .iter()
                            .find(|p| p.scenario == sc && p.multiplier == m)
                            .map(metric)
                            .unwrap_or(0.0)
                    })
                    .collect(),
            })
            .collect();
        write(
            dir,
            &format!("serve_{slug}.svg"),
            &line_chart(
                &format!("Serve sweep — {label} vs traffic multiplier"),
                &multipliers,
                &series,
                "traffic multiplier",
                label,
            ),
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    #[test]
    fn writes_every_figure_file() {
        let dir = std::env::temp_dir().join(format!("kube_fgs_figs_{}", std::process::id()));
        write_all(&dir, 2).unwrap();
        let expected = [
            "fig4_dgemm_runtime.svg",
            "fig5_dgemm_response.svg",
            "fig6_mixed_running.svg",
            "fig6_overall_response.svg",
            "fig7_makespan.svg",
            "fig7_gantt_cm_g_tg.svg",
            "fig8_framework_runtime.svg",
            "fig9_framework_response.svg",
            "table3_makespan.csv",
            "queue_policy_response.svg",
            "queue_policy_ablation.csv",
            "fairness_tenant_response.svg",
            "fairness_ablation.csv",
        ];
        for f in expected {
            let p = dir.join(f);
            assert!(p.exists(), "{f} missing");
            let content = std::fs::read_to_string(&p).unwrap();
            assert!(!content.is_empty());
            if f.ends_with(".svg") {
                assert!(content.starts_with("<svg"), "{f}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_scaling_emits_csv_and_curves_per_mix() {
        use crate::cluster::HeterogeneityMix;
        use crate::scheduler::QueuePolicyKind;
        let points = experiments::scaling_sweep(
            2,
            &[2, 4],
            &[HeterogeneityMix::Uniform, HeterogeneityMix::FatThin],
            &[QueuePolicyKind::FifoSkip, QueuePolicyKind::Sjf],
            &[1],
            2,
            30.0,
        );
        let dir =
            std::env::temp_dir().join(format!("kube_fgs_scaling_{}", std::process::id()));
        write_scaling(&dir, &points).unwrap();
        for f in [
            "scaling_sweep.csv",
            "scaling_response_uniform.svg",
            "scaling_makespan_uniform.svg",
            "scaling_utilization_uniform.svg",
            "scaling_response_fat_thin.svg",
            "scaling_makespan_fat_thin.svg",
            "scaling_utilization_fat_thin.svg",
        ] {
            let p = dir.join(f);
            assert!(p.exists(), "{f} missing");
            let content = std::fs::read_to_string(&p).unwrap();
            assert!(!content.is_empty());
            if f.ends_with(".svg") {
                assert!(content.starts_with("<svg"), "{f}");
                assert!(content.contains("<polyline"), "{f} has curves");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_elasticity_emits_csv_and_bar_charts() {
        // Small trace: file-shape checks only (the ablation's dominance
        // acceptance lives in tests/integration.rs).
        let rows = experiments::elasticity_ablation(2, 10, 20.0);
        let dir =
            std::env::temp_dir().join(format!("kube_fgs_elastic_{}", std::process::id()));
        write_elasticity(&dir, &rows).unwrap();
        for f in [
            "elasticity.csv",
            "elasticity_response.svg",
            "elasticity_makespan.svg",
            "elasticity_utilization.svg",
        ] {
            let p = dir.join(f);
            assert!(p.exists(), "{f} missing");
            let content = std::fs::read_to_string(&p).unwrap();
            assert!(!content.is_empty());
            if f.ends_with(".svg") {
                assert!(content.starts_with("<svg"), "{f}");
            } else {
                assert!(content.contains("malleable"), "{f} lists every mode");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_serve_emits_csv_and_curves() {
        // Tiny sweep: file-shape checks only (the saturation acceptance
        // lives in tests/integration.rs).
        let points = experiments::serve_sweep(
            2,
            &[Scenario::CmGTg],
            &[1.0, 2.0],
            3600.0,
            1,
            None,
            false,
        );
        let dir = std::env::temp_dir().join(format!("kube_fgs_serve_{}", std::process::id()));
        write_serve(&dir, &points).unwrap();
        for f in [
            "serve_sweep.csv",
            "serve_p50.svg",
            "serve_p95.svg",
            "serve_p99.svg",
            "serve_violations.svg",
        ] {
            let p = dir.join(f);
            assert!(p.exists(), "{f} missing");
            let content = std::fs::read_to_string(&p).unwrap();
            assert!(!content.is_empty());
            if f.ends_with(".svg") {
                assert!(content.starts_with("<svg"), "{f}");
                assert!(content.contains("<polyline"), "{f} has curves");
            } else {
                assert!(content.contains("violation_fraction"), "{f} lists the SLO columns");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scenario_parse_used_by_gantt_names() {
        // Gantt filenames must round-trip through Scenario::parse.
        for s in crate::scenario::TABLE2_SCENARIOS {
            assert!(Scenario::parse(s.name()).is_some());
        }
    }
}
