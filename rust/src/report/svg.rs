//! SVG figure rendering — publication-style versions of the paper's
//! figures (grouped bar charts for Figs. 4–6/8–9, Gantt panels for
//! Fig. 7, line charts for the scaling curves), written without external
//! dependencies.
//!
//! `kube-fgs figures --out DIR` drops one .svg per paper figure;
//! `kube-fgs scaling --out DIR` adds the scaling curves.

use std::fmt::Write as _;

/// A single data series (one scenario) in a grouped bar chart.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub values: Vec<f64>,
}

const PALETTE: [&str; 8] = [
    "#4c72b0", "#dd8452", "#55a868", "#c44e52", "#8172b3", "#937860", "#da8bc3", "#8c8c8c",
];

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// Nice round step for an axis covering [0, max].
fn axis_step(max: f64) -> f64 {
    if max <= 0.0 {
        return 1.0;
    }
    let raw = max / 5.0;
    let mag = 10f64.powf(raw.log10().floor());
    let norm = raw / mag;
    let step = if norm < 1.5 {
        1.0
    } else if norm < 3.5 {
        2.0
    } else if norm < 7.5 {
        5.0
    } else {
        10.0
    };
    step * mag
}

/// Grouped bar chart: `categories` on the x-axis, one bar per series in
/// each category. Returns a complete standalone SVG document.
pub fn bar_chart(
    title: &str,
    categories: &[&str],
    series: &[Series],
    y_label: &str,
) -> String {
    assert!(!categories.is_empty() && !series.is_empty());
    for s in series {
        assert_eq!(s.values.len(), categories.len(), "series {} length", s.name);
    }
    let (w, h) = (900.0, 420.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 46.0, 88.0);
    let plot_w = w - ml - mr;
    let plot_h = h - mt - mb;
    let max = series
        .iter()
        .flat_map(|s| s.values.iter().copied())
        .fold(0.0_f64, f64::max)
        .max(1e-9)
        * 1.08;

    let mut svg = String::new();
    let _ = write!(
        svg,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}" font-family="Helvetica,Arial,sans-serif">"#
    );
    let _ = write!(svg, r#"<rect width="{w}" height="{h}" fill="white"/>"#);
    let _ = write!(
        svg,
        r#"<text x="{}" y="24" font-size="16" text-anchor="middle" font-weight="bold">{}</text>"#,
        w / 2.0,
        esc(title)
    );

    // y axis + gridlines.
    let step = axis_step(max);
    let mut y = 0.0;
    while y <= max {
        let py = mt + plot_h * (1.0 - y / max);
        let _ = write!(
            svg,
            r##"<line x1="{ml}" y1="{py}" x2="{}" y2="{py}" stroke="#dddddd" stroke-width="1"/>"##,
            ml + plot_w
        );
        let _ = write!(
            svg,
            r#"<text x="{}" y="{}" font-size="11" text-anchor="end">{}</text>"#,
            ml - 6.0,
            py + 4.0,
            if step >= 1.0 { format!("{y:.0}") } else { format!("{y:.2}") }
        );
        y += step;
    }
    let _ = write!(
        svg,
        r#"<text x="16" y="{}" font-size="12" text-anchor="middle" transform="rotate(-90 16 {})">{}</text>"#,
        mt + plot_h / 2.0,
        mt + plot_h / 2.0,
        esc(y_label)
    );

    // bars.
    let ncat = categories.len() as f64;
    let nser = series.len() as f64;
    let group_w = plot_w / ncat;
    let bar_w = (group_w * 0.8) / nser;
    for (ci, _) in categories.iter().enumerate() {
        for (si, s) in series.iter().enumerate() {
            let v = s.values[ci];
            let bh = plot_h * v / max;
            let x = ml + group_w * ci as f64 + group_w * 0.1 + bar_w * si as f64;
            let y = mt + plot_h - bh;
            let color = PALETTE[si % PALETTE.len()];
            let _ = write!(
                svg,
                r#"<rect x="{x:.1}" y="{y:.1}" width="{:.1}" height="{bh:.1}" fill="{color}"><title>{}: {v:.1}</title></rect>"#,
                bar_w * 0.92,
                esc(&s.name)
            );
        }
    }

    // x labels.
    for (ci, cat) in categories.iter().enumerate() {
        let x = ml + group_w * (ci as f64 + 0.5);
        let _ = write!(
            svg,
            r#"<text x="{x:.1}" y="{}" font-size="11" text-anchor="end" transform="rotate(-30 {x:.1} {})">{}</text>"#,
            mt + plot_h + 16.0,
            mt + plot_h + 16.0,
            esc(cat)
        );
    }

    // legend.
    let mut lx = ml;
    let ly = h - 14.0;
    for (si, s) in series.iter().enumerate() {
        let color = PALETTE[si % PALETTE.len()];
        let _ = write!(svg, r#"<rect x="{lx}" y="{}" width="11" height="11" fill="{color}"/>"#, ly - 10.0);
        let _ = write!(
            svg,
            r#"<text x="{}" y="{ly}" font-size="11">{}</text>"#,
            lx + 15.0,
            esc(&s.name)
        );
        lx += 15.0 + 8.0 * s.name.len() as f64 + 18.0;
    }
    svg.push_str("</svg>");
    svg
}

/// Multi-series line chart over a shared numeric x-axis (the scaling
/// curves: x = cluster size, one polyline per queue policy). Returns a
/// complete standalone SVG document.
pub fn line_chart(
    title: &str,
    xs: &[f64],
    series: &[Series],
    x_label: &str,
    y_label: &str,
) -> String {
    assert!(!xs.is_empty() && !series.is_empty());
    for s in series {
        assert_eq!(s.values.len(), xs.len(), "series {} length", s.name);
    }
    let (w, h) = (900.0, 420.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 46.0, 88.0);
    let plot_w = w - ml - mr;
    let plot_h = h - mt - mb;
    let x_min = xs.iter().copied().fold(f64::INFINITY, f64::min);
    let x_max = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max).max(x_min + 1e-9);
    let y_max = series
        .iter()
        .flat_map(|s| s.values.iter().copied())
        .fold(0.0_f64, f64::max)
        .max(1e-9)
        * 1.08;
    let px = |x: f64| ml + plot_w * (x - x_min) / (x_max - x_min);
    let py = |y: f64| mt + plot_h * (1.0 - y / y_max);

    let mut svg = String::new();
    let _ = write!(
        svg,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}" font-family="Helvetica,Arial,sans-serif">"#
    );
    let _ = write!(svg, r#"<rect width="{w}" height="{h}" fill="white"/>"#);
    let _ = write!(
        svg,
        r#"<text x="{}" y="24" font-size="16" text-anchor="middle" font-weight="bold">{}</text>"#,
        w / 2.0,
        esc(title)
    );

    // y axis + gridlines.
    let step = axis_step(y_max);
    let mut y = 0.0;
    while y <= y_max {
        let gy = py(y);
        let _ = write!(
            svg,
            r##"<line x1="{ml}" y1="{gy}" x2="{}" y2="{gy}" stroke="#dddddd" stroke-width="1"/>"##,
            ml + plot_w
        );
        let _ = write!(
            svg,
            r#"<text x="{}" y="{}" font-size="11" text-anchor="end">{}</text>"#,
            ml - 6.0,
            gy + 4.0,
            if step >= 1.0 { format!("{y:.0}") } else { format!("{y:.2}") }
        );
        y += step;
    }
    let _ = write!(
        svg,
        r#"<text x="16" y="{}" font-size="12" text-anchor="middle" transform="rotate(-90 16 {})">{}</text>"#,
        mt + plot_h / 2.0,
        mt + plot_h / 2.0,
        esc(y_label)
    );

    // x ticks at the sample points.
    for &x in xs {
        let gx = px(x);
        let _ = write!(
            svg,
            r##"<line x1="{gx:.1}" y1="{mt}" x2="{gx:.1}" y2="{}" stroke="#eeeeee" stroke-width="1"/>"##,
            mt + plot_h
        );
        let _ = write!(
            svg,
            r#"<text x="{gx:.1}" y="{}" font-size="11" text-anchor="middle">{x:.0}</text>"#,
            mt + plot_h + 16.0
        );
    }
    let _ = write!(
        svg,
        r#"<text x="{}" y="{}" font-size="12" text-anchor="middle">{}</text>"#,
        ml + plot_w / 2.0,
        mt + plot_h + 36.0,
        esc(x_label)
    );

    // polylines + markers.
    for (si, s) in series.iter().enumerate() {
        let color = PALETTE[si % PALETTE.len()];
        let pts: Vec<String> = xs
            .iter()
            .zip(&s.values)
            .map(|(&x, &v)| format!("{:.1},{:.1}", px(x), py(v)))
            .collect();
        let _ = write!(
            svg,
            r#"<polyline fill="none" stroke="{color}" stroke-width="2" points="{}"/>"#,
            pts.join(" ")
        );
        for (&x, &v) in xs.iter().zip(&s.values) {
            let _ = write!(
                svg,
                r#"<circle cx="{:.1}" cy="{:.1}" r="3" fill="{color}"><title>{}: {v:.1}</title></circle>"#,
                px(x),
                py(v),
                esc(&s.name)
            );
        }
    }

    // legend.
    let mut lx = ml;
    let ly = h - 14.0;
    for (si, s) in series.iter().enumerate() {
        let color = PALETTE[si % PALETTE.len()];
        let _ = write!(svg, r#"<rect x="{lx}" y="{}" width="11" height="11" fill="{color}"/>"#, ly - 10.0);
        let _ = write!(
            svg,
            r#"<text x="{}" y="{ly}" font-size="11">{}</text>"#,
            lx + 15.0,
            esc(&s.name)
        );
        lx += 15.0 + 8.0 * s.name.len() as f64 + 18.0;
    }
    svg.push_str("</svg>");
    svg
}

/// Gantt chart (Fig. 7 scheduling-process panel): one row per job with a
/// waiting span and a running span.
pub struct GanttRow {
    pub label: String,
    pub submit: f64,
    pub start: f64,
    pub finish: f64,
}

pub fn gantt_chart(title: &str, rows: &[GanttRow]) -> String {
    assert!(!rows.is_empty());
    let w = 960.0;
    let row_h = 18.0;
    let (ml, mr, mt, mb) = (150.0, 20.0, 46.0, 40.0);
    let h = mt + mb + row_h * rows.len() as f64;
    let t_end = rows.iter().map(|r| r.finish).fold(1.0_f64, f64::max);
    let plot_w = w - ml - mr;
    let px = |t: f64| ml + plot_w * t / t_end;

    let mut svg = String::new();
    let _ = write!(
        svg,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}" font-family="Helvetica,Arial,sans-serif">"#
    );
    let _ = write!(svg, r#"<rect width="{w}" height="{h}" fill="white"/>"#);
    let _ = write!(
        svg,
        r#"<text x="{}" y="24" font-size="15" text-anchor="middle" font-weight="bold">{}</text>"#,
        w / 2.0,
        esc(title)
    );
    // time gridlines.
    let step = axis_step(t_end);
    let mut t = 0.0;
    while t <= t_end {
        let x = px(t);
        let _ = write!(
            svg,
            r##"<line x1="{x:.1}" y1="{mt}" x2="{x:.1}" y2="{}" stroke="#e5e5e5"/>"##,
            h - mb
        );
        let _ = write!(
            svg,
            r#"<text x="{x:.1}" y="{}" font-size="10" text-anchor="middle">{t:.0}s</text>"#,
            h - mb + 14.0
        );
        t += step;
    }
    for (i, r) in rows.iter().enumerate() {
        let y = mt + row_h * i as f64;
        let _ = write!(
            svg,
            r#"<text x="{}" y="{}" font-size="10" text-anchor="end">{}</text>"#,
            ml - 6.0,
            y + row_h * 0.7,
            esc(&r.label)
        );
        // waiting span.
        if r.start > r.submit {
            let _ = write!(
                svg,
                r##"<rect x="{:.1}" y="{:.1}" width="{:.1}" height="{:.1}" fill="#cccccc"><title>wait {:.0}s</title></rect>"##,
                px(r.submit),
                y + 3.0,
                (px(r.start) - px(r.submit)).max(0.5),
                row_h - 6.0,
                r.start - r.submit
            );
        }
        // running span.
        let _ = write!(
            svg,
            r##"<rect x="{:.1}" y="{:.1}" width="{:.1}" height="{:.1}" fill="#4c72b0"><title>run {:.0}s</title></rect>"##,
            px(r.start),
            y + 3.0,
            (px(r.finish) - px(r.start)).max(0.5),
            row_h - 6.0,
            r.finish - r.start
        );
    }
    svg.push_str("</svg>");
    svg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_is_valid_svg_with_all_elements() {
        let svg = bar_chart(
            "Fig. 4",
            &["NONE", "CM"],
            &[
                Series { name: "EP-DGEMM".into(), values: vec![850.0, 690.0] },
                Series { name: "EP-STREAM".into(), values: vec![1170.0, 980.0] },
            ],
            "seconds",
        );
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<rect").count() >= 5, true, "bars + bg + legend");
        assert!(svg.contains("EP-DGEMM") && svg.contains("NONE"));
        assert!(svg.contains("Fig. 4"));
    }

    #[test]
    fn bar_chart_escapes_markup() {
        let svg = bar_chart(
            "a<b & c>d",
            &["x"],
            &[Series { name: "s&s".into(), values: vec![1.0] }],
            "y",
        );
        assert!(svg.contains("a&lt;b &amp; c&gt;d"));
        assert!(!svg.contains("<b &"));
    }

    #[test]
    fn gantt_renders_wait_and_run_spans() {
        let svg = gantt_chart(
            "Fig. 7",
            &[
                GanttRow { label: "j1".into(), submit: 0.0, start: 100.0, finish: 500.0 },
                GanttRow { label: "j2".into(), submit: 50.0, start: 50.0, finish: 300.0 },
            ],
        );
        assert!(svg.contains("wait 100s"));
        assert!(svg.contains("run 400s"));
        assert!(svg.contains("j2"));
    }

    #[test]
    fn line_chart_renders_series_and_axes() {
        let svg = line_chart(
            "Scaling — overall response",
            &[8.0, 16.0, 32.0],
            &[
                Series { name: "fifo".into(), values: vec![100.0, 150.0, 210.0] },
                Series { name: "easy_backfill".into(), values: vec![90.0, 120.0, 160.0] },
            ],
            "workers",
            "seconds",
        );
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.matches("<circle").count() >= 6, "markers per point");
        assert!(svg.contains("easy_backfill") && svg.contains("workers"));
    }

    #[test]
    #[should_panic]
    fn line_chart_rejects_mismatched_series() {
        line_chart(
            "t",
            &[1.0, 2.0],
            &[Series { name: "s".into(), values: vec![1.0] }],
            "x",
            "y",
        );
    }

    #[test]
    fn axis_step_is_round() {
        assert_eq!(axis_step(10.0), 2.0);
        assert_eq!(axis_step(97.0), 20.0);
        assert_eq!(axis_step(3000.0), 500.0);
        assert_eq!(axis_step(0.0), 1.0);
    }

    #[test]
    #[should_panic]
    fn bar_chart_rejects_mismatched_series() {
        bar_chart(
            "t",
            &["a", "b"],
            &[Series { name: "s".into(), values: vec![1.0] }],
            "y",
        );
    }
}
