//! Reporting: text tables, CSV export, and the ASCII Gantt chart behind
//! the Fig.-7 "scheduling process" panels.

pub mod figures;
pub mod svg;

use std::collections::BTreeMap;

use crate::apiserver::Event;
use crate::metrics::ExperimentMetrics;
use crate::simulator::SimOutput;
use crate::workload::ALL_BENCHMARKS;

/// Render a text table: header + rows, column-aligned.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (cell, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {cell:<w$} |"));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// CSV rendering (RFC-4180-ish; quotes cells containing separators).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| -> String {
        if s.contains(',') || s.contains('"') || s.contains('\n') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut out = headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

/// Format seconds as the paper's Table-III "D days, HH:MM:SS (S s)" form.
pub fn fmt_makespan(secs: f64) -> String {
    let s = secs.round() as u64;
    let days = s / 86_400;
    let h = (s % 86_400) / 3_600;
    let m = (s % 3_600) / 60;
    let sec = s % 60;
    format!("{days} days, {h:02}:{m:02}:{sec:02} ({s} s)")
}

/// Summary block for one scenario run (Fig.-6-style aggregate).
pub fn scenario_summary(name: &str, m: &ExperimentMetrics) -> String {
    let mut rows = Vec::new();
    for b in ALL_BENCHMARKS {
        if let Some(avg) = m.avg_running.get(&b) {
            rows.push(vec![b.name().to_string(), format!("{avg:.1}")]);
        }
    }
    rows.push(vec!["overall response (T)".into(), format!("{:.1}", m.overall_response)]);
    rows.push(vec!["makespan".into(), format!("{:.1}", m.makespan)]);
    rows.push(vec!["avg wait".into(), format!("{:.1}", m.avg_wait)]);
    format!("== {name} ==\n{}", table(&["metric", "seconds"], &rows))
}

/// ASCII Gantt of the scheduling process (Fig. 7): one row per job,
/// bracketed wait (`.`) and run (`#`) spans over a compressed time axis.
pub fn gantt(out: &SimOutput, width: usize) -> String {
    let m = ExperimentMetrics::from(out);
    let t_end = m
        .per_job
        .iter()
        .map(|r| r.finish_time)
        .fold(1.0_f64, f64::max);
    let scale = width as f64 / t_end;
    let mut s = String::new();
    s.push_str(&format!(
        "time 0 .. {:.0}s  ('.' waiting, '#' running)\n",
        t_end
    ));
    for r in &m.per_job {
        let submit = (r.submit_time * scale).round() as usize;
        let start = (r.start_time * scale).round() as usize;
        let finish = ((r.finish_time * scale).round() as usize).max(start + 1);
        let mut line = vec![b' '; width.max(finish)];
        for c in line.iter_mut().take(start).skip(submit) {
            *c = b'.';
        }
        for c in line.iter_mut().take(finish).skip(start) {
            *c = b'#';
        }
        s.push_str(&format!(
            "{:>12} |{}\n",
            format!("{}-{}", r.benchmark.name(), r.id.0),
            String::from_utf8(line).unwrap()
        ));
    }
    s
}

/// Per-node pod-placement timeline extracted from the event log (the lower
/// panels of Fig. 7).
pub fn node_timeline(out: &SimOutput) -> String {
    let mut per_node: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for e in &out.api.events {
        if let Event::PodBound { t, pod, node } = e {
            let p = &out.api.pods[pod];
            per_node
                .entry(node.0)
                .or_default()
                .push(format!("t={t:.0}s {} ({} tasks)", p.name, p.ntasks));
        }
    }
    let mut s = String::new();
    for (node, pods) in per_node {
        s.push_str(&format!(
            "{}:\n",
            out.api.spec.nodes[node].name
        ));
        for line in pods {
            s.push_str(&format!("  {line}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            &["a", "long-header"],
            &[
                vec!["x".into(), "1".into()],
                vec!["yyyy".into(), "22".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn csv_escapes_commas_and_quotes() {
        let c = csv(&["a", "b"], &[vec!["x,y".into(), "q\"q".into()]]);
        assert!(c.contains("\"x,y\""));
        assert!(c.contains("\"q\"\"q\""));
    }

    #[test]
    fn makespan_format_matches_table3() {
        assert_eq!(fmt_makespan(2520.0), "0 days, 00:42:00 (2520 s)");
        assert_eq!(fmt_makespan(123_055.0), "1 days, 10:10:55 (123055 s)");
    }

    #[test]
    fn gantt_renders_wait_and_run() {
        use crate::apiserver::ApiServer;
        use crate::cluster::{ClusterSpec, JobId};
        use crate::kubelet::KubeletConfig;
        use crate::simulator::JobRecord;
        use crate::workload::Benchmark;
        let out = SimOutput {
            records: vec![JobRecord {
                id: JobId(1),
                benchmark: Benchmark::EpDgemm,
                tenant: crate::workload::DEFAULT_TENANT,
                priority: 0,
                submit_time: 0.0,
                start_time: 50.0,
                finish_time: 100.0,
                running_secs: 50.0,
            }],
            unschedulable: vec![],
            api: ApiServer::new(ClusterSpec::paper(), KubeletConfig::default_policy()),
            sched_stats: Default::default(),
            core_stats: Default::default(),
        };
        let g = gantt(&out, 40);
        assert!(g.contains('.'), "wait span rendered: {g}");
        assert!(g.contains('#'), "run span rendered: {g}");
        assert!(g.contains("EP-DGEMM-1"));
    }
}
