//! Job-queue policy subsystem — *which job goes next* (the layer next to
//! the placement plugins, which decide *where* its pods land).
//!
//! In the paper's multi-layer design this sits inside the
//! infrastructure-layer scheduler: the application-layer planner has
//! already chosen each job's granularity, the controller has built its
//! pods, and the queue discipline decides the order in which the
//! [`crate::scheduler::Scheduler`] session tries to place the resulting
//! gangs. The paper's own scheduler walks the pending queue FIFO and
//! silently skips gang-blocked jobs, so a large job at the head can
//! starve behind a stream of small ones. This module makes the queue
//! discipline a plugin: a [`QueuePolicy`] orders the pending queue,
//! decides skip-vs-block on a gang failure, and may hold backfill
//! reservations computed from the projected completion times of the
//! running jobs.
//!
//! Six implementations:
//! - [`FifoSkip`] — the seed behaviour made explicit: FIFO order, a
//!   blocked job is skipped (later jobs may overtake it indefinitely);
//! - [`FifoStrict`] — FIFO order, a blocked job blocks the session (no
//!   overtaking, no starvation, poor utilization);
//! - [`Sjf`] — shortest-job-first by the perf model's walltime estimate,
//!   blocked jobs skipped;
//! - [`EasyBackfill`] — FIFO order; the first blocked job gets a
//!   reservation at its *shadow time* (the projected instant enough
//!   resources free up for its gang), and later jobs are backfilled only
//!   if their estimated completion does not cross the shadow time;
//! - [`ConservativeBackfill`] — *every* blocked job holds a reservation,
//!   tracked on a per-resource availability profile
//!   ([`ResourceTimeline`]): backfills may use holes behind reservations
//!   but can never take resources a reservation counted on, so no queued
//!   job's start is ever pushed back (up to estimate error);
//! - [`FairShare`] — multi-tenant weighted deficit ordering: tenants with
//!   the least weight-normalized service consumed go first, then priority,
//!   then FIFO within a tenant.

use std::collections::{BTreeMap, BTreeSet};

use crate::apiserver::{ApiServer, Event};
use crate::cluster::{ClusterSpec, JobId, NodeId, NodeRole, Pod, PodPhase, PodRole, Resources};
use crate::perfmodel::{walltime_factor, Calibration};

/// Selector for the queue discipline, carried by `SchedulerConfig`
/// (kept `Copy` so scheduler profiles stay plain values).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueuePolicyKind {
    /// Seed behaviour: FIFO walk, gang-blocked jobs skipped.
    FifoSkip,
    /// FIFO walk, first gang-blocked job ends the session.
    FifoStrict,
    /// Shortest-job-first by estimated walltime.
    Sjf,
    /// EASY backfilling: FIFO + reservation for the first blocked job.
    EasyBackfill,
    /// Conservative backfilling: a reservation for every blocked job.
    ConservativeBackfill,
    /// Multi-tenant weighted fair share (deficit ordering).
    FairShare,
}

/// All queue policies, in ablation-table order.
pub const ALL_QUEUE_POLICIES: [QueuePolicyKind; 6] = [
    QueuePolicyKind::FifoSkip,
    QueuePolicyKind::FifoStrict,
    QueuePolicyKind::Sjf,
    QueuePolicyKind::EasyBackfill,
    QueuePolicyKind::ConservativeBackfill,
    QueuePolicyKind::FairShare,
];

impl QueuePolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            QueuePolicyKind::FifoSkip => "fifo",
            QueuePolicyKind::FifoStrict => "fifo_strict",
            QueuePolicyKind::Sjf => "sjf",
            QueuePolicyKind::EasyBackfill => "easy_backfill",
            QueuePolicyKind::ConservativeBackfill => "cons_backfill",
            QueuePolicyKind::FairShare => "fair_share",
        }
    }

    /// Parse a CLI/config spelling (case-insensitive, common aliases).
    pub fn parse(s: &str) -> Option<QueuePolicyKind> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "fifo" | "fifo_skip" => Some(QueuePolicyKind::FifoSkip),
            "fifo_strict" | "strict" => Some(QueuePolicyKind::FifoStrict),
            "sjf" | "shortest_job_first" => Some(QueuePolicyKind::Sjf),
            "easy_backfill" | "easy" | "backfill" | "bf" => {
                Some(QueuePolicyKind::EasyBackfill)
            }
            "cons_backfill" | "conservative" | "conservative_backfill" | "cbf" => {
                Some(QueuePolicyKind::ConservativeBackfill)
            }
            "fair_share" | "fairshare" | "fair" | "fs" => Some(QueuePolicyKind::FairShare),
            _ => None,
        }
    }

    pub fn build(&self) -> Box<dyn QueuePolicy> {
        match self {
            QueuePolicyKind::FifoSkip => Box::new(FifoSkip),
            QueuePolicyKind::FifoStrict => Box::new(FifoStrict),
            QueuePolicyKind::Sjf => Box::new(Sjf),
            QueuePolicyKind::EasyBackfill => Box::new(EasyBackfill),
            QueuePolicyKind::ConservativeBackfill => Box::new(ConservativeBackfill),
            QueuePolicyKind::FairShare => Box::new(FairShare),
        }
    }

    /// Disciplines whose block/reserve semantics only exist under gang
    /// all-or-nothing; rejected for no-gang scheduler profiles at the
    /// CLI/config boundary.
    pub fn requires_gang(&self) -> bool {
        matches!(
            self,
            QueuePolicyKind::FifoStrict
                | QueuePolicyKind::EasyBackfill
                | QueuePolicyKind::ConservativeBackfill
        )
    }
}

impl std::fmt::Display for QueuePolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Read-only view of one scheduling session handed to queue decisions.
pub struct QueueContext<'a> {
    pub api: &'a ApiServer,
    pub now: f64,
    /// Projected completion time of each running job (the simulator feeds
    /// its exact projections; standalone callers get base-time estimates).
    pub projected_completion: &'a BTreeMap<JobId, f64>,
    /// The session's current free-resource view, indexed by node.
    pub free: &'a [Resources],
    /// Multiplier on the queue layer's walltime estimates — the
    /// misprediction model (`SchedulerConfig::walltime_error_factor`);
    /// 1.0 trusts the perf model's estimates.
    pub walltime_factor: f64,
}

impl QueueContext<'_> {
    /// The walltime estimate the queue layer plans with: the perf model's
    /// [`estimated_runtime`] scaled by the session's error factor.
    pub fn estimate(&self, job: JobId) -> f64 {
        estimated_runtime(self.api, job) * self.walltime_factor
    }
}

/// What a gang-placement failure means for the rest of the session.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GangDecision {
    /// Keep walking the queue; the failed job stays pending.
    Skip,
    /// Stop the session: nothing behind the failed job may start.
    Block,
    /// Hold a reservation for the failed job: later jobs may start only
    /// if they are projected to finish before `shadow_time`.
    Reserve { shadow_time: f64 },
}

/// The queue discipline plugin: ordering + gang-failure semantics +
/// backfill admission under a held reservation.
///
/// `order` applies to every scheduler profile; the gang-failure and
/// backfill hooks only fire under gang all-or-nothing (`config.gang`), so
/// the block/reserve disciplines are rejected for no-gang profiles at the
/// CLI/config boundary rather than silently degrading to FIFO-skip.
///
/// # Examples
///
/// ```
/// use kube_fgs::scheduler::{QueuePolicy, QueuePolicyKind};
///
/// // Parse a CLI/config spelling and build the discipline it names.
/// let kind = QueuePolicyKind::parse("easy").unwrap();
/// assert_eq!(kind, QueuePolicyKind::EasyBackfill);
/// let policy: Box<dyn QueuePolicy> = kind.build();
/// assert_eq!(policy.kind(), kind);
///
/// // EASY reads the running jobs' projected completions for its shadow
/// // time, and its reserve semantics only exist under gang scheduling.
/// assert!(policy.needs_projections());
/// assert!(kind.requires_gang());
///
/// // Conservative backfilling reserves for every blocked job; EASY only
/// // for the first.
/// assert!(QueuePolicyKind::ConservativeBackfill.build().reserves_every_job());
/// assert!(!policy.reserves_every_job());
/// ```
pub trait QueuePolicy {
    fn kind(&self) -> QueuePolicyKind;

    /// Reorder the pending queue (input: FIFO by submit time). `now` feeds
    /// time-dependent orderings (fair-share deficit counters).
    fn order(&self, api: &ApiServer, now: f64, pending: &mut Vec<JobId>);

    /// Decide what a gang failure means. Policies where
    /// [`QueuePolicy::reserves_every_job`] is false are only consulted for
    /// the *first* failure of a session (EASY semantics); conservative
    /// backfilling is consulted for every one.
    fn on_gang_failure(&self, ctx: &QueueContext<'_>, job: JobId) -> GangDecision;

    /// With the session's earliest reservation at `shadow_time`, may `job`
    /// still be tried?
    fn may_backfill(&self, ctx: &QueueContext<'_>, job: JobId, shadow_time: f64) -> bool;

    /// Whether this policy reads the projected-completion map. Lets
    /// [`Scheduler::cycle`](crate::scheduler::Scheduler::cycle) skip
    /// building completion estimates on the default (FIFO) hot path.
    fn needs_projections(&self) -> bool {
        false
    }

    /// Conservative disciplines hold a reservation for *every* blocked job
    /// of the session, not just the first.
    fn reserves_every_job(&self) -> bool {
        false
    }
}

/// Estimated walltime of a job: the benchmark's calibrated base runtime
/// scaled by the perf model's pre-placement slowdown estimate
/// ([`walltime_factor`]) for the job's planned worker split. SJF ordering
/// and the backfill windows use this estimate (placement-dependent
/// contention is not known ahead of time, so backfill guarantees are soft,
/// as in real EASY deployments with user-supplied walltimes).
///
/// Uses the default [`Calibration`] — the queue layer has no handle on a
/// per-simulation calibration, and every current scenario runs the
/// defaults. A calibration-sweep feature would need to thread the
/// instance through [`QueueContext`] (ROADMAP: queue-policy axis).
pub fn estimated_runtime(api: &ApiServer, job: JobId) -> f64 {
    let obj = &api.jobs[&job];
    let bench = obj.planned.spec.benchmark;
    let worker_tasks: Vec<u32> = obj
        .pods
        .iter()
        .map(|pid| &api.pods[pid])
        .filter(|p| p.is_worker())
        .map(|p| p.ntasks)
        .collect();
    bench.base_running_secs() * walltime_factor(bench, &worker_tasks, &Calibration::default())
}

/// Estimate of every running job's completion, for callers that schedule
/// without a simulator (`Scheduler::cycle`): started + estimated runtime
/// (scaled by the misprediction factor — these are *queue* estimates, not
/// actual runtimes), clamped to `now` for overrunning jobs.
pub fn estimated_completions(
    api: &ApiServer,
    now: f64,
    walltime_factor: f64,
) -> BTreeMap<JobId, f64> {
    api.running_jobs()
        .into_iter()
        .map(|id| {
            let job = &api.jobs[&id];
            let start = job.start_time.unwrap_or(now);
            (id, (start + estimated_runtime(api, id) * walltime_factor).max(now))
        })
        .collect()
}

/// Greedy role-constrained first-fit of `pods` into the per-node `free`
/// vector, mutating it as pods are placed and returning the per-pod
/// `(node, requests)` assignment in input order, or `None` as soon as
/// some pod cannot fit. A cheap stand-in for a full scored placement,
/// shared by the EASY shadow-time search, the conservative resource
/// timeline, and the simulator's submit-time gang-feasibility check.
pub fn first_fit_assignment<'a>(
    spec: &ClusterSpec,
    free: &mut [Resources],
    pods: impl Iterator<Item = &'a Pod>,
) -> Option<Vec<(NodeId, Resources)>> {
    let mut placed = Vec::new();
    for pod in pods {
        let mut chosen = None;
        for (n, f) in free.iter_mut().enumerate() {
            let role_ok = match pod.role {
                PodRole::Launcher => spec.nodes[n].role == NodeRole::ControlPlane,
                PodRole::Worker { .. } => spec.nodes[n].role == NodeRole::Worker,
            };
            if role_ok && pod.requests.fits_within(f) {
                *f -= pod.requests;
                chosen = Some(NodeId(n));
                break;
            }
        }
        match chosen {
            Some(node) => placed.push((node, pod.requests)),
            None => return None,
        }
    }
    Some(placed)
}

/// Boolean form of [`first_fit_assignment`] for callers that only need
/// feasibility.
pub fn first_fit_pods<'a>(
    spec: &ClusterSpec,
    free: &mut [Resources],
    pods: impl Iterator<Item = &'a Pod>,
) -> bool {
    first_fit_assignment(spec, free, pods).is_some()
}

/// Can `job`'s pending pods be first-fit placed into `free`? Shared by the
/// shadow-time search and the preemption victim selection.
pub fn job_fits(api: &ApiServer, free: &[Resources], job: JobId) -> bool {
    let mut trial: Vec<Resources> = free.to_vec();
    let pending = api.jobs[&job]
        .pods
        .iter()
        .map(|pid| &api.pods[pid])
        .filter(|p| p.phase == PodPhase::Pending);
    first_fit_pods(&api.spec, &mut trial, pending)
}

/// EASY shadow time: walk the running jobs in projected-completion order,
/// releasing their resources onto the session's free view, until the
/// blocked job's gang fits. Returns `None` when it can never fit (the job
/// is infeasible for this cluster even when idle).
pub fn shadow_time(ctx: &QueueContext<'_>, job: JobId) -> Option<f64> {
    let mut free: Vec<Resources> = ctx.free.to_vec();
    if job_fits(ctx.api, &free, job) {
        return Some(ctx.now);
    }
    let mut releases: Vec<(f64, JobId)> = ctx
        .api
        .running_jobs()
        .into_iter()
        .map(|id| {
            let t = ctx
                .projected_completion
                .get(&id)
                .copied()
                .unwrap_or_else(|| ctx.now + ctx.estimate(id));
            (t.max(ctx.now), id)
        })
        .collect();
    releases.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    for (t, id) in releases {
        for pid in &ctx.api.jobs[&id].pods {
            let pod = &ctx.api.pods[pid];
            if let (Some(node), PodPhase::Bound | PodPhase::Running) = (pod.node, pod.phase) {
                free[node.0] += pod.requests;
            }
        }
        if job_fits(ctx.api, &free, job) {
            return Some(t);
        }
    }
    None
}

/// Per-resource availability profile for conservative backfilling: a step
/// function `time -> per-node free resources`, seeded from the session's
/// free view plus the projected completion of every running job. Blocked
/// jobs *claim* their reservation window `[start, start + walltime)` out
/// of the profile, so every later decision sees exactly what is left:
///
/// - a backfill may use holes *behind* reservations (the earlier
///   earliest-shadow-only gate rejected any job whose estimate crossed the
///   first shadow, even when it took nothing a reservation counted on);
/// - a backfill can never occupy resources a reservation counted on (the
///   earlier gate could not see *which* resources a shadow referred to, so
///   a second blocked job's reservation could be silently violated).
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceTimeline {
    /// `(segment start, per-node free)` sorted by time. The first segment
    /// starts at the session's `now`; each segment extends to the next
    /// start, the last one to infinity. Segment starts are distinct
    /// (releases at bit-equal times share one point — the rule the
    /// incrementally maintained [`TimelineCache`] reproduces exactly).
    points: Vec<(f64, Vec<Resources>)>,
}

impl ResourceTimeline {
    /// Build the release profile at `ctx.now` from scratch: the session's
    /// free view, growing at each running job's projected completion.
    /// This is the pinned reference for the persistent [`TimelineCache`];
    /// `Scheduler` sessions normally clone the cache instead.
    pub fn new(ctx: &QueueContext<'_>) -> ResourceTimeline {
        let mut releases: Vec<(f64, JobId)> = ctx
            .api
            .running_jobs()
            .into_iter()
            .map(|id| {
                let t = ctx
                    .projected_completion
                    .get(&id)
                    .copied()
                    .unwrap_or_else(|| ctx.now + ctx.estimate(id));
                (t.max(ctx.now), id)
            })
            .collect();
        releases.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut points = vec![(ctx.now, ctx.free.to_vec())];
        for (t, id) in releases {
            let mut free = points.last().unwrap().1.clone();
            for pid in &ctx.api.jobs[&id].pods {
                let pod = &ctx.api.pods[pid];
                if let (Some(node), PodPhase::Bound | PodPhase::Running) =
                    (pod.node, pod.phase)
                {
                    free[node.0] += pod.requests;
                }
            }
            let last = points.last_mut().unwrap();
            if t == last.0 {
                last.1 = free;
            } else {
                points.push((t, free));
            }
        }
        ResourceTimeline { points }
    }

    /// Elementwise minimum free over the window `[from, until)` — the
    /// capacity a job running through that window may rely on.
    pub fn min_free_over(&self, from: f64, until: f64) -> Vec<Resources> {
        let mut min: Option<Vec<Resources>> = None;
        for (i, (start, free)) in self.points.iter().enumerate() {
            let end = self.points.get(i + 1).map(|p| p.0).unwrap_or(f64::INFINITY);
            if end <= from || *start >= until {
                continue;
            }
            match &mut min {
                None => min = Some(free.clone()),
                Some(m) => {
                    for (mm, f) in m.iter_mut().zip(free) {
                        mm.cpu_milli = mm.cpu_milli.min(f.cpu_milli);
                        mm.mem_bytes = mm.mem_bytes.min(f.mem_bytes);
                    }
                }
            }
        }
        min.unwrap_or_else(|| self.points.last().unwrap().1.clone())
    }

    /// Ensure a segment boundary exists at `t` (cloning the covering
    /// segment's free view) and return its index.
    fn ensure_point(&mut self, t: f64) -> usize {
        match self.points.iter().position(|(s, _)| *s >= t - 1e-9) {
            Some(i) if (self.points[i].0 - t).abs() < 1e-9 => i,
            Some(i) => {
                debug_assert!(i >= 1, "claim before the profile start");
                let free = self.points[i - 1].1.clone();
                self.points.insert(i, (t, free));
                i
            }
            None => {
                let free = self.points.last().unwrap().1.clone();
                self.points.push((t, free));
                self.points.len() - 1
            }
        }
    }

    /// Subtract a placement from every segment overlapping
    /// `[start, end)`. Callers verify the placement fits
    /// [`ResourceTimeline::min_free_over`] of the same window first;
    /// the subtraction saturates as a belt-and-braces guard against
    /// floating-point boundary cases.
    pub fn claim(&mut self, start: f64, end: f64, placement: &[(NodeId, Resources)]) {
        let i0 = self.ensure_point(start);
        let i1 = self.ensure_point(end);
        for (_, free) in &mut self.points[i0..i1] {
            for &(node, req) in placement {
                free[node.0] = free[node.0].saturating_sub(&req);
            }
        }
    }

    /// Earliest start `t >= now` at which `job`'s pending gang first-fits
    /// the profile for its whole window `[t, t + est)`, with the placement
    /// found. `None` when no segment admits it (the job is infeasible
    /// under the current claims even with everything released).
    ///
    /// Candidate windows are evaluated against a range-minimum segment
    /// tree built once per call. The window starting at segment `i`
    /// covers exactly the segments `[i, i1)` — starts are strictly
    /// ascending, so segment `i` is the first one ending past `t_i`, and
    /// `i1` is the first segment starting at or after the window end —
    /// and the elementwise `u64` minimum is associative and commutative,
    /// so the tree's answer is *bit-identical* to the linear scan under
    /// any association: O(points × (log points + nodes)) against the
    /// retained reference's O(points² × nodes) under heavy conservative
    /// queues. Debug builds assert every window minimum against
    /// [`ResourceTimeline::min_free_over`]; whole simulations are pinned
    /// across the two paths by a property test.
    pub fn earliest_fit(
        &self,
        api: &ApiServer,
        job: JobId,
        est: f64,
    ) -> Option<(f64, Vec<(NodeId, Resources)>)> {
        let tree = MinTree::build(&self.points);
        for i in 0..self.points.len() {
            let t = self.points[i].0;
            let until = t + est;
            // First segment starting at or after the window end; the
            // window is empty (est <= 0) when it does not reach past `i`.
            let i1 = self.points.partition_point(|p| p.0 < until);
            let mut min = match tree.query(i, i1) {
                Some(m) => m,
                None => self.points.last().unwrap().1.clone(),
            };
            #[cfg(debug_assertions)]
            debug_assert_eq!(
                min,
                self.min_free_over(t, until),
                "segment-tree window minimum drifted from the linear scan at {t}"
            );
            let pending = api.jobs[&job]
                .pods
                .iter()
                .map(|pid| &api.pods[pid])
                .filter(|p| p.phase == PodPhase::Pending);
            if let Some(placement) = first_fit_assignment(&api.spec, &mut min, pending) {
                return Some((t, placement));
            }
        }
        None
    }

    /// The retained linear-scan reference for
    /// [`ResourceTimeline::earliest_fit`]: every candidate start re-scans
    /// the whole profile through [`ResourceTimeline::min_free_over`].
    /// Kept verbatim as the pinned reference the segment-tree path is
    /// debug-asserted and property-pinned against; forced through every
    /// scheduler call site by `Scheduler::force_linear_earliest_fit`.
    pub fn earliest_fit_linear(
        &self,
        api: &ApiServer,
        job: JobId,
        est: f64,
    ) -> Option<(f64, Vec<(NodeId, Resources)>)> {
        for i in 0..self.points.len() {
            let t = self.points[i].0;
            let mut min = self.min_free_over(t, t + est);
            let pending = api.jobs[&job]
                .pods
                .iter()
                .map(|pid| &api.pods[pid])
                .filter(|p| p.phase == PodPhase::Pending);
            if let Some(placement) = first_fit_assignment(&api.spec, &mut min, pending) {
                return Some((t, placement));
            }
        }
        None
    }

    /// Dispatch between the segment-tree default and the pinned linear
    /// reference — the `force_timeline_rebuild`-style forcing hook the
    /// scheduler threads through every earliest-fit call site.
    pub fn earliest_fit_forced(
        &self,
        api: &ApiServer,
        job: JobId,
        est: f64,
        force_linear: bool,
    ) -> Option<(f64, Vec<(NodeId, Resources)>)> {
        if force_linear {
            self.earliest_fit_linear(api, job, est)
        } else {
            self.earliest_fit(api, job, est)
        }
    }

    /// Build a profile directly from `(segment start, per-node free)`
    /// points — starts strictly ascending, every free vector the same
    /// length. Benches and property tests use this to drive
    /// [`ResourceTimeline::earliest_fit`] against synthetic profiles
    /// without simulating the running set that would produce them.
    pub fn from_points(points: Vec<(f64, Vec<Resources>)>) -> ResourceTimeline {
        assert!(!points.is_empty(), "profile needs at least the base segment");
        for w in points.windows(2) {
            assert!(w[0].0 < w[1].0, "segment starts must be strictly ascending");
            assert_eq!(w[0].1.len(), w[1].1.len(), "per-node free vectors must agree");
        }
        ResourceTimeline { points }
    }
}

/// Range-minimum segment tree over the profile's per-segment free
/// vectors, built once per [`ResourceTimeline::earliest_fit`] call. The
/// combining operation — elementwise `u64` minimum over
/// `(cpu_milli, mem_bytes)` — is associative and commutative, so any
/// association over a segment range yields the same bits as the linear
/// left fold; no floating point is involved.
struct MinTree {
    n: usize,
    /// Heap layout: `tree[n + i]` holds segment `i`'s free vector,
    /// `tree[k]` the elementwise minimum of its two children.
    tree: Vec<Vec<Resources>>,
}

impl MinTree {
    fn build(points: &[(f64, Vec<Resources>)]) -> MinTree {
        let n = points.len();
        let mut tree = vec![Vec::new(); 2 * n];
        for (i, (_, free)) in points.iter().enumerate() {
            tree[n + i] = free.clone();
        }
        for k in (1..n).rev() {
            tree[k] = Self::merged(&tree[2 * k], &tree[2 * k + 1]);
        }
        MinTree { n, tree }
    }

    fn merged(a: &[Resources], b: &[Resources]) -> Vec<Resources> {
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                Resources::new(x.cpu_milli.min(y.cpu_milli), x.mem_bytes.min(y.mem_bytes))
            })
            .collect()
    }

    fn min_into(acc: &mut Option<Vec<Resources>>, seg: &[Resources]) {
        match acc {
            None => *acc = Some(seg.to_vec()),
            Some(m) => {
                for (mm, f) in m.iter_mut().zip(seg) {
                    mm.cpu_milli = mm.cpu_milli.min(f.cpu_milli);
                    mm.mem_bytes = mm.mem_bytes.min(f.mem_bytes);
                }
            }
        }
    }

    /// Elementwise minimum over segments `[l, r)`; `None` when empty.
    fn query(&self, l: usize, r: usize) -> Option<Vec<Resources>> {
        let mut acc: Option<Vec<Resources>> = None;
        let (mut l, mut r) = (l + self.n, r.min(self.n) + self.n);
        while l < r {
            if l & 1 == 1 {
                Self::min_into(&mut acc, &self.tree[l]);
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                Self::min_into(&mut acc, &self.tree[r]);
            }
            l /= 2;
            r /= 2;
        }
        acc
    }
}

/// One running job's cached entry in the persistent release profile.
#[derive(Debug, Clone)]
struct JobRelease {
    /// Effective release time (projection clamped to the session `now`).
    t: f64,
    /// Whether the release holds its own profile point (`t` was strictly
    /// past `now` when added). Uncounted releases are merged into every
    /// segment (the reference folds them into the base point).
    counted: bool,
    /// Per-pod `(node, requests)` released at `t`.
    placement: Vec<(NodeId, Resources)>,
}

/// Persistent conservative-backfill release profile (§Perf): the
/// [`ResourceTimeline`] used to be rebuilt from scratch at every
/// conservative session's first gang failure — O(running jobs × nodes)
/// per session in vector clones and pod walks. The cache keeps the
/// profile across sessions and folds in only what changed, event-driven:
///
/// - job start / completion / preemption dirty exactly the windows of the
///   jobs involved (the API server's event log, consumed from a cursor,
///   flags restarts whose placement must be re-derived; the running-set
///   diff handles arrivals and departures);
/// - allocation changes re-anchor the base segment to the live free view
///   (an exact per-node shift of every segment);
/// - a moved projection relocates one job's release point.
///
/// Claims never touch the cache: sessions clone the profile
/// ([`TimelineCache::session_profile`]) and claim on the clone. All
/// resource arithmetic is integer-exact and release times are compared
/// bit-for-bit, so the maintained profile equals the from-scratch rebuild
/// *exactly*: debug builds assert it after every refresh, and a property
/// test pins whole simulations bit-identical across the two paths.
#[derive(Debug, Clone)]
pub struct TimelineCache {
    profile: ResourceTimeline,
    releases: BTreeMap<JobId, JobRelease>,
    /// Counted releases per release-time bit pattern; a profile point is
    /// dropped when its count reaches zero.
    point_jobs: BTreeMap<u64, usize>,
    /// The free view the base segment is anchored to (the session free
    /// view of the last refresh).
    base_free: Vec<Resources>,
    /// `ApiServer::events` consumed so far (restart detection).
    event_cursor: usize,
    /// [`ApiServer::instance_id`] the cursor belongs to.
    api_id: u64,
}

impl TimelineCache {
    /// Build the cache from scratch at a conservative session's first
    /// gang failure (cold start; later sessions go through
    /// [`TimelineCache::refresh`]).
    pub fn new(ctx: &QueueContext<'_>) -> TimelineCache {
        let profile = ResourceTimeline::new(ctx);
        let mut releases = BTreeMap::new();
        let mut point_jobs: BTreeMap<u64, usize> = BTreeMap::new();
        for id in ctx.api.running_jobs() {
            let t_raw = ctx
                .projected_completion
                .get(&id)
                .copied()
                .unwrap_or_else(|| ctx.now + ctx.estimate(id));
            let t = t_raw.max(ctx.now);
            let counted = t > ctx.now;
            if counted {
                *point_jobs.entry(t.to_bits()).or_insert(0) += 1;
            }
            releases.insert(id, JobRelease { t, counted, placement: placement_of(ctx.api, id) });
        }
        TimelineCache {
            profile,
            releases,
            point_jobs,
            base_free: ctx.free.to_vec(),
            event_cursor: ctx.api.events.len(),
            api_id: ctx.api.instance_id(),
        }
    }

    /// The maintained release profile (claims-free).
    pub fn profile(&self) -> &ResourceTimeline {
        &self.profile
    }

    /// The profile clone a session claims reservations on.
    pub fn session_profile(&self) -> ResourceTimeline {
        self.profile.clone()
    }

    /// Fold everything that changed since the last refresh into the
    /// profile. Equal, after this returns, to `ResourceTimeline::new(ctx)`
    /// bit for bit.
    pub fn refresh(&mut self, ctx: &QueueContext<'_>) {
        // Staleness guard: a different API server instance invalidates
        // the cursor and every cached placement — rebuild cold.
        if self.api_id != ctx.api.instance_id() {
            *self = TimelineCache::new(ctx);
            return;
        }
        // 1. Restarts since the last refresh: a preempted job re-placed
        //    while we were not looking is Running at both observations but
        //    with a different placement — force a re-derive.
        let mut restarted: BTreeSet<JobId> = BTreeSet::new();
        for event in &ctx.api.events[self.event_cursor..] {
            if let Event::JobStarted { job, .. } = event {
                restarted.insert(*job);
            }
        }
        self.event_cursor = ctx.api.events.len();
        // 2. Re-anchor the base to the live free view: shift every segment
        //    by the per-node delta (adds before subtracts — all segment
        //    frees are >= the old base, so the arithmetic stays exact).
        for (n, &new) in ctx.free.iter().enumerate() {
            let old = self.base_free[n];
            if old != new {
                for (_, free) in &mut self.profile.points {
                    free[n] = Resources::new(
                        free[n].cpu_milli + new.cpu_milli - old.cpu_milli,
                        free[n].mem_bytes + new.mem_bytes - old.mem_bytes,
                    );
                }
                self.base_free[n] = new;
            }
        }
        // 3. Reconcile the cached releases with the running set.
        let mut desired: BTreeSet<JobId> = BTreeSet::new();
        for id in ctx.api.running_jobs() {
            desired.insert(id);
            let t_raw = ctx
                .projected_completion
                .get(&id)
                .copied()
                .unwrap_or_else(|| ctx.now + ctx.estimate(id));
            let t = t_raw.max(ctx.now);
            let counted = t > ctx.now;
            let unchanged = match self.releases.get(&id) {
                Some(r) => {
                    !restarted.contains(&id)
                        && r.t.to_bits() == t.to_bits()
                        && r.counted == counted
                }
                None => false,
            };
            if unchanged {
                continue;
            }
            if let Some(old) = self.releases.remove(&id) {
                self.remove_release(&old);
                let placement = if restarted.contains(&id) {
                    placement_of(ctx.api, id)
                } else {
                    old.placement
                };
                self.add_release(ctx.now, t, counted, &placement);
                self.releases.insert(id, JobRelease { t, counted, placement });
            } else {
                let placement = placement_of(ctx.api, id);
                self.add_release(ctx.now, t, counted, &placement);
                self.releases.insert(id, JobRelease { t, counted, placement });
            }
        }
        // 4. Drop releases of jobs that left the running set.
        let gone: Vec<JobId> =
            self.releases.keys().copied().filter(|id| !desired.contains(id)).collect();
        for id in gone {
            let old = self.releases.remove(&id).unwrap();
            self.remove_release(&old);
        }
        // 5. Advance the base segment to the session time. Points at or
        //    before `now` were all moved or removed above (their releases
        //    re-clamped), so this only retimes the base.
        debug_assert!(
            self.profile.points.len() < 2 || self.profile.points[1].0 > ctx.now,
            "stale profile point survived the refresh"
        );
        self.profile.points[0].0 = ctx.now;
    }

    /// Add a release to the profile: counted releases get (or share) a
    /// point at `t` and enter every segment from it on; uncounted ones
    /// (clamped to `now`) enter every segment.
    fn add_release(&mut self, now: f64, t: f64, counted: bool, placement: &[(NodeId, Resources)]) {
        if counted {
            let count = self.point_jobs.entry(t.to_bits()).or_insert(0);
            if *count == 0 {
                let pos = self.profile.points.partition_point(|(s, _)| *s < t);
                debug_assert!(pos >= 1, "release point before the base segment");
                let free = self.profile.points[pos - 1].1.clone();
                self.profile.points.insert(pos, (t, free));
            }
            *count += 1;
            let pos = self.profile.points.partition_point(|(s, _)| *s < t);
            for (_, free) in &mut self.profile.points[pos..] {
                for &(node, req) in placement {
                    free[node.0] += req;
                }
            }
        } else {
            debug_assert!(t <= now, "uncounted release past now");
            for (_, free) in &mut self.profile.points {
                for &(node, req) in placement {
                    free[node.0] += req;
                }
            }
        }
    }

    /// Exact inverse of [`TimelineCache::add_release`]; drops the point
    /// when its last counted release leaves.
    fn remove_release(&mut self, release: &JobRelease) {
        if release.counted {
            let t = release.t;
            let pos = self.profile.points.partition_point(|(s, _)| *s < t);
            for (_, free) in &mut self.profile.points[pos..] {
                for &(node, req) in &release.placement {
                    free[node.0] -= req;
                }
            }
            let bits = t.to_bits();
            let count = self
                .point_jobs
                .get_mut(&bits)
                .expect("counted release without a point refcount");
            *count -= 1;
            if *count == 0 {
                self.point_jobs.remove(&bits);
                debug_assert!(self.profile.points[pos].0.to_bits() == bits);
                self.profile.points.remove(pos);
            }
        } else {
            for (_, free) in &mut self.profile.points {
                for &(node, req) in &release.placement {
                    free[node.0] -= req;
                }
            }
        }
    }
}

/// The per-pod `(node, requests)` a running job releases at completion
/// (integer adds — accumulation order does not matter, so the cached form
/// reproduces the reference's pod-walk exactly).
fn placement_of(api: &ApiServer, job: JobId) -> Vec<(NodeId, Resources)> {
    api.jobs[&job]
        .pods
        .iter()
        .map(|pid| &api.pods[pid])
        .filter_map(|pod| match (pod.node, pod.phase) {
            (Some(node), PodPhase::Bound | PodPhase::Running) => Some((node, pod.requests)),
            _ => None,
        })
        .collect()
}

/// Seed behaviour: FIFO, blocked jobs skipped.
pub struct FifoSkip;

impl QueuePolicy for FifoSkip {
    fn kind(&self) -> QueuePolicyKind {
        QueuePolicyKind::FifoSkip
    }

    fn order(&self, _api: &ApiServer, _now: f64, _pending: &mut Vec<JobId>) {}

    fn on_gang_failure(&self, _ctx: &QueueContext<'_>, _job: JobId) -> GangDecision {
        GangDecision::Skip
    }

    fn may_backfill(&self, _ctx: &QueueContext<'_>, _job: JobId, _shadow: f64) -> bool {
        true
    }
}

/// FIFO where the head blocks: no overtaking, so no starvation.
pub struct FifoStrict;

impl QueuePolicy for FifoStrict {
    fn kind(&self) -> QueuePolicyKind {
        QueuePolicyKind::FifoStrict
    }

    fn order(&self, _api: &ApiServer, _now: f64, _pending: &mut Vec<JobId>) {}

    fn on_gang_failure(&self, _ctx: &QueueContext<'_>, _job: JobId) -> GangDecision {
        GangDecision::Block
    }

    fn may_backfill(&self, _ctx: &QueueContext<'_>, _job: JobId, _shadow: f64) -> bool {
        false
    }
}

/// Shortest-job-first on the estimated walltime; FIFO + id tiebreak keeps
/// the order total and deterministic.
pub struct Sjf;

impl QueuePolicy for Sjf {
    fn kind(&self) -> QueuePolicyKind {
        QueuePolicyKind::Sjf
    }

    fn order(&self, api: &ApiServer, _now: f64, pending: &mut Vec<JobId>) {
        // Walltime estimates scan the job's pods — compute each key once,
        // not once per comparison.
        let est: BTreeMap<JobId, f64> =
            pending.iter().map(|&id| (id, estimated_runtime(api, id))).collect();
        pending.sort_by(|&a, &b| {
            est[&a]
                .total_cmp(&est[&b])
                .then_with(|| {
                    api.jobs[&a].submit_time.total_cmp(&api.jobs[&b].submit_time)
                })
                .then(a.cmp(&b))
        });
    }

    fn on_gang_failure(&self, _ctx: &QueueContext<'_>, _job: JobId) -> GangDecision {
        GangDecision::Skip
    }

    fn may_backfill(&self, _ctx: &QueueContext<'_>, _job: JobId, _shadow: f64) -> bool {
        true
    }
}

/// EASY backfilling (Lifka '95): FIFO, with a shadow-time reservation for
/// the first blocked job; later jobs start only if they are projected to
/// finish before the shadow time, so the reservation is never pushed back
/// (up to estimate error).
pub struct EasyBackfill;

impl QueuePolicy for EasyBackfill {
    fn kind(&self) -> QueuePolicyKind {
        QueuePolicyKind::EasyBackfill
    }

    fn order(&self, _api: &ApiServer, _now: f64, _pending: &mut Vec<JobId>) {}

    fn on_gang_failure(&self, ctx: &QueueContext<'_>, job: JobId) -> GangDecision {
        match shadow_time(ctx, job) {
            Some(t) => GangDecision::Reserve { shadow_time: t },
            // Infeasible even on an idle cluster: don't let it dam the
            // queue (the simulator marks such jobs unschedulable anyway).
            None => GangDecision::Skip,
        }
    }

    fn may_backfill(&self, ctx: &QueueContext<'_>, job: JobId, shadow: f64) -> bool {
        ctx.now + ctx.estimate(job) <= shadow + 1e-9
    }

    fn needs_projections(&self) -> bool {
        true
    }
}

/// Conservative backfilling (Mu'alem & Feitelson '01): FIFO, with a
/// resource reservation for *every* blocked job of the session.
///
/// The scheduler runs this discipline against a true per-resource
/// availability profile ([`ResourceTimeline`]): each blocked job claims
/// its `[start, start + walltime)` window out of the profile at the
/// earliest instant its gang fits, and a later job may start only if its
/// own window first-fits what is left. Backfills can therefore use holes
/// *behind* reservations, and can never occupy resources a reservation
/// counted on — the earlier earliest-shadow-only gate could do neither
/// (it rejected any estimate crossing the first shadow, yet could still
/// silently violate a *second* blocked job's reservation, whose shadow
/// ignored the first reservation's future occupancy).
///
/// The trait's own `on_gang_failure`/`may_backfill` hooks keep the
/// scalar-shadow semantics for standalone callers; `Scheduler` sessions
/// use the timeline (see `cycle_with_projections`).
pub struct ConservativeBackfill;

impl QueuePolicy for ConservativeBackfill {
    fn kind(&self) -> QueuePolicyKind {
        QueuePolicyKind::ConservativeBackfill
    }

    fn order(&self, _api: &ApiServer, _now: f64, _pending: &mut Vec<JobId>) {}

    fn on_gang_failure(&self, ctx: &QueueContext<'_>, job: JobId) -> GangDecision {
        match shadow_time(ctx, job) {
            Some(t) => GangDecision::Reserve { shadow_time: t },
            None => GangDecision::Skip,
        }
    }

    fn may_backfill(&self, ctx: &QueueContext<'_>, job: JobId, shadow: f64) -> bool {
        ctx.now + ctx.estimate(job) <= shadow + 1e-9
    }

    fn needs_projections(&self) -> bool {
        true
    }

    fn reserves_every_job(&self) -> bool {
        true
    }
}

/// Multi-tenant weighted fair share: order the queue by each tenant's
/// weight-normalized service deficit (core-seconds consumed so far divided
/// by the tenant's weight, ascending — the tenant furthest below its share
/// goes first), then by job priority (descending), then FIFO. Weights live
/// on the API server (`ApiServer::set_tenant_weight`); unknown tenants
/// weigh 1.0. Gang failures skip (EASY-style starvation protection can be
/// layered via the scheduler's priority preemption instead).
pub struct FairShare;

impl QueuePolicy for FairShare {
    fn kind(&self) -> QueuePolicyKind {
        QueuePolicyKind::FairShare
    }

    fn order(&self, api: &ApiServer, now: f64, pending: &mut Vec<JobId>) {
        let usage = api.tenant_usage(now);
        let deficit = |id: JobId| -> f64 {
            let tenant = api.jobs[&id].planned.spec.tenant;
            usage.get(&tenant).copied().unwrap_or(0.0) / api.tenant_weight(tenant)
        };
        pending.sort_by(|&a, &b| {
            deficit(a)
                .total_cmp(&deficit(b))
                .then_with(|| {
                    api.jobs[&b]
                        .planned
                        .spec
                        .priority
                        .cmp(&api.jobs[&a].planned.spec.priority)
                })
                .then_with(|| {
                    api.jobs[&a].submit_time.total_cmp(&api.jobs[&b].submit_time)
                })
                .then(a.cmp(&b))
        });
    }

    fn on_gang_failure(&self, _ctx: &QueueContext<'_>, _job: JobId) -> GangDecision {
        GangDecision::Skip
    }

    fn may_backfill(&self, _ctx: &QueueContext<'_>, _job: JobId, _shadow: f64) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::controller::{JobController, VolcanoMpiController};
    use crate::kubelet::KubeletConfig;
    use crate::planner::{plan, GranularityPolicy, SystemInfo};
    use crate::workload::{Benchmark, JobSpec};

    fn api_with_jobs(benches: &[Benchmark]) -> ApiServer {
        let mut api = ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity());
        let info = SystemInfo::homogeneous(4);
        for (i, &b) in benches.iter().enumerate() {
            let spec = JobSpec::paper_job(i as u64 + 1, b, i as f64);
            let planned = plan(&spec, GranularityPolicy::None, info);
            let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
            api.create_job(planned, pods, hostfile, i as f64);
        }
        api
    }

    #[test]
    fn kind_names_round_trip_and_aliases_parse() {
        for kind in ALL_QUEUE_POLICIES {
            assert_eq!(QueuePolicyKind::parse(kind.name()), Some(kind));
            assert_eq!(kind.build().kind(), kind);
        }
        assert_eq!(QueuePolicyKind::parse("EASY"), Some(QueuePolicyKind::EasyBackfill));
        assert_eq!(QueuePolicyKind::parse("bf"), Some(QueuePolicyKind::EasyBackfill));
        assert_eq!(QueuePolicyKind::parse("FIFO-STRICT"), Some(QueuePolicyKind::FifoStrict));
        assert_eq!(
            QueuePolicyKind::parse("conservative"),
            Some(QueuePolicyKind::ConservativeBackfill)
        );
        assert_eq!(QueuePolicyKind::parse("CBF"), Some(QueuePolicyKind::ConservativeBackfill));
        assert_eq!(QueuePolicyKind::parse("fair-share"), Some(QueuePolicyKind::FairShare));
        assert_eq!(QueuePolicyKind::parse("fs"), Some(QueuePolicyKind::FairShare));
        assert_eq!(QueuePolicyKind::parse("nope"), None);
        // Gang requirement: reserve/block disciplines only.
        assert!(QueuePolicyKind::FifoStrict.requires_gang());
        assert!(QueuePolicyKind::EasyBackfill.requires_gang());
        assert!(QueuePolicyKind::ConservativeBackfill.requires_gang());
        assert!(!QueuePolicyKind::FairShare.requires_gang());
        assert!(!QueuePolicyKind::Sjf.requires_gang());
    }

    #[test]
    fn sjf_orders_by_estimated_runtime() {
        // Walltime estimates keep the base-runtime ordering for identical
        // single-worker shapes: G-RandomRing (320 s base) < G-FFT (400 s) <
        // EP-STREAM (480 s) < EP-DGEMM (600 s) < MiniFE (720 s).
        let api = api_with_jobs(&[
            Benchmark::MiniFe,
            Benchmark::GRandomRing,
            Benchmark::EpDgemm,
            Benchmark::GFft,
            Benchmark::EpStream,
        ]);
        let mut pending = api.pending_jobs();
        Sjf.order(&api, 0.0, &mut pending);
        let ordered: Vec<u64> = pending.iter().map(|j| j.0).collect();
        assert_eq!(ordered, vec![2, 4, 5, 3, 1]);
    }

    #[test]
    fn estimated_runtime_is_perfmodel_walltime_not_base_time() {
        let api = api_with_jobs(&[Benchmark::EpDgemm]);
        let est = estimated_runtime(&api, JobId(1));
        let base = Benchmark::EpDgemm.base_running_secs();
        // A single 16-task container pays the intra-cgroup scheduling term.
        assert!(est > base, "est {est} must exceed base {base}");
        assert!(est < base * 1.3, "est {est} within model range");
    }

    #[test]
    fn sjf_ties_break_fifo_then_id() {
        let api = api_with_jobs(&[Benchmark::EpDgemm, Benchmark::EpDgemm, Benchmark::EpDgemm]);
        let mut pending = api.pending_jobs();
        Sjf.order(&api, 0.0, &mut pending);
        assert_eq!(pending, api.pending_jobs(), "equal runtimes keep FIFO order");
    }

    #[test]
    fn fair_share_orders_by_weighted_deficit_then_priority() {
        use crate::workload::TenantId;
        // Jobs 1..4: tenants A, A, B, B (equal shapes). Tenant A has
        // consumed service; B has not — B's jobs go first.
        let mut api = ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity());
        let info = SystemInfo::homogeneous(4);
        for (i, (tenant, priority)) in
            [(TenantId(0), 0u32), (TenantId(0), 5), (TenantId(1), 0), (TenantId(1), 5)]
                .into_iter()
                .enumerate()
        {
            let spec = JobSpec::paper_job(i as u64 + 1, Benchmark::EpDgemm, i as f64)
                .with_tenant(tenant, priority);
            let planned = plan(&spec, GranularityPolicy::None, info);
            let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
            api.create_job(planned, pods, hostfile, i as f64);
        }
        // Give tenant 0 prior service by running+finishing one of its jobs.
        let mut sched = crate::scheduler::Scheduler::new(
            crate::scheduler::SchedulerConfig::volcano_default(1),
        );
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started.len(), 4, "idle cluster fits all four");
        for &j in &started {
            api.finish_job(j, 100.0);
        }
        // Re-submit the same four shapes as jobs 5..8.
        for (i, (tenant, priority)) in
            [(TenantId(0), 0u32), (TenantId(0), 5), (TenantId(1), 0), (TenantId(1), 5)]
                .into_iter()
                .enumerate()
        {
            let spec = JobSpec::paper_job(i as u64 + 5, Benchmark::EpDgemm, 100.0 + i as f64)
                .with_tenant(tenant, priority);
            let planned = plan(&spec, GranularityPolicy::None, info);
            let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
            api.create_job(planned, pods, hostfile, 100.0 + i as f64);
        }
        // Both tenants consumed equally so far; weight tenant 1 higher →
        // smaller normalized deficit → its jobs first, priority desc within.
        api.set_tenant_weight(TenantId(1), 4.0);
        let mut pending = api.pending_jobs();
        FairShare.order(&api, 100.0, &mut pending);
        let ordered: Vec<u64> = pending.iter().map(|j| j.0).collect();
        assert_eq!(ordered, vec![8, 7, 6, 5], "tenant 1 first, priority desc within tenant");
    }

    #[test]
    fn conservative_reserves_for_every_blocked_job() {
        assert!(ConservativeBackfill.reserves_every_job());
        assert!(!EasyBackfill.reserves_every_job());
        assert!(ConservativeBackfill.needs_projections());
    }

    #[test]
    fn shadow_time_is_earliest_sufficient_release() {
        // Fill the 8 single-worker slots, then ask for the shadow time of a
        // 9th identical job: it fits as soon as the first running job ends.
        let mut api = api_with_jobs(&[Benchmark::EpDgemm; 9]);
        let mut sched = crate::scheduler::Scheduler::new(
            crate::scheduler::SchedulerConfig::volcano_default(1),
        );
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started.len(), 8);
        let blocked = api.pending_jobs()[0];
        let mut projected = BTreeMap::new();
        for (i, &j) in started.iter().enumerate() {
            projected.insert(j, 100.0 + i as f64 * 10.0);
        }
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let ctx = QueueContext {
            api: &api,
            now: 9.0,
            projected_completion: &projected,
            free: &free,
            walltime_factor: 1.0,
        };
        assert_eq!(shadow_time(&ctx, blocked), Some(100.0));
    }

    #[test]
    fn shadow_time_none_for_infeasible_job() {
        let mut api = api_with_jobs(&[Benchmark::EpDgemm]);
        // A job whose single worker wants 64 cores can never fit a 32-core
        // node.
        let mut spec = JobSpec::paper_job(7, Benchmark::EpDgemm, 0.0);
        spec.ntasks = 64;
        spec.resources = crate::cluster::Resources::new(64_000, crate::cluster::gib(128));
        let planned = plan(&spec, GranularityPolicy::None, SystemInfo::homogeneous(4));
        let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
        api.create_job(planned, pods, hostfile, 0.0);
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let projected = BTreeMap::new();
        let ctx = QueueContext {
            api: &api,
            now: 0.0,
            projected_completion: &projected,
            free: &free,
            walltime_factor: 1.0,
        };
        assert_eq!(shadow_time(&ctx, JobId(7)), None);
        assert_eq!(
            EasyBackfill.on_gang_failure(&ctx, JobId(7)),
            GangDecision::Skip,
            "infeasible jobs must not dam the queue"
        );
    }

    #[test]
    fn resource_timeline_claims_shift_later_fits() {
        // Full cluster (8 running 16-core DGEMMs), staggered projected
        // completions at 100, 110, ... The profile's base equals the
        // session free view, the far future equals the idle cluster, the
        // blocked job first fits at the earliest release, and claiming
        // that window pushes an identical job to the *next* release.
        let mut api = api_with_jobs(&[Benchmark::EpDgemm; 9]);
        let mut sched = crate::scheduler::Scheduler::new(
            crate::scheduler::SchedulerConfig::volcano_default(1),
        );
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started.len(), 8);
        let blocked = api.pending_jobs()[0];
        let mut projected = BTreeMap::new();
        for (i, &j) in started.iter().enumerate() {
            projected.insert(j, 100.0 + i as f64 * 10.0);
        }
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let ctx = QueueContext {
            api: &api,
            now: 9.0,
            projected_completion: &projected,
            free: &free,
            walltime_factor: 1.0,
        };
        let tl = ResourceTimeline::new(&ctx);
        assert_eq!(tl.min_free_over(9.0, 9.5), free, "base segment = session free");
        let idle = tl.min_free_over(1e6, 1e6 + 1.0);
        for n in api.spec.node_ids() {
            assert_eq!(idle[n.0], api.spec.node(n).allocatable(), "far future = idle");
        }
        let est = estimated_runtime(&api, blocked);
        let (t_s, placement) = tl.earliest_fit(&api, blocked, est).unwrap();
        assert_eq!(t_s, 100.0, "earliest release admits the gang");
        let mut claimed = tl.clone();
        claimed.claim(t_s, t_s + est, &placement);
        let (t_s2, _) = claimed.earliest_fit(&api, blocked, est).unwrap();
        assert!(t_s2 > t_s, "claimed window pushes the next fit later: {t_s2}");
    }

    #[test]
    fn timeline_cache_refresh_tracks_the_rebuild_exactly() {
        // Loaded cluster, cache built at t=9; then one job finishes (its
        // release leaves, the base grows), a queued job starts (new
        // release, base shrinks on its node), one projection moves, and
        // the clock advances past a release — after every refresh the
        // cache must equal a from-scratch rebuild bit for bit.
        let mut api = api_with_jobs(&[Benchmark::EpDgemm; 10]);
        let mut sched = crate::scheduler::Scheduler::new(
            crate::scheduler::SchedulerConfig::volcano_default(1),
        );
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started.len(), 8);
        let mut projected = BTreeMap::new();
        for (i, &j) in started.iter().enumerate() {
            projected.insert(j, 100.0 + i as f64 * 10.0);
        }
        let free_of = |api: &ApiServer| -> Vec<Resources> {
            api.spec.node_ids().map(|n| api.free_on(n)).collect()
        };
        let f0 = free_of(&api);
        let ctx0 = QueueContext {
            api: &api,
            now: 9.0,
            projected_completion: &projected,
            free: &f0,
            walltime_factor: 1.0,
        };
        let mut cache = TimelineCache::new(&ctx0);
        assert_eq!(cache.profile(), &ResourceTimeline::new(&ctx0), "cold build");
        // No-op refresh: nothing changed.
        cache.refresh(&ctx0);
        assert_eq!(cache.profile(), &ResourceTimeline::new(&ctx0), "no-op refresh");
        // Churn: finish, start, move a projection, advance time.
        api.finish_job(started[0], 100.0);
        let second = sched.cycle(&mut api, 100.0);
        assert_eq!(second.len(), 1, "one queued job takes the freed slot");
        projected.remove(&started[0]);
        projected.insert(second[0], 800.0);
        projected.insert(started[3], 170.0);
        let f1 = free_of(&api);
        let ctx1 = QueueContext {
            api: &api,
            now: 105.0,
            projected_completion: &projected,
            free: &f1,
            walltime_factor: 1.0,
        };
        cache.refresh(&ctx1);
        assert_eq!(cache.profile(), &ResourceTimeline::new(&ctx1), "churn refresh");
        // Advance past the 110/120/130 releases: they clamp to `now` and
        // fold into the base segment, exactly as the rebuild does.
        let ctx2 = QueueContext {
            api: &api,
            now: 131.0,
            projected_completion: &projected,
            free: &f1,
            walltime_factor: 1.0,
        };
        cache.refresh(&ctx2);
        assert_eq!(cache.profile(), &ResourceTimeline::new(&ctx2), "time advance");
        // The session's claim surface is a clone: claiming on it never
        // perturbs the cache.
        let before = cache.profile().clone();
        let mut session = cache.session_profile();
        session.claim(200.0, 300.0, &[(NodeId(1), Resources::new(4_000, 0))]);
        assert_eq!(cache.profile(), &before, "claims stay session-local");
    }

    #[test]
    fn backfill_window_admits_only_jobs_that_finish_before_shadow() {
        let api = api_with_jobs(&[Benchmark::GRandomRing, Benchmark::MiniFe]);
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let projected = BTreeMap::new();
        let ctx = QueueContext {
            api: &api,
            now: 0.0,
            projected_completion: &projected,
            free: &free,
            walltime_factor: 1.0,
        };
        // Shadow at 350 s: the ring job (walltime estimate ~333 s) fits the
        // window, MiniFE (~791 s estimate) does not.
        assert!(EasyBackfill.may_backfill(&ctx, JobId(1), 350.0));
        assert!(!EasyBackfill.may_backfill(&ctx, JobId(2), 350.0));
        // Strict never backfills; FIFO-skip always walks on.
        assert!(!FifoStrict.may_backfill(&ctx, JobId(1), 350.0));
        assert!(FifoSkip.may_backfill(&ctx, JobId(2), 350.0));
    }

    #[test]
    fn gang_failure_decisions_match_policies() {
        let api = api_with_jobs(&[Benchmark::EpDgemm]);
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let projected = BTreeMap::new();
        let ctx = QueueContext {
            api: &api,
            now: 0.0,
            projected_completion: &projected,
            free: &free,
            walltime_factor: 1.0,
        };
        assert_eq!(FifoSkip.on_gang_failure(&ctx, JobId(1)), GangDecision::Skip);
        assert_eq!(FifoStrict.on_gang_failure(&ctx, JobId(1)), GangDecision::Block);
        assert_eq!(Sjf.on_gang_failure(&ctx, JobId(1)), GangDecision::Skip);
    }
}
