//! The scheduling pipeline — Volcano-style **actions** driven by
//! **plugins** registered in tiers.
//!
//! [`Scheduler::cycle_with_projections`](super::Scheduler::cycle_with_projections)
//! runs one session as an ordered list of actions (enqueue → allocate →
//! preempt → reclaim → backfill, [`ActionKind`]). `enqueue` runs once per
//! session (build the pending queue, let ordering plugins refine it); the
//! remaining actions are per-job *stages*: each pending job flows through
//! them in the configured order until one consumes it (placed, held, or
//! the session ends). Per-job staging — rather than Volcano's
//! session-scoped loops per action — is what keeps the pipeline
//! bit-identical to the monolithic legacy loop it replaced: the legacy
//! code interleaved allocate/preempt/backfill per job, and the RNG jitter
//! stream (one draw per feasible node) plus the post-preemption session
//! rebuild both depend on that interleaving.
//!
//! Plugins hang off the session at three kinds of callback, mirroring
//! Volcano's `Session` registration:
//!
//! - **order** (OrderFn): refine the pending-queue order after the queue
//!   discipline's own sort;
//! - **predicates** ([`Plugin::admit`], [`Plugin::may_evict`]): veto a
//!   job's allocation this session, or a running job's eviction;
//! - **victim/decision hooks** ([`Plugin::override_gang_failure`],
//!   [`Plugin::reclaim`]): escalate a gang failure (aging turns Skip into
//!   Block) or nominate running jobs to reclaim.
//!
//! The queue disciplines ([`QueuePolicy`](super::QueuePolicy)) are the
//! pipeline's ordering/backfill plugin slot (order + gang-failure
//! decision + backfill gate), and [`PreemptionPolicy`](super::PreemptionPolicy)
//! is its victim-cost plugin slot — both predate this module and keep
//! their specialized traits; the [`Plugin`] trait hosts the cross-cutting
//! policies (quota admission, starvation aging, preemption budgets).
//! Plugins are consulted tier by tier, registration order within a tier:
//! tier 0 holds the core admission plugins (quota), tier 1 the optional
//! policy plugins (aging, budgets).
//!
//! The default [`PipelineConfig`] (all five actions, no optional plugins)
//! is **legacy-equivalent**: `rust/tests/differential.rs` pins the
//! pipeline bit-identical to the retired monolithic loop (kept behind
//! [`Scheduler::force_legacy_scheduler`](super::Scheduler::force_legacy_scheduler)
//! as the reference) for every scenario × placement engine × cluster mix,
//! and a 200-case fuzz property in `rust/tests/properties.rs` does the
//! same over randomized traces, clusters, and configs.

use std::collections::BTreeMap;

use crate::apiserver::{ApiServer, JobPhase};
use crate::cluster::{JobId, NodeId, Pod, PodId, PodPhase, Resources};
use crate::workload::TenantId;

use super::placement::SessionState;
use super::queue::{self, GangDecision, QueueContext, ResourceTimeline};
use super::Scheduler;

/// One step of the scheduling pipeline. `Enqueue` is session-scoped
/// (build + order the pending queue); the rest are per-job stages run in
/// the configured order until one consumes the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActionKind {
    /// Build the pending queue: discipline order, then plugin OrderFns.
    Enqueue,
    /// Admission predicates + backfill gates, then gang (or per-pod)
    /// placement on the session state.
    Allocate,
    /// On gang failure: evict a minimal set of strictly-lower-priority
    /// victims ([`super::PreemptionPolicy`] cost order, filtered by
    /// [`Plugin::may_evict`]) and commit the proven plan. With a
    /// malleable [`ElasticityConfig`], shrink deltas from running elastic
    /// jobs are offered before whole-job eviction.
    Preempt,
    /// On gang failure of an *elastic* job: mold the pending plan
    /// stepwise down toward its `min` width, retrying the gang at each
    /// narrower width. A provable no-op without an [`ElasticityConfig`]
    /// (the default), so the default pipeline stays legacy-equivalent.
    Resize,
    /// On gang failure: plugins may nominate running jobs to reclaim
    /// ([`Plugin::reclaim`]); no built-in plugin does, so the default
    /// pipeline's reclaim is a no-op extension point.
    Reclaim,
    /// On gang failure: the discipline's reservation semantics — EASY
    /// shadow time, conservative timeline claim, or skip/block.
    Backfill,
}

/// Every action, in the default (legacy-equivalent) order.
pub const ALL_ACTIONS: [ActionKind; 6] = [
    ActionKind::Enqueue,
    ActionKind::Allocate,
    ActionKind::Preempt,
    ActionKind::Resize,
    ActionKind::Reclaim,
    ActionKind::Backfill,
];

impl ActionKind {
    pub fn name(&self) -> &'static str {
        match self {
            ActionKind::Enqueue => "enqueue",
            ActionKind::Allocate => "allocate",
            ActionKind::Preempt => "preempt",
            ActionKind::Resize => "resize",
            ActionKind::Reclaim => "reclaim",
            ActionKind::Backfill => "backfill",
        }
    }

    /// Parse a config spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<ActionKind> {
        match s.to_ascii_lowercase().as_str() {
            "enqueue" => Some(ActionKind::Enqueue),
            "allocate" => Some(ActionKind::Allocate),
            "preempt" => Some(ActionKind::Preempt),
            "resize" => Some(ActionKind::Resize),
            "reclaim" => Some(ActionKind::Reclaim),
            "backfill" => Some(ActionKind::Backfill),
            _ => None,
        }
    }

    /// Position in the canonical order (validation checks the configured
    /// list is a subsequence of it).
    fn rank(&self) -> usize {
        ALL_ACTIONS.iter().position(|a| a == self).unwrap()
    }
}

impl std::fmt::Display for ActionKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// An ordered, duplicate-free subset of the pipeline actions. Fixed-size
/// so [`super::SchedulerConfig`] stays `Copy` (the whole config surface —
/// scenario tables, ablation grids — relies on that).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ActionList {
    kinds: [ActionKind; 6],
    len: u8,
}

impl ActionList {
    /// Build from a slice; rejects duplicates and more than 6 entries.
    pub fn of(actions: &[ActionKind]) -> Result<ActionList, String> {
        if actions.len() > ALL_ACTIONS.len() {
            return Err(format!("pipeline lists {} actions (max 6)", actions.len()));
        }
        let mut list = ActionList { kinds: [ActionKind::Enqueue; 6], len: 0 };
        for &a in actions {
            if list.contains(a) {
                return Err(format!("pipeline action {a:?} listed twice", a = a.name()));
            }
            list.kinds[list.len as usize] = a;
            list.len += 1;
        }
        Ok(list)
    }

    pub fn as_slice(&self) -> &[ActionKind] {
        &self.kinds[..self.len as usize]
    }

    pub fn contains(&self, action: ActionKind) -> bool {
        self.as_slice().contains(&action)
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Starvation-aging plugin knobs (`pipeline.plugins[] = {"name": "aging"}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AgingConfig {
    /// A pending job that has waited at least this long is *starved*: the
    /// ordering hook moves it to the queue head and its gang failure
    /// escalates from the discipline's decision to `Block`, so nothing
    /// submitted later can overtake it (FIFO-skip's starvation fix,
    /// carried in ROADMAP since PR 2).
    pub threshold_secs: f64,
}

/// How far the elasticity plugin may take a job's `elasticity` range
/// (`pipeline.plugins[] = {"name": "elasticity", "mode": ...}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElasticityMode {
    /// Width is negotiated only *before* start: a gang-blocked elastic
    /// job is molded stepwise down toward its `min` width until its gang
    /// fits; once running, the width never changes.
    Moldable,
    /// Moldable, plus runtime resizes: expand-into-drain (grow running
    /// elastic jobs toward `preferred` — or `max` on an empty queue —
    /// when free capacity would otherwise idle) and shrink-before-preempt
    /// (offer tail-worker shrink deltas from lower-priority elastic jobs
    /// before evicting whole jobs).
    Malleable,
}

impl ElasticityMode {
    pub fn name(&self) -> &'static str {
        match self {
            ElasticityMode::Moldable => "moldable",
            ElasticityMode::Malleable => "malleable",
        }
    }

    /// Parse a config spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<ElasticityMode> {
        match s.to_ascii_lowercase().as_str() {
            "moldable" => Some(ElasticityMode::Moldable),
            "malleable" => Some(ElasticityMode::Malleable),
            _ => None,
        }
    }
}

/// Elasticity plugin knobs. Registering the plugin is what arms the
/// `resize` action — without it (the default), jobs' `elasticity` ranges
/// are carried but never acted on, and the pipeline stays bit-identical
/// to the legacy scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElasticityConfig {
    pub mode: ElasticityMode,
}

/// Preemption-budget plugin knobs
/// (`pipeline.plugins[] = {"name": "preemption_budget"}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetConfig {
    /// Sliding-window length the evictions are counted over.
    pub window_secs: f64,
    /// Maximum evictions charged to one victim tenant per window; a
    /// tenant at its budget cannot lose another job until the window
    /// slides past an earlier eviction.
    pub max_evictions: u32,
}

/// The `pipeline` key of [`super::SchedulerConfig`]: the ordered action
/// list plus the optional tier-1 plugins. The default is
/// legacy-equivalent — all five actions in canonical order, no optional
/// plugins — so every golden digest and ablation number is unchanged
/// unless a config opts in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineConfig {
    pub actions: ActionList,
    /// Starvation aging (tier 1); `None` = not registered.
    pub aging: Option<AgingConfig>,
    /// Per-tenant preemption budget (tier 1); `None` = not registered.
    pub budget: Option<BudgetConfig>,
    /// Elastic resize policy (tier 1); `None` = not registered — the
    /// `resize` action is then a provable no-op.
    pub elasticity: Option<ElasticityConfig>,
}

impl PipelineConfig {
    /// The default pipeline: every action in canonical order, no optional
    /// plugins — bit-identical to the legacy monolithic scheduler.
    pub fn legacy_equivalent() -> PipelineConfig {
        PipelineConfig {
            actions: ActionList::of(&ALL_ACTIONS).unwrap(),
            aging: None,
            budget: None,
            elasticity: None,
        }
    }

    /// Same pipeline with a different action list.
    pub fn with_actions(mut self, actions: ActionList) -> Self {
        self.actions = actions;
        self
    }

    /// Same pipeline with starvation aging registered.
    pub fn with_aging(mut self, threshold_secs: f64) -> Self {
        self.aging = Some(AgingConfig { threshold_secs });
        self
    }

    /// Same pipeline with a per-tenant preemption budget registered.
    pub fn with_budget(mut self, window_secs: f64, max_evictions: u32) -> Self {
        self.budget = Some(BudgetConfig { window_secs, max_evictions });
        self
    }

    /// Same pipeline with the elasticity plugin registered.
    pub fn with_elasticity(mut self, mode: ElasticityMode) -> Self {
        self.elasticity = Some(ElasticityConfig { mode });
        self
    }

    /// Structural validation (config files route parse errors through
    /// this; the builders assert it).
    pub fn validate(&self) -> Result<(), String> {
        if !self.actions.contains(ActionKind::Enqueue)
            || !self.actions.contains(ActionKind::Allocate)
        {
            return Err("pipeline.actions must include \"enqueue\" and \"allocate\"".into());
        }
        let ranks: Vec<usize> = self.actions.as_slice().iter().map(ActionKind::rank).collect();
        if ranks.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!(
                "pipeline.actions must follow the canonical order {:?}",
                ALL_ACTIONS.map(|a| a.name())
            ));
        }
        if let Some(aging) = self.aging {
            if !(aging.threshold_secs > 0.0) {
                return Err("pipeline aging threshold_secs must be positive".into());
            }
        }
        if let Some(budget) = self.budget {
            if !(budget.window_secs > 0.0) {
                return Err("pipeline budget window_secs must be positive".into());
            }
            // A zero budget is "never preempt" — drop the preempt action
            // instead of configuring a budget that can never be spent.
            if budget.max_evictions == 0 {
                return Err("pipeline budget max_evictions must be >= 1".into());
            }
        }
        if self.elasticity.is_some() && !self.actions.contains(ActionKind::Resize) {
            return Err(
                "pipeline.plugins lists \"elasticity\" but pipeline.actions omits \"resize\""
                    .into(),
            );
        }
        Ok(())
    }
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig::legacy_equivalent()
    }
}

/// A cross-cutting scheduling policy hanging off the session's callbacks.
/// Every hook has a no-op default, so a plugin implements only the
/// callbacks it cares about (Volcano's `OnSessionOpen` registration
/// style). Hooks take `&mut self` so stateful plugins (budgets) can keep
/// their ledgers without interior mutability.
pub trait Plugin {
    fn name(&self) -> &'static str;

    /// OrderFn: refine the pending-queue order. Runs after the queue
    /// discipline's sort; implementations must be stable with respect to
    /// the order they are handed.
    fn order(&mut self, _api: &ApiServer, _now: f64, _pending: &mut Vec<JobId>) {}

    /// PredicateFn: may `job` be considered for allocation this session?
    /// Any veto holds the job as `Pending` without planning or claiming a
    /// reservation.
    fn admit(&mut self, _api: &ApiServer, _now: f64, _job: JobId) -> bool {
        true
    }

    /// VictimFn: may the preempt action evict `victim`? Vetoed candidates
    /// are dropped before victim selection.
    fn may_evict(&mut self, _api: &ApiServer, _now: f64, _victim: JobId) -> bool {
        true
    }

    /// Escalate a gang failure: the first `Some` across tiers replaces
    /// the queue discipline's [`GangDecision`]. Only consulted when the
    /// session holds no reservation (same rule as the discipline itself).
    fn override_gang_failure(
        &mut self,
        _api: &ApiServer,
        _now: f64,
        _job: JobId,
    ) -> Option<GangDecision> {
        None
    }

    /// Reclaim hook: nominate running jobs to evict-and-requeue so the
    /// gang-blocked `job` can retry on the freed capacity. No built-in
    /// plugin implements this — it is the extension point the reclaim
    /// action exists for (cross-tenant quota reclamation, elastic
    /// shrink).
    fn reclaim(&mut self, _api: &ApiServer, _now: f64, _job: JobId) -> Vec<JobId> {
        Vec::new()
    }

    /// Notification: `victims` were just evicted (preempt or reclaim).
    fn on_evictions(&mut self, _api: &ApiServer, _now: f64, _victims: &[JobId]) {}

    /// Notification: `job` just started.
    fn on_job_started(&mut self, _api: &ApiServer, _now: f64, _job: JobId) {}
}

/// The session's plugin registry: tiers consulted in order, registration
/// order within a tier. Tier 0 holds the core admission plugins, tier 1
/// the optional policy plugins.
#[derive(Default)]
pub struct PluginSet {
    tiers: Vec<Vec<Box<dyn Plugin>>>,
}

impl PluginSet {
    /// The registry a [`PipelineConfig`] describes: quota admission at
    /// tier 0; aging, budget, and elasticity (when configured) at tier 1.
    pub fn from_config(config: &PipelineConfig) -> PluginSet {
        let mut set = PluginSet::default();
        set.register(0, Box::new(QuotaPlugin));
        if let Some(aging) = config.aging {
            set.register(1, Box::new(AgingPlugin::new(aging)));
        }
        if let Some(budget) = config.budget {
            set.register(1, Box::new(BudgetPlugin::new(budget)));
        }
        if let Some(elasticity) = config.elasticity {
            set.register(1, Box::new(ElasticityPlugin::new(elasticity)));
        }
        set
    }

    /// Register a plugin at the given tier (tests and downstream callers
    /// extend the pipeline without touching the config surface).
    pub fn register(&mut self, tier: usize, plugin: Box<dyn Plugin>) {
        while self.tiers.len() <= tier {
            self.tiers.push(Vec::new());
        }
        self.tiers[tier].push(plugin);
    }

    /// Registered plugin names, tier by tier.
    pub fn names(&self) -> Vec<&'static str> {
        self.tiers.iter().flatten().map(|p| p.name()).collect()
    }

    fn order(&mut self, api: &ApiServer, now: f64, pending: &mut Vec<JobId>) {
        for plugin in self.tiers.iter_mut().flatten() {
            plugin.order(api, now, pending);
        }
    }

    fn admits(&mut self, api: &ApiServer, now: f64, job: JobId) -> bool {
        self.tiers.iter_mut().flatten().all(|p| p.admit(api, now, job))
    }

    pub(super) fn may_evict(&mut self, api: &ApiServer, now: f64, victim: JobId) -> bool {
        self.tiers.iter_mut().flatten().all(|p| p.may_evict(api, now, victim))
    }

    fn override_gang_failure(
        &mut self,
        api: &ApiServer,
        now: f64,
        job: JobId,
    ) -> Option<GangDecision> {
        self.tiers
            .iter_mut()
            .flatten()
            .find_map(|p| p.override_gang_failure(api, now, job))
    }

    fn reclaim(&mut self, api: &ApiServer, now: f64, job: JobId) -> Vec<JobId> {
        let mut victims: Vec<JobId> = Vec::new();
        for plugin in self.tiers.iter_mut().flatten() {
            for v in plugin.reclaim(api, now, job) {
                if !victims.contains(&v) {
                    victims.push(v);
                }
            }
        }
        victims
    }

    fn on_evictions(&mut self, api: &ApiServer, now: f64, victims: &[JobId]) {
        for plugin in self.tiers.iter_mut().flatten() {
            plugin.on_evictions(api, now, victims);
        }
    }

    fn on_job_started(&mut self, api: &ApiServer, now: f64, job: JobId) {
        for plugin in self.tiers.iter_mut().flatten() {
            plugin.on_job_started(api, now, job);
        }
    }
}

/// ResourceQuota admission as a plugin: a job whose tenant is over quota
/// is held `Pending` — it neither plans nor claims a reservation
/// (capacity frees when the tenant's running jobs end).
pub struct QuotaPlugin;

impl Plugin for QuotaPlugin {
    fn name(&self) -> &'static str {
        "quota"
    }

    fn admit(&mut self, api: &ApiServer, _now: f64, job: JobId) -> bool {
        api.quota_admits(job)
    }
}

/// Starvation aging: a pending job that has waited past the threshold is
/// moved to the queue head, and its gang failure escalates to `Block`, so
/// no later submission can overtake it — under FIFO-skip a wide job
/// behind a stream of narrow backfills is otherwise starved indefinitely.
pub struct AgingPlugin {
    config: AgingConfig,
}

impl AgingPlugin {
    pub fn new(config: AgingConfig) -> AgingPlugin {
        AgingPlugin { config }
    }

    fn starved(&self, api: &ApiServer, now: f64, job: JobId) -> bool {
        now - api.jobs[&job].submit_time >= self.config.threshold_secs
    }
}

impl Plugin for AgingPlugin {
    fn name(&self) -> &'static str {
        "aging"
    }

    /// Stable partition: starved jobs first, each half keeping the order
    /// the discipline chose.
    fn order(&mut self, api: &ApiServer, now: f64, pending: &mut Vec<JobId>) {
        let (starved, fresh): (Vec<JobId>, Vec<JobId>) =
            pending.iter().partition(|&&j| self.starved(api, now, j));
        pending.clear();
        pending.extend(starved);
        pending.extend(fresh);
    }

    fn override_gang_failure(
        &mut self,
        api: &ApiServer,
        now: f64,
        job: JobId,
    ) -> Option<GangDecision> {
        if self.starved(api, now, job) {
            Some(GangDecision::Block)
        } else {
            None
        }
    }
}

/// Per-tenant preemption budget: a sliding-window cap on how many jobs
/// one tenant can lose to preemption. Victim candidates of a tenant at
/// its budget are vetoed, so sustained high-priority arrivals cannot
/// starve a low-priority tenant through endless evictions.
pub struct BudgetPlugin {
    config: BudgetConfig,
    /// Eviction timestamps charged to each victim tenant (pruned as the
    /// window slides).
    evictions: BTreeMap<TenantId, Vec<f64>>,
}

impl BudgetPlugin {
    pub fn new(config: BudgetConfig) -> BudgetPlugin {
        BudgetPlugin { config, evictions: BTreeMap::new() }
    }

    fn charged(&mut self, tenant: TenantId, now: f64) -> u32 {
        let window_start = now - self.config.window_secs;
        match self.evictions.get_mut(&tenant) {
            Some(times) => {
                times.retain(|&t| t > window_start);
                times.len() as u32
            }
            None => 0,
        }
    }
}

impl Plugin for BudgetPlugin {
    fn name(&self) -> &'static str {
        "preemption_budget"
    }

    fn may_evict(&mut self, api: &ApiServer, now: f64, victim: JobId) -> bool {
        let tenant = api.jobs[&victim].planned.spec.tenant;
        self.charged(tenant, now) < self.config.max_evictions
    }

    fn on_evictions(&mut self, api: &ApiServer, now: f64, victims: &[JobId]) {
        for &v in victims {
            let tenant = api.jobs[&v].planned.spec.tenant;
            self.evictions.entry(tenant).or_default().push(now);
        }
    }
}

/// The elasticity plugin: registering it (tier 1) arms the pipeline's
/// resize verbs. The mold/expand/shrink machinery itself lives in the
/// scheduler's action stages — it rewrites pods and the session's trial
/// placement state, which the [`Plugin`] callback surface deliberately
/// cannot touch — gated on this plugin's [`ElasticityConfig`]:
///
/// - the `resize` action molds gang-blocked pending elastic jobs
///   stepwise toward `min` (both modes);
/// - the `preempt` action offers shrink deltas from running
///   lower-priority elastic jobs before whole-job eviction
///   ([`ElasticityMode::Malleable`] only);
/// - after the queue drains, expand-into-drain grows running elastic
///   jobs into capacity nothing pending could use (malleable only).
pub struct ElasticityPlugin {
    config: ElasticityConfig,
}

impl ElasticityPlugin {
    pub fn new(config: ElasticityConfig) -> ElasticityPlugin {
        ElasticityPlugin { config }
    }
}

impl Plugin for ElasticityPlugin {
    fn name(&self) -> &'static str {
        "elasticity"
    }

    /// Malleable victim tier: a running elastic job that still has shrink
    /// room is not evicted whole — the preempt stage has already taken
    /// its shrink deltas, and what remains is its `min`-width core.
    fn may_evict(&mut self, api: &ApiServer, _now: f64, victim: JobId) -> bool {
        if self.config.mode != ElasticityMode::Malleable {
            return true;
        }
        match api.jobs[&victim].planned.spec.elasticity {
            Some(e) => api.worker_width(victim) <= e.min,
            None => true,
        }
    }
}

/// Per-session state the actions share — the `Session` object the plugins
/// and actions hang off (trial placement state, EASY reservations, the
/// conservative timeline, and the jobs started so far).
pub(super) struct Session {
    pub(super) now: f64,
    /// Walltime-estimate misprediction factor (config knob).
    pub(super) wf: f64,
    /// Conservative discipline: every blocked job claims a reservation.
    pub(super) conservative: bool,
    pub(super) state: SessionState,
    pub(super) started: Vec<JobId>,
    /// EASY: shadow times of the reservations held this session.
    pub(super) reservations: Vec<f64>,
    /// Conservative: the availability profile, cloned from the persistent
    /// cache at the session's first gang failure.
    pub(super) timeline: Option<ResourceTimeline>,
}

/// What an action did with the job it was handed.
enum Outcome {
    /// Job consumed (placed, held, or reservation claimed) — next job.
    Done,
    /// Not handled here — fall through to the next action.
    Next,
    /// End the whole session (a `Block` decision).
    Stop,
}

impl Scheduler {
    /// Run one session through the configured action pipeline. The
    /// default configuration is pinned bit-identical to
    /// [`Scheduler::cycle_legacy`] (the retired monolithic loop) by
    /// `tests/differential.rs` and the fuzz property.
    pub(super) fn run_pipeline(
        &mut self,
        api: &mut ApiServer,
        now: f64,
        projected: &BTreeMap<JobId, f64>,
    ) -> Vec<JobId> {
        let actions = self.config.pipeline.actions;
        let mut state = SessionState::snapshot(api);
        state.index = self.engine.session_index(api);
        let mut session = Session {
            now,
            wf: self.config.walltime_error_factor,
            conservative: self.queue_policy.reserves_every_job(),
            state,
            started: Vec::new(),
            reservations: Vec::new(),
            timeline: None,
        };
        let mut plugins = std::mem::take(&mut self.plugins);

        // Enqueue runs once per session (validation pins it first; the
        // per-job loop below treats it as a no-op stage).
        let pending = self.act_enqueue(api, now, &mut plugins);

        'queue: for job_id in pending {
            let mut gang_failed = false;
            for &action in actions.as_slice() {
                let outcome = match action {
                    ActionKind::Enqueue => Outcome::Next,
                    ActionKind::Allocate => self.act_allocate(
                        api,
                        &mut session,
                        &mut plugins,
                        projected,
                        job_id,
                        &mut gang_failed,
                    ),
                    ActionKind::Preempt => {
                        self.act_preempt(api, &mut session, &mut plugins, job_id, gang_failed)
                    }
                    ActionKind::Resize => {
                        self.act_resize(api, &mut session, &mut plugins, job_id, gang_failed)
                    }
                    ActionKind::Reclaim => {
                        self.act_reclaim(api, &mut session, &mut plugins, job_id, gang_failed)
                    }
                    ActionKind::Backfill => self.act_backfill(
                        api,
                        &mut session,
                        &mut plugins,
                        projected,
                        job_id,
                        gang_failed,
                    ),
                };
                match outcome {
                    Outcome::Done => continue 'queue,
                    Outcome::Next => {}
                    Outcome::Stop => break 'queue,
                }
            }
        }
        // Expand-into-drain (malleable only): after the queue has had its
        // pass, grow running elastic jobs into capacity nothing pending
        // could claim this session. Guarded on an empty reservation set —
        // expansion must never take resources a backfill reservation
        // counted on.
        if self
            .config
            .pipeline
            .elasticity
            .map(|e| e.mode == ElasticityMode::Malleable)
            .unwrap_or(false)
            && session.reservations.is_empty()
            && session.timeline.is_none()
        {
            self.expand_into_drain(api, &mut session);
        }
        self.plugins = plugins;
        // Session-consistency pin: commits were mirrored into the session
        // state as they happened, so the trial free view must agree with
        // the API server at session end.
        #[cfg(debug_assertions)]
        for node in api.spec.node_ids() {
            debug_assert_eq!(
                session.state.free[node.0],
                api.free_on(node),
                "pipeline session free view drifted from the API server on {node:?}"
            );
        }
        session.started
    }

    /// Enqueue action: the pending queue in discipline order, refined by
    /// the plugins' OrderFns.
    fn act_enqueue(
        &mut self,
        api: &ApiServer,
        now: f64,
        plugins: &mut PluginSet,
    ) -> Vec<JobId> {
        let mut pending = api.pending_jobs();
        self.queue_policy.order(api, now, &mut pending);
        plugins.order(api, now, &mut pending);
        pending
    }

    /// Allocate action: admission predicates, backfill gates, then gang
    /// (or per-pod) placement. Mirrors the legacy loop's allocation arm
    /// exactly — including when estimates are taken and in which order
    /// the RNG jitter is drawn — so the default pipeline stays
    /// bit-identical.
    fn act_allocate(
        &mut self,
        api: &mut ApiServer,
        session: &mut Session,
        plugins: &mut PluginSet,
        projected: &BTreeMap<JobId, f64>,
        job_id: JobId,
        gang_failed: &mut bool,
    ) -> Outcome {
        let now = session.now;
        if !plugins.admits(api, now, job_id) {
            return Outcome::Done;
        }
        // Conservative sessions holding reservations: the job's whole
        // window must first-fit what the claims left over; the passing
        // (estimate, min-free window) pair is reused by the constrained
        // planning below.
        let mut admitted_window: Option<(f64, Vec<Resources>)> = None;
        if session.conservative && session.timeline.is_some() {
            let est = queue::estimated_runtime(api, job_id) * session.wf;
            let tl = session.timeline.as_mut().unwrap();
            let window = tl.min_free_over(now, now + est);
            if !queue::job_fits(api, &window, job_id) {
                // Window-rejected: hold this job's own reservation at its
                // earliest profile fit, claiming the window so no later
                // backfill can push its start back. A fit at `now` means
                // only the scored-greedy planner can be cornered — rely
                // on the next session's retry instead of claiming live
                // resources.
                if let Some((t_s, placement)) =
                    tl.earliest_fit_forced(api, job_id, est, self.force_linear_earliest_fit)
                {
                    if t_s > now + 1e-9 {
                        tl.claim(t_s, t_s + est, &placement);
                    }
                }
                return Outcome::Done;
            }
            admitted_window = Some((est, window));
        } else if let Some(shadow) = session.reservations.iter().copied().reduce(f64::min) {
            let ctx = QueueContext {
                api: &*api,
                now,
                projected_completion: projected,
                free: &session.state.free,
                walltime_factor: session.wf,
            };
            if !self.queue_policy.may_backfill(&ctx, job_id, shadow) {
                return Outcome::Done;
            }
        }
        if self.config.gang {
            // All-or-nothing. A conservative session holding reservations
            // plans against the window-constrained free view (a trial
            // state), so the scored placement can never occupy resources
            // a reservation counted on; otherwise plan against the live
            // state and roll back the undo log on failure.
            let planned: Option<(Vec<(PodId, NodeId, Option<usize>)>, Option<f64>)> =
                if let Some((est, constrained)) = admitted_window {
                    let mut trial =
                        SessionState::new(api, constrained, session.state.placement.clone());
                    self.plan_job(api, &mut trial, job_id).map(|b| (b, Some(est)))
                } else {
                    let checkpoint = session.state.checkpoint();
                    match self.plan_job(api, &mut session.state, job_id) {
                        Some(binds) => Some((binds, None)),
                        None => {
                            session.state.rollback_to(checkpoint);
                            None
                        }
                    }
                };
            match planned {
                Some((binds, window_est)) => {
                    if let Some(est) = window_est {
                        // Mirror the trial plan into the live session
                        // state and claim the job's running window out of
                        // the profile (its release past `now + est` stays
                        // visible to later reservations).
                        let placement: Vec<(NodeId, Resources)> = binds
                            .iter()
                            .map(|&(pid, node, _)| (node, api.pods[&pid].requests))
                            .collect();
                        for &(pid, node, g) in &binds {
                            session.state.apply(
                                api.pods[&pid].requests,
                                node,
                                g.map(|gg| (job_id, gg)),
                            );
                        }
                        session.timeline.as_mut().unwrap().claim(now, now + est, &placement);
                    }
                    Self::commit_gang(api, binds, job_id, now);
                    session.started.push(job_id);
                    plugins.on_job_started(api, now, job_id);
                    Outcome::Done
                }
                None => {
                    *gang_failed = true;
                    Outcome::Next
                }
            }
        } else {
            // Kubernetes default: bind pods individually as they fit.
            let pending: Vec<PodId> = api.jobs[&job_id]
                .pods
                .iter()
                .filter(|pid| api.pods[pid].phase == PodPhase::Pending)
                .copied()
                .collect();
            for pid in pending {
                let pod = api.pods[&pid].clone();
                if let Some(node) = self.place_pod(api, &mut session.state, &pod, None) {
                    let ok = api.bind_pod(pid, node, now);
                    assert!(ok, "kubelet admission failed after predicate pass");
                }
            }
            let all_bound = api.jobs[&job_id]
                .pods
                .iter()
                .all(|pid| api.pods[pid].phase == PodPhase::Bound);
            if all_bound {
                api.start_job(job_id, now);
                session.started.push(job_id);
                plugins.on_job_started(api, now, job_id);
            }
            Outcome::Done
        }
    }

    /// Preempt action: plan against a trial view with a minimal victim
    /// set released ([`Plugin::may_evict`] filters the candidates), and
    /// only evict once the plan is proven — a scored-greedy corner case
    /// must never preempt for nothing.
    fn act_preempt(
        &mut self,
        api: &mut ApiServer,
        session: &mut Session,
        plugins: &mut PluginSet,
        job_id: JobId,
        gang_failed: bool,
    ) -> Outcome {
        if !gang_failed || !self.config.preemption {
            return Outcome::Next;
        }
        // Shrink-before-preempt (malleable only): offer tail-worker
        // shrink deltas from running lower-priority elastic jobs before
        // evicting anything whole. A successful shrink either starts the
        // blocked job right here or leaves the freed capacity for the
        // fall-through eviction plan below.
        if self
            .config
            .pipeline
            .elasticity
            .map(|e| e.mode == ElasticityMode::Malleable)
            .unwrap_or(false)
        {
            if let Outcome::Done = self.shrink_before_preempt(api, session, plugins, job_id) {
                return Outcome::Done;
            }
        }
        let now = session.now;
        let planned = self.plan_with_preemption(
            api,
            &session.state,
            job_id,
            &session.started,
            now,
            Some(&mut *plugins),
        );
        match planned {
            Some((victims, binds)) => {
                for &v in &victims {
                    api.preempt_job(v, now);
                }
                self.preempted.extend_from_slice(&victims);
                plugins.on_evictions(api, now, &victims);
                Self::commit_gang(api, binds, job_id, now);
                session.started.push(job_id);
                plugins.on_job_started(api, now, job_id);
                // The eviction + commit invalidated the session view and
                // the release profile: rebuild the state, drop the
                // reservations (they re-derive at the next failure; the
                // engine index and the timeline cache both catch up from
                // their cursors).
                session.state = SessionState::snapshot(api);
                session.state.index = self.engine.session_index(api);
                session.reservations.clear();
                session.timeline = None;
                Outcome::Done
            }
            None => Outcome::Next,
        }
    }

    /// Resize action (mold): a gang-blocked *elastic* job is molded
    /// stepwise down toward its `min` worker count, retrying the gang
    /// plan at each narrower width; the first width that plans commits
    /// and starts. Without an [`ElasticityConfig`] — or for rigid jobs —
    /// this is a provable no-op, so the default pipeline stays
    /// bit-identical to the legacy scheduler.
    fn act_resize(
        &mut self,
        api: &mut ApiServer,
        session: &mut Session,
        plugins: &mut PluginSet,
        job_id: JobId,
        gang_failed: bool,
    ) -> Outcome {
        if !gang_failed || self.config.pipeline.elasticity.is_none() || !self.config.gang {
            return Outcome::Next;
        }
        // Molding behind live reservations would be an un-gated backfill:
        // sessions holding claims keep the backfill action's semantics.
        if !session.reservations.is_empty() || session.timeline.is_some() {
            return Outcome::Next;
        }
        let Some(e) = api.jobs[&job_id].planned.spec.elasticity else {
            return Outcome::Next;
        };
        let now = session.now;
        let mut width = api.worker_width(job_id);
        while width > e.min {
            width -= 1;
            api.mold_job(job_id, width, now);
            let checkpoint = session.state.checkpoint();
            match self.plan_job(api, &mut session.state, job_id) {
                Some(binds) => {
                    Self::commit_gang(api, binds, job_id, now);
                    session.started.push(job_id);
                    plugins.on_job_started(api, now, job_id);
                    return Outcome::Done;
                }
                None => session.state.rollback_to(checkpoint),
            }
        }
        Outcome::Next
    }

    /// Malleable shrink tier: before whole-job eviction, trial-release
    /// the tail workers of running, strictly-lower-priority elastic jobs
    /// (cheapest first: lowest priority, then lowest id; highest worker
    /// index first within a job, matching the real shrink), one worker at
    /// a time down to each job's `min`, until the blocked gang first-fits
    /// the freed view. A fitting trial commits the shrinks — real
    /// releases, logged `JobResized` events, and the moved-memory deltas
    /// the simulator charges resize cost for — and re-plans the blocked
    /// job live. A trial that never fits shrinks nothing.
    fn shrink_before_preempt(
        &mut self,
        api: &mut ApiServer,
        session: &mut Session,
        plugins: &mut PluginSet,
        job_id: JobId,
    ) -> Outcome {
        if !self.config.gang {
            return Outcome::Next;
        }
        let now = session.now;
        // Same never-for-nothing guard as victim selection: if the gang
        // already first-fits, shrinking cannot be what unblocks it.
        if queue::job_fits(api, &session.state.free, job_id) {
            return Outcome::Next;
        }
        let priority = api.jobs[&job_id].planned.spec.priority;
        let mut candidates: Vec<JobId> = api
            .running_jobs()
            .into_iter()
            .filter(|id| {
                let j = &api.jobs[id];
                j.planned.spec.priority < priority
                    && j.planned.spec.elasticity.is_some()
                    && !session.started.contains(id)
            })
            .collect();
        candidates.sort_by_key(|id| (api.jobs[id].planned.spec.priority, *id));
        if candidates.is_empty() {
            return Outcome::Next;
        }
        let mut free = session.state.free.clone();
        let mut deltas: Vec<(JobId, u32)> = Vec::new();
        let mut fits = false;
        'trial: for &cand in &candidates {
            let e = api.jobs[&cand].planned.spec.elasticity.unwrap();
            let mut workers: Vec<&Pod> = api.jobs[&cand]
                .pods
                .iter()
                .map(|pid| &api.pods[pid])
                .filter(|p| p.is_worker())
                .collect();
            workers.sort_by_key(|p| (p.worker_index(), p.id));
            let width = workers.len() as u32;
            let mut removed = 0u32;
            for pod in workers.iter().rev() {
                if width - removed <= e.min {
                    break;
                }
                if let Some(node) = pod.node {
                    free[node.0] += pod.requests;
                }
                removed += 1;
                if queue::job_fits(api, &free, job_id) {
                    deltas.push((cand, removed));
                    fits = true;
                    break 'trial;
                }
            }
            if removed > 0 {
                deltas.push((cand, removed));
            }
        }
        if !fits {
            return Outcome::Next;
        }
        for &(cand, remove) in &deltas {
            let freed_mem = api.shrink_job(cand, remove, now);
            self.resized.push((cand, freed_mem));
        }
        // The releases invalidated the session view: rebuild and re-plan
        // the blocked job live (reservations re-derive at the next
        // failure, exactly as after an eviction).
        session.state = SessionState::snapshot(api);
        session.state.index = self.engine.session_index(api);
        session.reservations.clear();
        session.timeline = None;
        let checkpoint = session.state.checkpoint();
        match self.plan_job(api, &mut session.state, job_id) {
            Some(binds) => {
                Self::commit_gang(api, binds, job_id, now);
                session.started.push(job_id);
                plugins.on_job_started(api, now, job_id);
                Outcome::Done
            }
            None => {
                session.state.rollback_to(checkpoint);
                Outcome::Next
            }
        }
    }

    /// Expand-into-drain (malleable): grow running elastic jobs one
    /// worker at a time — round-robin in ascending job order — into free
    /// capacity nothing pending claimed this session. The growth target
    /// is `preferred`; with an empty pending queue the drain is real and
    /// jobs may grow to `max`. Every committed expansion binds a fresh
    /// tail worker through the ordinary kubelet admission path and logs a
    /// `JobResized` event.
    fn expand_into_drain(&mut self, api: &mut ApiServer, session: &mut Session) {
        let now = session.now;
        let queue_empty = api.pending_jobs().is_empty();
        loop {
            let mut grew = false;
            let candidates: Vec<JobId> = api
                .running_jobs()
                .into_iter()
                .filter(|id| {
                    let j = &api.jobs[id];
                    match j.planned.spec.elasticity {
                        Some(e) => {
                            let target = if queue_empty { e.max } else { e.preferred };
                            api.worker_width(*id) < target
                        }
                        None => false,
                    }
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            for job_id in candidates {
                let pid = api.expand_job(job_id);
                let pod = api.pods[&pid].clone();
                match self.place_pod(api, &mut session.state, &pod, None) {
                    Some(node) => {
                        let ok = api.bind_pod(pid, node, now);
                        assert!(ok, "kubelet admission failed after predicate pass");
                        // Mirror the bind into the session's trial view
                        // (free + capacity index), exactly as committed
                        // allocations are — the session-end consistency
                        // pin compares this view against the API server.
                        session.state.apply(pod.requests, node, None);
                        api.complete_expand(job_id, now);
                        self.resized.push((job_id, pod.requests.mem_bytes));
                        grew = true;
                    }
                    None => api.cancel_expand(job_id, pid),
                }
            }
            if !grew {
                break;
            }
        }
    }

    /// Reclaim action: plugins may nominate running jobs to evict-and-
    /// requeue for the gang-blocked job; the freed capacity is then
    /// retried immediately. No built-in plugin nominates anything, so the
    /// default pipeline's reclaim is a documented no-op.
    fn act_reclaim(
        &mut self,
        api: &mut ApiServer,
        session: &mut Session,
        plugins: &mut PluginSet,
        job_id: JobId,
        gang_failed: bool,
    ) -> Outcome {
        if !gang_failed {
            return Outcome::Next;
        }
        let now = session.now;
        let victims: Vec<JobId> = plugins
            .reclaim(api, now, job_id)
            .into_iter()
            .filter(|v| {
                api.jobs.get(v).map(|j| j.phase == JobPhase::Running).unwrap_or(false)
                    && !session.started.contains(v)
            })
            .collect();
        if victims.is_empty() {
            return Outcome::Next;
        }
        for &v in &victims {
            api.preempt_job(v, now);
        }
        self.preempted.extend_from_slice(&victims);
        plugins.on_evictions(api, now, &victims);
        // The evictions invalidated the session view: rebuild, then retry
        // the blocked job on the reclaimed capacity.
        session.state = SessionState::snapshot(api);
        session.state.index = self.engine.session_index(api);
        session.reservations.clear();
        session.timeline = None;
        let checkpoint = session.state.checkpoint();
        match self.plan_job(api, &mut session.state, job_id) {
            Some(binds) => {
                Self::commit_gang(api, binds, job_id, now);
                session.started.push(job_id);
                plugins.on_job_started(api, now, job_id);
                Outcome::Done
            }
            None => {
                session.state.rollback_to(checkpoint);
                Outcome::Next
            }
        }
    }

    /// Backfill action: the discipline's reservation semantics for a job
    /// that neither allocated nor preempted its way in — conservative
    /// timeline claims, the EASY shadow reservation, or skip/block
    /// (optionally escalated by a plugin's
    /// [`Plugin::override_gang_failure`]).
    fn act_backfill(
        &mut self,
        api: &mut ApiServer,
        session: &mut Session,
        plugins: &mut PluginSet,
        projected: &BTreeMap<JobId, f64>,
        job_id: JobId,
        gang_failed: bool,
    ) -> Outcome {
        if !gang_failed {
            return Outcome::Next;
        }
        let now = session.now;
        if session.conservative {
            // First failure clones the persistent profile (refreshed
            // event-driven); every blocked job claims its earliest-fit
            // window.
            if session.timeline.is_none() {
                let timeline = {
                    let ctx = QueueContext {
                        api: &*api,
                        now,
                        projected_completion: projected,
                        free: &session.state.free,
                        walltime_factor: session.wf,
                    };
                    self.session_timeline(&ctx)
                };
                session.timeline = Some(timeline);
            }
            let tl = session.timeline.as_mut().unwrap();
            let est = queue::estimated_runtime(api, job_id) * session.wf;
            if let Some((t_s, placement)) =
                tl.earliest_fit_forced(api, job_id, est, self.force_linear_earliest_fit)
            {
                // A fit at `now` (gang first-fits, planner cornered
                // itself) claims nothing — the job retries next session.
                if t_s > now + 1e-9 {
                    tl.claim(t_s, t_s + est, &placement);
                }
            }
            return Outcome::Done;
        }
        let decision = if session.reservations.is_empty() {
            match plugins.override_gang_failure(api, now, job_id) {
                Some(decision) => decision,
                None => {
                    let ctx = QueueContext {
                        api: &*api,
                        now,
                        projected_completion: projected,
                        free: &session.state.free,
                        walltime_factor: session.wf,
                    };
                    self.queue_policy.on_gang_failure(&ctx, job_id)
                }
            }
        } else {
            GangDecision::Skip
        };
        match decision {
            GangDecision::Skip => Outcome::Done,
            GangDecision::Block => Outcome::Stop,
            GangDecision::Reserve { shadow_time } => {
                // A shadow at `now` (the gang first-fits but scored-greedy
                // cornered itself) would zero the backfill window — same
                // guard as the conservative path above.
                if shadow_time > now + 1e-9 {
                    session.reservations.push(shadow_time);
                }
                Outcome::Done
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, Resources};
    use crate::controller::VolcanoMpiController;
    use crate::controller::JobController;
    use crate::kubelet::KubeletConfig;
    use crate::perfmodel::Calibration;
    use crate::planner::{plan, GranularityPolicy, SystemInfo};
    use crate::scheduler::{Scheduler, SchedulerConfig};
    use crate::simulator::Simulation;
    use crate::workload::{Benchmark, JobSpec};

    fn api() -> ApiServer {
        ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity())
    }

    /// Submit an `ntasks`-core single-worker job with a tenant/priority
    /// (1000 milli-cores and 2 GiB per task, the paper-job shape).
    fn submit_job(
        api: &mut ApiServer,
        id: u64,
        ntasks: u32,
        tenant: TenantId,
        priority: u32,
        now: f64,
    ) -> JobId {
        let mut spec =
            JobSpec::paper_job(id, Benchmark::EpDgemm, now).with_tenant(tenant, priority);
        spec.ntasks = ntasks;
        spec.resources =
            Resources::new(ntasks as u64 * 1000, ntasks as u64 * crate::cluster::gib(2));
        let info = SystemInfo::of(&api.spec);
        let planned = plan(&spec, GranularityPolicy::None, info);
        let job_id = planned.spec.id;
        let (pods, hostfile) = VolcanoMpiController.build(&planned, api);
        api.create_job(planned, pods, hostfile, now);
        job_id
    }

    /// Fill the paper cluster (4 × 32 cores) with eight 16-core jobs.
    fn fill_cluster(api: &mut ApiServer, sched: &mut Scheduler, tenant: TenantId, priority: u32) {
        for i in 1..=8 {
            submit_job(api, i, 16, tenant, priority, 0.0);
        }
        assert_eq!(sched.cycle(api, 0.0).len(), 8, "cluster must pack full");
    }

    #[test]
    fn action_list_rejects_duplicates_and_overflow() {
        assert!(ActionList::of(&ALL_ACTIONS).is_ok());
        assert!(ActionList::of(&[]).unwrap().is_empty());
        let dup = [ActionKind::Enqueue, ActionKind::Allocate, ActionKind::Allocate];
        assert!(ActionList::of(&dup).unwrap_err().contains("twice"));
        let seven = [ActionKind::Enqueue; 7];
        assert!(ActionList::of(&seven).is_err());
        let list = ActionList::of(&[ActionKind::Enqueue, ActionKind::Allocate]).unwrap();
        assert_eq!(list.as_slice(), &[ActionKind::Enqueue, ActionKind::Allocate]);
        assert!(list.contains(ActionKind::Allocate));
        assert!(!list.contains(ActionKind::Preempt));
    }

    #[test]
    fn action_names_round_trip() {
        for a in ALL_ACTIONS {
            assert_eq!(ActionKind::parse(a.name()), Some(a));
            assert_eq!(ActionKind::parse(&a.name().to_ascii_uppercase()), Some(a));
        }
        assert_eq!(ActionKind::parse("bogus"), None);
    }

    #[test]
    fn pipeline_validation_pins_required_actions_and_order() {
        assert!(PipelineConfig::legacy_equivalent().validate().is_ok());
        assert_eq!(PipelineConfig::default(), PipelineConfig::legacy_equivalent());

        // enqueue + allocate are mandatory.
        let no_alloc = PipelineConfig::default()
            .with_actions(ActionList::of(&[ActionKind::Enqueue, ActionKind::Backfill]).unwrap());
        assert!(no_alloc.validate().unwrap_err().contains("allocate"));

        // Present actions must follow the canonical relative order.
        let reordered = PipelineConfig::default().with_actions(
            ActionList::of(&[ActionKind::Allocate, ActionKind::Enqueue]).unwrap(),
        );
        assert!(reordered.validate().unwrap_err().contains("canonical order"));

        // A canonical subsequence is fine.
        let subset = PipelineConfig::default().with_actions(
            ActionList::of(&[ActionKind::Enqueue, ActionKind::Allocate, ActionKind::Backfill])
                .unwrap(),
        );
        assert!(subset.validate().is_ok());

        // Plugin knobs must be positive.
        assert!(PipelineConfig::default().with_aging(0.0).validate().is_err());
        assert!(PipelineConfig::default().with_budget(-1.0, 1).validate().is_err());
        assert!(PipelineConfig::default().with_aging(100.0).with_budget(60.0, 1).validate().is_ok());
    }

    #[test]
    fn plugin_registry_reflects_the_config() {
        let base = PluginSet::from_config(&PipelineConfig::legacy_equivalent());
        assert_eq!(base.names(), vec!["quota"]);
        let full = PluginSet::from_config(
            &PipelineConfig::legacy_equivalent()
                .with_aging(100.0)
                .with_budget(60.0, 2)
                .with_elasticity(ElasticityMode::Malleable),
        );
        assert_eq!(full.names(), vec!["quota", "aging", "preemption_budget", "elasticity"]);
    }

    #[test]
    fn elasticity_config_requires_the_resize_action() {
        let ok = PipelineConfig::legacy_equivalent()
            .with_elasticity(ElasticityMode::Moldable);
        assert!(ok.validate().is_ok());
        let no_resize = ok.with_actions(
            ActionList::of(&[
                ActionKind::Enqueue,
                ActionKind::Allocate,
                ActionKind::Preempt,
                ActionKind::Reclaim,
                ActionKind::Backfill,
            ])
            .unwrap(),
        );
        assert!(no_resize.validate().unwrap_err().contains("resize"));
        for (s, m) in [
            ("moldable", ElasticityMode::Moldable),
            ("MALLEABLE", ElasticityMode::Malleable),
        ] {
            assert_eq!(ElasticityMode::parse(s), Some(m));
        }
        assert_eq!(ElasticityMode::parse("rigid"), None);
    }

    #[test]
    fn aging_blocks_overtaking_once_the_head_is_starved() {
        // Congested cluster with 16 free cores, a pending 32-core blocker
        // and an overtaking 8-core job. Under plain FIFO-skip the small
        // job overtakes forever; with aging, once the blocker has waited
        // past the threshold its gang failure escalates to Block and the
        // session ends before the small job is considered.
        let run = |aging: Option<f64>, now: f64| -> Vec<JobId> {
            let mut cfg = SchedulerConfig::volcano_default(1);
            if let Some(threshold) = aging {
                cfg = cfg.with_pipeline(
                    PipelineConfig::legacy_equivalent().with_aging(threshold),
                );
            }
            let mut api = api();
            let mut sched = Scheduler::new(cfg);
            fill_cluster(&mut api, &mut sched, TenantId(0), 0);
            api.finish_job(JobId(1), 2.0);
            let _blocker = submit_job(&mut api, 9, 32, TenantId(0), 0, 2.0);
            let small = submit_job(&mut api, 10, 8, TenantId(0), 0, now);
            let started = sched.cycle(&mut api, now);
            assert!(started.is_empty() || started == vec![small]);
            started
        };
        // Below the threshold the skip stream still overtakes.
        let started = run(Some(100.0), 50.0);
        assert_eq!(started.len(), 1, "not yet starved: small job overtakes");
        // Past it, the starved blocker dams the session.
        let started = run(Some(100.0), 200.0);
        assert!(started.is_empty(), "starved head must block: {started:?}");
        // And without the plugin nothing ever dams.
        let started = run(None, 200.0);
        assert_eq!(started.len(), 1, "plain FIFO-skip never blocks");
    }

    #[test]
    fn aging_lets_a_starved_wide_job_run_through_a_skip_stream() {
        // End-to-end regression (ROADMAP item since PR 2): a
        // cluster-wide job behind a steady stream of narrow jobs under
        // FIFO-skip. Without aging every narrow job overtakes and the
        // wide job starts only once the whole stream has drained; with
        // aging it runs as soon as the jobs admitted before starvation
        // finish.
        let start_of_wide = |aging: Option<f64>| -> f64 {
            let mut cfg = SchedulerConfig::volcano_default(7);
            if let Some(threshold) = aging {
                cfg = cfg.with_pipeline(
                    PipelineConfig::legacy_equivalent().with_aging(threshold),
                );
            }
            let sim = Simulation::new(
                ClusterSpec::with_workers(2),
                KubeletConfig::cpu_mem_affinity(),
                GranularityPolicy::None,
                Box::new(VolcanoMpiController),
                cfg,
                Calibration::default(),
                7,
            );
            // The wide job needs both 32-core nodes; 16-core narrow jobs
            // arrive every 30 s, far below their runtime, so the cluster
            // is never naturally idle until the stream ends.
            let mut wide = JobSpec::paper_job(100, Benchmark::EpDgemm, 5.0);
            wide.ntasks = 64;
            wide.resources = Resources::new(64_000, 64 * crate::cluster::gib(2));
            wide.default_workers = 2;
            let mut trace = vec![wide];
            for i in 0..15u64 {
                let mut narrow =
                    JobSpec::paper_job(i + 1, Benchmark::EpDgemm, 10.0 + 30.0 * i as f64);
                narrow.ntasks = 16;
                narrow.resources = Resources::new(16_000, 16 * crate::cluster::gib(2));
                trace.push(narrow);
            }
            let out = sim.run(&trace);
            assert_eq!(out.records.len(), 16, "every job must finish");
            out.records.iter().find(|r| r.id == JobId(100)).unwrap().start_time
        };
        let starved = start_of_wide(None);
        let aged = start_of_wide(Some(120.0));
        assert!(
            aged + 30.0 < starved,
            "aging must start the wide job earlier: aged {aged} vs starved {starved}"
        );
    }

    #[test]
    fn preemption_budget_caps_evictions_per_tenant_and_window() {
        let run = |budget: Option<(f64, u32)>| -> (Vec<usize>, ApiServer, Scheduler) {
            let mut cfg = SchedulerConfig::volcano_default(1).with_preemption(true);
            if let Some((window, max)) = budget {
                cfg = cfg.with_pipeline(
                    PipelineConfig::legacy_equivalent().with_budget(window, max),
                );
            }
            let mut api = api();
            let mut sched = Scheduler::new(cfg);
            fill_cluster(&mut api, &mut sched, TenantId(0), 0);
            let mut evicted_per_cycle = Vec::new();
            // Sustained high-priority arrivals: one 16-core tenant-1 job
            // every 50 s, each needing one eviction from tenant 0.
            for (i, t) in [(9u64, 50.0), (10, 100.0)] {
                submit_job(&mut api, i, 16, TenantId(1), 10, t);
                sched.cycle(&mut api, t);
                let victims = sched.take_preempted();
                for &v in &victims {
                    api.requeue_job(v, t);
                }
                evicted_per_cycle.push(victims.len());
            }
            (evicted_per_cycle, api, sched)
        };

        // Unbudgeted: both arrivals evict a batch victim.
        let (evicted, _, _) = run(None);
        assert_eq!(evicted, vec![1, 1]);

        // Budget of one eviction per 60 s window: the second arrival
        // (50 s after the first eviction) finds tenant 0 at its budget
        // and must queue instead.
        let (evicted, mut api, mut sched) = run(Some((60.0, 1)));
        assert_eq!(evicted, vec![1, 0], "second arrival is over budget");
        assert!(api.pending_jobs().contains(&JobId(10)));

        // The window slides: by t = 111 the t = 50 eviction has aged out
        // of the 60 s window and the queued job preempts its way in.
        let started = sched.cycle(&mut api, 111.0);
        assert_eq!(started, vec![JobId(10)], "window slid");
        assert_eq!(sched.take_preempted().len(), 1);
    }

    /// Test-only reclaim plugin: nominate a fixed victim for a fixed
    /// blocked job.
    struct ReclaimOne {
        blocked: JobId,
        victim: JobId,
    }

    impl Plugin for ReclaimOne {
        fn name(&self) -> &'static str {
            "test_reclaim_one"
        }

        fn reclaim(&mut self, _api: &ApiServer, _now: f64, job: JobId) -> Vec<JobId> {
            if job == self.blocked {
                vec![self.victim]
            } else {
                Vec::new()
            }
        }
    }

    #[test]
    fn reclaim_action_evicts_plugin_nominated_victims() {
        // Preemption is OFF: only the reclaim extension point can free
        // capacity, by a registered plugin's nomination.
        let mut api = api();
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(1));
        fill_cluster(&mut api, &mut sched, TenantId(0), 0);
        let blocked = submit_job(&mut api, 9, 16, TenantId(0), 0, 1.0);
        sched.register_plugin(1, Box::new(ReclaimOne { blocked, victim: JobId(1) }));
        let started = sched.cycle(&mut api, 1.0);
        assert_eq!(started, vec![blocked], "blocked job runs on reclaimed capacity");
        assert_eq!(sched.take_preempted(), vec![JobId(1)]);
        assert_eq!(api.jobs[&JobId(1)].phase, JobPhase::Preempted);
    }

    #[test]
    fn pipeline_without_preempt_action_never_evicts() {
        let run = |actions: &[ActionKind]| -> (Vec<JobId>, Vec<JobId>) {
            let mut api = api();
            let mut sched = Scheduler::new(
                SchedulerConfig::volcano_default(1)
                    .with_preemption(true)
                    .with_pipeline(
                        PipelineConfig::legacy_equivalent()
                            .with_actions(ActionList::of(actions).unwrap()),
                    ),
            );
            fill_cluster(&mut api, &mut sched, TenantId(0), 0);
            let _hi = submit_job(&mut api, 9, 16, TenantId(1), 10, 1.0);
            let started = sched.cycle(&mut api, 1.0);
            (started, sched.take_preempted())
        };
        let (started, evicted) = run(&ALL_ACTIONS);
        assert_eq!(started, vec![JobId(9)], "full pipeline preempts");
        assert_eq!(evicted.len(), 1);
        let (started, evicted) = run(&[
            ActionKind::Enqueue,
            ActionKind::Allocate,
            ActionKind::Backfill,
        ]);
        assert!(started.is_empty(), "no preempt action, no eviction: {started:?}");
        assert!(evicted.is_empty());
    }
}
