//! Task-group construction and worker ordering (paper Algorithm 3, step 1
//! plus the `WorkerOrderFn` auxiliary).
//!
//! The plugin groups a job's workers evenly into `N_g` groups (node
//! affinity within a group, anti-affinity among groups), then emits the
//! workers group-by-group so that each group's pods are scheduled
//! consecutively and can accrete onto the same node.

use crate::cluster::{Pod, PodId, Resources};

/// One task group being built for a job.
#[derive(Debug, Clone)]
pub struct TaskGroup {
    pub index: usize,
    pub workers: Vec<PodId>,
    pub requests: Resources,
}

/// Algorithm 3, step 1: build `n_groups` groups and allocate worker pods
/// into them so that group resource requests stay balanced
/// (`sortGroupByResourceRequests` + insert — equivalent to always adding
/// the next worker to the currently least-loaded group).
pub fn build_groups(workers: &[&Pod], n_groups: usize) -> Vec<TaskGroup> {
    assert!(n_groups > 0, "taskgroup plugin with zero groups");
    let mut groups: Vec<TaskGroup> = (0..n_groups)
        .map(|index| TaskGroup { index, workers: Vec::new(), requests: Resources::ZERO })
        .collect();
    for pod in workers {
        // sortGroupByResourceRequests orders the groups so the emptiest
        // group receives the next worker; ties broken by group index so the
        // assignment is deterministic.
        let g = groups
            .iter_mut()
            .min_by_key(|g| (g.requests.sort_key(), g.index))
            .unwrap();
        g.workers.push(pod.id);
        g.requests += pod.requests;
    }
    groups
}

/// `WorkerOrderFn`: enqueue workers group-by-group (not by pod id), so that
/// a group's workers are placed back-to-back and the Algorithm-4 affinity
/// score can accrete them onto one node.
pub fn worker_order(groups: &[TaskGroup]) -> Vec<PodId> {
    groups.iter().flat_map(|g| g.workers.iter().copied()).collect()
}

/// Group index of each pod, for committing onto `Pod::group` at bind time.
pub fn group_assignment(groups: &[TaskGroup]) -> Vec<(PodId, usize)> {
    groups
        .iter()
        .flat_map(|g| g.workers.iter().map(move |&p| (p, g.index)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{gib, JobId, PodRole};

    fn workers(n: usize, cores: u64) -> Vec<Pod> {
        (0..n)
            .map(|i| {
                let mut p = Pod::new(
                    PodId(i as u64 + 1),
                    JobId(1),
                    format!("w{i}"),
                    PodRole::Worker { index: i as u32 },
                );
                p.ntasks = cores as u32;
                p.requests = Resources::new(cores * 1000, cores * gib(2));
                p
            })
            .collect()
    }

    #[test]
    fn equal_workers_spread_evenly() {
        let pods = workers(16, 1);
        let refs: Vec<&Pod> = pods.iter().collect();
        let groups = build_groups(&refs, 4);
        assert_eq!(groups.len(), 4);
        for g in &groups {
            assert_eq!(g.workers.len(), 4, "{groups:?}");
            assert_eq!(g.requests.cpu_milli, 4000);
        }
    }

    #[test]
    fn group_sizes_differ_by_at_most_one() {
        for (n, k) in [(7usize, 3usize), (5, 4), (16, 5), (1, 1), (3, 4)] {
            let pods = workers(n, 1);
            let refs: Vec<&Pod> = pods.iter().collect();
            let groups = build_groups(&refs, k);
            let sizes: Vec<usize> = groups.iter().map(|g| g.workers.len()).collect();
            let max = sizes.iter().max().unwrap();
            let min = sizes.iter().min().unwrap();
            assert!(max - min <= 1, "n={n} k={k}: {sizes:?}");
            assert_eq!(sizes.iter().sum::<usize>(), n);
        }
    }

    #[test]
    fn heterogeneous_workers_balance_by_request() {
        // Workers with 4,3,3,3,3 tasks (Algorithm 2's uneven split into 5).
        let mut pods = workers(5, 3);
        pods[0].requests = Resources::new(4000, 4 * gib(2));
        let refs: Vec<&Pod> = pods.iter().collect();
        let groups = build_groups(&refs, 2);
        let reqs: Vec<u64> = groups.iter().map(|g| g.requests.cpu_milli).collect();
        // 16 cores total; best split is 10/6 or better — greedy gives 7/9.
        assert!(reqs.iter().max().unwrap() - reqs.iter().min().unwrap() <= 4000, "{reqs:?}");
    }

    #[test]
    fn worker_order_is_group_major() {
        let pods = workers(6, 1);
        let refs: Vec<&Pod> = pods.iter().collect();
        let groups = build_groups(&refs, 2);
        let order = worker_order(&groups);
        assert_eq!(order.len(), 6);
        // First all of group 0's workers, then group 1's.
        let g0: Vec<PodId> = groups[0].workers.clone();
        assert_eq!(&order[..g0.len()], &g0[..]);
    }

    #[test]
    fn assignment_covers_every_worker_once() {
        let pods = workers(16, 1);
        let refs: Vec<&Pod> = pods.iter().collect();
        let groups = build_groups(&refs, 4);
        let mut assigned = group_assignment(&groups);
        assigned.sort();
        let ids: Vec<u64> = assigned.iter().map(|(p, _)| p.0).collect();
        assert_eq!(ids, (1..=16).collect::<Vec<u64>>());
    }
}
