//! Placement engine — the feasibility layer of the scheduling session.
//!
//! [`crate::scheduler::Scheduler`] scores candidate nodes per pod
//! (NodeOrderFn); *which* nodes are candidates is this module's job. The
//! reference implementation ([`LinearEngine`]) is the seed's linear scan:
//! every pod visits every node and runs the predicate (role + resource
//! fit) — O(nodes) per pod, the hot path ROADMAP names for 128-node
//! sessions. [`IndexedEngine`] replaces the scan with a [`CapacityIndex`]:
//! one free-capacity bucket per [`crate::cluster::CapacityClass`]
//! (nodes sharing role + allocatable shape), ordered by free CPU, so a
//! pod's feasible set is enumerated by a range scan that never touches a
//! node without enough free capacity. The index is maintained
//! *incrementally*:
//!
//! - across sessions, from the API server's allocation-touch log
//!   ([`crate::apiserver::ApiServer::alloc_touched_since`]) — bind,
//!   release, preempt, requeue and unschedulable cleanup all land there —
//!   consumed from a cursor instead of rescanning every node;
//! - within a session, by the session state's undo log: every trial
//!   apply/rollback patches the session's clone of the index.
//!
//! Selections are **bit-identical** to the linear reference: the score
//! loop draws one RNG jitter per *feasible* node in ascending node order,
//! so an engine that enumerates exactly the feasible set in the same
//! order consumes the same RNG stream and picks the same argmax. A
//! randomized churn property test pins whole simulations equal across
//! engines, and debug builds assert the indexed feasible set equals the
//! linear scan after every delta (every `place_pod` call).

use std::collections::BTreeSet;

use crate::apiserver::ApiServer;
use crate::cluster::{ClusterSpec, NodeId, NodeRole, Pod, PodRole, Resources};

use super::score::{GroupKey, GroupPlacement};

/// Selector for the placement engine, carried by `SchedulerConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlacementEngineKind {
    /// Reference: linear predicate scan over every node, per pod.
    Linear,
    /// Per-class free-capacity buckets, incrementally maintained.
    Indexed,
}

/// All engines, reference first (ablation/bench order).
pub const ALL_PLACEMENT_ENGINES: [PlacementEngineKind; 2] =
    [PlacementEngineKind::Linear, PlacementEngineKind::Indexed];

impl PlacementEngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementEngineKind::Linear => "linear",
            PlacementEngineKind::Indexed => "indexed",
        }
    }

    /// Parse a CLI/config spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<PlacementEngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "linear" | "scan" => Some(PlacementEngineKind::Linear),
            "indexed" | "index" | "buckets" => Some(PlacementEngineKind::Indexed),
            _ => None,
        }
    }

    pub fn build(&self) -> Box<dyn PlacementEngine> {
        match self {
            PlacementEngineKind::Linear => Box::new(LinearEngine),
            PlacementEngineKind::Indexed => Box::new(IndexedEngine::new()),
        }
    }
}

impl std::fmt::Display for PlacementEngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Node role a pod's predicate requires (launchers live on the control
/// plane, workers on worker nodes — paper §V-B).
pub fn required_role(pod: &Pod) -> NodeRole {
    match pod.role {
        PodRole::Launcher => NodeRole::ControlPlane,
        PodRole::Worker { .. } => NodeRole::Worker,
    }
}

/// PredicateFn: feasibility of one pod on one node (role constraint +
/// resource fit against the given free view).
pub fn predicate(api: &ApiServer, free: &[Resources], pod: &Pod, node: NodeId) -> bool {
    api.spec.node(node).role == required_role(pod) && pod.requests.fits_within(&free[node.0])
}

/// Reference feasibility enumeration: the linear predicate scan, in
/// ascending node order (the order the score loop consumes).
pub fn linear_feasible_into(
    api: &ApiServer,
    free: &[Resources],
    pod: &Pod,
    out: &mut Vec<NodeId>,
) {
    out.clear();
    for node in api.spec.node_ids() {
        if predicate(api, free, pod, node) {
            out.push(node);
        }
    }
}

/// Per-class free-capacity buckets over one free view. Each bucket holds
/// `(free cpu, free mem, node)` tuples in a `BTreeSet`, so "every node of
/// this class with at least `req` free CPU" is a range scan from
/// `(req.cpu, 0, 0)` — nodes too full to matter are never visited.
#[derive(Debug, Clone)]
pub struct CapacityIndex {
    /// Mirror of the tracked free view, indexed by node.
    free: Vec<Resources>,
    /// Bucket index of each node.
    bucket_of: Vec<usize>,
    buckets: Vec<Bucket>,
}

#[derive(Debug, Clone)]
struct Bucket {
    role: NodeRole,
    /// `(free cpu millicores, free mem bytes, node index)`, ascending.
    nodes: BTreeSet<(u64, u64, usize)>,
}

impl CapacityIndex {
    /// Build the index for a free view from scratch (cold start; steady
    /// state goes through [`CapacityIndex::set_free`] deltas).
    pub fn build(spec: &ClusterSpec, free: &[Resources]) -> CapacityIndex {
        debug_assert_eq!(spec.nodes.len(), free.len());
        let classes = spec.capacity_classes();
        let mut bucket_of = vec![0usize; spec.nodes.len()];
        let mut buckets = Vec::with_capacity(classes.len());
        for (i, class) in classes.iter().enumerate() {
            let mut nodes = BTreeSet::new();
            for &id in &class.nodes {
                bucket_of[id.0] = i;
                nodes.insert((free[id.0].cpu_milli, free[id.0].mem_bytes, id.0));
            }
            buckets.push(Bucket { role: class.role, nodes });
        }
        CapacityIndex { free: free.to_vec(), bucket_of, buckets }
    }

    /// Update one node's tracked free capacity (an incremental delta from
    /// a bind, release, or session-trial apply/rollback).
    pub fn set_free(&mut self, node: NodeId, free: Resources) {
        let old = self.free[node.0];
        if old == free {
            return;
        }
        let bucket = &mut self.buckets[self.bucket_of[node.0]];
        let removed = bucket.nodes.remove(&(old.cpu_milli, old.mem_bytes, node.0));
        debug_assert!(removed, "index out of sync for node {node:?}");
        bucket.nodes.insert((free.cpu_milli, free.mem_bytes, node.0));
        self.free[node.0] = free;
    }

    /// Tracked free view (the mirror the consistency asserts compare).
    pub fn free_view(&self) -> &[Resources] {
        &self.free
    }

    /// Enumerate the feasible nodes for `pod`, ascending by node id —
    /// exactly the set (and order) the linear reference scan yields.
    pub fn feasible_into(&self, pod: &Pod, out: &mut Vec<NodeId>) {
        out.clear();
        let role = required_role(pod);
        let req = pod.requests;
        for bucket in &self.buckets {
            if bucket.role != role {
                continue;
            }
            for &(_, mem, node) in bucket.nodes.range((req.cpu_milli, 0, 0)..) {
                if mem >= req.mem_bytes {
                    out.push(NodeId(node));
                }
            }
        }
        out.sort_unstable();
    }
}

/// Trial state for one scheduling session (mutated as binds are decided,
/// committed to the API server only when the gang succeeds). Gang
/// all-or-nothing is implemented with an undo log instead of cloning the
/// whole state per job (§Perf: the clone dominated large sessions). The
/// main session state carries the engine's [`CapacityIndex`] (patched by
/// every apply/rollback); trial states built for preemption planning or
/// window-constrained conservative backfills carry none and fall back to
/// the linear scan.
pub(crate) struct SessionState {
    pub(crate) free: Vec<Resources>,
    pub(crate) placement: GroupPlacement,
    /// Undo log of (pod requests, node, group) applied since the last
    /// checkpoint; replayed backwards on gang failure.
    pub(crate) log: Vec<(Resources, NodeId, Option<GroupKey>)>,
    /// Allocatable CPU (millicores) of the largest worker class — the
    /// normalizer of the class-aware best-fit scoring term.
    pub(crate) max_worker_cpu: u64,
    /// Free-capacity index mirroring `free` (None = linear reference).
    pub(crate) index: Option<CapacityIndex>,
}

impl SessionState {
    pub(crate) fn new(
        api: &ApiServer,
        free: Vec<Resources>,
        placement: GroupPlacement,
    ) -> SessionState {
        SessionState {
            free,
            placement,
            log: Vec::new(),
            max_worker_cpu: api.spec.max_worker_cores() as u64 * 1000,
            index: None,
        }
    }

    pub(crate) fn snapshot(api: &ApiServer) -> SessionState {
        SessionState::new(
            api,
            api.spec.node_ids().map(|n| api.free_on(n)).collect(),
            api.group_placement().clone(),
        )
    }

    pub(crate) fn apply(&mut self, requests: Resources, node: NodeId, group: Option<GroupKey>) {
        self.free[node.0] -= requests;
        if let Some(index) = &mut self.index {
            index.set_free(node, self.free[node.0]);
        }
        if let Some(key) = group {
            self.placement.record(key, node);
        }
        self.log.push((requests, node, group));
    }

    pub(crate) fn checkpoint(&self) -> usize {
        self.log.len()
    }

    pub(crate) fn rollback_to(&mut self, checkpoint: usize) {
        while self.log.len() > checkpoint {
            let (requests, node, group) = self.log.pop().unwrap();
            self.free[node.0] += requests;
            if let Some(index) = &mut self.index {
                index.set_free(node, self.free[node.0]);
            }
            if let Some(key) = group {
                self.placement.remove(key, node);
            }
        }
    }

    /// The feasible nodes for `pod` under this state's free view,
    /// ascending by node id. Uses the capacity index when present; debug
    /// builds assert the indexed set equals the linear reference after
    /// every delta (this runs once per `place_pod`, so the whole test
    /// suite exercises the equivalence on its traces).
    pub(crate) fn feasible_into(&self, api: &ApiServer, pod: &Pod, out: &mut Vec<NodeId>) {
        match &self.index {
            Some(index) => {
                index.feasible_into(pod, out);
                #[cfg(debug_assertions)]
                {
                    let mut reference = Vec::new();
                    linear_feasible_into(api, &self.free, pod, &mut reference);
                    assert_eq!(
                        *out, reference,
                        "indexed feasible set drifted from the linear reference for {:?}",
                        pod.id
                    );
                }
            }
            None => linear_feasible_into(api, &self.free, pod, out),
        }
    }
}

/// The placement-engine plugin: owns whatever persistent structure the
/// feasibility enumeration needs and hands each session its view.
pub trait PlacementEngine {
    fn kind(&self) -> PlacementEngineKind;

    /// Called at session start (and after a mid-session preemption
    /// invalidates the session view): return the capacity index the
    /// session should carry, or `None` for the linear reference scan.
    fn session_index(&mut self, api: &ApiServer) -> Option<CapacityIndex>;
}

/// Reference engine: no index, every pod scans every node.
pub struct LinearEngine;

impl PlacementEngine for LinearEngine {
    fn kind(&self) -> PlacementEngineKind {
        PlacementEngineKind::Linear
    }

    fn session_index(&mut self, _api: &ApiServer) -> Option<CapacityIndex> {
        None
    }
}

/// Indexed engine: keeps a persistent base [`CapacityIndex`] in sync with
/// the API server's allocation view by replaying the allocation-touch log
/// from a cursor (bind/release/preempt/requeue events — never a full
/// rescan), and clones it for each session's trial mutations.
pub struct IndexedEngine {
    base: Option<CapacityIndex>,
    cursor: usize,
    /// [`ApiServer::instance_id`] the cursor belongs to.
    api_id: u64,
}

impl IndexedEngine {
    pub fn new() -> IndexedEngine {
        IndexedEngine { base: None, cursor: 0, api_id: 0 }
    }
}

impl Default for IndexedEngine {
    fn default() -> Self {
        IndexedEngine::new()
    }
}

impl PlacementEngine for IndexedEngine {
    fn kind(&self) -> PlacementEngineKind {
        PlacementEngineKind::Indexed
    }

    fn session_index(&mut self, api: &ApiServer) -> Option<CapacityIndex> {
        // A different API server instance invalidates the cursor: rebuild
        // cold (log length / node count alone cannot distinguish
        // same-shape servers).
        let stale = self.base.is_none() || self.api_id != api.instance_id();
        if stale {
            let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
            self.base = Some(CapacityIndex::build(&api.spec, &free));
        } else {
            let base = self.base.as_mut().unwrap();
            for &node in api.alloc_touched_since(self.cursor) {
                base.set_free(node, api.free_on(node));
            }
        }
        self.api_id = api.instance_id();
        self.cursor = api.alloc_version();
        let base = self.base.as_ref().unwrap();
        #[cfg(debug_assertions)]
        for node in api.spec.node_ids() {
            debug_assert_eq!(
                base.free[node.0],
                api.free_on(node),
                "index free view drifted from the API server on {node:?}"
            );
        }
        Some(base.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{gib, HeterogeneityMix, JobId, PodId};
    use crate::kubelet::KubeletConfig;
    use crate::util::Rng;

    fn worker_pod(cores: u64) -> Pod {
        let mut p = Pod::new(PodId(1), JobId(1), "w".into(), PodRole::Worker { index: 0 });
        p.requests = Resources::new(cores * 1000, cores * gib(2));
        p
    }

    fn launcher_pod() -> Pod {
        let mut p = Pod::new(PodId(2), JobId(1), "l".into(), PodRole::Launcher);
        p.requests = Resources::new(100, gib(1));
        p
    }

    #[test]
    fn engine_kind_names_round_trip() {
        for kind in ALL_PLACEMENT_ENGINES {
            assert_eq!(PlacementEngineKind::parse(kind.name()), Some(kind));
            assert_eq!(kind.build().kind(), kind);
        }
        assert_eq!(PlacementEngineKind::parse("INDEXED"), Some(PlacementEngineKind::Indexed));
        assert_eq!(PlacementEngineKind::parse("scan"), Some(PlacementEngineKind::Linear));
        assert_eq!(PlacementEngineKind::parse("nope"), None);
    }

    #[test]
    fn index_enumerates_exactly_the_linear_feasible_set() {
        let api = ApiServer::new(
            ClusterSpec::mixed(8, HeterogeneityMix::Tiered),
            KubeletConfig::cpu_mem_affinity(),
        );
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let index = CapacityIndex::build(&api.spec, &free);
        let mut got = Vec::new();
        let mut want = Vec::new();
        for cores in [1u64, 8, 16, 32, 64, 128] {
            let pod = worker_pod(cores);
            index.feasible_into(&pod, &mut got);
            linear_feasible_into(&api, &free, &pod, &mut want);
            assert_eq!(got, want, "{cores} cores");
        }
        let pod = launcher_pod();
        index.feasible_into(&pod, &mut got);
        linear_feasible_into(&api, &free, &pod, &mut want);
        assert_eq!(got, want, "launcher role-constrained to the control plane");
    }

    /// Property: under random set_free churn, the index stays equal to the
    /// linear reference for random requests.
    #[test]
    fn prop_index_matches_linear_under_random_churn() {
        let mut rng = Rng::seed_from_u64(77);
        for case in 0..30u64 {
            let mix = [
                HeterogeneityMix::Uniform,
                HeterogeneityMix::FatThin,
                HeterogeneityMix::Tiered,
            ][rng.range_usize(0, 3)];
            let workers = rng.range_usize(1, 12);
            let api = ApiServer::new(
                ClusterSpec::mixed(workers, mix),
                KubeletConfig::cpu_mem_affinity(),
            );
            let mut free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
            let mut index = CapacityIndex::build(&api.spec, &free);
            let mut got = Vec::new();
            let mut want = Vec::new();
            for _ in 0..60 {
                // Mutate one node's free capacity within its allocatable.
                let node = NodeId(rng.range_usize(0, free.len()));
                let alloc = api.spec.node(node).allocatable();
                let new = Resources::new(
                    rng.range_usize(0, alloc.cpu_milli as usize + 1) as u64,
                    rng.range_usize(0, alloc.mem_bytes as usize + 1) as u64,
                );
                free[node.0] = new;
                index.set_free(node, new);
                let pod = worker_pod(rng.range_usize(1, 65) as u64);
                index.feasible_into(&pod, &mut got);
                linear_feasible_into(&api, &free, &pod, &mut want);
                assert_eq!(got, want, "case {case}");
            }
            assert_eq!(index.free_view(), free.as_slice(), "case {case}: mirror drift");
        }
    }

    #[test]
    fn indexed_engine_replays_the_alloc_log_incrementally() {
        let mut api = ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity());
        let mut engine = IndexedEngine::new();
        let idle = engine.session_index(&api).unwrap();
        for n in api.spec.node_ids() {
            assert_eq!(idle.free_view()[n.0], api.free_on(n));
        }
        // Bind a pod out-of-band; the next session must see it via the log.
        use crate::workload::{Benchmark, Granularity, JobSpec, PlannedJob};
        let planned = PlannedJob {
            spec: JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0),
            granularity: Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 },
        };
        let mut pod = worker_pod(16);
        pod.id = api.fresh_pod_id();
        pod.job = JobId(1);
        let pid = pod.id;
        api.create_job(planned, vec![pod], vec![], 0.0);
        assert!(api.bind_pod(pid, NodeId(1), 0.0));
        let loaded = engine.session_index(&api).unwrap();
        for n in api.spec.node_ids() {
            assert_eq!(loaded.free_view()[n.0], api.free_on(n), "replayed node {n:?}");
        }
    }
}
