//! Infrastructure-layer scheduler — a Volcano-style scheduling framework
//! with pluggable gang admission, filtering (PredicateFn) and scoring
//! (NodeOrderFn), hosting the paper's task-group plugin (Algorithms 3–4)
//! next to the baseline policies (stock Volcano gang, Kubernetes default).
//!
//! Each [`Scheduler::cycle`] is one Volcano session: snapshot free
//! resources, walk the pending-job queue FIFO, and for each job place its
//! pods (gang: all-or-nothing on a trial state; no-gang: individually).

pub mod score;
pub mod taskgroup;

use std::collections::BTreeMap;

use crate::apiserver::ApiServer;
use crate::cluster::{JobId, NodeId, NodeRole, Pod, PodId, PodPhase, Resources};
use crate::util::Rng;

pub use score::{least_requested, taskgroup_score, GroupKey, GroupPlacement};
pub use taskgroup::{build_groups, group_assignment, worker_order, TaskGroup};

/// Scheduler profile (paper Table II "Volcano" column + §V-E frameworks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerConfig {
    /// Volcano gang plugin: a job starts only when every pod is placeable.
    pub gang: bool,
    /// The paper's task-group plugin (Algorithms 3–4).
    pub taskgroup: bool,
    /// Seed for the default scheduler's random tie-breaking.
    pub seed: u64,
}

impl SchedulerConfig {
    /// Stock Volcano: gang only (baseline NONE/CM/CM_S/CM_G scenarios).
    pub fn volcano_default(seed: u64) -> Self {
        SchedulerConfig { gang: true, taskgroup: false, seed }
    }

    /// The paper's fine-grained scheduler: gang + task-group.
    pub fn fine_grained(seed: u64) -> Self {
        SchedulerConfig { gang: true, taskgroup: true, seed }
    }

    /// Kubernetes default scheduler (Kubeflow baseline): per-pod, no gang.
    pub fn kube_default(seed: u64) -> Self {
        SchedulerConfig { gang: false, taskgroup: false, seed }
    }
}

pub struct Scheduler {
    pub config: SchedulerConfig,
    rng: Rng,
}

/// Trial state for one scheduling session (mutated as binds are decided,
/// committed to the API server only when the gang succeeds). Gang
/// all-or-nothing is implemented with an undo log instead of cloning the
/// whole state per job (§Perf: the clone dominated large sessions).
struct SessionState {
    free: Vec<Resources>,
    placement: GroupPlacement,
    /// Undo log of (pod requests, node, group) applied since the last
    /// checkpoint; replayed backwards on gang failure.
    log: Vec<(Resources, NodeId, Option<GroupKey>)>,
}

impl SessionState {
    fn apply(&mut self, requests: Resources, node: NodeId, group: Option<GroupKey>) {
        self.free[node.0] -= requests;
        if let Some(key) = group {
            self.placement.record(key, node);
        }
        self.log.push((requests, node, group));
    }

    fn checkpoint(&self) -> usize {
        self.log.len()
    }

    fn rollback_to(&mut self, checkpoint: usize) {
        while self.log.len() > checkpoint {
            let (requests, node, group) = self.log.pop().unwrap();
            self.free[node.0] += requests;
            if let Some(key) = group {
                self.placement.remove(key, node);
            }
        }
    }
}

impl Scheduler {
    pub fn new(config: SchedulerConfig) -> Scheduler {
        Scheduler { config, rng: Rng::seed_from_u64(config.seed) }
    }

    /// Rebuild the cluster-wide group-placement view from bound/running
    /// pods (groups only exist for jobs scheduled by the task-group
    /// plugin).
    fn rebuild_placement(api: &ApiServer) -> GroupPlacement {
        let mut p = GroupPlacement::default();
        for pod in api.pods.values() {
            if matches!(pod.phase, PodPhase::Bound | PodPhase::Running) {
                if let (Some(group), Some(node)) = (pod.group, pod.node) {
                    p.record((pod.job, group), node);
                }
            }
        }
        p
    }

    /// PredicateFn: feasibility filter for one pod on one node (role
    /// constraint + resource fit against the session's free view).
    fn predicate(api: &ApiServer, state: &SessionState, pod: &Pod, node: NodeId) -> bool {
        let role_ok = match pod.role {
            crate::cluster::PodRole::Launcher => {
                api.spec.node(node).role == NodeRole::ControlPlane
            }
            crate::cluster::PodRole::Worker { .. } => {
                api.spec.node(node).role == NodeRole::Worker
            }
        };
        role_ok && pod.requests.fits_within(&state.free[node.0])
    }

    /// NodeOrderFn: composite score. The task-group term (Algorithm 4)
    /// dominates when enabled; the default scheduler's integer-quantized
    /// LeastRequested + random tie-break reproduces upstream behaviour
    /// (near-equal nodes are chosen effectively at random — the paper's
    /// "the scheduler randomly chooses the nodes").
    fn node_score(
        &mut self,
        api: &ApiServer,
        state: &SessionState,
        _pod: &Pod,
        group: Option<(GroupKey, usize)>,
        node: NodeId,
    ) -> f64 {
        let mut score = 0.0;
        if let Some((key, group_len)) = group {
            score += 10.0 * taskgroup_score(&state.placement, key, group_len, node);
        }
        // Stock Volcano / default-scheduler behaviour: near-equal nodes
        // are picked effectively at random (the paper: "by default the
        // scheduler randomly chooses the nodes to deploy the pods within a
        // same job") — jitter dominates unless utilization differs a lot.
        let lr = least_requested(&state.free[node.0], &api.spec.node(node).allocatable());
        score += lr * 0.2;
        score + self.rng.f64() * 3.0
    }

    /// Place one pod on the best feasible node in the session state.
    fn place_pod(
        &mut self,
        api: &ApiServer,
        state: &mut SessionState,
        pod: &Pod,
        group: Option<(GroupKey, usize)>,
    ) -> Option<NodeId> {
        let mut best: Option<(f64, NodeId)> = None;
        for node in api.spec.node_ids() {
            if !Self::predicate(api, state, pod, node) {
                continue;
            }
            let s = self.node_score(api, state, pod, group, node);
            if best.map(|(bs, _)| s > bs).unwrap_or(true) {
                best = Some((s, node));
            }
        }
        let (_, node) = best?;
        state.apply(pod.requests, node, group.map(|(key, _)| key));
        Some(node)
    }

    /// Plan the bindings for one job on the trial state. Returns the
    /// per-pod (pod, node, group) decisions, or None if some pod cannot be
    /// placed (gang failure).
    fn plan_job(
        &mut self,
        api: &ApiServer,
        state: &mut SessionState,
        job_id: JobId,
    ) -> Option<Vec<(PodId, NodeId, Option<usize>)>> {
        let job = &api.jobs[&job_id];
        let pending_pods: Vec<&Pod> = job
            .pods
            .iter()
            .map(|pid| &api.pods[pid])
            .filter(|p| p.phase == PodPhase::Pending)
            .collect();

        // Worker ordering + group assignment (Algorithm 3 step 1 +
        // WorkerOrderFn) under the task-group plugin; plain index order
        // otherwise.
        let workers: Vec<&Pod> = pending_pods.iter().copied().filter(|p| p.is_worker()).collect();
        let (order, group_of): (Vec<PodId>, BTreeMap<PodId, usize>) = if self.config.taskgroup {
            let n_groups = job.planned.granularity.n_groups.max(1) as usize;
            let groups = build_groups(&workers, n_groups.min(workers.len().max(1)));
            let order = worker_order(&groups);
            let assignment = group_assignment(&groups).into_iter().collect();
            (order, assignment)
        } else {
            (workers.iter().map(|p| p.id).collect(), BTreeMap::new())
        };

        let group_len: BTreeMap<usize, usize> = {
            let mut m: BTreeMap<usize, usize> = BTreeMap::new();
            for g in group_of.values() {
                *m.entry(*g).or_insert(0) += 1;
            }
            m
        };

        let mut binds = Vec::with_capacity(pending_pods.len());
        // Step 2 of Algorithm 3: predicate + priority for each worker, in
        // WorkerOrderFn order.
        for pid in &order {
            let pod = &api.pods[pid];
            let group = group_of
                .get(pid)
                .map(|&g| (((job_id, g)) as GroupKey, group_len[&g]));
            match self.place_pod(api, state, pod, group) {
                Some(node) => binds.push((*pid, node, group_of.get(pid).copied())),
                None => return None,
            }
        }
        // Launchers (and any non-worker pods) placed last.
        for pod in pending_pods.iter().filter(|p| !p.is_worker()) {
            match self.place_pod(api, state, pod, None) {
                Some(node) => binds.push((pod.id, node, None)),
                None => return None,
            }
        }
        Some(binds)
    }

    /// One scheduling session. Returns the jobs started in this cycle.
    pub fn cycle(&mut self, api: &mut ApiServer, now: f64) -> Vec<JobId> {
        let mut started = Vec::new();
        let mut state = SessionState {
            free: api.spec.node_ids().map(|n| api.free_on(n)).collect(),
            placement: Self::rebuild_placement(api),
            log: Vec::new(),
        };

        for job_id in api.pending_jobs() {
            if self.config.gang {
                // All-or-nothing: plan against the live state, roll back the
                // undo log on failure.
                let checkpoint = state.checkpoint();
                match self.plan_job(api, &mut state, job_id) {
                    Some(binds) => {
                        for (pid, node, group) in binds {
                            if let Some(g) = group {
                                api.pods.get_mut(&pid).unwrap().group = Some(g);
                            }
                            let ok = api.bind_pod(pid, node, now);
                            assert!(ok, "kubelet admission failed after predicate pass");
                        }
                        api.start_job(job_id, now);
                        started.push(job_id);
                    }
                    None => {
                        state.rollback_to(checkpoint);
                        continue; // job stays pending; try later jobs
                    }
                }
            } else {
                // Kubernetes default: bind pods individually as they fit.
                let pending: Vec<PodId> = api.jobs[&job_id]
                    .pods
                    .iter()
                    .filter(|pid| api.pods[pid].phase == PodPhase::Pending)
                    .copied()
                    .collect();
                for pid in pending {
                    let pod = api.pods[&pid].clone();
                    if let Some(node) = self.place_pod(api, &mut state, &pod, None) {
                        let ok = api.bind_pod(pid, node, now);
                        assert!(ok, "kubelet admission failed after predicate pass");
                    }
                }
                let all_bound = api.jobs[&job_id]
                    .pods
                    .iter()
                    .all(|pid| api.pods[pid].phase == PodPhase::Bound);
                if all_bound {
                    api.start_job(job_id, now);
                    started.push(job_id);
                }
            }
        }
        started
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::controller::{JobController, NativeVolcanoController, VolcanoMpiController};
    use crate::kubelet::KubeletConfig;
    use crate::planner::{plan, GranularityPolicy, SystemInfo};
    use crate::workload::{Benchmark, JobSpec};

    fn submit(
        api: &mut ApiServer,
        controller: &dyn JobController,
        policy: GranularityPolicy,
        id: u64,
        bench: Benchmark,
    ) -> JobId {
        let spec = JobSpec::paper_job(id, bench, 0.0);
        let info = SystemInfo { available_nodes: api.spec.worker_count() as u32 };
        let planned = plan(&spec, policy, info);
        let job_id = planned.spec.id;
        let (pods, hostfile) = controller.build(&planned, api);
        api.create_job(planned, pods, hostfile, 0.0);
        job_id
    }

    fn api() -> ApiServer {
        ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity())
    }

    #[test]
    fn baseline_schedules_single_worker_job() {
        let mut api = api();
        let job = submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, 1, Benchmark::EpDgemm);
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(1));
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started, vec![job]);
        let workers = api.worker_pods_of(job);
        assert_eq!(workers.len(), 1);
        assert!(api.spec.node(workers[0].node.unwrap()).role == NodeRole::Worker);
        // Launcher landed on the control plane.
        let launcher = api.pods.values().find(|p| !p.is_worker()).unwrap();
        assert_eq!(launcher.node, Some(api.spec.control_plane_id()));
    }

    #[test]
    fn taskgroup_spreads_scale_job_one_worker_per_node() {
        let mut api = api();
        let job = submit(&mut api, &VolcanoMpiController, GranularityPolicy::Scale, 1, Benchmark::EpDgemm);
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        let mut nodes: Vec<usize> =
            api.worker_pods_of(job).iter().map(|p| p.node.unwrap().0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 4, "4 workers must land on 4 distinct nodes");
    }

    #[test]
    fn taskgroup_accretes_granularity_groups_per_node() {
        let mut api = api();
        let job = submit(
            &mut api,
            &VolcanoMpiController,
            GranularityPolicy::Granularity,
            1,
            Benchmark::EpDgemm,
        );
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        // 16 single-task workers in 4 groups: each node gets exactly one
        // group of 4 workers.
        let mut per_node: BTreeMap<usize, u32> = BTreeMap::new();
        for p in api.worker_pods_of(job) {
            *per_node.entry(p.node.unwrap().0).or_insert(0) += p.ntasks;
        }
        let counts: Vec<u32> = per_node.values().copied().collect();
        assert_eq!(counts, vec![4, 4, 4, 4], "{per_node:?}");
        // And group assignments were committed to the pods.
        assert!(api.worker_pods_of(job).iter().all(|p| p.group.is_some()));
    }

    #[test]
    fn gang_holds_job_until_capacity_frees() {
        let mut api = api();
        // Fill the cluster with 8 × 16-core single-worker jobs.
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(1));
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        // A ninth job cannot gang-start.
        let nine = submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, 9, Benchmark::EpDgemm);
        assert!(sched.cycle(&mut api, 1.0).is_empty());
        assert_eq!(api.pending_jobs(), vec![nine]);
        // No partial binding happened (gang all-or-nothing).
        assert!(api.jobs[&nine]
            .pods
            .iter()
            .all(|pid| api.pods[pid].phase == PodPhase::Pending));
        // Finish one job; the queued one starts on the next cycle.
        api.finish_job(JobId(1), 2.0);
        assert_eq!(sched.cycle(&mut api, 2.0), vec![nine]);
    }

    #[test]
    fn no_gang_binds_partially() {
        let mut api = api();
        // Fill all worker nodes.
        let mut gang = Scheduler::new(SchedulerConfig::volcano_default(1));
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        gang.cycle(&mut api, 0.0);
        // Kubeflow-style job: launcher fits (control plane), worker does not.
        let job = submit(&mut api, &crate::controller::KubeflowController, GranularityPolicy::None, 9, Benchmark::EpDgemm);
        let mut kube = Scheduler::new(SchedulerConfig::kube_default(2));
        assert!(kube.cycle(&mut api, 1.0).is_empty());
        let phases: Vec<PodPhase> =
            api.jobs[&job].pods.iter().map(|pid| api.pods[pid].phase).collect();
        assert!(
            phases.contains(&PodPhase::Bound) && phases.contains(&PodPhase::Pending),
            "{phases:?}"
        );
    }

    #[test]
    fn native_volcano_scatters_sixteen_containers() {
        let mut api = api();
        let job = submit(&mut api, &NativeVolcanoController, GranularityPolicy::None, 1, Benchmark::GRandomRing);
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(7));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        let workers = api.worker_pods_of(job);
        assert_eq!(workers.len(), 16);
        let mut nodes: Vec<usize> = workers.iter().map(|p| p.node.unwrap().0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert!(nodes.len() > 1, "stock spreading must scatter the containers");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut api = api();
            for i in 1..=4 {
                submit(&mut api, &VolcanoMpiController, GranularityPolicy::Scale, i, Benchmark::EpStream);
            }
            let mut sched = Scheduler::new(SchedulerConfig::fine_grained(seed));
            sched.cycle(&mut api, 0.0);
            api.pods
                .values()
                .map(|p| (p.id, p.node.map(|n| n.0)))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
    }
}
