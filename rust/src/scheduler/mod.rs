//! Infrastructure-layer scheduler — a Volcano-style scheduling framework
//! with pluggable gang admission, filtering (PredicateFn) and scoring
//! (NodeOrderFn), hosting the paper's task-group plugin (Algorithms 3–4)
//! next to the baseline policies (stock Volcano gang, Kubernetes default).
//!
//! This is the lower half of the paper's two-layer contribution: the
//! application-layer planner ([`crate::planner`]) picks each job's
//! granularity, a controller materializes the pods, and this layer
//! decides *where* they run — under gang semantics, a queue discipline
//! ([`queue`]), optional priority preemption, and node-class-aware
//! scoring on heterogeneous clusters (best-fit across fat/thin/balanced
//! classes, so wide pods keep fat nodes available).
//!
//! Each [`Scheduler::cycle`] is one Volcano session: snapshot free
//! resources, walk the pending-job queue in the [`QueuePolicy`]'s order,
//! and for each job place its pods (gang: all-or-nothing on a trial
//! state; no-gang: individually). The queue policy decides what a gang
//! failure means — skip (seed behaviour), block, an EASY shadow-time
//! reservation, or a claim on the conservative per-resource
//! [`ResourceTimeline`] (see [`queue`]).
//!
//! Two session structures are maintained incrementally instead of rebuilt
//! (§Perf), each pinned bit-identical to a from-scratch reference by
//! property tests and debug asserts: the feasibility enumeration lives in
//! the [`placement`] engine (per-class free-capacity buckets replayed
//! from the API server's allocation-touch log vs. the linear scan), and
//! the conservative backfill's [`ResourceTimeline`] persists across
//! sessions in a [`TimelineCache`] (event-driven invalidation vs. the
//! per-session rebuild).

pub mod pipeline;
pub mod placement;
pub mod queue;
pub mod score;
pub mod taskgroup;

use std::collections::BTreeMap;

use crate::apiserver::ApiServer;
use crate::cluster::{JobId, NodeId, Pod, PodId, PodPhase, Resources};
use crate::perfmodel::Calibration;
use crate::util::Rng;

use placement::SessionState;

pub use pipeline::{
    ActionKind, ActionList, AgingConfig, AgingPlugin, BudgetConfig, BudgetPlugin,
    ElasticityConfig, ElasticityMode, ElasticityPlugin, PipelineConfig, Plugin, PluginSet,
    QuotaPlugin, ALL_ACTIONS,
};
pub use placement::{
    CapacityIndex, IndexedEngine, LinearEngine, PlacementEngine, PlacementEngineKind,
    ALL_PLACEMENT_ENGINES,
};
pub use queue::{
    estimated_completions, estimated_runtime, first_fit_assignment, job_fits, shadow_time,
    ConservativeBackfill, EasyBackfill, FairShare, FifoSkip, FifoStrict, GangDecision,
    QueueContext, QueuePolicy, QueuePolicyKind, ResourceTimeline, Sjf, TimelineCache,
    ALL_QUEUE_POLICIES,
};
pub use score::{least_requested, taskgroup_score, GroupKey, GroupPlacement};
pub use taskgroup::{build_groups, group_assignment, worker_order, TaskGroup};

/// Scheduler-throughput counters, accumulated across every session of a
/// [`Scheduler`]'s lifetime: how many sessions ran and how many placement
/// decisions (jobs started) they committed. The simulator copies them
/// into [`crate::simulator::SimOutput`] so benches can report
/// sessions/sec and decisions/sec (`placement_bench.json` in CI tracks
/// the trajectory). Counters never feed back into scheduling, so they
/// cannot perturb any pinned digest.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedulerStats {
    /// Scheduling sessions run (one per `cycle`/`cycle_with_projections`).
    pub sessions: u64,
    /// Jobs started across all sessions (gang commits + per-pod starts).
    pub decisions: u64,
}

/// Victim-selection policy for priority preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PreemptionPolicy {
    /// Cheapest victims by (priority, usefulness, latest start): the
    /// historical default.
    MinimalVictim,
    /// Cost-aware: prefer the victim losing the least work — score =
    /// service invested so far (completed stints + the current one) plus
    /// the calibrated checkpoint-restart cost of its memory image.
    LeastWorkLost,
}

impl PreemptionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PreemptionPolicy::MinimalVictim => "minimal_victim",
            PreemptionPolicy::LeastWorkLost => "least_work_lost",
        }
    }

    /// Parse a CLI/config spelling (case-insensitive, `-` tolerated).
    pub fn parse(s: &str) -> Option<PreemptionPolicy> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "minimal_victim" | "minimal" => Some(PreemptionPolicy::MinimalVictim),
            "least_work_lost" | "work_lost" | "cost_aware" => {
                Some(PreemptionPolicy::LeastWorkLost)
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for PreemptionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Scheduler profile (paper Table II "Volcano" column + §V-E frameworks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedulerConfig {
    /// Volcano gang plugin: a job starts only when every pod is placeable.
    pub gang: bool,
    /// The paper's task-group plugin (Algorithms 3–4).
    pub taskgroup: bool,
    /// Queue discipline for the pending-job walk.
    pub queue: QueuePolicyKind,
    /// Priority preemption: a gang-blocked job may evict a minimal set of
    /// strictly-lower-priority running jobs (requires `gang`).
    pub preemption: bool,
    /// Victim-selection policy when preemption is enabled.
    pub preemption_policy: PreemptionPolicy,
    /// Placement engine. The indexed default is bit-identical to the
    /// linear reference scan (property-pinned); `linear` exists for
    /// before/after benches and as the pinned reference.
    pub engine: PlacementEngineKind,
    /// Multiplier on the queue layer's walltime *estimates* only (the
    /// misprediction model — user-supplied walltimes are rarely exact).
    /// Actual runtimes are untouched; SJF/fair-share orderings are
    /// scale-invariant, so the knob bites on backfill windows and
    /// conservative reservations.
    pub walltime_error_factor: f64,
    /// The action/plugin pipeline a session runs (ordered actions plus
    /// the optional tier-1 plugins). The default is legacy-equivalent:
    /// all five actions in canonical order, no optional plugins —
    /// pinned bit-identical to the retired monolithic loop by
    /// `tests/differential.rs`.
    pub pipeline: PipelineConfig,
    /// Seed for the default scheduler's random tie-breaking.
    pub seed: u64,
}

impl SchedulerConfig {
    /// Stock Volcano: gang only (baseline NONE/CM/CM_S/CM_G scenarios).
    pub fn volcano_default(seed: u64) -> Self {
        SchedulerConfig {
            gang: true,
            taskgroup: false,
            queue: QueuePolicyKind::FifoSkip,
            preemption: false,
            preemption_policy: PreemptionPolicy::MinimalVictim,
            engine: PlacementEngineKind::Indexed,
            walltime_error_factor: 1.0,
            pipeline: PipelineConfig::legacy_equivalent(),
            seed,
        }
    }

    /// The paper's fine-grained scheduler: gang + task-group.
    pub fn fine_grained(seed: u64) -> Self {
        SchedulerConfig { taskgroup: true, ..SchedulerConfig::volcano_default(seed) }
    }

    /// Kubernetes default scheduler (Kubeflow baseline): per-pod, no gang.
    pub fn kube_default(seed: u64) -> Self {
        SchedulerConfig { gang: false, ..SchedulerConfig::volcano_default(seed) }
    }

    /// Same profile under a different queue discipline.
    pub fn with_queue(mut self, queue: QueuePolicyKind) -> Self {
        self.queue = queue;
        self
    }

    /// Same profile with priority preemption toggled.
    pub fn with_preemption(mut self, preemption: bool) -> Self {
        self.preemption = preemption;
        self
    }

    /// Same profile under a different victim-selection policy.
    pub fn with_preemption_policy(mut self, policy: PreemptionPolicy) -> Self {
        self.preemption_policy = policy;
        self
    }

    /// Same profile under a different placement engine.
    pub fn with_engine(mut self, engine: PlacementEngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Same profile under a different walltime-estimate error factor.
    pub fn with_walltime_error_factor(mut self, factor: f64) -> Self {
        assert!(factor > 0.0, "walltime_error_factor must be positive");
        self.walltime_error_factor = factor;
        self
    }

    /// Same profile under a different action/plugin pipeline. Panics on
    /// a structurally invalid pipeline (config files surface the same
    /// error through `PipelineConfig::validate` instead).
    pub fn with_pipeline(mut self, pipeline: PipelineConfig) -> Self {
        if let Err(e) = pipeline.validate() {
            panic!("invalid pipeline config: {e}");
        }
        self.pipeline = pipeline;
        self
    }
}

pub struct Scheduler {
    pub config: SchedulerConfig,
    rng: Rng,
    queue_policy: Box<dyn QueuePolicy>,
    /// Placement engine (feasibility enumeration): indexed by default,
    /// linear reference on request — selections are bit-identical.
    engine: Box<dyn PlacementEngine>,
    /// Persistent conservative-backfill release profile, refreshed
    /// event-driven at each conservative session's first gang failure
    /// (None until one happens; non-conservative disciplines never pay).
    timeline_cache: Option<TimelineCache>,
    /// Rebuild the [`ResourceTimeline`] from scratch every session — the
    /// pre-incremental reference path benches and property tests compare
    /// against.
    pub force_timeline_rebuild: bool,
    /// Run the retired monolithic session loop ([`Scheduler::cycle_legacy`])
    /// instead of the action pipeline — the pinned reference path the
    /// differential harness and the fuzz property compare against.
    pub force_legacy_scheduler: bool,
    /// Answer every conservative-backfill earliest-fit query through the
    /// retained linear scan ([`ResourceTimeline::earliest_fit_linear`])
    /// instead of the segment-tree default — the pinned reference path
    /// benches and property tests compare against.
    pub force_linear_earliest_fit: bool,
    /// Session/decision throughput counters (see [`SchedulerStats`]).
    pub stats: SchedulerStats,
    /// The session's plugin registry (tiers consulted in order), built
    /// from `config.pipeline`; [`Scheduler::register_plugin`] extends it.
    plugins: PluginSet,
    /// Jobs evicted by priority preemption since the last
    /// [`Scheduler::take_preempted`] call (the simulator drains this after
    /// every cycle and re-queues them with checkpoint-restart cost).
    preempted: Vec<JobId>,
    /// Runtime resizes `(job, moved memory bytes)` committed since the last
    /// [`Scheduler::take_resized`] call — the simulator drains this after
    /// every cycle, charges the calibrated resize (checkpoint/restart) cost
    /// and re-derives the jobs' interference rates at their new widths.
    resized: Vec<(JobId, u64)>,
    /// Scratch buffer for per-pod feasible candidates (reused across
    /// `place_pod` calls so the hot loop stays allocation-free).
    candidates: Vec<NodeId>,
}

impl Scheduler {
    pub fn new(config: SchedulerConfig) -> Scheduler {
        Scheduler {
            config,
            rng: Rng::seed_from_u64(config.seed),
            queue_policy: config.queue.build(),
            engine: config.engine.build(),
            timeline_cache: None,
            force_timeline_rebuild: false,
            force_legacy_scheduler: false,
            force_linear_earliest_fit: false,
            stats: SchedulerStats::default(),
            plugins: PluginSet::from_config(&config.pipeline),
            preempted: Vec::new(),
            resized: Vec::new(),
            candidates: Vec::new(),
        }
    }

    /// Register an extra plugin at the given tier (tier 0 = core
    /// admission, tier 1 = policy). The built-in registry from
    /// `config.pipeline` is kept; callers extend it — the reclaim
    /// action's nominations, for instance, only ever come from here.
    pub fn register_plugin(&mut self, tier: usize, plugin: Box<dyn Plugin>) {
        self.plugins.register(tier, plugin);
    }

    /// Swap the placement engine (benches/tests toggle the linear
    /// reference vs the indexed default; outputs are bit-identical).
    pub fn set_engine(&mut self, kind: PlacementEngineKind) {
        self.config.engine = kind;
        self.engine = kind.build();
    }

    /// Drain the jobs preempted by the most recent cycle(s). The simulator
    /// calls this after every session; standalone callers that enable
    /// preemption must re-queue the drained jobs themselves
    /// (`ApiServer::requeue_job`).
    pub fn take_preempted(&mut self) -> Vec<JobId> {
        std::mem::take(&mut self.preempted)
    }

    /// Drain the `(job, moved memory bytes)` resize commits from the most
    /// recent cycle(s). Always empty unless the pipeline runs with an
    /// `elasticity` plugin — the rigid path never resizes.
    pub fn take_resized(&mut self) -> Vec<(JobId, u64)> {
        std::mem::take(&mut self.resized)
    }

    /// Reference implementation: rebuild the cluster-wide group-placement
    /// view by scanning every pod (groups only exist for jobs scheduled by
    /// the task-group plugin). Sessions use the API server's incrementally
    /// maintained [`ApiServer::group_placement`] instead (§Perf: this scan
    /// touches every pod ever created — including succeeded ones — once
    /// per session); a property test pins the two views equal.
    pub fn rebuild_placement(api: &ApiServer) -> GroupPlacement {
        let mut p = GroupPlacement::default();
        for pod in api.pods.values() {
            if matches!(pod.phase, PodPhase::Bound | PodPhase::Running) {
                if let (Some(group), Some(node)) = (pod.group, pod.node) {
                    p.record((pod.job, group), node);
                }
            }
        }
        p
    }

    /// NodeOrderFn: composite score. The task-group term (Algorithm 4)
    /// dominates when enabled; the default scheduler's integer-quantized
    /// LeastRequested + random tie-break reproduces upstream behaviour
    /// (near-equal nodes are chosen effectively at random — the paper's
    /// "the scheduler randomly chooses the nodes").
    fn node_score(
        &mut self,
        api: &ApiServer,
        state: &SessionState,
        _pod: &Pod,
        group: Option<(GroupKey, usize)>,
        node: NodeId,
    ) -> f64 {
        let mut score = 0.0;
        if let Some((key, group_len)) = group {
            score += 10.0 * taskgroup_score(&state.placement, key, group_len, node);
        }
        // Stock Volcano / default-scheduler behaviour: near-equal nodes
        // are picked effectively at random (the paper: "by default the
        // scheduler randomly chooses the nodes to deploy the pods within a
        // same job") — jitter dominates unless utilization differs a lot.
        let lr = least_requested(&state.free[node.0], &api.spec.node(node).allocatable());
        score += lr * 0.2;
        // Class-aware best-fit on heterogeneous clusters: prefer the
        // smallest node class that fits, preserving fat nodes for wide
        // pods. On homogeneous clusters this subtracts the same constant
        // from every feasible worker node and changes nothing.
        if state.max_worker_cpu > 0 {
            let alloc = api.spec.node(node).allocatable().cpu_milli as f64;
            score -= 2.0 * alloc / state.max_worker_cpu as f64;
        }
        score + self.rng.f64() * 3.0
    }

    /// Place one pod on the best feasible node in the session state. The
    /// placement engine enumerates the feasible set (indexed: a per-class
    /// range scan; linear: the reference predicate walk) in ascending node
    /// order, so the RNG jitter stream — one draw per feasible node — is
    /// identical across engines and so is the argmax.
    fn place_pod(
        &mut self,
        api: &ApiServer,
        state: &mut SessionState,
        pod: &Pod,
        group: Option<(GroupKey, usize)>,
    ) -> Option<NodeId> {
        let mut candidates = std::mem::take(&mut self.candidates);
        state.feasible_into(api, pod, &mut candidates);
        let mut best: Option<(f64, NodeId)> = None;
        for &node in &candidates {
            let s = self.node_score(api, state, pod, group, node);
            if best.map(|(bs, _)| s > bs).unwrap_or(true) {
                best = Some((s, node));
            }
        }
        self.candidates = candidates;
        let (_, node) = best?;
        state.apply(pod.requests, node, group.map(|(key, _)| key));
        Some(node)
    }

    /// Plan the bindings for one job on the trial state. Returns the
    /// per-pod (pod, node, group) decisions, or None if some pod cannot be
    /// placed (gang failure).
    fn plan_job(
        &mut self,
        api: &ApiServer,
        state: &mut SessionState,
        job_id: JobId,
    ) -> Option<Vec<(PodId, NodeId, Option<usize>)>> {
        let job = &api.jobs[&job_id];
        let pending_pods: Vec<&Pod> = job
            .pods
            .iter()
            .map(|pid| &api.pods[pid])
            .filter(|p| p.phase == PodPhase::Pending)
            .collect();

        // Worker ordering + group assignment (Algorithm 3 step 1 +
        // WorkerOrderFn) under the task-group plugin; plain index order
        // otherwise.
        let workers: Vec<&Pod> = pending_pods.iter().copied().filter(|p| p.is_worker()).collect();
        let (order, group_of): (Vec<PodId>, BTreeMap<PodId, usize>) = if self.config.taskgroup {
            let n_groups = job.planned.granularity.n_groups.max(1) as usize;
            let groups = build_groups(&workers, n_groups.min(workers.len().max(1)));
            let order = worker_order(&groups);
            let assignment = group_assignment(&groups).into_iter().collect();
            (order, assignment)
        } else {
            (workers.iter().map(|p| p.id).collect(), BTreeMap::new())
        };

        let group_len: BTreeMap<usize, usize> = {
            let mut m: BTreeMap<usize, usize> = BTreeMap::new();
            for g in group_of.values() {
                *m.entry(*g).or_insert(0) += 1;
            }
            m
        };

        let mut binds = Vec::with_capacity(pending_pods.len());
        // Step 2 of Algorithm 3: predicate + priority for each worker, in
        // WorkerOrderFn order.
        for pid in &order {
            let pod = &api.pods[pid];
            let group = group_of.get(pid).map(|&g| ((job_id, g), group_len[&g]));
            match self.place_pod(api, state, pod, group) {
                Some(node) => binds.push((*pid, node, group_of.get(pid).copied())),
                None => return None,
            }
        }
        // Launchers (and any non-worker pods) placed last.
        for pod in pending_pods.iter().filter(|p| !p.is_worker()) {
            match self.place_pod(api, state, pod, None) {
                Some(node) => binds.push((pod.id, node, None)),
                None => return None,
            }
        }
        Some(binds)
    }

    /// Select a minimal set of running jobs whose eviction would let
    /// `job`'s gang fit the session's free view. Candidates are running
    /// jobs of *strictly lower* priority (never jobs started this
    /// session); cheapest victims first — lowest priority, then usefulness
    /// (victims on nodes the blocked gang can use), then the
    /// [`PreemptionPolicy`] cost order: latest start under
    /// `minimal_victim`, least work lost (service invested + calibrated
    /// restart cost) under `least_work_lost` — then highest id. A
    /// backward pass drops victims whose release turned out unnecessary,
    /// so the returned set is minimal (no proper subset suffices).
    /// Returns `None` when no candidate set makes the gang fit.
    fn select_victims(
        &self,
        api: &ApiServer,
        state: &SessionState,
        job: JobId,
        started: &[JobId],
        now: f64,
        plugins: Option<&mut PluginSet>,
    ) -> Option<Vec<JobId>> {
        // The scored-greedy planner can fail where first-fit succeeds; if
        // the gang already first-fits the session's free view, eviction
        // cannot help — never preempt for nothing.
        if queue::job_fits(api, &state.free, job) {
            return None;
        }
        let priority = api.jobs[&job].planned.spec.priority;
        let mut candidates: Vec<JobId> = api
            .running_jobs()
            .into_iter()
            .filter(|id| api.jobs[id].planned.spec.priority < priority)
            .filter(|id| !started.contains(id))
            .collect();
        // Pipeline victim predicates ([`Plugin::may_evict`]): a vetoed
        // candidate (e.g. its tenant is at its preemption budget) is
        // dropped before selection. The legacy reference path passes no
        // plugins; the default pipeline registers no vetoing plugin, so
        // the candidate set — and everything downstream — is unchanged.
        if let Some(plugins) = plugins {
            candidates.retain(|&id| plugins.may_evict(api, now, id));
        }
        if candidates.is_empty() {
            return None;
        }
        // Class-aware usefulness: a victim only helps if it frees capacity
        // on a node class where the blocked gang's widest pending pod could
        // ever fit. On homogeneous clusters every victim qualifies and the
        // order is unchanged.
        let widest = api.jobs[&job]
            .pods
            .iter()
            .map(|pid| &api.pods[pid])
            .filter(|p| p.phase == PodPhase::Pending)
            .map(|p| p.requests)
            .max_by_key(Resources::sort_key)
            .unwrap_or(Resources::ZERO);
        let useful = |id: &JobId| -> bool {
            api.jobs[id].pods.iter().map(|pid| &api.pods[pid]).any(|p| {
                matches!(p.phase, PodPhase::Bound | PodPhase::Running)
                    && p.node
                        .map(|n| widest.fits_within(&api.spec.node(n).allocatable()))
                        .unwrap_or(false)
            })
        };
        // Precompute each candidate's (priority, usefulness, cost) sort
        // key once — `useful` walks pods and the cost term reads the job
        // map, too much for a per-comparison closure (same convention as
        // SJF's precomputed estimates). The cost term is ascending: under
        // `minimal_victim` it is the negated start time (latest start
        // first — least progress in the current stint); under
        // `least_work_lost` it is the work evicting the victim throws
        // away — service invested across all stints plus the calibrated
        // checkpoint-restart cost of its memory image (the queue layer's
        // default-calibration convention, see `estimated_runtime`).
        let policy = self.config.preemption_policy;
        let calib = Calibration::default();
        let key: BTreeMap<JobId, (u32, bool, f64)> = candidates
            .iter()
            .map(|&id| {
                let j = &api.jobs[&id];
                let cost = match policy {
                    PreemptionPolicy::MinimalVictim => {
                        -j.start_time.unwrap_or(f64::NEG_INFINITY)
                    }
                    PreemptionPolicy::LeastWorkLost => {
                        let stint = (now - j.start_time.unwrap_or(now)).max(0.0);
                        j.served_secs
                            + stint
                            + calib.restart_cost_secs(j.planned.spec.resources.mem_bytes)
                    }
                };
                (id, (j.planned.spec.priority, useful(&id), cost))
            })
            .collect();
        candidates.sort_by(|a, b| {
            let ((pa, ua, ca), (pb, ub, cb)) = (key[a], key[b]);
            pa.cmp(&pb).then(ub.cmp(&ua)).then(ca.total_cmp(&cb)).then(b.cmp(a))
        });
        let release = |free: &mut [Resources], id: JobId| {
            for pid in &api.jobs[&id].pods {
                let pod = &api.pods[pid];
                if let (Some(node), PodPhase::Bound | PodPhase::Running) =
                    (pod.node, pod.phase)
                {
                    free[node.0] += pod.requests;
                }
            }
        };
        let mut free = state.free.clone();
        let mut chosen: Vec<JobId> = Vec::new();
        let mut sufficient = false;
        for &id in &candidates {
            release(&mut free, id);
            chosen.push(id);
            if queue::job_fits(api, &free, job) {
                sufficient = true;
                break;
            }
        }
        if !sufficient {
            return None;
        }
        // Backward minimization: try dropping each victim in turn.
        let mut i = 0;
        while i < chosen.len() && chosen.len() > 1 {
            let mut trial = state.free.clone();
            for (k, &id) in chosen.iter().enumerate() {
                if k != i {
                    release(&mut trial, id);
                }
            }
            if queue::job_fits(api, &trial, job) {
                chosen.remove(i);
            } else {
                i += 1;
            }
        }
        Some(chosen)
    }

    /// Try to place `job` by preemption: pick a minimal victim set
    /// ([`Scheduler::select_victims`]) and plan the gang against a trial
    /// view with the victims' resources released. Returns the victims and
    /// the proven plan, or `None` — in which case nothing was evicted
    /// (the scored-greedy planner may still corner itself where first-fit
    /// succeeds; that failure must never cost a running job its slot).
    fn plan_with_preemption(
        &mut self,
        api: &ApiServer,
        state: &SessionState,
        job: JobId,
        started: &[JobId],
        now: f64,
        plugins: Option<&mut PluginSet>,
    ) -> Option<(Vec<JobId>, Vec<(PodId, NodeId, Option<usize>)>)> {
        let victims = self.select_victims(api, state, job, started, now, plugins)?;
        let mut free = state.free.clone();
        let mut placement = state.placement.clone();
        for &v in &victims {
            for pid in &api.jobs[&v].pods {
                let pod = &api.pods[pid];
                if let (Some(node), PodPhase::Bound | PodPhase::Running) =
                    (pod.node, pod.phase)
                {
                    free[node.0] += pod.requests;
                    if let Some(g) = pod.group {
                        placement.remove((v, g), node);
                    }
                }
            }
        }
        let mut trial = SessionState::new(api, free, placement);
        let binds = self.plan_job(api, &mut trial, job)?;
        Some((victims, binds))
    }

    /// Commit a successful gang plan: persist group assignments, bind
    /// every pod (kubelet admission must succeed after the predicate
    /// pass), and start the job. Shared by the normal gang-success arm
    /// and the post-preemption retry.
    fn commit_gang(
        api: &mut ApiServer,
        binds: Vec<(PodId, NodeId, Option<usize>)>,
        job_id: JobId,
        now: f64,
    ) {
        for (pid, node, group) in binds {
            if let Some(g) = group {
                api.pods.get_mut(&pid).unwrap().group = Some(g);
            }
            let ok = api.bind_pod(pid, node, now);
            assert!(ok, "kubelet admission failed after predicate pass");
        }
        api.start_job(job_id, now);
    }

    /// One scheduling session with base-time completion estimates (callers
    /// with a simulator should prefer [`Scheduler::cycle_with_projections`],
    /// which feeds exact projections to the backfill reservation). The
    /// estimates are only built for policies that read them, so the
    /// default FIFO hot path stays allocation-free here.
    pub fn cycle(&mut self, api: &mut ApiServer, now: f64) -> Vec<JobId> {
        let projected = if self.queue_policy.needs_projections() {
            estimated_completions(api, now, self.config.walltime_error_factor)
        } else {
            BTreeMap::new()
        };
        self.cycle_with_projections(api, now, &projected)
    }

    /// The session's conservative-backfill availability profile: a clone
    /// of the persistently maintained release profile (claims stay on the
    /// clone, so the cache keeps the pure profile), refreshed event-driven
    /// from the API server's event log and the live free view. With
    /// [`Scheduler::force_timeline_rebuild`] set, the from-scratch rebuild
    /// ([`ResourceTimeline::new`]) runs instead — the pinned reference
    /// path. Debug builds assert the refreshed cache equals the rebuild
    /// after every refresh, so the whole test suite exercises the
    /// equivalence on its traces.
    fn session_timeline(&mut self, ctx: &QueueContext<'_>) -> ResourceTimeline {
        if self.force_timeline_rebuild {
            return ResourceTimeline::new(ctx);
        }
        if let Some(cache) = self.timeline_cache.as_mut() {
            cache.refresh(ctx);
        } else {
            self.timeline_cache = Some(TimelineCache::new(ctx));
        }
        let cache = self.timeline_cache.as_ref().unwrap();
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            cache.profile(),
            &ResourceTimeline::new(ctx),
            "persistent timeline drifted from the per-session rebuild"
        );
        cache.session_profile()
    }

    /// One scheduling session: runs the configured action pipeline
    /// ([`pipeline`] — enqueue, then per job allocate → preempt →
    /// reclaim → backfill until one consumes it). EASY holds a single
    /// shadow-time reservation for the first blocked job and gates later
    /// candidates on it; conservative backfilling maintains a full
    /// per-resource [`ResourceTimeline`]: every blocked job claims its
    /// reservation window out of the profile, and later jobs are admitted
    /// (and planned) against what is left, so backfills may use holes
    /// behind reservations yet can never take resources a reservation
    /// counted on. Returns the jobs started in this cycle.
    ///
    /// With [`Scheduler::force_legacy_scheduler`] set, the retired
    /// monolithic loop ([`Scheduler::cycle_legacy`]) runs instead — the
    /// pinned reference the differential harness compares against.
    pub fn cycle_with_projections(
        &mut self,
        api: &mut ApiServer,
        now: f64,
        projected: &BTreeMap<JobId, f64>,
    ) -> Vec<JobId> {
        self.stats.sessions += 1;
        let started = if self.force_legacy_scheduler {
            self.cycle_legacy(api, now, projected)
        } else {
            self.run_pipeline(api, now, projected)
        };
        self.stats.decisions += started.len() as u64;
        started
    }

    /// The retired monolithic session loop, kept verbatim as the pinned
    /// reference for the action pipeline: `tests/differential.rs` and the
    /// fuzz property in `tests/properties.rs` assert the default pipeline
    /// produces bit-identical `SimOutput` (placements, event log, per-job
    /// timings) on every scenario × placement engine × cluster mix. Walks
    /// the pending queue in the queue policy's order; on a gang failure
    /// the scheduler may first attempt priority preemption
    /// (`config.preemption`), then the policy decides what the failure
    /// means — skip the job (seed behaviour), end the session, or hold a
    /// backfill reservation.
    fn cycle_legacy(
        &mut self,
        api: &mut ApiServer,
        now: f64,
        projected: &BTreeMap<JobId, f64>,
    ) -> Vec<JobId> {
        let mut started = Vec::new();
        let wf = self.config.walltime_error_factor;
        // The queue layer's walltime estimate (the single place the
        // misprediction factor is applied — same rule as
        // `QueueContext::estimate`).
        let estimate = |api: &ApiServer, job: JobId| queue::estimated_runtime(api, job) * wf;
        let mut state = SessionState::snapshot(api);
        state.index = self.engine.session_index(api);

        let mut pending = api.pending_jobs();
        self.queue_policy.order(api, now, &mut pending);
        // EASY: shadow time of the single reservation held for the first
        // blocked job of the session.
        let mut reservations: Vec<f64> = Vec::new();
        // Conservative: the per-resource availability profile, cloned from
        // the persistent cache at the session's first gang failure.
        let conservative = self.queue_policy.reserves_every_job();
        let mut timeline: Option<ResourceTimeline> = None;

        for job_id in pending {
            // ResourceQuota admission: a job whose tenant is over quota is
            // held as Pending — it neither plans nor claims a reservation
            // (capacity frees when the tenant's running jobs end).
            if !api.quota_admits(job_id) {
                continue;
            }
            // Conservative sessions holding reservations: the job's whole
            // window must first-fit what the claims left over; the passing
            // (estimate, min-free window) pair is reused by the
            // constrained planning below.
            let mut admitted_window: Option<(f64, Vec<Resources>)> = None;
            if conservative && timeline.is_some() {
                let est = estimate(api, job_id);
                let tl = timeline.as_mut().unwrap();
                let window = tl.min_free_over(now, now + est);
                if !queue::job_fits(api, &window, job_id) {
                    // Window-rejected: hold this job's own reservation at
                    // its earliest profile fit, claiming the window so no
                    // later backfill can push its start back. A fit at
                    // `now` means only the scored-greedy planner can be
                    // cornered — rely on the next session's retry instead
                    // of claiming live resources.
                    if let Some((t_s, placement)) =
                        tl.earliest_fit_forced(api, job_id, est, self.force_linear_earliest_fit)
                    {
                        if t_s > now + 1e-9 {
                            tl.claim(t_s, t_s + est, &placement);
                        }
                    }
                    continue;
                }
                admitted_window = Some((est, window));
            } else if let Some(shadow) = reservations.iter().copied().reduce(f64::min) {
                let ctx = QueueContext {
                    api: &*api,
                    now,
                    projected_completion: projected,
                    free: &state.free,
                    walltime_factor: wf,
                };
                if !self.queue_policy.may_backfill(&ctx, job_id, shadow) {
                    continue;
                }
            }
            if self.config.gang {
                // All-or-nothing. A conservative session holding
                // reservations plans against the window-constrained free
                // view (a trial state), so the scored placement can never
                // occupy resources a reservation counted on; otherwise
                // plan against the live state and roll back the undo log
                // on failure.
                let planned: Option<(Vec<(PodId, NodeId, Option<usize>)>, Option<f64>)> =
                    if let Some((est, constrained)) = admitted_window {
                        let mut trial =
                            SessionState::new(api, constrained, state.placement.clone());
                        self.plan_job(api, &mut trial, job_id).map(|b| (b, Some(est)))
                    } else {
                        let checkpoint = state.checkpoint();
                        match self.plan_job(api, &mut state, job_id) {
                            Some(binds) => Some((binds, None)),
                            None => {
                                state.rollback_to(checkpoint);
                                None
                            }
                        }
                    };
                match planned {
                    Some((binds, window_est)) => {
                        if let Some(est) = window_est {
                            // Mirror the trial plan into the live session
                            // state and claim the job's running window out
                            // of the profile (its release past `now + est`
                            // stays visible to later reservations).
                            let placement: Vec<(NodeId, Resources)> = binds
                                .iter()
                                .map(|&(pid, node, _)| (node, api.pods[&pid].requests))
                                .collect();
                            for &(pid, node, g) in &binds {
                                state.apply(
                                    api.pods[&pid].requests,
                                    node,
                                    g.map(|gg| (job_id, gg)),
                                );
                            }
                            timeline.as_mut().unwrap().claim(now, now + est, &placement);
                        }
                        Self::commit_gang(api, binds, job_id, now);
                        started.push(job_id);
                    }
                    None => {
                        // Priority preemption: plan against a trial view
                        // with a minimal victim set released, and only
                        // evict once the plan is proven — a scored-greedy
                        // corner case must never preempt for nothing.
                        if self.config.preemption {
                            if let Some((victims, binds)) =
                                self.plan_with_preemption(api, &state, job_id, &started, now, None)
                            {
                                for &v in &victims {
                                    api.preempt_job(v, now);
                                }
                                self.preempted.extend_from_slice(&victims);
                                Self::commit_gang(api, binds, job_id, now);
                                started.push(job_id);
                                // The eviction + commit invalidated the
                                // session view and the release profile:
                                // rebuild the state, drop the reservations
                                // (they re-derive at the next failure; the
                                // engine index and the timeline cache both
                                // catch up from their cursors).
                                state = SessionState::snapshot(api);
                                state.index = self.engine.session_index(api);
                                reservations.clear();
                                timeline = None;
                                continue;
                            }
                        }
                        if conservative {
                            // First failure clones the persistent profile
                            // (refreshed event-driven); every blocked job
                            // claims its earliest-fit window.
                            if timeline.is_none() {
                                let ctx = QueueContext {
                                    api: &*api,
                                    now,
                                    projected_completion: projected,
                                    free: &state.free,
                                    walltime_factor: wf,
                                };
                                timeline = Some(self.session_timeline(&ctx));
                            }
                            let tl = timeline.as_mut().unwrap();
                            let est = estimate(api, job_id);
                            if let Some((t_s, placement)) = tl.earliest_fit_forced(
                                api,
                                job_id,
                                est,
                                self.force_linear_earliest_fit,
                            ) {
                                // A fit at `now` (gang first-fits, planner
                                // cornered itself) claims nothing — the
                                // job retries next session.
                                if t_s > now + 1e-9 {
                                    tl.claim(t_s, t_s + est, &placement);
                                }
                            }
                            continue;
                        }
                        let decision = if reservations.is_empty() {
                            let ctx = QueueContext {
                                api: &*api,
                                now,
                                projected_completion: projected,
                                free: &state.free,
                                walltime_factor: wf,
                            };
                            self.queue_policy.on_gang_failure(&ctx, job_id)
                        } else {
                            GangDecision::Skip
                        };
                        match decision {
                            GangDecision::Skip => {}
                            GangDecision::Block => break,
                            GangDecision::Reserve { shadow_time } => {
                                // A shadow at `now` (the gang first-fits
                                // but scored-greedy cornered itself) would
                                // zero the backfill window — same guard as
                                // the conservative path above.
                                if shadow_time > now + 1e-9 {
                                    reservations.push(shadow_time);
                                }
                            }
                        }
                        continue; // job stays pending; try later jobs
                    }
                }
            } else {
                // Kubernetes default: bind pods individually as they fit.
                let pending: Vec<PodId> = api.jobs[&job_id]
                    .pods
                    .iter()
                    .filter(|pid| api.pods[pid].phase == PodPhase::Pending)
                    .copied()
                    .collect();
                for pid in pending {
                    let pod = api.pods[&pid].clone();
                    if let Some(node) = self.place_pod(api, &mut state, &pod, None) {
                        let ok = api.bind_pod(pid, node, now);
                        assert!(ok, "kubelet admission failed after predicate pass");
                    }
                }
                let all_bound = api.jobs[&job_id]
                    .pods
                    .iter()
                    .all(|pid| api.pods[pid].phase == PodPhase::Bound);
                if all_bound {
                    api.start_job(job_id, now);
                    started.push(job_id);
                }
            }
        }
        started
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, NodeRole};
    use crate::controller::{JobController, NativeVolcanoController, VolcanoMpiController};
    use crate::kubelet::KubeletConfig;
    use crate::planner::{plan, GranularityPolicy, SystemInfo};
    use crate::workload::{Benchmark, JobSpec};

    fn submit(
        api: &mut ApiServer,
        controller: &dyn JobController,
        policy: GranularityPolicy,
        id: u64,
        bench: Benchmark,
    ) -> JobId {
        let spec = JobSpec::paper_job(id, bench, 0.0);
        let info = SystemInfo::of(&api.spec);
        let planned = plan(&spec, policy, info);
        let job_id = planned.spec.id;
        let (pods, hostfile) = controller.build(&planned, api);
        api.create_job(planned, pods, hostfile, 0.0);
        job_id
    }

    fn api() -> ApiServer {
        ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity())
    }

    #[test]
    fn baseline_schedules_single_worker_job() {
        let mut api = api();
        let job = submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, 1, Benchmark::EpDgemm);
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(1));
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started, vec![job]);
        let workers = api.worker_pods_of(job);
        assert_eq!(workers.len(), 1);
        assert!(api.spec.node(workers[0].node.unwrap()).role == NodeRole::Worker);
        // Launcher landed on the control plane.
        let launcher = api.pods.values().find(|p| !p.is_worker()).unwrap();
        assert_eq!(launcher.node, Some(api.spec.control_plane_id()));
    }

    #[test]
    fn taskgroup_spreads_scale_job_one_worker_per_node() {
        let mut api = api();
        let job = submit(&mut api, &VolcanoMpiController, GranularityPolicy::Scale, 1, Benchmark::EpDgemm);
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        let mut nodes: Vec<usize> =
            api.worker_pods_of(job).iter().map(|p| p.node.unwrap().0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 4, "4 workers must land on 4 distinct nodes");
    }

    #[test]
    fn taskgroup_accretes_granularity_groups_per_node() {
        let mut api = api();
        let job = submit(
            &mut api,
            &VolcanoMpiController,
            GranularityPolicy::Granularity,
            1,
            Benchmark::EpDgemm,
        );
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        // 16 single-task workers in 4 groups: each node gets exactly one
        // group of 4 workers.
        let mut per_node: BTreeMap<usize, u32> = BTreeMap::new();
        for p in api.worker_pods_of(job) {
            *per_node.entry(p.node.unwrap().0).or_insert(0) += p.ntasks;
        }
        let counts: Vec<u32> = per_node.values().copied().collect();
        assert_eq!(counts, vec![4, 4, 4, 4], "{per_node:?}");
        // And group assignments were committed to the pods.
        assert!(api.worker_pods_of(job).iter().all(|p| p.group.is_some()));
    }

    #[test]
    fn gang_holds_job_until_capacity_frees() {
        let mut api = api();
        // Fill the cluster with 8 × 16-core single-worker jobs.
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(1));
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        // A ninth job cannot gang-start.
        let nine = submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, 9, Benchmark::EpDgemm);
        assert!(sched.cycle(&mut api, 1.0).is_empty());
        assert_eq!(api.pending_jobs(), vec![nine]);
        // No partial binding happened (gang all-or-nothing).
        assert!(api.jobs[&nine]
            .pods
            .iter()
            .all(|pid| api.pods[pid].phase == PodPhase::Pending));
        // Finish one job; the queued one starts on the next cycle.
        api.finish_job(JobId(1), 2.0);
        assert_eq!(sched.cycle(&mut api, 2.0), vec![nine]);
    }

    #[test]
    fn no_gang_binds_partially() {
        let mut api = api();
        // Fill all worker nodes.
        let mut gang = Scheduler::new(SchedulerConfig::volcano_default(1));
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        gang.cycle(&mut api, 0.0);
        // Kubeflow-style job: launcher fits (control plane), worker does not.
        let job = submit(&mut api, &crate::controller::KubeflowController, GranularityPolicy::None, 9, Benchmark::EpDgemm);
        let mut kube = Scheduler::new(SchedulerConfig::kube_default(2));
        assert!(kube.cycle(&mut api, 1.0).is_empty());
        let phases: Vec<PodPhase> =
            api.jobs[&job].pods.iter().map(|pid| api.pods[pid].phase).collect();
        assert!(
            phases.contains(&PodPhase::Bound) && phases.contains(&PodPhase::Pending),
            "{phases:?}"
        );
    }

    #[test]
    fn native_volcano_scatters_sixteen_containers() {
        let mut api = api();
        let job = submit(&mut api, &NativeVolcanoController, GranularityPolicy::None, 1, Benchmark::GRandomRing);
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(7));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        let workers = api.worker_pods_of(job);
        assert_eq!(workers.len(), 16);
        let mut nodes: Vec<usize> = workers.iter().map(|p| p.node.unwrap().0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert!(nodes.len() > 1, "stock spreading must scatter the containers");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut api = api();
            for i in 1..=4 {
                submit(&mut api, &VolcanoMpiController, GranularityPolicy::Scale, i, Benchmark::EpStream);
            }
            let mut sched = Scheduler::new(SchedulerConfig::fine_grained(seed));
            sched.cycle(&mut api, 0.0);
            api.pods
                .values()
                .map(|p| (p.id, p.node.map(|n| n.0)))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
    }

    /// Submit a job with a custom task count (one worker holding all tasks
    /// under `GranularityPolicy::None`, so the worker requests
    /// `ntasks` cores).
    fn submit_sized(api: &mut ApiServer, id: u64, bench: Benchmark, ntasks: u32) -> JobId {
        let mut spec = JobSpec::paper_job(id, bench, 0.0);
        spec.ntasks = ntasks;
        spec.resources =
            Resources::new(ntasks as u64 * 1000, ntasks as u64 * crate::cluster::gib(2));
        let info = SystemInfo::of(&api.spec);
        let planned = plan(&spec, GranularityPolicy::None, info);
        let job_id = planned.spec.id;
        let (pods, hostfile) = VolcanoMpiController.build(&planned, api);
        api.create_job(planned, pods, hostfile, 0.0);
        job_id
    }

    /// Finish one running job whose (single) worker sits on the given
    /// worker node, so tests control exactly which node gains free cores.
    fn finish_one_on(api: &mut ApiServer, node: NodeId, now: f64) -> JobId {
        let job = api
            .running_jobs()
            .into_iter()
            .find(|&j| {
                api.worker_pods_of(j).first().and_then(|p| p.node) == Some(node)
            })
            .expect("no running job on the requested node");
        api.finish_job(job, now);
        job
    }

    /// Cluster with 7 running 16-core jobs + one finished, leaving exactly
    /// one node (worker node 1 — the first-fit choice, so the conservative
    /// timeline's claims land there deterministically) with 16 free cores,
    /// then three queued jobs: a 32-core job that cannot fit (the gang
    /// blocker), an 8-core ring job (short, ~333 s walltime estimate), and
    /// an 8-core MiniFE job (long, ~791 s estimate — past the ~688 s
    /// shadow time projected from the running DGEMMs' walltime estimates).
    fn congested_api_with_blocker(queue: QueuePolicyKind) -> (ApiServer, Scheduler, Vec<JobId>) {
        congested_api_with_blocker_cfg(SchedulerConfig::volcano_default(1).with_queue(queue))
    }

    /// [`congested_api_with_blocker`] with full control of the scheduler
    /// profile (the walltime-misprediction tests tune the error factor).
    fn congested_api_with_blocker_cfg(
        cfg: SchedulerConfig,
    ) -> (ApiServer, Scheduler, Vec<JobId>) {
        let mut api = api();
        let mut sched = Scheduler::new(cfg);
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        finish_one_on(&mut api, NodeId(1), 2.0);
        let blocker = submit_sized(&mut api, 9, Benchmark::EpDgemm, 32);
        let short = submit_sized(&mut api, 10, Benchmark::GRandomRing, 8);
        let long = submit_sized(&mut api, 11, Benchmark::MiniFe, 8);
        (api, sched, vec![blocker, short, long])
    }

    #[test]
    fn fifo_skip_overtakes_blocked_head() {
        let (mut api, mut sched, ids) = congested_api_with_blocker(QueuePolicyKind::FifoSkip);
        let started = sched.cycle(&mut api, 2.0);
        assert_eq!(started, vec![ids[1], ids[2]], "both small jobs overtake");
        assert_eq!(api.pending_jobs(), vec![ids[0]]);
    }

    #[test]
    fn fifo_strict_blocks_session_behind_gang_failure() {
        let (mut api, mut sched, ids) = congested_api_with_blocker(QueuePolicyKind::FifoStrict);
        assert!(sched.cycle(&mut api, 2.0).is_empty(), "head blocks everything");
        assert_eq!(api.pending_jobs(), ids);
    }

    #[test]
    fn easy_backfill_admits_only_jobs_within_shadow_window() {
        // Shadow time for the 32-core blocker is ~688 s (projected end of
        // the running DGEMMs at their walltime estimates); the ~333 s ring
        // job fits the window, the ~791 s MiniFE job does not.
        let (mut api, mut sched, ids) =
            congested_api_with_blocker(QueuePolicyKind::EasyBackfill);
        let started = sched.cycle(&mut api, 2.0);
        assert_eq!(started, vec![ids[1]], "only the short job backfills");
        assert_eq!(api.pending_jobs(), vec![ids[0], ids[2]]);
    }

    #[test]
    fn conservative_backfill_guards_every_reservation() {
        // Same congested cluster under conservative backfilling: the
        // blocker claims the freed node's window from ~688 s, the ring job
        // backfills inside the hole before it, and MiniFE — whose ~791 s
        // window would run through the claim on the only node with free
        // cores — is rejected (it holds a reservation of its own instead).
        let (mut api, mut sched, ids) =
            congested_api_with_blocker(QueuePolicyKind::ConservativeBackfill);
        let started = sched.cycle(&mut api, 2.0);
        assert_eq!(started, vec![ids[1]], "only the short job backfills");
        assert_eq!(api.pending_jobs(), vec![ids[0], ids[2]]);
    }

    #[test]
    fn conservative_timeline_backfills_holes_behind_the_reservation() {
        // Two nodes gain 16 free cores; a 32-core blocker claims the first
        // of them (plus its release) from the shadow time on. A long
        // 8-core MiniFE job whose estimate crosses the shadow — rejected
        // outright by an earliest-shadow-only gate — fits the *second*
        // free node through its whole window, taking nothing the
        // reservation counted on, so the timeline admits it.
        let mut api = api();
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(1)
                .with_queue(QueuePolicyKind::ConservativeBackfill),
        );
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        finish_one_on(&mut api, NodeId(1), 2.0);
        finish_one_on(&mut api, NodeId(2), 2.0);
        let blocker = submit_sized(&mut api, 9, Benchmark::EpDgemm, 32);
        let long_narrow = submit_sized(&mut api, 10, Benchmark::MiniFe, 8);
        let started = sched.cycle(&mut api, 2.0);
        assert_eq!(started, vec![long_narrow], "hole behind the reservation is usable");
        assert_eq!(api.pending_jobs(), vec![blocker]);
        // And the backfill landed outside the blocker's claimed node.
        let node = api.worker_pods_of(long_narrow)[0].node.unwrap();
        assert_ne!(node, NodeId(1), "claimed node stays reserved");
    }

    #[test]
    fn conservative_timeline_protects_later_reservations() {
        // Same two free nodes, but now TWO 32-core blockers: the first
        // claims node 1, the second (window-rejected) claims node 2 from
        // the shadow on. The same long 8-core job now crosses *some* claim
        // on every node, so admitting it would push a reservation back —
        // the timeline rejects it (the earliest-shadow gate could not even
        // see which resources the second reservation counted on).
        let mut api = api();
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(1)
                .with_queue(QueuePolicyKind::ConservativeBackfill),
        );
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        finish_one_on(&mut api, NodeId(1), 2.0);
        finish_one_on(&mut api, NodeId(2), 2.0);
        let blocker_a = submit_sized(&mut api, 9, Benchmark::EpDgemm, 32);
        let blocker_b = submit_sized(&mut api, 10, Benchmark::EpDgemm, 32);
        let long_narrow = submit_sized(&mut api, 11, Benchmark::MiniFe, 8);
        let started = sched.cycle(&mut api, 2.0);
        assert!(started.is_empty(), "no job may delay the held reservations: {started:?}");
        assert_eq!(api.pending_jobs(), vec![blocker_a, blocker_b, long_narrow]);
    }

    #[test]
    fn conservative_window_rejected_job_reserves_when_waiting_on_a_release() {
        // Conservative backfilling with two blocked jobs: a 32-core gang
        // blocker reserves at ~688 s; a 24-core job is window-rejected
        // (estimate ~701 s crosses the shadow) and — because it cannot fit
        // the 16 free cores now — takes a reservation of its own (the
        // EASY policy would give it nothing). A short ring job still
        // backfills under both shadows; neither blocked job dams the
        // session.
        let mut api = api();
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(1)
                .with_queue(QueuePolicyKind::ConservativeBackfill),
        );
        for i in 1..=8 {
            submit(&mut api, &VolcanoMpiController, GranularityPolicy::None, i, Benchmark::EpDgemm);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        api.finish_job(JobId(1), 2.0);
        let blocker = submit_sized(&mut api, 9, Benchmark::EpDgemm, 32);
        let second = submit_sized(&mut api, 10, Benchmark::EpDgemm, 24);
        let short = submit_sized(&mut api, 11, Benchmark::GRandomRing, 8);
        let started = sched.cycle(&mut api, 2.0);
        assert_eq!(started, vec![short], "short job backfills under both reservations");
        assert_eq!(api.pending_jobs(), vec![blocker, second]);
    }

    #[test]
    fn heterogeneous_scoring_prefers_smallest_fitting_class() {
        use crate::cluster::HeterogeneityMix;
        // An 8-core single-worker job on an idle fat/thin cluster fits
        // both classes; the best-fit term biases placement onto thin
        // nodes (preserving the fat nodes for wide pods). The jitter term
        // keeps it stochastic, so assert a strong majority across seeds.
        let mut thin_wins = 0;
        for seed in 0..20u64 {
            let mut api = ApiServer::new(
                ClusterSpec::mixed(8, HeterogeneityMix::FatThin),
                KubeletConfig::cpu_mem_affinity(),
            );
            let job = submit_sized(&mut api, 1, Benchmark::EpDgemm, 8);
            let mut sched = Scheduler::new(SchedulerConfig::volcano_default(seed));
            assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
            let node = api.worker_pods_of(job)[0].node.unwrap();
            if api.spec.node(node).allocatable_cores() == 16 {
                thin_wins += 1;
            }
        }
        assert!(thin_wins >= 15, "thin nodes won only {thin_wins}/20 placements");
    }

    #[test]
    fn heterogeneous_wide_gang_only_fits_fat_nodes() {
        use crate::cluster::HeterogeneityMix;
        // A 32-core single worker exceeds the thin class (16 cores): the
        // predicate must confine it to a fat node.
        let mut api = ApiServer::new(
            ClusterSpec::mixed(8, HeterogeneityMix::FatThin),
            KubeletConfig::cpu_mem_affinity(),
        );
        let job = submit_sized(&mut api, 1, Benchmark::EpDgemm, 32);
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(3));
        assert_eq!(sched.cycle(&mut api, 0.0), vec![job]);
        let node = api.worker_pods_of(job)[0].node.unwrap();
        assert_eq!(api.spec.node(node).allocatable_cores(), 64, "must land on a fat node");
    }

    #[test]
    fn heterogeneous_preemption_evicts_only_victims_on_useful_nodes() {
        use crate::cluster::HeterogeneityMix;
        // Cluster: 1 fat (64 cores) + 3 thin (16 cores). Fill every node
        // with low-priority 16-core jobs (4 fit the fat node), then submit
        // a high-priority 32-core job: only fat-node victims can help, and
        // the minimal set holds exactly two of them.
        let mut api = ApiServer::new(
            ClusterSpec::heterogeneous(&[
                crate::cluster::NodeClass::fat(1),
                crate::cluster::NodeClass::thin(3),
            ])
            .unwrap(),
            KubeletConfig::cpu_mem_affinity(),
        );
        let mut sched =
            Scheduler::new(SchedulerConfig::volcano_default(1).with_preemption(true));
        for i in 1..=7 {
            submit_sized(&mut api, i, Benchmark::EpDgemm, 16);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 7, "cluster fully packed");
        let fat_node = api
            .spec
            .node_ids()
            .find(|&n| api.spec.node(n).role == NodeRole::Worker
                && api.spec.node(n).allocatable_cores() == 64)
            .unwrap();
        let mut spec = JobSpec::paper_job(8, Benchmark::EpDgemm, 1.0);
        spec.ntasks = 32;
        spec.resources = Resources::new(32_000, 32 * crate::cluster::gib(2));
        spec.priority = 10;
        let info = SystemInfo::of(&api.spec);
        let planned = plan(&spec, GranularityPolicy::None, info);
        let hi = planned.spec.id;
        let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
        api.create_job(planned, pods, hostfile, 1.0);
        assert_eq!(sched.cycle(&mut api, 1.0), vec![hi]);
        let victims = sched.take_preempted();
        assert_eq!(victims.len(), 2, "minimal set: two fat-node victims: {victims:?}");
        for v in &victims {
            // Victims' (released) pods all lived on the fat node.
            for pid in &api.jobs[v].pods {
                let pod = &api.pods[pid];
                if pod.is_worker() {
                    assert_eq!(pod.phase, PodPhase::Pending, "victim released");
                }
            }
        }
        // And the high-priority worker landed on the fat node.
        assert_eq!(api.worker_pods_of(hi)[0].node, Some(fat_node));
    }

    #[test]
    fn sjf_starts_shorter_jobs_first() {
        let mut api = api();
        let long = submit_sized(&mut api, 1, Benchmark::EpDgemm, 8);
        let short = submit_sized(&mut api, 2, Benchmark::GRandomRing, 8);
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(3).with_queue(QueuePolicyKind::Sjf),
        );
        assert_eq!(sched.cycle(&mut api, 0.0), vec![short, long]);
    }

    #[test]
    fn fifo_skip_reproduces_default_config_decisions() {
        // The explicit FifoSkip policy is the seed's implicit behaviour:
        // identical configs modulo the queue field must place identically.
        let run = |cfg: SchedulerConfig| {
            let mut api = api();
            for i in 1..=6 {
                submit(&mut api, &VolcanoMpiController, GranularityPolicy::Granularity, i, Benchmark::MiniFe);
            }
            let mut sched = Scheduler::new(cfg);
            sched.cycle(&mut api, 0.0);
            api.pods
                .values()
                .map(|p| (p.id, p.node.map(|n| n.0), p.group))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            run(SchedulerConfig::fine_grained(5)),
            run(SchedulerConfig::fine_grained(5).with_queue(QueuePolicyKind::FifoSkip))
        );
    }

    /// Submit like [`submit`] but with a tenant/priority on the job spec.
    fn submit_prio(
        api: &mut ApiServer,
        policy: GranularityPolicy,
        id: u64,
        bench: Benchmark,
        priority: u32,
        now: f64,
    ) -> JobId {
        let spec = JobSpec::paper_job(id, bench, now)
            .with_tenant(crate::workload::TenantId(priority.min(1)), priority);
        let info = SystemInfo::of(&api.spec);
        let planned = plan(&spec, policy, info);
        let job_id = planned.spec.id;
        let (pods, hostfile) = VolcanoMpiController.build(&planned, api);
        api.create_job(planned, pods, hostfile, now);
        job_id
    }

    #[test]
    fn preemption_evicts_minimal_lower_priority_victim_set() {
        let mut api = api();
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(1).with_preemption(true),
        );
        // Fill the cluster with 8 priority-0 jobs.
        for i in 1..=8 {
            submit_prio(&mut api, GranularityPolicy::None, i, Benchmark::EpDgemm, 0, 0.0);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        // A priority-10 16-core job arrives: exactly one victim needed.
        let hi = submit_prio(&mut api, GranularityPolicy::None, 9, Benchmark::EpDgemm, 10, 1.0);
        let started = sched.cycle(&mut api, 1.0);
        assert_eq!(started, vec![hi], "high-priority job starts via preemption");
        let victims = sched.take_preempted();
        assert_eq!(victims.len(), 1, "minimal victim set: {victims:?}");
        assert_eq!(api.jobs[&victims[0]].phase, crate::apiserver::JobPhase::Preempted);
        assert_eq!(api.jobs[&victims[0]].planned.spec.priority, 0);
        // The victim's pods are fully released.
        for pid in &api.jobs[&victims[0]].pods {
            let pod = &api.pods[pid];
            assert_eq!(pod.phase, PodPhase::Pending);
            assert_eq!(pod.node, None);
        }
        // Re-queue the victim; once capacity frees it runs again.
        api.requeue_job(victims[0], 1.0);
        assert_eq!(api.pending_jobs(), vec![victims[0]]);
        api.finish_job(hi, 2.0);
        assert_eq!(sched.cycle(&mut api, 2.0), vec![victims[0]]);
        // No preemption was needed the second time.
        assert!(sched.take_preempted().is_empty());
    }

    #[test]
    fn preemption_never_evicts_equal_or_higher_priority() {
        let mut api = api();
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(1).with_preemption(true),
        );
        for i in 1..=8 {
            submit_prio(&mut api, GranularityPolicy::None, i, Benchmark::EpDgemm, 10, 0.0);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
        // Equal priority: must queue, not preempt.
        let equal = submit_prio(&mut api, GranularityPolicy::None, 9, Benchmark::EpDgemm, 10, 1.0);
        assert!(sched.cycle(&mut api, 1.0).is_empty());
        assert!(sched.take_preempted().is_empty());
        assert_eq!(api.pending_jobs(), vec![equal]);
        // Disabled preemption: a higher-priority job also queues.
        let mut no_pre = Scheduler::new(SchedulerConfig::volcano_default(2));
        let hi = submit_prio(&mut api, GranularityPolicy::None, 10, Benchmark::EpDgemm, 99, 2.0);
        assert!(no_pre.cycle(&mut api, 2.0).is_empty());
        assert!(no_pre.take_preempted().is_empty());
        assert!(api.pending_jobs().contains(&hi));
    }

    /// Property: the API server's incrementally maintained group-placement
    /// view equals the full pod-scan rebuild at every step of a randomized
    /// schedule → preempt → requeue → finish churn, and preempt → re-place
    /// → complete leaves free resources and placement identical to
    /// never-preempted bookkeeping (everything returned, placement empty).
    #[test]
    fn prop_incremental_placement_matches_rebuild_under_preemption_churn() {
        let benches = [
            Benchmark::EpDgemm,
            Benchmark::EpStream,
            Benchmark::GFft,
            Benchmark::GRandomRing,
            Benchmark::MiniFe,
        ];
        for case in 0..20u64 {
            let mut rng = Rng::seed_from_u64(7100 + case);
            let mut api = api();
            let mut sched = Scheduler::new(
                SchedulerConfig::fine_grained(case).with_preemption(true),
            );
            let check = |api: &ApiServer, step: &str| {
                assert_eq!(
                    api.group_placement(),
                    &Scheduler::rebuild_placement(api),
                    "case {case}: placement drifted after {step}"
                );
            };
            let n = rng.range_usize(4, 12);
            for i in 1..=n {
                let prio = if rng.f64() < 0.3 { 10 } else { 0 };
                submit_prio(
                    &mut api,
                    GranularityPolicy::Granularity,
                    i as u64,
                    benches[rng.range_usize(0, benches.len())],
                    prio,
                    0.0,
                );
            }
            let mut t = 0.0;
            for _ in 0..20 {
                t += 1.0;
                sched.cycle(&mut api, t);
                check(&api, "cycle");
                for id in sched.take_preempted() {
                    api.requeue_job(id, t);
                    check(&api, "requeue");
                }
                let running = api.running_jobs();
                if running.is_empty() && api.pending_jobs().is_empty() {
                    break;
                }
                if !running.is_empty() && rng.f64() < 0.7 {
                    let id = running[rng.range_usize(0, running.len())];
                    api.finish_job(id, t);
                    check(&api, "finish");
                }
            }
            // Drain: finish everything still running, then keep cycling
            // until the queue is empty (requeue any stragglers).
            for _ in 0..200 {
                t += 1.0;
                for id in api.running_jobs() {
                    api.finish_job(id, t);
                }
                check(&api, "drain-finish");
                if api.pending_jobs().is_empty() {
                    break;
                }
                sched.cycle(&mut api, t);
                for id in sched.take_preempted() {
                    api.requeue_job(id, t);
                }
                check(&api, "drain-cycle");
            }
            assert!(api.pending_jobs().is_empty(), "case {case}: queue not drained");
            // Never-preempted bookkeeping: all resources home, empty view.
            for nd in api.spec.node_ids() {
                assert_eq!(
                    api.free_on(nd),
                    api.spec.node(nd).allocatable(),
                    "case {case}: leaked resources"
                );
            }
            assert_eq!(api.group_placement(), &GroupPlacement::default(), "case {case}");
        }
    }

    /// Property: gang rollback is exact. After `rollback_to`, the session's
    /// free view and group placement must equal their pre-plan snapshots at
    /// every nesting level, and a fully-unwound session must equal a fresh
    /// rebuild from the API server — across randomized multi-job sessions
    /// and every queue policy (which reorder the jobs being planned).
    #[test]
    fn prop_gang_rollback_restores_session_exactly() {
        let benches = [
            Benchmark::EpDgemm,
            Benchmark::EpStream,
            Benchmark::GFft,
            Benchmark::GRandomRing,
            Benchmark::MiniFe,
        ];
        let policies = [
            GranularityPolicy::None,
            GranularityPolicy::Scale,
            GranularityPolicy::Granularity,
        ];
        for case in 0..30u64 {
            let mut rng = Rng::seed_from_u64(9000 + case);
            let mut api = api();
            let n = rng.range_usize(4, 14);
            for i in 1..=n {
                submit(
                    &mut api,
                    &VolcanoMpiController,
                    policies[rng.range_usize(0, policies.len())],
                    i as u64,
                    benches[rng.range_usize(0, benches.len())],
                );
            }
            let kind = ALL_QUEUE_POLICIES[rng.range_usize(0, ALL_QUEUE_POLICIES.len())];
            let mut sched =
                Scheduler::new(SchedulerConfig::fine_grained(case).with_queue(kind));
            // Commit some jobs for real so the session starts from a dirty
            // cluster; the rest stay pending.
            sched.cycle(&mut api, 0.0);

            let mut state = SessionState::new(
                &api,
                api.spec.node_ids().map(|nd| api.free_on(nd)).collect(),
                Scheduler::rebuild_placement(&api),
            );
            let mut frames = Vec::new();
            for &job in &api.pending_jobs() {
                frames.push((state.checkpoint(), state.free.clone(), state.placement.clone()));
                let _ = sched.plan_job(&api, &mut state, job);
            }
            for (cp, free, placement) in frames.into_iter().rev() {
                state.rollback_to(cp);
                assert_eq!(state.free, free, "case {case}: free drifted");
                assert_eq!(state.placement, placement, "case {case}: placement drifted");
            }
            state.rollback_to(0);
            let rebuilt_free: Vec<Resources> =
                api.spec.node_ids().map(|nd| api.free_on(nd)).collect();
            assert_eq!(state.free, rebuilt_free, "case {case}: free != rebuild");
            assert_eq!(
                state.placement,
                Scheduler::rebuild_placement(&api),
                "case {case}: placement != rebuild"
            );
            assert!(state.log.is_empty(), "case {case}: log not fully unwound");
        }
    }

    #[test]
    fn indexed_engine_matches_linear_reference_placements() {
        use crate::cluster::HeterogeneityMix;
        // Same seed, same submissions, same finish churn on a
        // heterogeneous cluster: the two engines must bind every pod to
        // the same node (the RNG jitter stream is per-feasible-node, and
        // both engines enumerate the identical feasible set in the same
        // order). Debug builds additionally assert the sets per pod.
        let run = |engine: PlacementEngineKind| {
            let mut api = ApiServer::new(
                ClusterSpec::mixed(6, HeterogeneityMix::FatThin),
                KubeletConfig::cpu_mem_affinity(),
            );
            let mut sched =
                Scheduler::new(SchedulerConfig::fine_grained(3).with_engine(engine));
            for i in 1..=10 {
                submit(
                    &mut api,
                    &VolcanoMpiController,
                    GranularityPolicy::Granularity,
                    i,
                    Benchmark::EpDgemm,
                );
            }
            let mut t = 0.0;
            for _ in 0..6 {
                t += 1.0;
                sched.cycle(&mut api, t);
                for id in api.running_jobs().into_iter().take(2) {
                    api.finish_job(id, t + 0.5);
                }
            }
            api.pods
                .values()
                .map(|p| (p.id, p.node.map(|n| n.0), p.group))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            run(PlacementEngineKind::Linear),
            run(PlacementEngineKind::Indexed),
            "engines must place bit-identically"
        );
    }

    #[test]
    fn quota_holds_over_quota_jobs_pending_until_capacity_frees() {
        use crate::workload::TenantId;
        // Two-tenant regression: tenant 0 holds a 16-core quota; its
        // second job is held Pending by admission even though the cluster
        // has free capacity, while tenant 1 is unaffected. Completion of
        // the first job frees the quota and the held job starts.
        let mut api = api();
        let mut sched = Scheduler::new(SchedulerConfig::volcano_default(1));
        api.set_tenant_quota(TenantId(0), Resources::new(16_000, u64::MAX));
        let a = submit_prio(&mut api, GranularityPolicy::None, 1, Benchmark::EpDgemm, 0, 0.0);
        let b = submit_prio(&mut api, GranularityPolicy::None, 2, Benchmark::EpDgemm, 0, 0.0);
        let c = submit_prio(&mut api, GranularityPolicy::None, 3, Benchmark::EpDgemm, 1, 0.0);
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started, vec![a, c], "tenant 0's second job held by quota");
        assert_eq!(api.pending_jobs(), vec![b]);
        assert_eq!(api.jobs[&b].phase, crate::apiserver::JobPhase::Pending);
        // The hold is quota, not capacity: the gang would first-fit.
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        assert!(queue::job_fits(&api, &free, b), "capacity exists; quota is the gate");
        api.finish_job(a, 10.0);
        assert_eq!(sched.cycle(&mut api, 10.0), vec![b], "completion frees the quota");
    }

    /// Drive a cluster into a state where the minimal-victim and
    /// least-work-lost policies disagree: equal-priority victims where
    /// the *latest-started* job (the default's pick) carries a long prior
    /// stint, while a mid-aged job has barely run.
    fn preemption_victim_under(policy: PreemptionPolicy) -> Vec<JobId> {
        let mut api = api();
        let mut sched = Scheduler::new(
            SchedulerConfig::volcano_default(1)
                .with_preemption(true)
                .with_preemption_policy(policy),
        );
        for i in 1..=8 {
            submit_prio(&mut api, GranularityPolicy::None, i, Benchmark::EpDgemm, 0, 0.0);
        }
        assert_eq!(sched.cycle(&mut api, 0.0).len(), 8, "cluster packed");
        // Job 9 starts at t=400 in job 2's slot: 110 s of work by t=510.
        api.finish_job(JobId(2), 400.0);
        let b = submit_prio(&mut api, GranularityPolicy::None, 9, Benchmark::EpDgemm, 0, 400.0);
        assert_eq!(sched.cycle(&mut api, 400.0), vec![b]);
        // Job 1 is preempted at t=500 (500 s served) and restarts at
        // t=505: latest start_time, but 505 s of invested work by t=510.
        api.preempt_job(JobId(1), 500.0);
        api.requeue_job(JobId(1), 500.0);
        assert_eq!(sched.cycle(&mut api, 505.0), vec![JobId(1)]);
        // A high-priority 16-core job needs exactly one victim at t=510.
        let hi = submit_prio(&mut api, GranularityPolicy::None, 10, Benchmark::EpDgemm, 10, 510.0);
        assert_eq!(sched.cycle(&mut api, 510.0), vec![hi]);
        sched.take_preempted()
    }

    #[test]
    fn least_work_lost_prefers_the_victim_with_least_invested_work() {
        // Work lost at t=510 (equal restart costs cancel): job 9 = 110 s,
        // job 1 = 500 prior + 5 current = 505 s, jobs 3..8 = 510 s.
        assert_eq!(
            preemption_victim_under(PreemptionPolicy::LeastWorkLost),
            vec![JobId(9)],
            "cost-aware policy evicts the young victim"
        );
        // The default prefers the latest start — job 1, despite its 505 s
        // of invested work.
        assert_eq!(
            preemption_victim_under(PreemptionPolicy::MinimalVictim),
            vec![JobId(1)],
            "minimal-victim default is unchanged"
        );
    }

    #[test]
    fn walltime_error_factor_gates_backfill_admission() {
        // Exact projections (the simulator path): the shadow stays at the
        // true release (~688 s) while each backfill candidate's window
        // scales with the error factor — estimates only, never runtimes.
        let run = |factor: f64| {
            let (mut api, mut sched, ids) = congested_api_with_blocker_cfg(
                SchedulerConfig::volcano_default(1)
                    .with_queue(QueuePolicyKind::EasyBackfill)
                    .with_walltime_error_factor(factor),
            );
            let projected = queue::estimated_completions(&api, 2.0, 1.0);
            (sched.cycle_with_projections(&mut api, 2.0, &projected), ids)
        };
        let (started, ids) = run(1.0);
        assert_eq!(started, vec![ids[1]], "honest estimate: the ring job backfills");
        let (started, _) = run(3.0);
        assert!(
            started.is_empty(),
            "3x over-estimate pushes the ring job's window past the shadow: {started:?}"
        );
        let (started, ids) = run(0.3);
        assert_eq!(
            started,
            vec![ids[1], ids[2]],
            "under-estimation admits the long MiniFE job into the window too"
        );
    }

    #[test]
    fn conservative_protection_survives_walltime_misprediction() {
        // The two-blocker-protection scenario under uniformly wrong
        // estimates: reservations are claimed from the same mis-estimated
        // profile, so no backfill whose (scaled) window crosses a claim is
        // ever admitted — the no-reservation-violated guarantee holds
        // under both under- and over-estimation.
        for factor in [0.5, 2.0] {
            let mut api = api();
            let mut sched = Scheduler::new(
                SchedulerConfig::volcano_default(1)
                    .with_queue(QueuePolicyKind::ConservativeBackfill)
                    .with_walltime_error_factor(factor),
            );
            for i in 1..=8 {
                submit(
                    &mut api,
                    &VolcanoMpiController,
                    GranularityPolicy::None,
                    i,
                    Benchmark::EpDgemm,
                );
            }
            assert_eq!(sched.cycle(&mut api, 0.0).len(), 8);
            finish_one_on(&mut api, NodeId(1), 2.0);
            finish_one_on(&mut api, NodeId(2), 2.0);
            let blocker_a = submit_sized(&mut api, 9, Benchmark::EpDgemm, 32);
            let blocker_b = submit_sized(&mut api, 10, Benchmark::EpDgemm, 32);
            let long_narrow = submit_sized(&mut api, 11, Benchmark::MiniFe, 8);
            let started = sched.cycle(&mut api, 2.0);
            assert!(
                started.is_empty(),
                "factor {factor}: a reservation would be violated: {started:?}"
            );
            assert_eq!(
                api.pending_jobs(),
                vec![blocker_a, blocker_b, long_narrow],
                "factor {factor}"
            );
        }
    }
}
