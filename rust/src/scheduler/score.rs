//! Node scoring functions: the default Kubernetes-style priorities used by
//! the baselines, and the task-group `NodeOrderFn` (paper Algorithm 4).

use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::{JobId, NodeId, Resources};

/// Kubernetes `LeastRequestedPriority`-style score in [0, 10]: favour nodes
/// with the most free requested resources (this is what the default
/// scheduler and stock Volcano use for spreading).
pub fn least_requested(free: &Resources, allocatable: &Resources) -> f64 {
    let cpu = if allocatable.cpu_milli == 0 {
        0.0
    } else {
        free.cpu_milli as f64 / allocatable.cpu_milli as f64
    };
    let mem = if allocatable.mem_bytes == 0 {
        0.0
    } else {
        free.mem_bytes as f64 / allocatable.mem_bytes as f64
    };
    (cpu + mem) * 5.0
}

/// Group identity across jobs: groups are per-job objects.
pub type GroupKey = (JobId, usize);

/// The cluster-wide group placement view Algorithm 4 scores against,
/// maintained incrementally by the scheduling session as binds commit.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GroupPlacement {
    /// (job, group) -> nodes already bound for that group, with counts.
    pub bound_nodes: BTreeMap<GroupKey, BTreeMap<NodeId, u32>>,
    /// node -> set of groups with at least one pod bound there.
    pub groups_on_node: BTreeMap<NodeId, BTreeSet<GroupKey>>,
}

impl GroupPlacement {
    pub fn record(&mut self, key: GroupKey, node: NodeId) {
        *self.bound_nodes.entry(key).or_default().entry(node).or_insert(0) += 1;
        self.groups_on_node.entry(node).or_default().insert(key);
    }

    /// Exact inverse of [`GroupPlacement::record`]: empty inner maps/sets
    /// are pruned so a record+remove pair restores the structure
    /// bit-for-bit (the gang undo-log relies on this for its rollback
    /// invariant).
    pub fn remove(&mut self, key: GroupKey, node: NodeId) {
        if let Some(nodes) = self.bound_nodes.get_mut(&key) {
            if let Some(c) = nodes.get_mut(&node) {
                *c -= 1;
                if *c == 0 {
                    nodes.remove(&node);
                    if let Some(set) = self.groups_on_node.get_mut(&node) {
                        set.remove(&key);
                        if set.is_empty() {
                            self.groups_on_node.remove(&node);
                        }
                    }
                }
            }
            if nodes.is_empty() {
                self.bound_nodes.remove(&key);
            }
        }
    }

    /// Number of this group's pods already bound on `node`.
    pub fn bound_on(&self, key: GroupKey, node: NodeId) -> u32 {
        self.bound_nodes
            .get(&key)
            .and_then(|m| m.get(&node))
            .copied()
            .unwrap_or(0)
    }

    /// Number of *other* groups present on `node`.
    pub fn other_groups_on(&self, key: GroupKey, node: NodeId) -> usize {
        self.groups_on_node
            .get(&node)
            .map(|s| s.iter().filter(|&&k| k != key).count())
            .unwrap_or(0)
    }
}

/// Algorithm 4 — `NodeOrderFn` node score for a worker of a task group:
///
/// 1. base score: pods of the *same group* already bound on this node
///    (affinity: accrete the group onto one node);
/// 2. plus the group's remaining worker count (constant across nodes —
///    kept for fidelity with the pseudocode);
/// 3. minus one per *other* group present on the node (anti-affinity:
///    spread distinct groups apart).
pub fn taskgroup_score(
    placement: &GroupPlacement,
    key: GroupKey,
    group_len: usize,
    node: NodeId,
) -> f64 {
    let mut score = placement.bound_on(key, node) as f64;
    score += group_len as f64;
    score -= placement.other_groups_on(key, node) as f64;
    score
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gib;

    #[test]
    fn least_requested_prefers_empty_nodes() {
        let alloc = Resources::new(32_000, gib(248));
        let empty = least_requested(&alloc, &alloc);
        let half = least_requested(&Resources::new(16_000, gib(124)), &alloc);
        let full = least_requested(&Resources::ZERO, &alloc);
        assert!(empty > half && half > full);
        assert!((empty - 10.0).abs() < 1e-9);
        assert!((full - 0.0).abs() < 1e-9);
    }

    #[test]
    fn group_affinity_raises_score_on_bound_node() {
        let mut p = GroupPlacement::default();
        let key = (JobId(1), 0);
        p.record(key, NodeId(1));
        p.record(key, NodeId(1));
        let bound = taskgroup_score(&p, key, 4, NodeId(1));
        let fresh = taskgroup_score(&p, key, 4, NodeId(2));
        assert!(bound > fresh, "{bound} vs {fresh}");
        assert_eq!(bound - fresh, 2.0);
    }

    #[test]
    fn group_antiaffinity_lowers_score_with_other_groups() {
        let mut p = GroupPlacement::default();
        let mine = (JobId(1), 0);
        let other1 = (JobId(1), 1);
        let other2 = (JobId(2), 0);
        p.record(other1, NodeId(1));
        p.record(other2, NodeId(1));
        let crowded = taskgroup_score(&p, mine, 4, NodeId(1));
        let empty = taskgroup_score(&p, mine, 4, NodeId(2));
        assert_eq!(empty - crowded, 2.0, "two other groups => -2");
    }

    #[test]
    fn affinity_beats_antiaffinity_when_own_group_dominates() {
        // A node with 3 of my pods + 1 other group still beats a fresh node.
        let mut p = GroupPlacement::default();
        let mine = (JobId(1), 0);
        p.record(mine, NodeId(1));
        p.record(mine, NodeId(1));
        p.record(mine, NodeId(1));
        p.record((JobId(2), 0), NodeId(1));
        assert!(
            taskgroup_score(&p, mine, 4, NodeId(1)) > taskgroup_score(&p, mine, 4, NodeId(2))
        );
    }

    #[test]
    fn remove_undoes_record() {
        let mut p = GroupPlacement::default();
        let key = (JobId(1), 0);
        p.record(key, NodeId(1));
        p.record(key, NodeId(1));
        p.remove(key, NodeId(1));
        assert_eq!(p.bound_on(key, NodeId(1)), 1);
        p.remove(key, NodeId(1));
        assert_eq!(p.bound_on(key, NodeId(1)), 0);
        assert_eq!(p.other_groups_on((JobId(9), 9), NodeId(1)), 0);
    }
}
