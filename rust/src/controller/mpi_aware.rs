//! Dynamic MPI-aware Job Controller plugin (paper Algorithm 2).
//!
//! Enhances the Volcano job controller: given a planned job (granularity
//! already selected by the planner agent), it (1) allocates the `N_t` MPI
//! tasks into the `N_w` workers RoundRobin, (2) sets each worker's resource
//! requests/limits to `R(cpu/N_t · nTasks, memory/N_t · nTasks)`, and (3)
//! generates the hostfile entry (`hostname slots=nTasks`) for every worker.

use crate::cluster::{HostfileEntry, Pod, PodRole};
use crate::workload::PlannedJob;

use super::PodFactory;

/// Step 2: allocate `N_t` tasks into `N_w` workers in RoundRobin fashion.
/// Returns the task count of each worker (differs by at most one).
pub fn allocate_tasks(n_tasks: u32, n_workers: u32) -> Vec<u32> {
    assert!(n_workers > 0, "job with zero workers");
    let mut counts = vec![0u32; n_workers as usize];
    for t in 0..n_tasks {
        counts[(t % n_workers) as usize] += 1;
    }
    counts
}

/// Algorithm 2: build the launcher + worker pods and the hostfile for a
/// planned job.
pub fn build_pods(
    job: &PlannedJob,
    factory: &mut dyn PodFactory,
) -> (Vec<Pod>, Vec<HostfileEntry>) {
    // Step 1: get job specification.
    let spec = &job.spec;
    let n_t = spec.ntasks;
    let n_w = job.granularity.n_workers;
    let per_task = spec.resources; // divided by N_t via Resources::scaled

    // Step 2: allocate tasks into workers in RoundRobin.
    let n_tasks_in_worker = allocate_tasks(n_t, n_w);

    // Step 3: set up pod resources and the hostfile according to the number
    // of tasks allocated.
    let mut pods = Vec::with_capacity(n_w as usize + 1);
    let mut hostfile = Vec::with_capacity(n_w as usize);
    for (i, &ntasks) in n_tasks_in_worker.iter().enumerate() {
        let name = format!("{}-worker-{}", spec.name, i);
        let mut pod = factory.make_pod(spec.id, &name, PodRole::Worker { index: i as u32 });
        pod.ntasks = ntasks;
        pod.requests = per_task.scaled(ntasks as u64, n_t as u64);
        pod.limits = pod.requests;
        hostfile.push(HostfileEntry { hostname: name, slots: ntasks });
        pods.push(pod);
    }

    // Pods = Pods_w + Pod_l: the launcher (mpirun host) is a small
    // burstable pod pinned to the control plane by the scheduler.
    let launcher_name = format!("{}-launcher", spec.name);
    let mut launcher = factory.make_pod(spec.id, &launcher_name, PodRole::Launcher);
    launcher.requests = crate::cluster::Resources::new(100, crate::cluster::gib(1));
    launcher.limits = launcher.requests;
    pods.push(launcher);

    (pods, hostfile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{gib, JobId, PodId, Resources};
    use crate::workload::{Benchmark, Granularity, JobSpec};

    struct TestFactory(u64);
    impl PodFactory for TestFactory {
        fn make_pod(&mut self, job: JobId, name: &str, role: PodRole) -> Pod {
            self.0 += 1;
            Pod::new(PodId(self.0), job, name.to_string(), role)
        }
    }

    fn planned(n_workers: u32) -> PlannedJob {
        PlannedJob {
            spec: JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0),
            granularity: Granularity { n_nodes: 4, n_workers, n_groups: 4 },
        }
    }

    #[test]
    fn round_robin_conserves_tasks_and_balances() {
        for (nt, nw) in [(16u32, 1u32), (16, 4), (16, 16), (16, 5), (7, 3), (1, 1)] {
            let counts = allocate_tasks(nt, nw);
            assert_eq!(counts.iter().sum::<u32>(), nt, "{nt}/{nw}");
            let max = counts.iter().max().unwrap();
            let min = counts.iter().min().unwrap();
            assert!(max - min <= 1, "{nt}/{nw}: {counts:?}");
        }
    }

    #[test]
    fn worker_resources_scale_with_task_count() {
        let (pods, _) = build_pods(&planned(4), &mut TestFactory(0));
        let workers: Vec<_> = pods.iter().filter(|p| p.is_worker()).collect();
        assert_eq!(workers.len(), 4);
        for w in &workers {
            assert_eq!(w.ntasks, 4);
            // R(cpu/N_t · nTasks) = 16 cores / 16 · 4 = 4 cores.
            assert_eq!(w.requests, Resources::new(4000, 4 * gib(2)));
        }
    }

    #[test]
    fn uneven_split_gives_remainder_to_first_workers() {
        let mut job = planned(5);
        job.spec.ntasks = 16;
        let (pods, hostfile) = build_pods(&job, &mut TestFactory(0));
        let ntasks: Vec<u32> = pods.iter().filter(|p| p.is_worker()).map(|p| p.ntasks).collect();
        assert_eq!(ntasks, vec![4, 3, 3, 3, 3]);
        assert_eq!(hostfile[0].slots, 4);
        // Resources follow the task share.
        let w0 = pods.iter().find(|p| p.worker_index() == Some(0)).unwrap();
        assert_eq!(w0.requests.cpu_milli, 4000);
    }

    #[test]
    fn hostfile_matches_workers() {
        let (pods, hostfile) = build_pods(&planned(4), &mut TestFactory(0));
        assert_eq!(hostfile.len(), 4);
        for (entry, pod) in hostfile.iter().zip(pods.iter().filter(|p| p.is_worker())) {
            assert_eq!(entry.hostname, pod.name);
            assert_eq!(entry.slots, pod.ntasks);
        }
        assert_eq!(hostfile.iter().map(|h| h.slots).sum::<u32>(), 16);
    }

    #[test]
    fn launcher_is_last_and_small() {
        let (pods, _) = build_pods(&planned(4), &mut TestFactory(0));
        let launcher = pods.last().unwrap();
        assert_eq!(launcher.role, PodRole::Launcher);
        assert_eq!(launcher.ntasks, 0);
        assert!(launcher.requests.cpu_milli < 1000);
        assert_eq!(pods.len(), 5);
    }
}
