//! Job controllers: how a (planned) MPI job becomes pods + hostfile.
//!
//! Three controllers, matching the paper's evaluated frameworks:
//! - [`VolcanoMpiController`] — the paper's enhanced Volcano job controller
//!   with the MPI-aware plugin (Algorithm 2);
//! - [`KubeflowController`] — Kubeflow MPI-operator behaviour: one launcher
//!   plus one worker holding *all* MPI processes;
//! - [`NativeVolcanoController`] — stock Volcano MPI example behaviour:
//!   one task per container for every workload.

pub mod mpi_aware;

use crate::cluster::{HostfileEntry, JobId, Pod, PodRole};
use crate::workload::{Granularity, PlannedJob};

/// Pod-identity allocator, implemented by the API server wrapper so
/// controllers can mint pods with cluster-unique ids.
pub trait PodFactory {
    fn make_pod(&mut self, job: JobId, name: &str, role: PodRole) -> Pod;
}

impl PodFactory for crate::apiserver::ApiServer {
    fn make_pod(&mut self, job: JobId, name: &str, role: PodRole) -> Pod {
        let id = self.fresh_pod_id();
        Pod::new(id, job, name.to_string(), role)
    }
}

/// A job controller materializes a planned job into pods + hostfile.
pub trait JobController {
    fn name(&self) -> &'static str;
    /// May override the planner's granularity (the baseline frameworks do).
    fn effective_granularity(&self, job: &PlannedJob) -> Granularity;
    fn build(&self, job: &PlannedJob, factory: &mut dyn PodFactory)
        -> (Vec<Pod>, Vec<HostfileEntry>);
}

/// The paper's controller: respects the planner's granularity and applies
/// Algorithm 2.
pub struct VolcanoMpiController;

impl JobController for VolcanoMpiController {
    fn name(&self) -> &'static str {
        "volcano+mpi-aware"
    }

    fn effective_granularity(&self, job: &PlannedJob) -> Granularity {
        job.granularity
    }

    fn build(
        &self,
        job: &PlannedJob,
        factory: &mut dyn PodFactory,
    ) -> (Vec<Pod>, Vec<HostfileEntry>) {
        mpi_aware::build_pods(job, factory)
    }
}

/// Kubeflow MPI operator (paper §II-B, §V-E): an MPI `Launcher` and a
/// single `Worker` container in which all MPI worker processes run; no
/// scheduler enhancement (the driver pairs this with the default-scheduler
/// profile and no gang).
pub struct KubeflowController;

impl JobController for KubeflowController {
    fn name(&self) -> &'static str {
        "kubeflow-mpi-operator"
    }

    fn effective_granularity(&self, _job: &PlannedJob) -> Granularity {
        Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 }
    }

    fn build(
        &self,
        job: &PlannedJob,
        factory: &mut dyn PodFactory,
    ) -> (Vec<Pod>, Vec<HostfileEntry>) {
        let forced = PlannedJob {
            spec: job.spec.clone(),
            granularity: self.effective_granularity(job),
        };
        mpi_aware::build_pods(&forced, factory)
    }
}

/// Native Volcano MPI example (paper §V-E): the job is partitioned as one
/// process per container for *every* workload — including the
/// network-intensive ones, which is exactly what Table III punishes.
pub struct NativeVolcanoController;

impl JobController for NativeVolcanoController {
    fn name(&self) -> &'static str {
        "volcano-native"
    }

    fn effective_granularity(&self, job: &PlannedJob) -> Granularity {
        let n_t = job.spec.ntasks;
        Granularity { n_nodes: n_t, n_workers: n_t, n_groups: 1 }
    }

    fn build(
        &self,
        job: &PlannedJob,
        factory: &mut dyn PodFactory,
    ) -> (Vec<Pod>, Vec<HostfileEntry>) {
        let forced = PlannedJob {
            spec: job.spec.clone(),
            granularity: self.effective_granularity(job),
        };
        mpi_aware::build_pods(&forced, factory)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PodId;
    use crate::workload::{Benchmark, JobSpec};

    struct TestFactory(u64);
    impl PodFactory for TestFactory {
        fn make_pod(&mut self, job: JobId, name: &str, role: PodRole) -> Pod {
            self.0 += 1;
            Pod::new(PodId(self.0), job, name.to_string(), role)
        }
    }

    fn planned() -> PlannedJob {
        PlannedJob {
            spec: JobSpec::paper_job(1, Benchmark::GFft, 0.0),
            granularity: Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 },
        }
    }

    #[test]
    fn kubeflow_always_one_worker() {
        let mut job = planned();
        job.granularity = Granularity { n_nodes: 4, n_workers: 16, n_groups: 4 };
        let (pods, hostfile) = KubeflowController.build(&job, &mut TestFactory(0));
        assert_eq!(pods.iter().filter(|p| p.is_worker()).count(), 1);
        assert_eq!(hostfile.len(), 1);
        assert_eq!(hostfile[0].slots, 16);
    }

    #[test]
    fn native_volcano_one_task_per_container_even_for_network_jobs() {
        let job = planned(); // G-FFT — network-intensive
        let (pods, hostfile) = NativeVolcanoController.build(&job, &mut TestFactory(0));
        let workers: Vec<_> = pods.iter().filter(|p| p.is_worker()).collect();
        assert_eq!(workers.len(), 16);
        assert!(workers.iter().all(|w| w.ntasks == 1));
        assert!(hostfile.iter().all(|h| h.slots == 1));
    }

    #[test]
    fn paper_controller_respects_planner() {
        let mut job = planned();
        job.spec = JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0);
        job.granularity = Granularity { n_nodes: 4, n_workers: 4, n_groups: 4 };
        let (pods, _) = VolcanoMpiController.build(&job, &mut TestFactory(0));
        assert_eq!(pods.iter().filter(|p| p.is_worker()).count(), 4);
    }

    #[test]
    fn apiserver_factory_mints_unique_ids() {
        use crate::cluster::ClusterSpec;
        use crate::kubelet::KubeletConfig;
        let mut api =
            crate::apiserver::ApiServer::new(ClusterSpec::paper(), KubeletConfig::default_policy());
        let a = api.make_pod(JobId(1), "a", PodRole::Launcher);
        let b = api.make_pod(JobId(1), "b", PodRole::Launcher);
        assert_ne!(a.id, b.id);
    }
}
