//! Sharded multi-scheduler scale-out (Volcano's multi-scheduler design):
//! the cluster is partitioned into scheduler *domains*
//! ([`ClusterSpec::shard_domains`] — by worker capacity class, a class is
//! never split), a cross-shard dispatcher assigns every job to one
//! domain up-front, and each domain runs a full [`crate::simulator::Simulation`]
//! of its own, in parallel on std threads. Determinism is by
//! construction, not by locking:
//!
//! - the dispatcher is single-threaded and walks the trace in submit
//!   order, so the assignment never depends on the thread pool;
//! - each domain derives its own RNG stream from the base seed and its
//!   *domain index* ([`shard_seed`]), not from scheduling order;
//! - results are collected into slots indexed by domain, so the merge
//!   order is the stable domain order no matter which thread finished
//!   first.
//!
//! A fixed seed therefore reproduces bit-identical per-shard
//! [`SimDigest`]s (and the [`combined_digest`] fold over them) for any
//! thread count — the property `tests/properties.rs` pins. On a
//! homogeneous cluster the partition collapses to one domain and the
//! runner (`experiments::RunSpec`) delegates to the plain
//! single-scheduler path, so `shards=1` — and any shard count on a
//! uniform mix — is *provably* today's scheduler, bit for bit.

use crate::cluster::{ClusterSpec, Resources};
use crate::simulator::SimDigest;
use crate::util::Rng;
use crate::workload::JobSpec;

/// Deterministic RNG-stream seed for one scheduler domain: derived from
/// the base seed and the *domain index* (stable under any thread count).
/// Distinct shards get decorrelated streams; the single-domain case
/// never calls this — it delegates to the plain path on the base seed.
pub fn shard_seed(seed: u64, shard: usize) -> u64 {
    Rng::seed_from_u64(seed).derive(shard as u64).next_u64()
}

/// One domain's dispatch-relevant capacity summary.
struct DomainCap {
    /// Distinct worker shapes present (one entry per capacity class).
    shapes: Vec<Resources>,
    /// Aggregate worker allocatable.
    total: Resources,
    /// Aggregate worker cpu (the relative-load denominator), widened so
    /// the cross-multiplied load comparison below cannot overflow.
    cpu: u128,
}

impl DomainCap {
    fn of(domain: &ClusterSpec) -> DomainCap {
        let mut shapes: Vec<Resources> = Vec::new();
        let mut total = Resources::new(0, 0);
        for &id in &domain.worker_ids() {
            let alloc = domain.node(id).allocatable();
            if !shapes.contains(&alloc) {
                shapes.push(alloc);
            }
            total += alloc;
        }
        DomainCap { shapes, total, cpu: total.cpu_milli.max(1) as u128 }
    }

    /// Can this domain plausibly host the job at all? At least one worker
    /// shape must fit a single task and the aggregate must cover the
    /// whole job. Jobs that pass here but still fail gang feasibility in
    /// the domain are recorded unschedulable by its simulation — exactly
    /// what a single-domain run does with an infeasible job.
    fn admits(&self, spec: &JobSpec) -> bool {
        let task = spec.per_task_resources();
        self.shapes.iter().any(|s| task.fits_within(s))
            && spec.resources.fits_within(&self.total)
    }
}

/// Cross-shard dispatcher: assign every job of `trace` to one scheduler
/// domain, up-front and single-threaded, so the assignment is identical
/// regardless of how many threads later run the domains. Jobs are walked
/// in submit order (ties by id) and greedily routed to the *least
/// relatively loaded* feasible domain — assigned cpu over domain worker
/// cpu, compared exactly in cross-multiplied integers, ties to the
/// lowest domain index. A job no domain admits goes to domain 0, which
/// records it unschedulable exactly as a single-domain run would.
pub fn dispatch(domains: &[ClusterSpec], trace: &[JobSpec]) -> Vec<Vec<JobSpec>> {
    assert!(!domains.is_empty(), "dispatch needs at least one domain");
    let caps: Vec<DomainCap> = domains.iter().map(DomainCap::of).collect();
    let mut load: Vec<u128> = vec![0; domains.len()];
    let mut order: Vec<usize> = (0..trace.len()).collect();
    order.sort_by(|&a, &b| {
        trace[a]
            .submit_time
            .total_cmp(&trace[b].submit_time)
            .then(trace[a].id.cmp(&trace[b].id))
    });
    let mut shards: Vec<Vec<JobSpec>> = vec![Vec::new(); domains.len()];
    for i in order {
        let spec = &trace[i];
        let mut best: Option<usize> = None;
        for (d, cap) in caps.iter().enumerate() {
            if !cap.admits(spec) {
                continue;
            }
            best = Some(match best {
                None => d,
                // load[d]/cpu[d] < load[b]/cpu[b]  ⇔  cross-multiplied.
                Some(b) if load[d] * caps[b].cpu < load[b] * caps[d].cpu => d,
                Some(b) => b,
            });
        }
        let target = best.unwrap_or(0);
        load[target] += spec.resources.cpu_milli as u128;
        shards[target].push(spec.clone());
    }
    shards
}

/// Order-sensitive FNV-1a fold over per-shard digests (stable domain
/// order): one `u64` fingerprint for a whole sharded run. For a
/// single-domain run this is just a restatement of that shard's digest —
/// two runs have equal folds iff every shard's output is bit-identical.
pub fn combined_digest(digests: &[SimDigest]) -> u64 {
    let mut bytes: Vec<u8> = Vec::with_capacity(digests.len() * 56);
    for d in digests {
        for w in [
            d.placements,
            d.events,
            d.records,
            d.n_records as u64,
            d.n_unschedulable as u64,
            d.response_bits,
            d.makespan_bits,
        ] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
    }
    crate::simulator::fnv1a(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::HeterogeneityMix;
    use crate::workload::two_tenant_trace;

    #[test]
    fn shard_seeds_are_stable_and_distinct() {
        assert_eq!(shard_seed(7, 0), shard_seed(7, 0));
        assert_ne!(shard_seed(7, 0), shard_seed(7, 1));
        assert_ne!(shard_seed(7, 0), shard_seed(8, 0));
    }

    #[test]
    fn dispatch_covers_every_job_exactly_once_and_is_deterministic() {
        let cluster = ClusterSpec::mixed(12, HeterogeneityMix::Tiered);
        let domains = cluster.shard_domains(3);
        assert_eq!(domains.len(), 3);
        let trace = two_tenant_trace(40, 20.0, 5);
        let a = dispatch(&domains, &trace);
        let b = dispatch(&domains, &trace);
        let total: usize = a.iter().map(Vec::len).sum();
        assert_eq!(total, trace.len());
        let mut ids: Vec<u64> = a.iter().flatten().map(|j| j.id.0).collect();
        ids.sort_unstable();
        let mut expect: Vec<u64> = trace.iter().map(|j| j.id.0).collect();
        expect.sort_unstable();
        assert_eq!(ids, expect, "every job dispatched exactly once");
        for (x, y) in a.iter().zip(&b) {
            let xi: Vec<u64> = x.iter().map(|j| j.id.0).collect();
            let yi: Vec<u64> = y.iter().map(|j| j.id.0).collect();
            assert_eq!(xi, yi, "dispatch must be deterministic");
        }
        // Balance sanity: with three comparable domains nothing collapses
        // onto a single shard.
        assert!(a.iter().filter(|s| !s.is_empty()).count() >= 2);
    }

    #[test]
    fn combined_digest_discriminates_shard_order_and_content() {
        let trace = two_tenant_trace(6, 30.0, 3);
        let out = crate::scenario::Scenario::CmGTg.simulation(3).run(&trace);
        let d1 = SimDigest::of(&out);
        let out2 = crate::scenario::Scenario::CmGTg.simulation(4).run(&trace);
        let d2 = SimDigest::of(&out2);
        assert_eq!(combined_digest(&[d1.clone()]), combined_digest(&[d1.clone()]));
        assert_ne!(combined_digest(&[d1.clone()]), combined_digest(&[d2.clone()]));
        assert_ne!(
            combined_digest(&[d1.clone(), d2.clone()]),
            combined_digest(&[d2, d1]),
            "shard order is part of the fingerprint"
        );
    }
}
