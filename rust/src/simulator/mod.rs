//! Discrete-event simulation engine with rate-based job progress.
//!
//! Jobs progress at `rate = 1 / slowdown(placement, co-location)`; the
//! performance model recomputes every running job's rate whenever the
//! cluster state changes (a job starts or finishes), so contention is
//! *dynamic* — exactly the effect the paper measures when co-scheduled
//! workloads interfere.
//!
//! Event loop: the next event is either the next job arrival or the
//! earliest predicted completion. Job progress is kept on an
//! *epoch-based lazy clock*: each running job stores its remaining work
//! anchored at the last instant its rate changed (`remaining` at
//! `sync_time`, plus `rate`), so advancing simulated time is O(1) — it
//! only moves `now` — and a job's anchor is touched exactly when a
//! placement delta changes its rate. Predicted absolute finish times are
//! indexed in a completion ledger (`BTreeSet` ordered by IEEE-754 bits),
//! so the next-completion query is O(log R) instead of a full
//! running-set scan, and the same index doubles as the projection map
//! the scheduler's backfill policies read. The pre-epoch stepped clock —
//! every event walks all running jobs and decrements
//! `remaining -= dt * rate` — is retained verbatim behind
//! [`Simulation::set_force_stepped_clock`] as the pinned reference; the
//! two clocks agree to < 1e-6 s per event time (not bit-identical:
//! summing per-event decrements rounds differently than the closed
//! form), which `tests/properties.rs` asserts.
//!
//! In the paper's multi-layer design this module is the experiment
//! driver: it couples the planner (granularity selection) to a controller
//! (pod construction), the scheduler (placement + queues + preemption),
//! the kubelets (cpuset admission) and the perf model, and integrates job
//! progress over time. Rates are maintained *incrementally*: a placement
//! event (start/finish/preempt) only recomputes the jobs whose contention
//! set changed, against a load snapshot patched per-node from cached
//! contributions — bit-identical to the full rescan (see
//! [`Simulation::force_full_recompute`] and the property tests).

pub mod shard;

use std::collections::{BTreeMap, BTreeSet};

use crate::apiserver::{ApiServer, JobPhase};
use crate::cluster::{ClusterSpec, JobId, NodeId, Pod, Resources};
use crate::controller::JobController;
use crate::kubelet::KubeletConfig;
use crate::perfmodel::{
    job_nic_demands, job_slowdown_with, job_socket_demands, Calibration, ClusterLoads,
};
use crate::planner::{plan, GranularityPolicy, SystemInfo};
use crate::scheduler::{PlacementEngineKind, Scheduler, SchedulerConfig};
use crate::util::Rng;
use crate::workload::{JobSpec, TenantId};

/// Per-running-job progress state — an epoch anchor: `remaining` is the
/// work left *at* `sync_time`, and between anchors the job progresses
/// linearly at `rate`. The epoch clock re-anchors only when the rate
/// changes; the stepped reference clock re-anchors at every event.
#[derive(Debug, Clone)]
struct JobProgress {
    /// Remaining work at `sync_time`, in ideal (slowdown-1) seconds.
    remaining: f64,
    /// Simulated time this anchor was last (re)synced.
    sync_time: f64,
    /// Current progress rate (1 / slowdown).
    rate: f64,
    /// Shared-pool variance factor, drawn once per job.
    noise: f64,
}

impl JobProgress {
    /// Remaining work at time `t >= sync_time`, closed form.
    fn remaining_at(&self, t: f64) -> f64 {
        self.remaining - (t - self.sync_time) * self.rate
    }

    /// Predicted absolute completion time from this anchor. Non-negative
    /// for the non-negative anchors the simulator produces, so its
    /// IEEE-754 bit pattern orders like the value (the completion-ledger
    /// key invariant).
    fn finish_time(&self) -> f64 {
        self.sync_time + (self.remaining / self.rate).max(0.0)
    }
}

/// Completed-run record for one job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: JobId,
    pub benchmark: crate::workload::Benchmark,
    pub tenant: TenantId,
    pub priority: u32,
    pub submit_time: f64,
    /// First time the job started (preempted jobs may restart later).
    pub start_time: f64,
    pub finish_time: f64,
    /// Total in-service seconds across all stints. For never-preempted
    /// jobs this equals `finish_time - start_time`; a preempted job's
    /// suspended gaps count as waiting, not running.
    pub running_secs: f64,
}

impl JobRecord {
    /// `T_i^w`: total queue wait — everything that was not service time
    /// (initial queueing plus any post-preemption re-queue gaps).
    pub fn wait(&self) -> f64 {
        self.response() - self.running_secs
    }

    /// `T_i^r`: in-service running time (summed across stints).
    pub fn running(&self) -> f64 {
        self.running_secs
    }

    /// `T_i = T_i^w + T_i^r`: response time.
    pub fn response(&self) -> f64 {
        self.finish_time - self.submit_time
    }
}

/// Simulator-core throughput counters for one run — the event-loop side
/// of the perf trajectory (the scheduler side is
/// [`crate::scheduler::SchedulerStats`]). `core_nanos` sums wall time
/// spent in the clock's own sections — the next-completion query, the
/// clock advance, the completion harvest, and (stepped mode only) the
/// per-session projection rebuild — so ns/event isolates the simulator
/// core from scheduler and perf-model work. Wall-clock derived, so never
/// part of any digest and excluded from every equality pin.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimCoreStats {
    /// Events processed by the event loop (arrivals + completion batches).
    pub events: u64,
    /// Arrival events (each may batch several same-instant submits).
    pub arrivals: u64,
    /// Completion events (each may batch several simultaneous finishes).
    pub completions: u64,
    /// Epoch-clock re-anchors: how often a running job's lazy
    /// `(remaining, sync_time)` pair was actually touched because its
    /// rate or remaining work changed. Always 0 under the stepped clock
    /// (which re-anchors everything at every event instead).
    pub resyncs: u64,
    /// Nanoseconds of wall time in the clock sections listed above.
    pub core_nanos: u64,
}

impl SimCoreStats {
    /// Mean simulator-core nanoseconds per event (0 for an empty run).
    pub fn nanos_per_event(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.core_nanos as f64 / self.events as f64
        }
    }

    /// Sum counters across shards/runs (whole-run merges in
    /// `experiments::RunOutput::core_stats`).
    pub fn merge(&mut self, other: &SimCoreStats) {
        self.events += other.events;
        self.arrivals += other.arrivals;
        self.completions += other.completions;
        self.resyncs += other.resyncs;
        self.core_nanos += other.core_nanos;
    }
}

/// Simulation output: per-job records + the final API server (event log,
/// placements) for reporting.
pub struct SimOutput {
    pub records: Vec<JobRecord>,
    /// Jobs whose gang can never fit the cluster, recorded as failed
    /// instead of aborting the run (they have no JobRecord).
    pub unschedulable: Vec<JobId>,
    pub api: ApiServer,
    /// Scheduler-throughput counters for the whole run (sessions run,
    /// placement decisions committed) — benches divide by wall time for
    /// sessions/sec and decisions/sec; never part of any digest.
    pub sched_stats: crate::scheduler::SchedulerStats,
    /// Simulator-core throughput counters (events processed, core
    /// nanoseconds) — benches divide by wall time for events/sec; never
    /// part of any digest.
    pub core_stats: SimCoreStats,
}

impl SimOutput {
    /// `T = Σ T_i`: overall response time (paper metric).
    pub fn overall_response(&self) -> f64 {
        self.records.iter().map(JobRecord::response).sum()
    }

    /// Number of preemption events recorded in the run's event log.
    pub fn preemption_count(&self) -> usize {
        self.api
            .events
            .iter()
            .filter(|e| matches!(e, crate::apiserver::Event::JobPreempted { .. }))
            .count()
    }

    /// Number of resize (mold/expand/shrink) events in the run's event
    /// log. Zero on every rigid trace — pinned by the elasticity ablation.
    pub fn resize_count(&self) -> usize {
        self.api
            .events
            .iter()
            .filter(|e| matches!(e, crate::apiserver::Event::JobResized { .. }))
            .count()
    }

    /// `T_makespan`: time for all jobs to terminate (0 for an empty run).
    pub fn makespan(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let first = self.records.iter().map(|r| r.submit_time).fold(f64::INFINITY, f64::min);
        let last = self.records.iter().map(|r| r.finish_time).fold(0.0, f64::max);
        last - first
    }

    /// Mean running time of one benchmark's jobs.
    pub fn avg_running(&self, bench: crate::workload::Benchmark) -> f64 {
        let xs: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.benchmark == bench)
            .map(JobRecord::running)
            .collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    /// Compact bit-exact fingerprint of the run — see [`SimDigest`].
    pub fn digest(&self) -> SimDigest {
        SimDigest::of(self)
    }
}

/// 64-bit FNV-1a over a byte stream — platform-stable (the digest inputs
/// are IEEE-754 bit patterns and ids, all iterated in deterministic
/// order), no dependencies, and cheap enough to fingerprint every fuzz
/// case.
pub(crate) fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100_0000_01b3);
    }
    hash
}

/// Compact, bit-exact fingerprint of one simulation: separate hashes over
/// the placement decisions (every `PodBound` event), the full event
/// sequence, and the per-job timing records, plus the headline stats as
/// raw IEEE-754 bit patterns. Two runs have equal digests iff their
/// observable outputs are bit-identical — the equality the differential
/// harness, the golden snapshots under `tests/golden/`, and the fuzz
/// property all pin.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimDigest {
    /// FNV-1a over the `(t, pod, node)` stream of every `PodBound` event.
    pub placements: u64,
    /// FNV-1a over the full event log (discriminant + timestamps + ids).
    pub events: u64,
    /// FNV-1a over the per-job records (id, tenant, priority, and the
    /// submit/start/finish/running times as bit patterns).
    pub records: u64,
    pub n_records: usize,
    pub n_unschedulable: usize,
    /// `overall_response()` as IEEE-754 bits.
    pub response_bits: u64,
    /// `makespan()` as IEEE-754 bits.
    pub makespan_bits: u64,
}

impl SimDigest {
    pub fn of(out: &SimOutput) -> SimDigest {
        use crate::apiserver::Event;
        let mut placements: Vec<u8> = Vec::new();
        let mut events: Vec<u8> = Vec::new();
        let mut push = |buf: &mut Vec<u8>, words: &[u64]| {
            for w in words {
                buf.extend_from_slice(&w.to_le_bytes());
            }
        };
        for e in &out.api.events {
            match *e {
                Event::JobSubmitted { t, job } => push(&mut events, &[1, t.to_bits(), job.0]),
                Event::PodBound { t, pod, node } => {
                    let words = [2, t.to_bits(), pod.0, node.0 as u64];
                    push(&mut events, &words);
                    push(&mut placements, &words);
                }
                Event::JobStarted { t, job } => push(&mut events, &[3, t.to_bits(), job.0]),
                Event::JobFinished { t, job } => push(&mut events, &[4, t.to_bits(), job.0]),
                Event::JobPreempted { t, job } => push(&mut events, &[5, t.to_bits(), job.0]),
                Event::JobUnschedulable { t, job } => {
                    push(&mut events, &[6, t.to_bits(), job.0])
                }
                Event::JobResized { t, job, workers } => {
                    push(&mut events, &[7, t.to_bits(), job.0, workers as u64])
                }
            }
        }
        let mut records: Vec<u8> = Vec::new();
        for r in &out.records {
            push(
                &mut records,
                &[
                    r.id.0,
                    r.tenant.0 as u64,
                    r.priority as u64,
                    r.submit_time.to_bits(),
                    r.start_time.to_bits(),
                    r.finish_time.to_bits(),
                    r.running_secs.to_bits(),
                ],
            );
        }
        SimDigest {
            placements: fnv1a(placements),
            events: fnv1a(events),
            records: fnv1a(records),
            n_records: out.records.len(),
            n_unschedulable: out.unschedulable.len(),
            response_bits: out.overall_response().to_bits(),
            makespan_bits: out.makespan().to_bits(),
        }
    }

    /// Render as a small JSON object. The u64 hashes/bit-patterns are
    /// serialized as fixed-width hex *strings*: the in-tree JSON value is
    /// f64-backed, which would silently round integers above 2^53.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"placements\": \"{:016x}\",\n",
                "  \"events\": \"{:016x}\",\n",
                "  \"records\": \"{:016x}\",\n",
                "  \"n_records\": {},\n",
                "  \"n_unschedulable\": {},\n",
                "  \"response_bits\": \"{:016x}\",\n",
                "  \"makespan_bits\": \"{:016x}\"\n",
                "}}\n"
            ),
            self.placements,
            self.events,
            self.records,
            self.n_records,
            self.n_unschedulable,
            self.response_bits,
            self.makespan_bits,
        )
    }

    /// Parse what [`SimDigest::to_json`] rendered.
    pub fn from_json(text: &str) -> Result<SimDigest, String> {
        let v = crate::util::Json::parse(text).map_err(|e| e.to_string())?;
        let hex = |key: &str| -> Result<u64, String> {
            let s = v.get(key).as_str().ok_or_else(|| format!("missing hex field {key:?}"))?;
            u64::from_str_radix(s, 16).map_err(|e| format!("bad hex in {key:?}: {e}"))
        };
        let count = |key: &str| -> Result<usize, String> {
            v.get(key)
                .as_u64()
                .map(|x| x as usize)
                .ok_or_else(|| format!("missing count field {key:?}"))
        };
        Ok(SimDigest {
            placements: hex("placements")?,
            events: hex("events")?,
            records: hex("records")?,
            n_records: count("n_records")?,
            n_unschedulable: count("n_unschedulable")?,
            response_bits: hex("response_bits")?,
            makespan_bits: hex("makespan_bits")?,
        })
    }
}

/// One running job's cached contribution to the cluster-wide load
/// snapshot, captured at placement time so release events can update the
/// snapshot without re-reading (already released) pods.
#[derive(Debug, Clone, Default)]
struct JobContribution {
    /// Distinct nodes hosting this job's workers — its contention set.
    nodes: BTreeSet<NodeId>,
    /// Per-node per-socket memory-bandwidth demand (bytes/s).
    socket: BTreeMap<NodeId, Vec<f64>>,
    /// Per-node NIC demand (bytes/s); empty for node-local traffic.
    nic: BTreeMap<NodeId, f64>,
    /// Per-node running MPI task counts.
    tasks: BTreeMap<NodeId, u32>,
}

/// A fully configured simulation: cluster + kubelet setting + planner
/// policy + controller + scheduler profile + perf model.
pub struct Simulation {
    pub api: ApiServer,
    scheduler: Scheduler,
    controller: Box<dyn JobController>,
    policy: GranularityPolicy,
    calib: Calibration,
    rng: Rng,
    progress: BTreeMap<JobId, JobProgress>,
    /// Checkpointed progress of preempted jobs, restored (plus the
    /// calibrated restart cost) when the job is re-placed.
    suspended: BTreeMap<JobId, JobProgress>,
    unschedulable: Vec<JobId>,
    now: f64,
    /// Incrementally maintained cluster-wide load snapshot — equal (bit
    /// for bit, in every value the perf model reads) to
    /// `ClusterLoads::snapshot` at all times; a debug assertion re-derives
    /// the full snapshot after every placement delta to pin this.
    loads: ClusterLoads,
    /// Cached per-job contributions backing `loads` (§Perf: release
    /// events subtract a cached contribution instead of rescanning the
    /// running set).
    contrib: BTreeMap<JobId, JobContribution>,
    /// node -> running jobs with at least one worker there (the
    /// contention index: a placement change on a node only dirties the
    /// rates of the jobs listed there).
    jobs_on_node: BTreeMap<NodeId, BTreeSet<JobId>>,
    /// Completion ledger (epoch clock): every running job's predicted
    /// absolute finish time, keyed by IEEE-754 bits so the `BTreeSet`
    /// orders numerically (finish times are non-negative finite), with
    /// the job id as tie-break — the same ordering the stepped
    /// reference's `min_by` scan used. Maintained exactly (entries are
    /// removed on every re-anchor, no lazy deletion), so `first()` *is*
    /// the next completion. Empty under the stepped clock.
    completions: BTreeSet<(u64, JobId)>,
    /// The per-job predicted finish times backing `completions`, shared
    /// with the scheduler as its projection map (§Perf: the stepped
    /// clock rebuilt this O(R) map from scratch every session). Empty
    /// under the stepped clock.
    projected: BTreeMap<JobId, f64>,
    /// Run every rate update as a full running-set rescan (the
    /// pre-incremental behaviour). Benches compare the two modes; must be
    /// set before `run` and left alone (the incremental caches go stale
    /// in full mode).
    pub force_full_recompute: bool,
    /// Run the retired stepped clock — every event decrements every
    /// running job's `remaining` by `dt * rate` and rescans the running
    /// set for the next completion — instead of the epoch ledger. The
    /// pinned reference path benches and the bounded-divergence property
    /// compare against; must be set before `run` and left alone (the
    /// completion ledger stays empty in stepped mode).
    pub force_stepped_clock: bool,
    /// Simulator-core throughput counters for this run (events, core
    /// nanoseconds); drained into [`SimOutput::core_stats`].
    core_stats: SimCoreStats,
    /// Per-benchmark ideal work override (seconds); defaults to
    /// `Benchmark::base_running_secs`. The e2e driver feeds PJRT-measured
    /// kernel times through this.
    pub base_work: BTreeMap<crate::workload::Benchmark, f64>,
}

impl Simulation {
    pub fn new(
        cluster: ClusterSpec,
        kubelet: KubeletConfig,
        policy: GranularityPolicy,
        controller: Box<dyn JobController>,
        scheduler_config: SchedulerConfig,
        calib: Calibration,
        seed: u64,
    ) -> Simulation {
        Simulation {
            api: ApiServer::new(cluster, kubelet),
            scheduler: Scheduler::new(scheduler_config),
            controller,
            policy,
            calib,
            rng: Rng::seed_from_u64(seed),
            progress: BTreeMap::new(),
            suspended: BTreeMap::new(),
            unschedulable: Vec::new(),
            now: 0.0,
            loads: ClusterLoads {
                socket_demands: BTreeMap::new(),
                nic_demands: BTreeMap::new(),
                tasks_on_node: BTreeMap::new(),
            },
            contrib: BTreeMap::new(),
            jobs_on_node: BTreeMap::new(),
            completions: BTreeSet::new(),
            projected: BTreeMap::new(),
            force_full_recompute: false,
            force_stepped_clock: false,
            core_stats: SimCoreStats::default(),
            base_work: BTreeMap::new(),
        }
    }

    /// Swap the scheduler's placement engine — the `linear` reference vs
    /// the `indexed` default. Outputs are bit-identical (property-pinned);
    /// benches compare the bookkeeping cost.
    pub fn set_placement_engine(&mut self, kind: PlacementEngineKind) {
        self.scheduler.set_engine(kind);
    }

    /// Force the conservative backfill timeline to rebuild from scratch
    /// every session (the pre-incremental reference path) instead of
    /// refreshing the scheduler's persistent cache.
    pub fn set_force_timeline_rebuild(&mut self, force: bool) {
        self.scheduler.force_timeline_rebuild = force;
    }

    /// Run every scheduling session through the retired monolithic loop
    /// instead of the action pipeline — the pinned reference path the
    /// differential golden-trace harness compares against.
    pub fn set_force_legacy_scheduler(&mut self, force: bool) {
        self.scheduler.force_legacy_scheduler = force;
    }

    /// Answer every conservative-backfill earliest-fit query through the
    /// retained linear scan instead of the segment-tree default — the
    /// pinned reference path benches and property tests compare against.
    pub fn set_force_linear_earliest_fit(&mut self, force: bool) {
        self.scheduler.force_linear_earliest_fit = force;
    }

    /// Run the simulation on the retired stepped clock (per-event
    /// `remaining -= dt * rate` over the whole running set) instead of
    /// the epoch-based lazy ledger — the pinned reference path the
    /// `sim_core` bench and the bounded-divergence property compare
    /// against. Set before `run` and leave alone.
    pub fn set_force_stepped_clock(&mut self, force: bool) {
        self.force_stepped_clock = force;
    }

    fn base_work_of(&self, bench: crate::workload::Benchmark) -> f64 {
        self.base_work.get(&bench).copied().unwrap_or_else(|| bench.base_running_secs())
    }

    /// Advance simulated time to `t`. Epoch clock: O(1) — progress is
    /// lazy, anchored at each job's last rate change. Stepped reference:
    /// decrement every running job's remaining work (the retired O(R)
    /// per-event walk), re-anchoring `sync_time` so the closed-form
    /// accessors stay exact.
    fn advance_to(&mut self, t: f64) {
        let tick = std::time::Instant::now();
        let dt = t - self.now;
        debug_assert!(dt >= -1e-9, "time went backwards: {} -> {}", self.now, t);
        if self.force_stepped_clock && dt > 0.0 {
            for p in self.progress.values_mut() {
                p.remaining -= dt * p.rate;
                p.sync_time = t;
            }
        }
        self.now = t;
        self.core_stats.core_nanos += tick.elapsed().as_nanos() as u64;
    }

    /// One job's current progress rate against the given load snapshot.
    ///
    /// Rigid jobs progress at exactly `1 / slowdown` — bit-identical to
    /// the pre-elasticity engine. Elastic jobs additionally scale by
    /// their *width factor* `active_tasks / ntasks`: a job shrunk to half
    /// its preferred tasks does half the work per second (linear-speedup
    /// model over the splittable kernels of the elastic catalogue). At
    /// the preferred width the factor is exactly 1.0, so an
    /// unresized elastic job rates identically to a rigid one.
    fn rate_of(&self, id: JobId, noise: f64, loads: &ClusterLoads) -> f64 {
        let slowdown = job_slowdown_with(&self.api, id, &self.calib, noise, loads).total;
        debug_assert!(slowdown >= 1.0 - 1e-9, "slowdown {slowdown} < 1");
        let spec = &self.api.jobs[&id].planned.spec;
        if spec.elasticity.is_some() {
            let width = self.api.active_tasks_of(id) as f64 / spec.ntasks as f64;
            width / slowdown
        } else {
            1.0 / slowdown
        }
    }

    /// Recompute every running job's rate from a fresh cluster-wide load
    /// snapshot — the full-rescan reference path, forced by
    /// [`Simulation::force_full_recompute`]; the maintained snapshot is
    /// replaced so the debug cross-check stays meaningful.
    fn recompute_rates(&mut self) {
        let ids: Vec<JobId> = self.progress.keys().copied().collect();
        let loads = ClusterLoads::snapshot(&self.api);
        for id in ids {
            let noise = self.progress[&id].noise;
            let rate = self.rate_of(id, noise, &loads);
            self.set_rate(id, rate);
        }
        self.loads = loads;
    }

    /// Capture one just-started job's contribution to the load snapshot.
    fn contribution_of(&self, job_id: JobId) -> JobContribution {
        let socket = job_socket_demands(&self.api, job_id);
        let nic = job_nic_demands(&self.api, job_id);
        let mut tasks: BTreeMap<NodeId, u32> = BTreeMap::new();
        let mut nodes: BTreeSet<NodeId> = BTreeSet::new();
        for pod in self.api.worker_pods_of(job_id) {
            if let Some(node) = pod.node {
                *tasks.entry(node).or_insert(0) += pod.ntasks;
                nodes.insert(node);
            }
        }
        JobContribution { nodes, socket, nic, tasks }
    }

    /// Apply a placement delta (jobs started / jobs whose placement was
    /// released by completion or preemption) to the maintained load
    /// snapshot, then recompute rates for exactly the jobs whose
    /// contention set changed: the started jobs plus every running job
    /// sharing a node with any change (§Perf: the full rescan walked the
    /// whole running set — and snapshotted the whole cluster — on every
    /// event, which dominates 128-worker sweeps).
    ///
    /// The dirtied nodes' load entries are rebuilt from the cached
    /// contributions in ascending job order — the same floating-point
    /// accumulation sequence as `ClusterLoads::snapshot` — so the
    /// maintained snapshot (and therefore every rate, and every simulated
    /// timestamp) is *bit-identical* to the full-rescan path.
    fn apply_placement_delta(&mut self, added: &[JobId], removed: &[JobId]) {
        if self.force_full_recompute {
            self.recompute_rates();
            return;
        }
        let mut changed_nodes: BTreeSet<NodeId> = BTreeSet::new();
        for id in removed {
            if let Some(c) = self.contrib.remove(id) {
                for &n in &c.nodes {
                    changed_nodes.insert(n);
                    if let Some(set) = self.jobs_on_node.get_mut(&n) {
                        set.remove(id);
                        if set.is_empty() {
                            self.jobs_on_node.remove(&n);
                        }
                    }
                }
            }
        }
        for &id in added {
            let c = self.contribution_of(id);
            for &n in &c.nodes {
                changed_nodes.insert(n);
                self.jobs_on_node.entry(n).or_default().insert(id);
            }
            self.contrib.insert(id, c);
        }

        // Rebuild each dirtied node's entries from the cached
        // contributions (ascending job order, matching the snapshot).
        for &n in &changed_nodes {
            let mut socket: Option<Vec<f64>> = None;
            let mut nic: Option<f64> = None;
            let mut tasks: Option<u32> = None;
            if let Some(jobs) = self.jobs_on_node.get(&n) {
                for id in jobs {
                    let c = &self.contrib[id];
                    if let Some(d) = c.socket.get(&n) {
                        let s = socket.get_or_insert_with(|| vec![0.0; d.len()]);
                        for (e, v) in s.iter_mut().zip(d) {
                            *e += v;
                        }
                    }
                    if let Some(d) = c.nic.get(&n) {
                        *nic.get_or_insert(0.0) += d;
                    }
                    if let Some(t) = c.tasks.get(&n) {
                        *tasks.get_or_insert(0) += t;
                    }
                }
            }
            match socket {
                Some(s) => {
                    self.loads.socket_demands.insert(n, s);
                }
                None => {
                    self.loads.socket_demands.remove(&n);
                }
            }
            match nic {
                Some(v) => {
                    self.loads.nic_demands.insert(n, v);
                }
                None => {
                    self.loads.nic_demands.remove(&n);
                }
            }
            match tasks {
                Some(t) => {
                    self.loads.tasks_on_node.insert(n, t);
                }
                None => {
                    self.loads.tasks_on_node.remove(&n);
                }
            }
        }

        // Dirty set: the started jobs plus every running job touching a
        // changed node.
        let mut affected: BTreeSet<JobId> = added.iter().copied().collect();
        for n in &changed_nodes {
            if let Some(set) = self.jobs_on_node.get(n) {
                affected.extend(set.iter().copied());
            }
        }
        for id in affected {
            if let Some(noise) = self.progress.get(&id).map(|p| p.noise) {
                let rate = self.rate_of(id, noise, &self.loads);
                self.set_rate(id, rate);
            }
        }
        #[cfg(debug_assertions)]
        self.assert_rates_match_full_recompute();
    }

    /// Debug-build property pin: every maintained rate must equal the rate
    /// a full snapshot + rescan would produce, bit for bit. Runs after
    /// every placement delta of every debug-mode simulation, so the whole
    /// test suite exercises the equivalence on its traces.
    #[cfg(debug_assertions)]
    fn assert_rates_match_full_recompute(&self) {
        let loads = ClusterLoads::snapshot(&self.api);
        for (&id, p) in &self.progress {
            let full = self.rate_of(id, p.noise, &loads);
            assert!(
                p.rate.to_bits() == full.to_bits(),
                "incremental rate drifted for {id:?}: {} vs full {}",
                p.rate,
                full
            );
        }
    }

    /// Earliest predicted completion among running jobs. Epoch clock:
    /// the completion ledger's first entry, O(log R). Stepped reference:
    /// the retired full scan over the running set (`total_cmp` replaces
    /// the old NaN-panicking `partial_cmp().unwrap()`; identical order
    /// on the finite times the simulator produces).
    fn next_completion(&self) -> Option<(f64, JobId)> {
        if !self.force_stepped_clock {
            return self.completions.first().map(|&(bits, id)| (f64::from_bits(bits), id));
        }
        self.progress
            .iter()
            .map(|(&id, p)| (p.finish_time(), id))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
    }

    /// Register a (re)started job's progress anchor and, on the epoch
    /// clock, index its predicted finish in the completion ledger and
    /// the shared projection map.
    fn progress_insert(&mut self, id: JobId, p: JobProgress) {
        if !self.force_stepped_clock {
            let finish = p.finish_time();
            self.completions.insert((finish.to_bits(), id));
            self.projected.insert(id, finish);
        }
        self.progress.insert(id, p);
    }

    /// Remove a job's progress (completion or preemption checkpoint),
    /// de-indexing it from the ledger and re-anchoring the returned
    /// checkpoint at `now` — so a preempted job's preserved remaining
    /// work is the same value the stepped clock would have accumulated
    /// (up to the clocks' documented float divergence).
    fn progress_remove(&mut self, id: JobId) -> Option<JobProgress> {
        let mut p = self.progress.remove(&id)?;
        if !self.force_stepped_clock {
            let finish = self.projected.remove(&id).expect("job missing from projection map");
            self.completions.remove(&(finish.to_bits(), id));
            p.remaining = p.remaining_at(self.now);
            p.sync_time = self.now;
        }
        Some(p)
    }

    /// Update one running job's rate. Epoch clock: a genuinely changed
    /// rate re-anchors `(remaining, sync_time)` at `now` and re-indexes
    /// the predicted finish; a *bit-identical* rate is a strict no-op.
    /// The no-op rule is what keeps `force_full_recompute` (which feeds
    /// every running job through here) bitwise-equal to the incremental
    /// delta path (which feeds only the dirty set): rates agree bit for
    /// bit between the two paths, so both re-anchor exactly the
    /// numerically-changed jobs at exactly the same times.
    fn set_rate(&mut self, id: JobId, rate: f64) {
        if self.force_stepped_clock {
            self.progress.get_mut(&id).unwrap().rate = rate;
            return;
        }
        if self.progress[&id].rate.to_bits() == rate.to_bits() {
            return;
        }
        let old = self.projected[&id];
        self.completions.remove(&(old.to_bits(), id));
        let now = self.now;
        let p = self.progress.get_mut(&id).unwrap();
        p.remaining = p.remaining_at(now);
        p.sync_time = now;
        p.rate = rate;
        let finish = p.finish_time();
        self.completions.insert((finish.to_bits(), id));
        self.projected.insert(id, finish);
        self.core_stats.resyncs += 1;
    }

    /// Charge extra remaining work to a running job (the
    /// checkpoint-restart cost of a runtime resize), re-anchoring and
    /// re-indexing under the epoch clock. No-op for jobs not running.
    fn add_remaining(&mut self, id: JobId, extra: f64) {
        if !self.progress.contains_key(&id) {
            return;
        }
        if self.force_stepped_clock {
            self.progress.get_mut(&id).unwrap().remaining += extra;
            return;
        }
        let old = self.projected[&id];
        self.completions.remove(&(old.to_bits(), id));
        let now = self.now;
        let p = self.progress.get_mut(&id).unwrap();
        p.remaining = p.remaining_at(now) + extra;
        p.sync_time = now;
        let finish = p.finish_time();
        self.completions.insert((finish.to_bits(), id));
        self.projected.insert(id, finish);
        self.core_stats.resyncs += 1;
    }

    /// Debug-build pin for the epoch clock: the completion ledger and
    /// the shared projection map must index exactly the running set, and
    /// every indexed finish time must equal the closed-form prediction
    /// from the job's live `(remaining, sync_time, rate)` anchor, bit
    /// for bit. Runs after every scheduling session of every debug-mode
    /// simulation, so the whole test suite exercises the invariant.
    #[cfg(debug_assertions)]
    fn assert_completion_ledger_consistent(&self) {
        if self.force_stepped_clock {
            return;
        }
        assert_eq!(self.completions.len(), self.progress.len(), "completion ledger size drifted");
        assert_eq!(self.projected.len(), self.progress.len(), "projection map size drifted");
        for (&id, p) in &self.progress {
            let finish = p.finish_time();
            assert!(
                self.completions.contains(&(finish.to_bits(), id)),
                "completion ledger missing {id:?} at {finish}"
            );
            assert!(
                self.projected.get(&id).is_some_and(|f| f.to_bits() == finish.to_bits()),
                "projection map drifted for {id:?}"
            );
        }
    }

    /// Submit one job *now*: plan granularity (Algorithm 1), build pods
    /// (Algorithm 2 or a baseline controller), register with the API
    /// server. Jobs whose gang can never fit the cluster (requests vs.
    /// total allocatable per role) are registered but immediately marked
    /// unschedulable instead of stalling the event loop forever.
    fn submit(&mut self, spec: &JobSpec) {
        let info = SystemInfo::of(&self.api.spec);
        let planned = plan(spec, self.policy, info);
        let (pods, hostfile) = self.controller.build(&planned, &mut self.api);
        let job_id = planned.spec.id;
        // Elastic jobs are feasible iff their *minimum*-width gang fits:
        // the scheduler may mold the pending plan down to `min` workers,
        // so only a job whose min gang can never fit is truly stuck.
        let feasible = match planned.spec.elasticity {
            Some(e) => {
                let min_gang: Vec<Pod> = pods
                    .iter()
                    .filter(|p| p.worker_index().map_or(true, |i| i < e.min))
                    .cloned()
                    .collect();
                gang_feasible(&self.api.spec, &min_gang)
            }
            None => gang_feasible(&self.api.spec, &pods),
        };
        self.api.create_job(planned, pods, hostfile, self.now);
        if !feasible {
            self.api.mark_unschedulable(job_id, self.now);
            self.unschedulable.push(job_id);
        }
    }

    /// Run one scheduling session and initialize progress for started
    /// jobs. The scheduler gets the simulator's exact projected completion
    /// times, which the backfill queue policies use for their shadow-time
    /// reservations. Jobs the scheduler preempted are checkpointed
    /// (progress preserved) and re-queued; when they are re-placed, they
    /// resume with the calibrated checkpoint-restart cost added to their
    /// remaining work.
    fn schedule(&mut self) {
        // Epoch clock: the maintained projection map is handed to the
        // scheduler as-is — the same index `next_completion` and the
        // completion harvest read (§Perf: the stepped reference rebuilds
        // this O(R) map from scratch every session).
        let tick = std::time::Instant::now();
        let rebuilt: Option<BTreeMap<JobId, f64>> = if self.force_stepped_clock {
            Some(self.progress.iter().map(|(&id, p)| (id, p.finish_time())).collect())
        } else {
            None
        };
        self.core_stats.core_nanos += tick.elapsed().as_nanos() as u64;
        let projected = rebuilt.as_ref().unwrap_or(&self.projected);
        let started = self.scheduler.cycle_with_projections(&mut self.api, self.now, projected);
        let preempted = self.scheduler.take_preempted();
        let resized = self.scheduler.take_resized();
        for &id in &preempted {
            let checkpoint =
                self.progress_remove(id).expect("preempted job without progress");
            self.api.requeue_job(id, self.now);
            self.suspended.insert(id, checkpoint);
        }
        if started.is_empty() && preempted.is_empty() && resized.is_empty() {
            return;
        }
        // Runtime resizes (expand/shrink of *running* jobs): charge the
        // calibrated checkpoint-restart cost for the moved memory image
        // (the delta workers' pages), then route the job through both
        // sides of the placement delta so its cached contribution is
        // rebuilt from the live post-resize pod set. Molds of pending
        // jobs never appear here — they start through `started` and cost
        // nothing.
        for &(id, moved_bytes) in &resized {
            self.add_remaining(id, self.calib.restart_cost_secs(moved_bytes));
        }
        for &job_id in &started {
            let bench = self.api.jobs[&job_id].planned.spec.benchmark;
            match self.suspended.remove(&job_id) {
                Some(mut p) => {
                    // Checkpoint-restart: preserved remaining work plus the
                    // restore cost for this job's memory image.
                    let mem = self.api.jobs[&job_id].planned.spec.resources.mem_bytes;
                    p.remaining += self.calib.restart_cost_secs(mem);
                    p.rate = 1.0;
                    p.sync_time = self.now;
                    self.progress_insert(job_id, p);
                }
                None => {
                    let noise = self
                        .rng
                        .derive(job_id.0)
                        .lognormal_noise(self.calib.none_variance_sigma);
                    self.progress_insert(
                        job_id,
                        JobProgress {
                            remaining: self.base_work_of(bench),
                            sync_time: self.now,
                            rate: 1.0,
                            noise,
                        },
                    );
                }
            }
        }
        if resized.is_empty() {
            self.apply_placement_delta(&started, &preempted);
        } else {
            let mut added = started;
            let mut removed = preempted;
            for &(id, _) in &resized {
                added.push(id);
                removed.push(id);
            }
            self.apply_placement_delta(&added, &removed);
        }
        #[cfg(debug_assertions)]
        self.assert_completion_ledger_consistent();
    }

    /// Run a trace to completion; returns per-job records + final state.
    /// Borrowing convenience over [`Simulation::run_owned`] for callers
    /// that keep their trace (sweeps replay one trace across policies).
    pub fn run(self, trace: &[JobSpec]) -> SimOutput {
        self.run_owned(trace.to_vec())
    }

    /// Run an owned trace to completion, draining arrivals by value (no
    /// per-submit clone). Arrivals sort by `total_cmp` — a NaN submit
    /// time sorts last and is submitted immediately when reached instead
    /// of panicking the sort (the same bug class PR 2 fixed in the
    /// pending queue).
    pub fn run_owned(mut self, mut arrivals: Vec<JobSpec>) -> SimOutput {
        use std::time::Instant;
        arrivals.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
        let total = arrivals.len();
        let mut arrivals = arrivals.into_iter().peekable();
        let mut finished = 0usize;

        while finished + self.unschedulable.len() < total {
            let arrival_t = arrivals.peek().map(|j| j.submit_time);
            let tick = Instant::now();
            let completion = self.next_completion();
            self.core_stats.core_nanos += tick.elapsed().as_nanos() as u64;

            let (t, is_arrival) = match (arrival_t, completion) {
                (Some(a), Some((c, _))) if a <= c => (a, true),
                (Some(a), None) => (a, true),
                (_, Some((c, _))) => (c, false),
                (None, None) => {
                    // Pending jobs but nothing running and no arrivals.
                    // Give the scheduler one more session first (defensive:
                    // re-queued preemption victims on an idle cluster must
                    // get a chance to restart before being declared stuck).
                    self.schedule();
                    if !self.progress.is_empty() {
                        continue;
                    }
                    // Nothing can start: the leftovers can never fit (the
                    // submit-time feasibility check should catch this;
                    // guard so an adversarial trace degrades to failed
                    // jobs instead of aborting the process).
                    let stuck = self.api.pending_jobs();
                    if stuck.is_empty() {
                        break;
                    }
                    for id in stuck {
                        self.api.mark_unschedulable(id, self.now);
                        self.unschedulable.push(id);
                    }
                    continue;
                }
            };

            self.advance_to(t.max(self.now));
            self.core_stats.events += 1;

            if is_arrival {
                self.core_stats.arrivals += 1;
                // The chosen arrival unconditionally (a NaN submit time
                // fails every `<=` comparison but must still make
                // progress), then batch all further arrivals at this
                // instant.
                let spec = arrivals.next().expect("arrival event without arrival");
                self.submit(&spec);
                while arrivals.peek().is_some_and(|j| j.submit_time <= self.now + 1e-12) {
                    let spec = arrivals.next().expect("peeked arrival vanished");
                    self.submit(&spec);
                }
            } else {
                self.core_stats.completions += 1;
                // Complete every job whose remaining work reached zero.
                let tick = Instant::now();
                let done: Vec<JobId> = if self.force_stepped_clock {
                    self.progress
                        .iter()
                        .filter(|(_, p)| p.remaining <= 1e-6)
                        .map(|(&id, _)| id)
                        .collect()
                } else {
                    // Harvest the ledger prefix whose remaining work at
                    // `now` is within the completion tolerance — the
                    // epoch-clock form of the stepped filter, stopping at
                    // the first entry still out of reach.
                    let mut done = Vec::new();
                    for &(_, id) in &self.completions {
                        if self.progress[&id].remaining_at(self.now) <= 1e-6 {
                            done.push(id);
                        } else {
                            break;
                        }
                    }
                    done
                };
                self.core_stats.core_nanos += tick.elapsed().as_nanos() as u64;
                debug_assert!(!done.is_empty(), "completion event with no finished job");
                for &id in &done {
                    self.progress_remove(id);
                    self.api.finish_job(id, self.now);
                    finished += 1;
                }
                self.apply_placement_delta(&[], &done);
            }

            // State changed: run a scheduling session (Volcano reacts to
            // job-add and resource-release events).
            self.schedule();
        }

        let records = self
            .api
            .jobs
            .values()
            .filter(|j| j.phase == JobPhase::Succeeded)
            .map(|j| JobRecord {
                id: j.planned.spec.id,
                benchmark: j.planned.spec.benchmark,
                tenant: j.planned.spec.tenant,
                priority: j.planned.spec.priority,
                submit_time: j.submit_time,
                start_time: j.first_start_time.expect("job never started"),
                finish_time: j.finish_time.expect("job never finished"),
                running_secs: j.served_secs,
            })
            .collect();
        SimOutput {
            records,
            unschedulable: self.unschedulable,
            api: self.api,
            sched_stats: self.scheduler.stats,
            core_stats: self.core_stats,
        }
    }
}

/// Gang-feasibility on an *idle* cluster: greedy first-fit-decreasing of
/// the job's pods into per-node allocatable capacity, respecting node
/// roles (shared first-fit with the EASY shadow-time search). A job that
/// fails this can never be scheduled, no matter what finishes — the
/// simulator records it as unschedulable at submit.
pub fn gang_feasible(spec: &ClusterSpec, pods: &[Pod]) -> bool {
    let mut free: Vec<Resources> = spec.nodes.iter().map(|n| n.allocatable()).collect();
    // Big pods first so the greedy check is not order-sensitive for the
    // homogeneous pod shapes the controllers emit.
    let mut order: Vec<usize> = (0..pods.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(pods[i].requests.sort_key()));
    crate::scheduler::queue::first_fit_pods(
        spec,
        &mut free,
        order.iter().map(|&i| &pods[i]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::VolcanoMpiController;
    use crate::workload::{exp1_trace, Benchmark};

    fn sim(kubelet: KubeletConfig, policy: GranularityPolicy, cfg: SchedulerConfig) -> Simulation {
        Simulation::new(
            ClusterSpec::paper(),
            kubelet,
            policy,
            Box::new(VolcanoMpiController),
            cfg,
            Calibration::default(),
            42,
        )
    }

    #[test]
    fn single_job_runs_at_base_time_when_pinned_single_task_containers() {
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::Granularity,
            SchedulerConfig::fine_grained(1),
        );
        let trace = vec![JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0)];
        let out = s.run(&trace);
        assert_eq!(out.records.len(), 1);
        let r = &out.records[0];
        assert!((r.wait() - 0.0).abs() < 1e-9);
        // CM_G placement: 16 pinned single-task containers, tiny comm cost.
        let base = Benchmark::EpDgemm.base_running_secs();
        assert!(
            (r.running() - base).abs() / base < 0.05,
            "running {} vs base {}",
            r.running(),
            base
        );
    }

    #[test]
    fn every_job_finishes_and_conserves_time_identities() {
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::Scale,
            SchedulerConfig::fine_grained(2),
        );
        let out = s.run(&exp1_trace());
        assert_eq!(out.records.len(), 10);
        for r in &out.records {
            assert!(r.start_time >= r.submit_time - 1e-9);
            assert!(r.finish_time > r.start_time);
            assert!((r.response() - (r.wait() + r.running())).abs() < 1e-9);
        }
        assert!(out.makespan() > 0.0);
        // All resources returned.
        for n in out.api.spec.node_ids() {
            assert_eq!(out.api.free_on(n), out.api.spec.node(n).allocatable());
        }
    }

    #[test]
    fn contention_slows_concurrent_jobs() {
        // Two STREAM jobs co-scheduled under CM (single 16-task workers):
        // each gets one socket per node or lands on separate nodes; if they
        // share a node, each socket is oversubscribed.
        let mk = |n_jobs: u64| {
            let s = sim(
                KubeletConfig::cpu_mem_affinity(),
                GranularityPolicy::None,
                SchedulerConfig::volcano_default(3),
            );
            let trace: Vec<JobSpec> = (1..=n_jobs)
                .map(|i| JobSpec::paper_job(i, Benchmark::EpStream, 0.0))
                .collect();
            s.run(&trace)
        };
        let one = mk(1).avg_running(Benchmark::EpStream);
        // A single 16-task STREAM worker on one socket already contends.
        assert!(one > Benchmark::EpStream.base_running_secs());
        let eight = mk(8);
        assert!(eight.records.len() == 8);
    }

    #[test]
    fn queueing_produces_wait_times() {
        // 9 jobs at t=0 on a cluster that fits 8: the ninth must wait.
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::None,
            SchedulerConfig::volcano_default(4),
        );
        let trace: Vec<JobSpec> =
            (1..=9).map(|i| JobSpec::paper_job(i, Benchmark::EpDgemm, 0.0)).collect();
        let out = s.run(&trace);
        let waited: Vec<&JobRecord> = out.records.iter().filter(|r| r.wait() > 1.0).collect();
        assert_eq!(waited.len(), 1, "exactly one job queues");
        assert!(out.overall_response() > 9.0 * Benchmark::EpDgemm.base_running_secs());
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let s = sim(
                KubeletConfig::default_policy(),
                GranularityPolicy::None,
                SchedulerConfig::volcano_default(5),
            );
            s.run(&exp1_trace())
                .records
                .iter()
                .map(|r| (r.id, r.finish_time.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn empty_trace_is_a_clean_noop() {
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::None,
            SchedulerConfig::volcano_default(1),
        );
        let out = s.run(&[]);
        assert!(out.records.is_empty());
        assert_eq!(out.makespan(), 0.0);
        assert_eq!(out.overall_response(), 0.0);
    }

    #[test]
    fn oversized_job_is_recorded_unschedulable_not_a_panic() {
        // A 64-task job under GranularityPolicy::None becomes one 64-core
        // worker, which can never fit a 32-core node. The seed panicked
        // with "simulation stalled"; it must now be recorded as failed
        // while the rest of the trace completes.
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::None,
            SchedulerConfig::volcano_default(1),
        );
        let mut big = JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0);
        big.ntasks = 64;
        big.resources = Resources::new(64_000, crate::cluster::gib(128));
        let trace = vec![big, JobSpec::paper_job(2, Benchmark::EpStream, 10.0)];
        let out = s.run(&trace);
        assert_eq!(out.unschedulable, vec![JobId(1)]);
        assert_eq!(out.api.jobs[&JobId(1)].phase, JobPhase::Unschedulable);
        assert_eq!(out.records.len(), 1);
        assert_eq!(out.records[0].id, JobId(2));
        // The feasible job ran clean — no resource leak from the failed one.
        for n in out.api.spec.node_ids() {
            assert_eq!(out.api.free_on(n), out.api.spec.node(n).allocatable());
        }
    }

    #[test]
    fn all_infeasible_trace_terminates_with_empty_records() {
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::None,
            SchedulerConfig::volcano_default(1),
        );
        let mut big = JobSpec::paper_job(1, Benchmark::MiniFe, 0.0);
        big.ntasks = 40;
        big.resources = Resources::new(40_000, crate::cluster::gib(80));
        let out = s.run(&[big]);
        assert!(out.records.is_empty());
        assert_eq!(out.unschedulable, vec![JobId(1)]);
    }

    #[test]
    fn gang_feasible_respects_roles_and_capacity() {
        use crate::cluster::{PodId, PodRole};
        let spec = ClusterSpec::paper();
        let mk = |id: u64, role: PodRole, cores: u64| {
            let mut p = Pod::new(PodId(id), JobId(1), format!("p{id}"), role);
            p.requests = Resources::new(cores * 1000, crate::cluster::gib(2));
            p
        };
        // Four 32-core workers exactly fill the four worker nodes.
        let full: Vec<Pod> =
            (0..4).map(|i| mk(i, PodRole::Worker { index: i as u32 }, 32)).collect();
        assert!(gang_feasible(&spec, &full));
        // A fifth worker cannot fit anywhere.
        let mut five = full.clone();
        five.push(mk(9, PodRole::Worker { index: 4 }, 32));
        assert!(!gang_feasible(&spec, &five));
        // A 33-core worker can never fit a 32-core node.
        assert!(!gang_feasible(&spec, &[mk(0, PodRole::Worker { index: 0 }, 33)]));
        // Launchers are role-constrained to the control plane (which a
        // worker may not use).
        assert!(gang_feasible(&spec, &[mk(0, PodRole::Launcher, 1)]));
    }

    #[test]
    fn simultaneous_arrivals_all_complete() {
        // Every job at t=0 — exercises the batched-arrival path.
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::Granularity,
            SchedulerConfig::fine_grained(3),
        );
        let trace: Vec<JobSpec> =
            (1..=12).map(|i| JobSpec::paper_job(i, Benchmark::MiniFe, 0.0)).collect();
        let out = s.run(&trace);
        assert_eq!(out.records.len(), 12);
        // 12 × 16 cores > 128-core cluster: at least 4 jobs must wait.
        let waited = out.records.iter().filter(|r| r.wait() > 1.0).count();
        assert!(waited >= 4, "waited={waited}");
    }

    #[test]
    fn high_priority_job_preempts_and_victim_restarts_with_cost() {
        use crate::workload::TenantId;
        // Fill the cluster with 8 long batch jobs at t=0; a priority-10
        // job arrives at t=50. With preemption it starts almost
        // immediately; the evicted victim restarts and pays the
        // checkpoint-restart cost, and every job still completes.
        let mk = |preemption: bool| {
            let cfg = SchedulerConfig::volcano_default(3).with_preemption(preemption);
            let s = Simulation::new(
                ClusterSpec::paper(),
                KubeletConfig::cpu_mem_affinity(),
                GranularityPolicy::None,
                Box::new(VolcanoMpiController),
                cfg,
                Calibration::default(),
                3,
            );
            let mut trace: Vec<JobSpec> =
                (1..=8).map(|i| JobSpec::paper_job(i, Benchmark::EpDgemm, 0.0)).collect();
            trace.push(
                JobSpec::paper_job(9, Benchmark::EpDgemm, 50.0).with_tenant(TenantId(1), 10),
            );
            s.run(&trace)
        };

        let pre = mk(true);
        assert_eq!(pre.records.len(), 9, "every job completes");
        let hi = pre.records.iter().find(|r| r.id == JobId(9)).unwrap();
        assert!(hi.wait() < 1.0, "high-priority wait {} should be ~0", hi.wait());
        assert_eq!(pre.preemption_count(), 1, "exactly one victim evicted");
        // Resources fully returned.
        for n in pre.api.spec.node_ids() {
            assert_eq!(pre.api.free_on(n), pre.api.spec.node(n).allocatable());
        }

        // Without preemption the high-priority job queues behind a full
        // cluster instead.
        let base = mk(false);
        let hi_base = base.records.iter().find(|r| r.id == JobId(9)).unwrap();
        assert!(hi_base.wait() > 100.0, "baseline wait {}", hi_base.wait());
        assert!(
            hi.response() < hi_base.response(),
            "preemption must cut the high-priority response: {} vs {}",
            hi.response(),
            hi_base.response()
        );
    }

    /// Property: the incrementally maintained rate path produces
    /// *bit-identical* simulations to the full-rescan reference, across
    /// cluster shapes (homogeneous + two heterogeneity mixes), schedulers,
    /// and preemption churn. (In debug builds every placement delta
    /// additionally re-verifies each maintained rate against a fresh full
    /// snapshot — see `assert_rates_match_full_recompute`.)
    #[test]
    fn prop_incremental_rates_match_full_recompute_bitwise() {
        use crate::cluster::HeterogeneityMix;
        use crate::workload::two_tenant_trace;
        for case in 0..6u64 {
            let cluster = || match case % 3 {
                0 => ClusterSpec::paper(),
                1 => ClusterSpec::mixed(6, HeterogeneityMix::FatThin),
                _ => ClusterSpec::mixed(6, HeterogeneityMix::Tiered),
            };
            let kubelet = if case % 2 == 0 {
                KubeletConfig::cpu_mem_affinity()
            } else {
                KubeletConfig::default_policy()
            };
            let mk = |force: bool| {
                let mut s = Simulation::new(
                    cluster(),
                    kubelet,
                    GranularityPolicy::Granularity,
                    Box::new(VolcanoMpiController),
                    SchedulerConfig::fine_grained(case).with_preemption(true),
                    Calibration::default(),
                    case,
                );
                s.force_full_recompute = force;
                s
            };
            let trace = two_tenant_trace(12, 40.0, case);
            let key = |o: &SimOutput| {
                o.records
                    .iter()
                    .map(|r| (r.id, r.start_time.to_bits(), r.finish_time.to_bits()))
                    .collect::<Vec<_>>()
            };
            let incremental = mk(false).run(&trace);
            let full = mk(true).run(&trace);
            assert_eq!(key(&incremental), key(&full), "case {case}");
            assert_eq!(incremental.unschedulable, full.unschedulable, "case {case}");
        }
    }

    /// The epoch ledger and the retired stepped clock schedule the same
    /// jobs and agree on every timestamp to well under the 1e-6 s
    /// completion tolerance (they cannot be bit-identical: per-event
    /// `remaining -= dt * rate` decrements round differently than the
    /// closed form). The full cross-scenario sweep lives in
    /// `tests/properties.rs`; this is the in-module smoke.
    #[test]
    fn stepped_clock_reference_matches_epoch_within_tolerance() {
        let mk = |stepped: bool| {
            let mut s = sim(
                KubeletConfig::cpu_mem_affinity(),
                GranularityPolicy::Scale,
                SchedulerConfig::fine_grained(2),
            );
            s.set_force_stepped_clock(stepped);
            s.run(&exp1_trace())
        };
        let epoch = mk(false);
        let stepped = mk(true);
        assert_eq!(epoch.records.len(), stepped.records.len());
        for (e, s) in epoch.records.iter().zip(&stepped.records) {
            assert_eq!(e.id, s.id);
            assert!(
                (e.start_time - s.start_time).abs() < 1e-6,
                "start drift for {:?}: {} vs {}",
                e.id,
                e.start_time,
                s.start_time
            );
            assert!(
                (e.finish_time - s.finish_time).abs() < 1e-6,
                "finish drift for {:?}: {} vs {}",
                e.id,
                e.finish_time,
                s.finish_time
            );
        }
        // The epoch clock re-anchors lazily; the stepped clock never
        // reports a resync (it re-anchors everything every event).
        assert!(epoch.core_stats.resyncs > 0);
        assert_eq!(stepped.core_stats.resyncs, 0);
    }

    #[test]
    fn core_stats_count_arrivals_and_completions() {
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::Scale,
            SchedulerConfig::fine_grained(2),
        );
        let out = s.run(&exp1_trace());
        let cs = out.core_stats;
        assert_eq!(cs.events, cs.arrivals + cs.completions);
        assert!(cs.arrivals >= 1, "at least one arrival batch");
        assert!(cs.completions >= 1, "at least one completion batch");
        assert!(cs.nanos_per_event() >= 0.0);
    }

    #[test]
    fn nan_submit_time_neither_panics_nor_hangs() {
        // The seed's sort used partial_cmp().unwrap(), which panics on a
        // NaN submit time. NaN now sorts last (total_cmp) and the
        // arrival is force-submitted when reached, so the run terminates
        // with every job recorded.
        let s = sim(
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::Granularity,
            SchedulerConfig::fine_grained(1),
        );
        let mut weird = JobSpec::paper_job(2, Benchmark::EpStream, 0.0);
        weird.submit_time = f64::NAN;
        let trace = vec![JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0), weird];
        let out = s.run(&trace);
        assert_eq!(out.records.len(), 2);
    }

    #[test]
    fn heterogeneous_cluster_completes_and_respects_class_capacity() {
        use crate::cluster::{HeterogeneityMix, PodPhase};
        let s = Simulation::new(
            ClusterSpec::mixed(8, HeterogeneityMix::FatThin),
            KubeletConfig::cpu_mem_affinity(),
            GranularityPolicy::Granularity,
            Box::new(VolcanoMpiController),
            SchedulerConfig::fine_grained(7),
            Calibration::default(),
            7,
        );
        let trace: Vec<JobSpec> =
            (1..=10).map(|i| JobSpec::paper_job(i, Benchmark::EpDgemm, (i as f64) * 30.0)).collect();
        let out = s.run(&trace);
        assert_eq!(out.records.len(), 10);
        // Post-mortem: every pod's historical node had the class capacity
        // for it, and all resources returned.
        for pod in out.api.pods.values() {
            assert_eq!(pod.phase, PodPhase::Succeeded);
            let node = pod.node.expect("succeeded pod keeps its node");
            assert!(
                pod.requests.fits_within(&out.api.spec.node(node).allocatable()),
                "pod {:?} exceeded its node class",
                pod.id
            );
        }
        for n in out.api.spec.node_ids() {
            assert_eq!(out.api.free_on(n), out.api.spec.node(n).allocatable());
        }
    }

    #[test]
    fn none_scenario_has_run_to_run_variance_across_jobs() {
        let s = sim(
            KubeletConfig::default_policy(),
            GranularityPolicy::None,
            SchedulerConfig::volcano_default(6),
        );
        let trace: Vec<JobSpec> = (1..=4)
            .map(|i| JobSpec::paper_job(i, Benchmark::EpDgemm, (i - 1) as f64 * 2000.0))
            .collect();
        let out = s.run(&trace);
        let times: Vec<f64> = out.records.iter().map(JobRecord::running).collect();
        let min = times.iter().copied().fold(f64::INFINITY, f64::min);
        let max = times.iter().copied().fold(0.0, f64::max);
        assert!(max - min > 1.0, "shared-pool variance expected: {times:?}");
    }
}
