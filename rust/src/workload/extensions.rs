//! Future-work extensions (paper §VI): I/O-intensive application profiles
//! and mixed HPC–AI workloads.
//!
//! The paper closes with two directions: "consider other application
//! profiles such as I/O applications" and "the scheduling of mixed HPC-AI
//! workloads on Kubernetes". This module implements both on top of the
//! core catalogue:
//!
//! - [`ExtBenchmark::IorLike`] — an IOR-style parallel-filesystem
//!   benchmark. On the paper's testbed storage is a shared GPFS mount, so
//!   its contention domain is *cluster-global* (all nodes share the
//!   filesystem), which makes granularity mostly irrelevant but makes
//!   co-scheduling two I/O jobs expensive — the planner keeps I/O jobs
//!   coarse and the task-group plugin's anti-affinity cannot help; only
//!   admission-level serialization would (a further extension).
//! - [`ExtBenchmark::AiTraining`] — a data-parallel SGD job: CPU-heavy
//!   compute with a periodic Allreduce, profile-wise between MiniFE and
//!   G-FFT. It benefits from `scale` granularity but not from full
//!   `granularity` splitting (gradient exchange grows with container
//!   count).
//!
//! Extended profiles map into the core [`Profile`] space for Algorithm 1
//! (the paper's planner is profile-driven, so new workloads only need a
//! profile mapping plus perf-model coefficients).

use super::benchmark::{Benchmark, MpiProfile, Profile};
use super::job::JobSpec;
use crate::cluster::{gib, JobId, Resources};

/// Extended workload catalogue entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExtBenchmark {
    /// One of the paper's five core benchmarks.
    Core(Benchmark),
    /// IOR-style shared-filesystem benchmark (future work: I/O profile).
    IorLike,
    /// Data-parallel training job (future work: mixed HPC-AI).
    AiTraining,
}

impl ExtBenchmark {
    pub fn name(&self) -> &'static str {
        match self {
            ExtBenchmark::Core(b) => b.name(),
            ExtBenchmark::IorLike => "IOR-like",
            ExtBenchmark::AiTraining => "AI-Training",
        }
    }

    /// Profile mapping used by Algorithm 1. I/O jobs behave like
    /// network-intensive ones from the planner's perspective (keep the
    /// processes together; splitting only multiplies filesystem clients);
    /// AI training is compute-dominant between collectives.
    pub fn planner_profile(&self) -> Profile {
        match self {
            ExtBenchmark::Core(b) => b.profile(),
            ExtBenchmark::IorLike => Profile::Network,
            ExtBenchmark::AiTraining => Profile::Cpu,
        }
    }

    pub fn mpi_profile(&self) -> MpiProfile {
        match self {
            ExtBenchmark::Core(b) => b.mpi_profile(),
            ExtBenchmark::IorLike => MpiProfile {
                comm_fraction: 0.70, // dominated by I/O waits
                dominant_op: "MPI_File_write_all",
                collective_share: 0.8,
            },
            ExtBenchmark::AiTraining => MpiProfile {
                comm_fraction: 0.20,
                dominant_op: "MPI_Allreduce(grads)",
                collective_share: 0.95,
            },
        }
    }

    pub fn base_running_secs(&self) -> f64 {
        match self {
            ExtBenchmark::Core(b) => b.base_running_secs(),
            ExtBenchmark::IorLike => 500.0,
            ExtBenchmark::AiTraining => 900.0,
        }
    }

    /// The closest core benchmark whose perf-model coefficients and AOT
    /// payload stand in for this workload in the simulator (the extended
    /// catalogue reuses the core rate model — DESIGN.md documents this as
    /// the approximation boundary of the future-work prototype).
    pub fn proxy(&self) -> Benchmark {
        match self {
            ExtBenchmark::Core(b) => *b,
            ExtBenchmark::IorLike => Benchmark::GRandomRing,
            ExtBenchmark::AiTraining => Benchmark::MiniFe,
        }
    }

    /// Build a paper-shaped job spec for this workload.
    pub fn job(&self, id: u64, submit_time: f64) -> JobSpec {
        let ntasks = 16;
        JobSpec {
            id: JobId(id),
            name: format!("{}-{}", self.name().to_lowercase().replace('-', ""), id),
            benchmark: self.proxy(),
            ntasks,
            resources: Resources::new(ntasks as u64 * 1000, ntasks as u64 * gib(2)),
            submit_time,
            default_workers: 1,
            tenant: super::job::DEFAULT_TENANT,
            priority: 0,
            elasticity: None,
        }
    }
}

/// A mixed HPC-AI trace (future work §VI): alternating core HPC jobs and
/// AI training jobs plus an I/O job per wave.
pub fn mixed_hpc_ai_trace(waves: usize, wave_interval: f64) -> Vec<JobSpec> {
    let mut jobs = Vec::new();
    let mut id = 0;
    for w in 0..waves {
        let t = w as f64 * wave_interval;
        for ext in [
            ExtBenchmark::Core(Benchmark::EpDgemm),
            ExtBenchmark::AiTraining,
            ExtBenchmark::Core(Benchmark::EpStream),
            ExtBenchmark::IorLike,
        ] {
            id += 1;
            jobs.push(ext.job(id, t + (id % 4) as f64 * 5.0));
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan, GranularityPolicy, SystemInfo};

    #[test]
    fn io_jobs_stay_coarse_under_granularity_policy() {
        let job = ExtBenchmark::IorLike.job(1, 0.0);
        // Profile mapping: the planner sees "network" and keeps it whole.
        assert!(ExtBenchmark::IorLike.planner_profile().is_network());
        let p = plan(&job, GranularityPolicy::Granularity, SystemInfo::homogeneous(4));
        assert_eq!(p.granularity.n_workers, 1);
    }

    #[test]
    fn ai_training_splits_like_cpu_jobs() {
        assert_eq!(ExtBenchmark::AiTraining.planner_profile(), Profile::Cpu);
        let job = ExtBenchmark::AiTraining.job(1, 0.0);
        let p = plan(&job, GranularityPolicy::Scale, SystemInfo::homogeneous(4));
        assert_eq!(p.granularity.n_workers, 4);
    }

    #[test]
    fn mixed_trace_shape() {
        let t = mixed_hpc_ai_trace(3, 300.0);
        assert_eq!(t.len(), 12);
        for w in t.windows(2) {
            assert!(w[0].id.0 < w[1].id.0);
        }
    }

    #[test]
    fn mixed_trace_runs_end_to_end() {
        use crate::scenario::Scenario;
        let trace = mixed_hpc_ai_trace(2, 600.0);
        for scenario in [Scenario::Cm, Scenario::CmGTg] {
            let out = scenario.simulation(5).run(&trace);
            assert_eq!(out.records.len(), 8, "{scenario}");
        }
        // Fine-grained still wins on the mixed workload.
        let cm = Scenario::Cm.simulation(5).run(&trace).overall_response();
        let fg = Scenario::CmGTg.simulation(5).run(&trace).overall_response();
        assert!(fg < cm, "CM_G_TG {fg} vs CM {cm}");
    }

    #[test]
    fn extended_profiles_have_sane_comm_fractions() {
        assert!(ExtBenchmark::IorLike.mpi_profile().comm_fraction > 0.5);
        assert!(ExtBenchmark::AiTraining.mpi_profile().comm_fraction < 0.3);
        assert_eq!(ExtBenchmark::Core(Benchmark::GFft).name(), "G-FFT");
    }
}
