//! MPI job specifications — what the user submits to Scanflow.
//!
//! Mirrors the paper's notation (Table I): a Job fixes `N_t` (the number of
//! MPI processes, as in `mpirun -np 16`) and per-job resource
//! requirements/limits `R(cpu, memory)`; the planner agent later fills in
//! the granularity (`N_w`, `N_g`, `N_n`).

use crate::cluster::{gib, JobId, Resources};

use super::benchmark::Benchmark;

/// Tenant (namespace/queue owner) identity for multi-tenant scheduling.
/// Fair-share weights are registered per tenant on the API server
/// (`ApiServer::set_tenant_weight`); jobs carry only the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

/// The default (single-submitter) tenant every paper trace uses.
pub const DEFAULT_TENANT: TenantId = TenantId(0);

/// Elastic worker-count range of a malleable job (Kub, arXiv 2410.10655):
/// the job can run on any worker count in `[min, max]`, with `preferred`
/// the width the application profile asks for. A rigid job (every paper
/// trace) simply carries no `Elasticity` at all; `min == max == preferred`
/// expresses the same thing explicitly.
///
/// Widths are in *workers*; each worker carries `ntasks / preferred` MPI
/// tasks, so `preferred` must divide `ntasks` (enforced by
/// [`Elasticity::validate`]) and a job at width `w` runs
/// `w * ntasks / preferred` of its tasks concurrently — the simulator
/// scales its progress rate by exactly that fraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Elasticity {
    /// Smallest worker count the job can make progress on (>= 1).
    pub min: u32,
    /// Largest worker count that still speeds the job up.
    pub max: u32,
    /// Profile-preferred worker count (the rigid plan's width).
    pub preferred: u32,
}

impl Elasticity {
    /// A rigid range: `min == max == preferred == workers`.
    pub fn rigid(workers: u32) -> Elasticity {
        Elasticity { min: workers, max: workers, preferred: workers }
    }

    /// Validate the range against a task count. Rejections mirror the
    /// config layer: `min` must be >= 1, `min <= preferred <= max`, and
    /// `preferred` must divide `ntasks` (workers are homogeneous).
    pub fn validate(&self, ntasks: u32) -> Result<(), String> {
        if self.min == 0 {
            return Err("elasticity: min workers must be >= 1".into());
        }
        if self.min > self.max {
            return Err(format!("elasticity: min {} > max {}", self.min, self.max));
        }
        if self.preferred < self.min || self.preferred > self.max {
            return Err(format!(
                "elasticity: preferred {} outside [min {}, max {}]",
                self.preferred, self.min, self.max
            ));
        }
        if ntasks % self.preferred != 0 {
            return Err(format!(
                "elasticity: preferred {} does not divide ntasks {}",
                self.preferred, ntasks
            ));
        }
        Ok(())
    }

    /// True when the range admits no resizing at all.
    pub fn is_rigid(&self) -> bool {
        self.min == self.max
    }
}

/// User-facing job specification.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub benchmark: Benchmark,
    /// `N_t`: number of MPI tasks (fixed by the user).
    pub ntasks: u32,
    /// Total job resources `R(cpu, memory)` — the paper runs
    /// exactly-subscribed: one core per task.
    pub resources: Resources,
    /// Submission time (seconds since experiment start).
    pub submit_time: f64,
    /// User-provided default worker count (used when no granularity policy
    /// is active; the paper's default deployments use a single worker).
    pub default_workers: u32,
    /// Submitting tenant (multi-tenant queues; the paper's single-submitter
    /// traces all use [`DEFAULT_TENANT`]).
    pub tenant: TenantId,
    /// Scheduling priority (PriorityClass value): higher wins. Under a
    /// preemption-enabled scheduler, a gang-blocked job may evict running
    /// jobs of *strictly lower* priority.
    pub priority: u32,
    /// Elastic worker-count range (`None` = rigid, the default for every
    /// paper trace). Only consulted by elasticity-aware schedulers; with
    /// no `elasticity` pipeline plugin the job is treated as rigid at its
    /// planned width.
    pub elasticity: Option<Elasticity>,
}

impl JobSpec {
    /// The paper's standard job: 16 tasks, exactly-subscribed (16 cores),
    /// 2 GiB per task.
    pub fn paper_job(id: u64, benchmark: Benchmark, submit_time: f64) -> JobSpec {
        let ntasks = 16;
        JobSpec {
            id: JobId(id),
            name: format!("{}-{}", benchmark.artifact(), id),
            benchmark,
            ntasks,
            resources: Resources::new(ntasks as u64 * 1000, ntasks as u64 * gib(2)),
            submit_time,
            default_workers: 1,
            tenant: DEFAULT_TENANT,
            priority: 0,
            elasticity: None,
        }
    }

    /// Same job submitted by `tenant` at the given priority.
    pub fn with_tenant(mut self, tenant: TenantId, priority: u32) -> JobSpec {
        self.tenant = tenant;
        self.priority = priority;
        self
    }

    /// Same job with an elastic worker-count range (panics on an invalid
    /// range — trace generators are the only callers and must be exact).
    pub fn with_elasticity(mut self, e: Elasticity) -> JobSpec {
        e.validate(self.ntasks).unwrap_or_else(|err| panic!("{}: {err}", self.name));
        self.elasticity = Some(e);
        self
    }

    /// Tasks carried by each worker of an elastic job (`ntasks` for a
    /// rigid one — its single planning knob is `default_workers`).
    pub fn tasks_per_worker(&self) -> u32 {
        match self.elasticity {
            Some(e) => self.ntasks / e.preferred,
            None => self.ntasks,
        }
    }

    /// Per-task resource share `R / N_t` (Algorithm 2 step 1).
    pub fn per_task_resources(&self) -> Resources {
        self.resources.scaled(1, self.ntasks as u64)
    }
}

/// Granularity decision produced by the planner agent (Algorithm 1 output):
/// the updated job metadata `(N_n, N_w, N_g)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Granularity {
    /// `N_n`: number of nodes the job should span.
    pub n_nodes: u32,
    /// `N_w`: number of worker pods.
    pub n_workers: u32,
    /// `N_g`: number of task groups (for the task-group plugin).
    pub n_groups: u32,
}

/// A job after planning: spec + granularity, ready for the job controller.
#[derive(Debug, Clone)]
pub struct PlannedJob {
    pub spec: JobSpec,
    pub granularity: Granularity,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_job_is_exactly_subscribed() {
        let j = JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0);
        assert_eq!(j.ntasks, 16);
        assert_eq!(j.resources.cpu_milli, 16_000);
        assert_eq!(j.per_task_resources(), Resources::new(1000, gib(2)));
        assert_eq!(j.default_workers, 1);
        // Single-submitter default: tenant 0, priority 0.
        assert_eq!(j.tenant, DEFAULT_TENANT);
        assert_eq!(j.priority, 0);
    }

    #[test]
    fn with_tenant_sets_queue_identity() {
        let j = JobSpec::paper_job(1, Benchmark::GFft, 0.0).with_tenant(TenantId(3), 7);
        assert_eq!(j.tenant, TenantId(3));
        assert_eq!(j.priority, 7);
    }

    #[test]
    fn job_names_are_unique_per_id() {
        let a = JobSpec::paper_job(1, Benchmark::GFft, 0.0);
        let b = JobSpec::paper_job(2, Benchmark::GFft, 0.0);
        assert_ne!(a.name, b.name);
    }

    #[test]
    fn paper_jobs_are_rigid_by_default() {
        let j = JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0);
        assert!(j.elasticity.is_none());
        assert_eq!(j.tasks_per_worker(), 16);
    }

    #[test]
    fn elasticity_validation_rejects_malformed_ranges() {
        let e = |min, max, preferred| Elasticity { min, max, preferred };
        assert!(e(2, 8, 4).validate(16).is_ok());
        assert!(e(0, 8, 4).validate(16).is_err(), "min 0");
        assert!(e(8, 2, 4).validate(16).is_err(), "min > max");
        assert!(e(2, 8, 1).validate(16).is_err(), "preferred below min");
        assert!(e(2, 8, 16).validate(16).is_err(), "preferred above max");
        assert!(e(2, 8, 5).validate(16).is_err(), "preferred !| ntasks");
        assert!(Elasticity::rigid(4).is_rigid());
        assert!(!e(2, 8, 4).is_rigid());
    }

    #[test]
    fn with_elasticity_fixes_tasks_per_worker() {
        let j = JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0)
            .with_elasticity(Elasticity { min: 2, max: 16, preferred: 8 });
        assert_eq!(j.tasks_per_worker(), 2);
        assert_eq!(j.elasticity.unwrap().preferred, 8);
    }
}
