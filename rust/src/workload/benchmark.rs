//! The paper's benchmark catalogue (§V-B): HPC Challenge EP-DGEMM,
//! EP-STREAM, G-FFT, G-RandomRing Bandwidth, and the MiniFE proxy app.
//!
//! Each benchmark carries the application profile the Scanflow planner
//! reads (Algorithm 1) and the resource-demand coefficients the performance
//! model uses. The *compute payload* of each benchmark is the AOT-compiled
//! Pallas kernel of the same name (see python/compile and rust/src/runtime).

use std::fmt;

/// Application profile — the classification the planner agent consumes.
/// (Paper: network-, CPU-, memory-intensive; MiniFE is CPU+memory.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Profile {
    Cpu,
    Memory,
    Network,
    CpuMemory,
}

impl Profile {
    /// Algorithm 1 branches on "network" vs "CPU || memory".
    pub fn is_network(&self) -> bool {
        matches!(self, Profile::Network)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Profile::Cpu => "cpu",
            Profile::Memory => "memory",
            Profile::Network => "network",
            Profile::CpuMemory => "cpu+memory",
        }
    }

    /// Parse the manifest/profile string emitted by python/compile/aot.py.
    pub fn parse(s: &str) -> Option<Profile> {
        match s {
            "cpu" => Some(Profile::Cpu),
            "memory" => Some(Profile::Memory),
            "network" => Some(Profile::Network),
            "cpu+memory" => Some(Profile::CpuMemory),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Benchmark {
    EpDgemm,
    EpStream,
    GFft,
    GRandomRing,
    MiniFe,
}

pub const ALL_BENCHMARKS: [Benchmark; 5] = [
    Benchmark::EpDgemm,
    Benchmark::EpStream,
    Benchmark::GFft,
    Benchmark::GRandomRing,
    Benchmark::MiniFe,
];

/// Per-benchmark MPI profile — the data behind the paper's Fig. 3.
#[derive(Debug, Clone, Copy)]
pub struct MpiProfile {
    /// Fraction of (well-placed, single-node) runtime spent in MPI calls.
    pub comm_fraction: f64,
    /// Dominant MPI operation, as Fig. 3 reports.
    pub dominant_op: &'static str,
    /// Fraction of MPI time that is global/collective (vs point-to-point).
    pub collective_share: f64,
}

impl Benchmark {
    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::EpDgemm => "EP-DGEMM",
            Benchmark::EpStream => "EP-STREAM",
            Benchmark::GFft => "G-FFT",
            Benchmark::GRandomRing => "G-RandomRing",
            Benchmark::MiniFe => "MiniFE",
        }
    }

    /// Artifact key (matches python/compile/model.py SPECS and
    /// artifacts/manifest.json).
    pub fn artifact(&self) -> &'static str {
        match self {
            Benchmark::EpDgemm => "dgemm",
            Benchmark::EpStream => "stream",
            Benchmark::GFft => "fft",
            Benchmark::GRandomRing => "ring",
            Benchmark::MiniFe => "minife",
        }
    }

    pub fn from_artifact(s: &str) -> Option<Benchmark> {
        ALL_BENCHMARKS.iter().copied().find(|b| b.artifact() == s)
    }

    /// Application profile (paper §V-B): EP-DGEMM is CPU-intensive,
    /// EP-STREAM memory-bandwidth-intensive, G-FFT and G-RandomRing
    /// network-intensive, MiniFE memory+CPU-intensive.
    pub fn profile(&self) -> Profile {
        match self {
            Benchmark::EpDgemm => Profile::Cpu,
            Benchmark::EpStream => Profile::Memory,
            Benchmark::GFft => Profile::Network,
            Benchmark::GRandomRing => Profile::Network,
            Benchmark::MiniFe => Profile::CpuMemory,
        }
    }

    /// MPI profile behind Fig. 3. Communication fractions follow the
    /// paper's classification (and [12]): throughput benchmarks barely
    /// communicate; G-FFT/G-RandomRing are dominated by global exchange;
    /// MiniFE has Allreduce that scales without much latency ([27]).
    pub fn mpi_profile(&self) -> MpiProfile {
        match self {
            Benchmark::EpDgemm => MpiProfile {
                comm_fraction: 0.02,
                dominant_op: "MPI_Allreduce(8B)",
                collective_share: 0.9,
            },
            Benchmark::EpStream => MpiProfile {
                comm_fraction: 0.03,
                dominant_op: "MPI_Allreduce(8B)",
                collective_share: 0.9,
            },
            Benchmark::GFft => MpiProfile {
                comm_fraction: 0.55,
                dominant_op: "MPI_Alltoall(large)",
                collective_share: 0.85,
            },
            Benchmark::GRandomRing => MpiProfile {
                comm_fraction: 0.65,
                dominant_op: "MPI_Sendrecv(ring)",
                collective_share: 0.1,
            },
            Benchmark::MiniFe => MpiProfile {
                comm_fraction: 0.12,
                dominant_op: "MPI_Allreduce(dot)",
                collective_share: 0.7,
            },
        }
    }

    /// Ideal (uncontended, best-placement) running time in seconds for the
    /// paper's 16-task configuration. Calibrated to the Exp-2 scale
    /// (makespan ≈ 2500 s for 20 jobs on 4 nodes — see perfmodel::calib).
    pub fn base_running_secs(&self) -> f64 {
        match self {
            Benchmark::EpDgemm => 600.0,
            Benchmark::EpStream => 480.0,
            Benchmark::GFft => 400.0,
            Benchmark::GRandomRing => 320.0,
            Benchmark::MiniFe => 720.0,
        }
    }

    /// Sustained memory-bandwidth demand per MPI task, bytes/s. Feeds the
    /// per-socket bandwidth-contention model. STREAM tasks each demand
    /// ~6 GB/s (16 tasks nearly saturate one 2697v4 socket, paper [12]).
    pub fn membw_demand_per_task(&self) -> f64 {
        match self {
            Benchmark::EpDgemm => 0.8e9,
            Benchmark::EpStream => 6.5e9,
            Benchmark::GFft => 1.2e9,
            Benchmark::GRandomRing => 0.6e9,
            Benchmark::MiniFe => 2.6e9,
        }
    }

    /// Bytes each task exchanges per second of communication phase —
    /// drives the Hockney network model (perfmodel::network).
    pub fn comm_bytes_per_task(&self) -> f64 {
        match self {
            Benchmark::EpDgemm => 1.0e5,
            Benchmark::EpStream => 1.0e5,
            Benchmark::GFft => 8.0e7,
            Benchmark::GRandomRing => 3.0e8,
            Benchmark::MiniFe => 1.0e5,
        }
    }
}

impl fmt::Display for Benchmark {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_match_paper_classification() {
        assert_eq!(Benchmark::EpDgemm.profile(), Profile::Cpu);
        assert_eq!(Benchmark::EpStream.profile(), Profile::Memory);
        assert_eq!(Benchmark::GFft.profile(), Profile::Network);
        assert_eq!(Benchmark::GRandomRing.profile(), Profile::Network);
        assert_eq!(Benchmark::MiniFe.profile(), Profile::CpuMemory);
    }

    #[test]
    fn artifact_round_trip() {
        for b in ALL_BENCHMARKS {
            assert_eq!(Benchmark::from_artifact(b.artifact()), Some(b));
        }
        assert_eq!(Benchmark::from_artifact("nope"), None);
    }

    #[test]
    fn profile_parse_round_trip() {
        for p in [Profile::Cpu, Profile::Memory, Profile::Network, Profile::CpuMemory] {
            assert_eq!(Profile::parse(p.as_str()), Some(p));
        }
        assert_eq!(Profile::parse("io"), None);
    }

    #[test]
    fn network_benchmarks_have_high_comm_fraction() {
        for b in ALL_BENCHMARKS {
            let cf = b.mpi_profile().comm_fraction;
            if b.profile().is_network() {
                assert!(cf > 0.4, "{b}: {cf}");
            } else {
                assert!(cf < 0.2, "{b}: {cf}");
            }
        }
    }

    #[test]
    fn stream_nearly_saturates_a_socket() {
        let demand = 16.0 * Benchmark::EpStream.membw_demand_per_task();
        let socket = 76.8e9;
        assert!(demand > socket, "16 STREAM tasks must oversubscribe one socket");
        assert!(demand < 1.5 * socket);
    }
}
