//! Open-loop arrival generators for the production-traffic serving
//! scenario (ROADMAP "production-traffic serving" item).
//!
//! The paper's traces are *closed* fixed lists of jobs; a serving cluster
//! instead sees an open-loop arrival process that keeps submitting no
//! matter how far behind the scheduler falls. This module provides three
//! seed-deterministic generators —
//!
//!   * homogeneous Poisson (memoryless request traffic),
//!   * a two-state MMPP (Markov-modulated Poisson process: calm/bursty
//!     phases with exponential dwell times, the classic bursty-traffic
//!     model), and
//!   * a diurnal rate envelope (sinusoidal day/night cycle, sampled by
//!     Lewis–Shedler thinning)
//!
//! — plus per-tenant composition ([`compose`]) and the mixed
//! [`serve_trace`] blending HPC gangs, AI-inference-sized jobs, and
//! microservice-sized jobs under per-class latency SLOs
//! ([`ServeClass::slo_secs`]). Rates are in jobs *per second*; the
//! serve-mix constants below are stated per hour and divided down.
//!
//! Determinism contract: every generator is a pure function of its
//! parameters and the seed. [`compose`] derives one independent substream
//! per tenant stream (`Rng::derive`), so a stream's arrivals are
//! bit-identical no matter what it is composed with. The generators only
//! *produce* traces — all fixed-trace paths (goldens, differential
//! matrix, fuzz) are untouched by construction, which
//! tests/properties.rs pins.

use crate::cluster::{gib, Resources};
use crate::util::Rng;

use super::benchmark::Benchmark;
use super::job::{JobSpec, TenantId};
use super::trace::ELASTIC_RANGE;

/// An open-loop arrival process over simulated seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson process: exponential inter-arrivals at `rate`
    /// jobs/second.
    Poisson { rate: f64 },
    /// Two-state Markov-modulated Poisson process: arrivals at
    /// `rates[s]` while in state `s`, dwell times exponential with mean
    /// `mean_dwell[s]` seconds, alternating states starting in state 0
    /// (the calm state by convention).
    Mmpp { rates: [f64; 2], mean_dwell: [f64; 2] },
    /// Non-homogeneous Poisson with a sinusoidal (diurnal) envelope:
    /// `rate(t) = base_rate * (1 + amplitude * sin(2πt / period_secs))`,
    /// sampled by Lewis–Shedler thinning. `amplitude` must be in [0, 1)
    /// so the rate stays positive.
    Diurnal { base_rate: f64, amplitude: f64, period_secs: f64 },
}

impl ArrivalProcess {
    /// Validate parameters; rejections mirror the config layer.
    pub fn validate(&self) -> Result<(), String> {
        let pos = |x: f64, what: &str| {
            if x.is_finite() && x > 0.0 {
                Ok(())
            } else {
                Err(format!("arrivals: {what} must be positive and finite (got {x})"))
            }
        };
        match *self {
            ArrivalProcess::Poisson { rate } => pos(rate, "poisson rate"),
            ArrivalProcess::Mmpp { rates, mean_dwell } => {
                pos(rates[0], "mmpp rate[0]")?;
                pos(rates[1], "mmpp rate[1]")?;
                pos(mean_dwell[0], "mmpp dwell[0]")?;
                pos(mean_dwell[1], "mmpp dwell[1]")
            }
            ArrivalProcess::Diurnal { base_rate, amplitude, period_secs } => {
                pos(base_rate, "diurnal base rate")?;
                pos(period_secs, "diurnal period")?;
                if (0.0..1.0).contains(&amplitude) {
                    Ok(())
                } else {
                    Err(format!("arrivals: diurnal amplitude must be in [0, 1) (got {amplitude})"))
                }
            }
        }
    }

    /// Generate all arrival times in `[0, horizon)`, strictly increasing,
    /// consuming `rng` deterministically.
    pub fn arrivals(&self, horizon: f64, rng: &mut Rng) -> Vec<f64> {
        let mut times = Vec::new();
        match *self {
            ArrivalProcess::Poisson { rate } => {
                let mut t = 0.0;
                loop {
                    // Exponential inter-arrival via inverse CDF.
                    t += -(1.0 - rng.f64()).ln() / rate;
                    if t >= horizon {
                        break;
                    }
                    times.push(t);
                }
            }
            ArrivalProcess::Mmpp { rates, mean_dwell } => {
                for (state, start, end) in mmpp_segments(mean_dwell, horizon, rng) {
                    let mut t = start;
                    loop {
                        t += -(1.0 - rng.f64()).ln() / rates[state];
                        if t >= end {
                            break;
                        }
                        times.push(t);
                    }
                }
            }
            ArrivalProcess::Diurnal { base_rate, amplitude, period_secs } => {
                // Lewis–Shedler thinning at the envelope maximum.
                let rate_max = base_rate * (1.0 + amplitude);
                let mut t = 0.0;
                loop {
                    t += -(1.0 - rng.f64()).ln() / rate_max;
                    if t >= horizon {
                        break;
                    }
                    let rate_t = base_rate
                        * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_secs).sin());
                    if rng.f64() < rate_t / rate_max {
                        times.push(t);
                    }
                }
            }
        }
        times
    }
}

/// The MMPP state path over `[0, horizon)`: `(state, start, end)` segments
/// with exponential dwell times of mean `mean_dwell[state]`, alternating
/// from state 0. Exposed so the dwell-time property test can check the
/// generator against its own transition statistics.
pub fn mmpp_segments(
    mean_dwell: [f64; 2],
    horizon: f64,
    rng: &mut Rng,
) -> Vec<(usize, f64, f64)> {
    let mut segments = Vec::new();
    let mut state = 0usize;
    let mut t = 0.0;
    while t < horizon {
        let dwell = -mean_dwell[state] * (1.0 - rng.f64()).ln();
        let end = (t + dwell).min(horizon);
        segments.push((state, t, end));
        t += dwell;
        state = 1 - state;
    }
    segments
}

/// A job class of the serving mix. Class identity is carried on the
/// tenant id (one tenant per class), so per-class SLO accounting can be
/// recovered from any `JobRecord` via [`ServeClass::of_tenant`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ServeClass {
    /// Full 16-task MPI gangs over the whole benchmark catalogue —
    /// the paper's batch HPC traffic.
    HpcGang,
    /// 4-task AI-inference-sized jobs (MiniFE kernel, the AI-training
    /// proxy of workload::extensions, at inference width).
    AiInference,
    /// Single-task microservice-sized jobs (network-profile ring kernel;
    /// the planner keeps network-profile singletons in one container).
    Microservice,
}

/// Every serving class, in tenant order.
pub const ALL_SERVE_CLASSES: [ServeClass; 3] =
    [ServeClass::HpcGang, ServeClass::AiInference, ServeClass::Microservice];

impl ServeClass {
    pub fn name(&self) -> &'static str {
        match self {
            ServeClass::HpcGang => "hpc_gang",
            ServeClass::AiInference => "ai_inference",
            ServeClass::Microservice => "microservice",
        }
    }

    /// Submitting tenant of this class (one tenant per class).
    pub fn tenant(&self) -> TenantId {
        match self {
            ServeClass::HpcGang => TenantId(0),
            ServeClass::AiInference => TenantId(1),
            ServeClass::Microservice => TenantId(2),
        }
    }

    /// Inverse of [`ServeClass::tenant`].
    pub fn of_tenant(tenant: TenantId) -> Option<ServeClass> {
        ALL_SERVE_CLASSES.iter().copied().find(|c| c.tenant() == tenant)
    }

    /// Scheduling priority: latency-sensitive classes outrank batch.
    pub fn priority(&self) -> u32 {
        match self {
            ServeClass::HpcGang => 0,
            ServeClass::AiInference => 5,
            ServeClass::Microservice => 10,
        }
    }

    /// MPI task count (gang width) of this class's jobs.
    pub fn ntasks(&self) -> u32 {
        match self {
            ServeClass::HpcGang => 16,
            ServeClass::AiInference => 4,
            ServeClass::Microservice => 1,
        }
    }

    /// Response-time SLO (submit → finish, seconds). Batch gangs get a
    /// relaxed target; inference and microservice traffic progressively
    /// tighter ones.
    pub fn slo_secs(&self) -> f64 {
        match self {
            ServeClass::HpcGang => 3600.0,
            ServeClass::AiInference => 1500.0,
            ServeClass::Microservice => 900.0,
        }
    }

    /// Draw this class's benchmark for one job. HPC gangs sample the whole
    /// catalogue (elastic gangs only the splittable compute kernels, as in
    /// `elastic_trace`); the other classes are single-kernel.
    fn benchmark(&self, elastic: bool, rng: &mut Rng) -> Benchmark {
        match self {
            ServeClass::HpcGang => {
                if elastic {
                    const SPLITTABLE: [Benchmark; 3] =
                        [Benchmark::EpDgemm, Benchmark::EpStream, Benchmark::MiniFe];
                    SPLITTABLE[rng.range_usize(0, SPLITTABLE.len())]
                } else {
                    use super::benchmark::ALL_BENCHMARKS;
                    ALL_BENCHMARKS[rng.range_usize(0, ALL_BENCHMARKS.len())]
                }
            }
            ServeClass::AiInference => Benchmark::MiniFe,
            ServeClass::Microservice => Benchmark::GRandomRing,
        }
    }

    /// Build one job of this class: exactly-subscribed like
    /// `JobSpec::paper_job` (one core and 2 GiB per task) at the class's
    /// gang width, tenant, and priority.
    fn job(&self, id: u64, submit_time: f64, elastic: bool, rng: &mut Rng) -> JobSpec {
        let mut spec = JobSpec::paper_job(id, self.benchmark(elastic, rng), submit_time);
        let ntasks = self.ntasks();
        spec.ntasks = ntasks;
        spec.resources = Resources::new(ntasks as u64 * 1000, ntasks as u64 * gib(2));
        let spec = spec.with_tenant(self.tenant(), self.priority());
        if elastic && *self == ServeClass::HpcGang {
            spec.with_elasticity(ELASTIC_RANGE)
        } else {
            spec
        }
    }
}

/// One tenant's open-loop stream: a job class fed by an arrival process.
/// `elastic` marks HPC gangs as malleable (`ELASTIC_RANGE`); it is
/// ignored for the narrow classes, whose widths the range cannot divide.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantStream {
    pub class: ServeClass,
    pub process: ArrivalProcess,
    pub elastic: bool,
}

/// Compose per-tenant streams into one trace over `[0, horizon_secs)`.
///
/// Each stream draws from independent substreams of the seed keyed by its
/// class's tenant id (`derive(1 + tenant)` for arrival times,
/// `derive(0x100 + tenant)` for benchmark choices), so a stream's
/// arrivals are bit-identical regardless of what it is composed with —
/// one stream per class, which is the serving mix's shape. The merged
/// trace is sorted by `(submit_time, stream index)` — ties break by
/// stream order, keeping the merge fully deterministic — and jobs are
/// numbered 1..=n in merged order.
pub fn compose(streams: &[TenantStream], horizon_secs: f64, seed: u64) -> Vec<JobSpec> {
    let root = Rng::seed_from_u64(seed);
    let mut events: Vec<(f64, usize)> = Vec::new();
    for (i, stream) in streams.iter().enumerate() {
        stream
            .process
            .validate()
            .unwrap_or_else(|e| panic!("compose: stream {i} ({}): {e}", stream.class.name()));
        let mut rng = root.derive(1 + stream.class.tenant().0 as u64);
        for t in stream.process.arrivals(horizon_secs, &mut rng) {
            events.push((t, i));
        }
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

    let mut job_rngs: Vec<Rng> =
        streams.iter().map(|s| root.derive(0x100 + s.class.tenant().0 as u64)).collect();
    events
        .into_iter()
        .enumerate()
        .map(|(k, (t, i))| {
            let stream = &streams[i];
            stream.class.job(k as u64 + 1, t, stream.elastic, &mut job_rngs[i])
        })
        .collect()
}

/// Base arrival rate of the HPC-gang stream at multiplier 1× (jobs/hour,
/// diurnal envelope).
pub const SERVE_HPC_PER_HOUR: f64 = 4.0;
/// Calm/bursty arrival rates of the AI-inference MMPP stream at 1×
/// (jobs/hour).
pub const SERVE_AI_PER_HOUR: [f64; 2] = [8.0, 32.0];
/// Mean dwell times of the AI-inference MMPP states (seconds): two calm
/// hours, half-hour bursts.
pub const SERVE_AI_DWELL_SECS: [f64; 2] = [7200.0, 1800.0];
/// Arrival rate of the microservice Poisson stream at 1× (jobs/hour).
pub const SERVE_MICRO_PER_HOUR: f64 = 16.0;
/// Day length of the diurnal HPC envelope (seconds).
pub const SERVE_DIURNAL_PERIOD_SECS: f64 = 86_400.0;
/// Amplitude of the diurnal HPC envelope (peak = 1.5× base).
pub const SERVE_DIURNAL_AMPLITUDE: f64 = 0.5;

fn serve_streams(multiplier: f64, elastic: bool) -> Vec<TenantStream> {
    let per_hour = |r: f64| r * multiplier / 3600.0;
    vec![
        TenantStream {
            class: ServeClass::HpcGang,
            process: ArrivalProcess::Diurnal {
                base_rate: per_hour(SERVE_HPC_PER_HOUR),
                amplitude: SERVE_DIURNAL_AMPLITUDE,
                period_secs: SERVE_DIURNAL_PERIOD_SECS,
            },
            elastic,
        },
        TenantStream {
            class: ServeClass::AiInference,
            process: ArrivalProcess::Mmpp {
                rates: [per_hour(SERVE_AI_PER_HOUR[0]), per_hour(SERVE_AI_PER_HOUR[1])],
                mean_dwell: SERVE_AI_DWELL_SECS,
            },
            elastic: false,
        },
        TenantStream {
            class: ServeClass::Microservice,
            process: ArrivalProcess::Poisson { rate: per_hour(SERVE_MICRO_PER_HOUR) },
            elastic: false,
        },
    ]
}

/// The production serving mix: diurnal HPC gangs + bursty (MMPP)
/// AI-inference traffic + steady microservice traffic, all rates scaled
/// by `multiplier`. Fully determined by `(horizon_secs, multiplier,
/// seed)`; `multiplier` sweeps 1×→100× to locate a policy's saturation
/// knee (`kube-fgs serve`).
pub fn serve_trace(horizon_secs: f64, multiplier: f64, seed: u64) -> Vec<JobSpec> {
    compose(&serve_streams(multiplier, false), horizon_secs, seed)
}

/// The elastic serving mix: same streams, but every HPC gang is malleable
/// (`ELASTIC_RANGE`, splittable kernels only) so elasticity-aware EL_*
/// policies can shrink gangs under load. Rigid policies run the identical
/// trace and simply ignore the range.
pub fn serve_trace_elastic(horizon_secs: f64, multiplier: f64, seed: u64) -> Vec<JobSpec> {
    compose(&serve_streams(multiplier, true), horizon_secs, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(t: &[JobSpec]) -> Vec<(Benchmark, TenantId, u32, u64)> {
        t.iter().map(|j| (j.benchmark, j.tenant, j.ntasks, j.submit_time.to_bits())).collect()
    }

    #[test]
    fn same_seed_bit_identical_different_seed_not() {
        let a = serve_trace(48.0 * 3600.0, 1.0, 7);
        let b = serve_trace(48.0 * 3600.0, 1.0, 7);
        let c = serve_trace(48.0 * 3600.0, 1.0, 8);
        assert_eq!(key(&a), key(&b));
        assert_ne!(key(&a), key(&c));
        assert!(!a.is_empty());
    }

    #[test]
    fn poisson_empirical_rate_matches_lambda() {
        // λ = 0.01/s over 10⁶ s ⇒ E[n] = 10 000, σ = 100; ±5σ bound.
        let mut rng = Rng::seed_from_u64(42);
        let n = ArrivalProcess::Poisson { rate: 0.01 }.arrivals(1e6, &mut rng).len() as f64;
        assert!((n - 10_000.0).abs() < 500.0, "n={n}");
    }

    #[test]
    fn diurnal_empirical_rate_matches_base_over_whole_periods() {
        // Over whole periods the sinusoid integrates to zero, so the mean
        // rate is the base rate: E[n] = 10 000 over 100 periods.
        let p = ArrivalProcess::Diurnal { base_rate: 0.01, amplitude: 0.5, period_secs: 1e4 };
        let mut rng = Rng::seed_from_u64(42);
        let n = p.arrivals(1e6, &mut rng).len() as f64;
        assert!((n - 10_000.0).abs() < 500.0, "n={n}");
    }

    #[test]
    fn mmpp_dwell_times_respect_transition_means() {
        let mean_dwell = [200.0, 50.0];
        let mut rng = Rng::seed_from_u64(7);
        let segs = mmpp_segments(mean_dwell, 2e5, &mut rng);
        // Alternation from state 0 and full coverage of the horizon.
        assert_eq!(segs[0].0, 0);
        assert_eq!(segs[0].1, 0.0);
        for w in segs.windows(2) {
            assert_eq!(w[0].2, w[1].1, "segments tile the horizon");
            assert_ne!(w[0].0, w[1].0, "states alternate");
        }
        // Empirical mean dwell per state within 20% of the configured
        // mean (last segment excluded: it is truncated at the horizon).
        for state in [0usize, 1] {
            let dwells: Vec<f64> = segs[..segs.len() - 1]
                .iter()
                .filter(|s| s.0 == state)
                .map(|s| s.2 - s.1)
                .collect();
            assert!(dwells.len() > 100, "state {state}: {} segments", dwells.len());
            let mean = dwells.iter().sum::<f64>() / dwells.len() as f64;
            let target = mean_dwell[state];
            assert!((mean - target).abs() < 0.2 * target, "state {state}: mean={mean}");
        }
    }

    #[test]
    fn mmpp_arrivals_burstier_in_fast_state() {
        // Sanity: overall arrivals land between the calm-only and
        // burst-only Poisson counts.
        let p = ArrivalProcess::Mmpp { rates: [0.002, 0.02], mean_dwell: [5_000.0, 5_000.0] };
        let mut rng = Rng::seed_from_u64(3);
        let n = p.arrivals(1e6, &mut rng).len() as f64;
        // Equal dwell ⇒ mean rate ≈ 0.011/s ⇒ E[n] ≈ 11 000.
        assert!((4_000.0..=18_000.0).contains(&n), "n={n}");
    }

    #[test]
    fn serve_trace_submit_times_non_decreasing_and_in_horizon() {
        let t = serve_trace(48.0 * 3600.0, 4.0, 2);
        for w in t.windows(2) {
            assert!(w[0].submit_time <= w[1].submit_time, "Simulator::run's sort is a no-op");
        }
        assert!(t.iter().all(|j| (0.0..48.0 * 3600.0).contains(&j.submit_time)));
        for (i, j) in t.iter().enumerate() {
            assert_eq!(j.id.0, i as u64 + 1);
        }
    }

    #[test]
    fn serve_trace_mixes_all_classes_with_class_shapes() {
        let t = serve_trace(48.0 * 3600.0, 2.0, 2);
        for class in ALL_SERVE_CLASSES {
            let of_class: Vec<_> = t.iter().filter(|j| j.tenant == class.tenant()).collect();
            assert!(!of_class.is_empty(), "{} missing", class.name());
            for j in &of_class {
                assert_eq!(j.ntasks, class.ntasks(), "{}", class.name());
                assert_eq!(j.priority, class.priority());
                assert_eq!(j.resources.cpu_milli, class.ntasks() as u64 * 1000);
                assert!(j.elasticity.is_none());
            }
        }
        // Microservice jobs stay on the network-profile kernel.
        for j in t.iter().filter(|j| j.tenant == ServeClass::Microservice.tenant()) {
            assert_eq!(j.benchmark, Benchmark::GRandomRing);
        }
    }

    #[test]
    fn elastic_serve_trace_marks_only_gangs_elastic() {
        let t = serve_trace_elastic(48.0 * 3600.0, 2.0, 2);
        let gang = ServeClass::HpcGang.tenant();
        assert!(t.iter().any(|j| j.tenant == gang));
        for j in &t {
            if j.tenant == gang {
                assert_eq!(j.elasticity, Some(ELASTIC_RANGE));
                assert!(!j.benchmark.profile().is_network());
            } else {
                assert!(j.elasticity.is_none());
            }
        }
    }

    #[test]
    fn multiplier_scales_arrival_volume() {
        let h = 48.0 * 3600.0;
        let n1 = serve_trace(h, 1.0, 2).len() as f64;
        let n8 = serve_trace(h, 8.0, 2).len() as f64;
        assert!(n8 > 4.0 * n1, "n1={n1} n8={n8}");
        // 1× mix volume: dwell-weighted AI rate is 0.8·8 + 0.2·32 =
        // 12.8/h, so the mix means ≈32.8 jobs/h ⇒ ~1574 over 48 h; ±35%.
        let ai = (SERVE_AI_PER_HOUR[0] * SERVE_AI_DWELL_SECS[0]
            + SERVE_AI_PER_HOUR[1] * SERVE_AI_DWELL_SECS[1])
            / (SERVE_AI_DWELL_SECS[0] + SERVE_AI_DWELL_SECS[1]);
        let expect = (SERVE_HPC_PER_HOUR + ai + SERVE_MICRO_PER_HOUR) * 48.0;
        assert!((n1 - expect).abs() < 0.35 * expect, "n1={n1} expect≈{expect}");
    }

    #[test]
    fn streams_are_independent_substreams() {
        // Dropping the other streams must not perturb a stream's arrival
        // times or kernels (tenant-keyed derive isolation).
        let all = serve_streams(1.0, false);
        let solo = [all[2]];
        let horizon = 48.0 * 3600.0;
        let merged = compose(&all, horizon, 5);
        let alone = compose(&solo, horizon, 5);
        let micro: Vec<(u64, Benchmark)> = merged
            .iter()
            .filter(|j| j.tenant == ServeClass::Microservice.tenant())
            .map(|j| (j.submit_time.to_bits(), j.benchmark))
            .collect();
        let alone_key: Vec<(u64, Benchmark)> =
            alone.iter().map(|j| (j.submit_time.to_bits(), j.benchmark)).collect();
        assert!(!micro.is_empty());
        assert_eq!(micro, alone_key);
    }

    #[test]
    fn validation_rejects_malformed_processes() {
        for bad in [
            ArrivalProcess::Poisson { rate: 0.0 },
            ArrivalProcess::Poisson { rate: -1.0 },
            ArrivalProcess::Poisson { rate: f64::NAN },
            ArrivalProcess::Mmpp { rates: [0.0, 1.0], mean_dwell: [1.0, 1.0] },
            ArrivalProcess::Mmpp { rates: [1.0, 1.0], mean_dwell: [1.0, 0.0] },
            ArrivalProcess::Diurnal { base_rate: 1.0, amplitude: 1.0, period_secs: 10.0 },
            ArrivalProcess::Diurnal { base_rate: 1.0, amplitude: -0.1, period_secs: 10.0 },
            ArrivalProcess::Diurnal { base_rate: 1.0, amplitude: 0.5, period_secs: 0.0 },
        ] {
            assert!(bad.validate().is_err(), "should reject: {bad:?}");
        }
        assert!(ArrivalProcess::Poisson { rate: 0.1 }.validate().is_ok());
    }

    #[test]
    fn slo_class_round_trips_through_tenant() {
        for class in ALL_SERVE_CLASSES {
            assert_eq!(ServeClass::of_tenant(class.tenant()), Some(class));
            assert!(class.slo_secs() > 0.0);
        }
        assert_eq!(ServeClass::of_tenant(TenantId(9)), None);
    }
}
