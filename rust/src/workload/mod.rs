//! Workload substrate: the paper's benchmark catalogue, MPI job specs,
//! the experiment trace generators (Exp 1–3), and the open-loop arrival
//! generators of the production serving scenario.

pub mod arrivals;
pub mod benchmark;
pub mod extensions;
pub mod job;
pub mod trace;

pub use arrivals::{
    compose, serve_trace, serve_trace_elastic, ArrivalProcess, ServeClass, TenantStream,
    ALL_SERVE_CLASSES,
};
pub use benchmark::{Benchmark, MpiProfile, Profile, ALL_BENCHMARKS};
pub use extensions::{mixed_hpc_ai_trace, ExtBenchmark};
pub use job::{Elasticity, Granularity, JobSpec, PlannedJob, TenantId, DEFAULT_TENANT};
pub use trace::{
    elastic_trace, exp1_trace, exp2_trace, exp3_trace, two_tenant_trace, uniform_trace,
    BATCH_TENANT, ELASTIC_RANGE, PROD_PRIORITY, PROD_SHARE, PROD_TENANT,
};
