//! Workload trace generators for the paper's three experiments.

use crate::util::Rng;

use super::benchmark::{Benchmark, ALL_BENCHMARKS};
use super::job::{Elasticity, JobSpec, TenantId};

/// Experiment 1 (§V-C): 10 EP-DGEMM jobs, arrival interval 60 s.
pub fn exp1_trace() -> Vec<JobSpec> {
    (0..10)
        .map(|i| JobSpec::paper_job(i + 1, Benchmark::EpDgemm, i as f64 * 60.0))
        .collect()
}

/// Experiment 2 (§V-D): 20 jobs — each of the five benchmarks four times,
/// in a random sequence, with submission times drawn uniformly from
/// [0, 1200] s. Fully determined by `seed`.
pub fn exp2_trace(seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(seed);
    // Four instances of each benchmark ...
    let mut benches: Vec<Benchmark> = ALL_BENCHMARKS
        .iter()
        .flat_map(|&b| std::iter::repeat(b).take(4))
        .collect();
    // ... in a random sequence,
    rng.shuffle(&mut benches);
    // ... with random submission times in [0, 1200].
    let mut times: Vec<f64> = (0..benches.len()).map(|_| rng.range_f64(0.0, 1200.0)).collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    benches
        .into_iter()
        .zip(times)
        .enumerate()
        .map(|(i, (bench, t))| JobSpec::paper_job(i as u64 + 1, bench, t))
        .collect()
}

/// Experiment 3 (§V-E) reuses the Experiment-2 trace ("other settings are
/// the same as experiment 2").
pub fn exp3_trace(seed: u64) -> Vec<JobSpec> {
    exp2_trace(seed)
}

/// Scalability ablation: `n` jobs sampled uniformly over the benchmark set
/// with Poisson-ish arrivals of the given mean interval.
pub fn uniform_trace(n: usize, mean_interval: f64, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            let bench = ALL_BENCHMARKS[rng.range_usize(0, ALL_BENCHMARKS.len())];
            // Exponential inter-arrival via inverse CDF.
            t += -mean_interval * (1.0 - rng.f64()).ln();
            JobSpec::paper_job(i as u64 + 1, bench, t)
        })
        .collect()
}

/// The batch tenant of the fairness ablation: the bulk submitter,
/// default priority.
pub const BATCH_TENANT: TenantId = TenantId(0);

/// The production tenant of the fairness ablation: a minority submitter
/// whose jobs carry [`PROD_PRIORITY`] and (by convention — weights are
/// registered on the API server) a larger fair-share weight.
pub const PROD_TENANT: TenantId = TenantId(1);

/// Priority of the production tenant's jobs (> 0 = may preempt batch jobs
/// under a preemption-enabled scheduler).
pub const PROD_PRIORITY: u32 = 10;

/// Share of the two-tenant trace submitted by the production tenant.
pub const PROD_SHARE: f64 = 0.2;

/// Multi-tenant fairness trace: the shape of [`uniform_trace`], but ~20% of
/// the jobs belong to a high-priority production tenant and the rest to a
/// batch tenant. Fully determined by `seed`.
pub fn two_tenant_trace(n: usize, mean_interval: f64, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            let bench = ALL_BENCHMARKS[rng.range_usize(0, ALL_BENCHMARKS.len())];
            t += -mean_interval * (1.0 - rng.f64()).ln();
            let spec = JobSpec::paper_job(i as u64 + 1, bench, t);
            if rng.f64() < PROD_SHARE {
                spec.with_tenant(PROD_TENANT, PROD_PRIORITY)
            } else {
                spec.with_tenant(BATCH_TENANT, 0)
            }
        })
        .collect()
}

/// Elastic worker range every job of [`elastic_trace`] carries: 16 tasks
/// over `preferred` 8 workers (2 tasks each), shrinkable to 2 workers and
/// expandable to 16. The wide preferred width is deliberate: a rigid run
/// must find 8-worker gangs, so fragmentation leaves capacity idle that
/// moldable/malleable runs use.
pub const ELASTIC_RANGE: Elasticity = Elasticity { min: 2, max: 16, preferred: 8 };

/// Splittable benchmarks for the elasticity ablation: compute- and
/// memory-bound kernels whose granularity the paper already splits fully.
/// (Network-bound jobs are kept out — the planner would keep them whole,
/// making elasticity moot.)
const ELASTIC_BENCHMARKS: [Benchmark; 3] =
    [Benchmark::EpDgemm, Benchmark::EpStream, Benchmark::MiniFe];

/// Elasticity-ablation trace: the two-tenant arrival shape (≈20% of jobs
/// from the high-priority production tenant), but every job is *elastic*
/// with [`ELASTIC_RANGE`]. The same trace is run rigid (elasticity
/// ignored), moldable, and malleable — the modes differ only in the
/// scheduler. Fully determined by `seed`.
pub fn elastic_trace(n: usize, mean_interval: f64, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            let bench = ELASTIC_BENCHMARKS[rng.range_usize(0, ELASTIC_BENCHMARKS.len())];
            t += -mean_interval * (1.0 - rng.f64()).ln();
            let spec = JobSpec::paper_job(i as u64 + 1, bench, t);
            let spec = if rng.f64() < PROD_SHARE {
                spec.with_tenant(PROD_TENANT, PROD_PRIORITY)
            } else {
                spec.with_tenant(BATCH_TENANT, 0)
            };
            spec.with_elasticity(ELASTIC_RANGE)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp1_shape() {
        let t = exp1_trace();
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|j| j.benchmark == Benchmark::EpDgemm));
        assert_eq!(t[0].submit_time, 0.0);
        assert_eq!(t[9].submit_time, 540.0);
    }

    #[test]
    fn exp2_has_four_of_each_benchmark() {
        let t = exp2_trace(42);
        assert_eq!(t.len(), 20);
        for b in ALL_BENCHMARKS {
            assert_eq!(t.iter().filter(|j| j.benchmark == b).count(), 4, "{b}");
        }
    }

    #[test]
    fn exp2_times_sorted_within_window() {
        let t = exp2_trace(42);
        for w in t.windows(2) {
            assert!(w[0].submit_time <= w[1].submit_time);
        }
        assert!(t.iter().all(|j| (0.0..=1200.0).contains(&j.submit_time)));
    }

    #[test]
    fn exp2_deterministic_per_seed() {
        let a = exp2_trace(7);
        let b = exp2_trace(7);
        let c = exp2_trace(8);
        assert_eq!(
            a.iter().map(|j| (j.benchmark, j.submit_time.to_bits())).collect::<Vec<_>>(),
            b.iter().map(|j| (j.benchmark, j.submit_time.to_bits())).collect::<Vec<_>>()
        );
        assert_ne!(
            a.iter().map(|j| (j.benchmark, j.submit_time.to_bits())).collect::<Vec<_>>(),
            c.iter().map(|j| (j.benchmark, j.submit_time.to_bits())).collect::<Vec<_>>()
        );
    }

    #[test]
    fn exp2_ids_unique_and_ordered() {
        let t = exp2_trace(3);
        for (i, j) in t.iter().enumerate() {
            assert_eq!(j.id.0, i as u64 + 1);
        }
    }

    #[test]
    fn uniform_trace_monotone_arrivals() {
        let t = uniform_trace(50, 30.0, 9);
        assert_eq!(t.len(), 50);
        for w in t.windows(2) {
            assert!(w[0].submit_time <= w[1].submit_time);
        }
    }

    #[test]
    fn two_tenant_trace_has_both_tenants_with_prod_minority() {
        let t = two_tenant_trace(200, 60.0, 7);
        assert_eq!(t.len(), 200);
        let prod = t.iter().filter(|j| j.tenant == PROD_TENANT).count();
        let batch = t.iter().filter(|j| j.tenant == BATCH_TENANT).count();
        assert_eq!(prod + batch, 200);
        // ~20% prod, with generous slack for the seeded draw.
        assert!((20..=70).contains(&prod), "prod={prod}");
        for j in &t {
            if j.tenant == PROD_TENANT {
                assert_eq!(j.priority, PROD_PRIORITY);
            } else {
                assert_eq!(j.priority, 0);
            }
        }
        for w in t.windows(2) {
            assert!(w[0].submit_time <= w[1].submit_time);
        }
    }

    #[test]
    fn elastic_trace_is_uniformly_elastic_and_two_tenant() {
        let t = elastic_trace(60, 30.0, 7);
        assert_eq!(t.len(), 60);
        for j in &t {
            let e = j.elasticity.expect("every elastic-trace job is elastic");
            assert_eq!(e, ELASTIC_RANGE);
            assert_eq!(j.ntasks % e.preferred, 0);
            assert_eq!(j.tasks_per_worker(), 2);
            assert!(!j.benchmark.profile().is_network(), "{}", j.benchmark);
        }
        assert!(t.iter().any(|j| j.tenant == PROD_TENANT));
        assert!(t.iter().any(|j| j.tenant == BATCH_TENANT));
        for w in t.windows(2) {
            assert!(w[0].submit_time <= w[1].submit_time);
        }
        // Deterministic per seed.
        let key = |t: &[JobSpec]| {
            t.iter().map(|j| (j.benchmark, j.tenant, j.submit_time.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(key(&elastic_trace(60, 30.0, 7)), key(&t));
        assert_ne!(key(&elastic_trace(60, 30.0, 8)), key(&t));
    }

    #[test]
    fn two_tenant_trace_deterministic_per_seed() {
        let key = |t: &[JobSpec]| {
            t.iter()
                .map(|j| (j.benchmark, j.tenant, j.priority, j.submit_time.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&two_tenant_trace(40, 60.0, 5)), key(&two_tenant_trace(40, 60.0, 5)));
        assert_ne!(key(&two_tenant_trace(40, 60.0, 5)), key(&two_tenant_trace(40, 60.0, 6)));
    }
}
