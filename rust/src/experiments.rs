//! Experiment drivers — one function per paper artefact (Figs. 3–9,
//! Table III), shared by the CLI, the examples, and the bench targets so
//! every surface reproduces identical numbers for a given seed.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::{ClusterSpec, HeterogeneityMix, JobId, Resources};
use crate::metrics::{ExperimentMetrics, SloReport};
use crate::perfmodel::Calibration;
use crate::report;
use crate::scenario::{Scenario, ELASTIC_SCENARIOS, EXP3_SCENARIOS, TABLE2_SCENARIOS};
use crate::scheduler::{
    ElasticityMode, PipelineConfig, PlacementEngineKind, PreemptionPolicy, QueuePolicyKind,
    SchedulerStats, ALL_QUEUE_POLICIES,
};
use crate::simulator::{shard, JobRecord, SimCoreStats, SimDigest, SimOutput, Simulation};
use crate::util::jain_index;
use crate::workload::{
    elastic_trace, exp1_trace, exp2_trace, serve_trace, serve_trace_elastic, two_tenant_trace,
    uniform_trace, Benchmark, JobSpec, TenantId, ALL_BENCHMARKS, BATCH_TENANT, PROD_TENANT,
};

/// Default experiment seed (any seed reproduces the paper's *shape*; this
/// one is used for every number recorded in EXPERIMENTS.md).
pub const DEFAULT_SEED: u64 = 2;

// ---------------------------------------------------------------------
// RunSpec — the unified run API. One builder covers what used to be a
// sprawl of `run_scenario*` free functions plus post-construction
// `Simulation::set_*` calls, and it is the only entry point that knows
// about sharded multi-scheduler runs.
// ---------------------------------------------------------------------

/// Declarative description of one experiment run: scenario + every
/// override knob + the sharding axis. Unset knobs (`None`) fall back to
/// the scenario's own defaults, so `RunSpec::new(s).seed(k).run(trace)`
/// is bit-identical to the historical `run_scenario(s, trace, k, None)`.
///
/// Sharding (`shards > 1`) partitions the cluster into per-class
/// scheduler domains ([`ClusterSpec::shard_domains`]), dispatches the
/// trace across them up-front ([`shard::dispatch`]), and runs one full
/// simulation per domain on a std thread pool. Determinism is by
/// construction (stable domain order, per-domain RNG streams derived
/// from the domain *index*), so the per-shard digests are bit-identical
/// for any thread count. On a homogeneous cluster — or with `shards =
/// 1` — the partition collapses and the run delegates to the plain
/// single-scheduler path on the base seed, provably unchanged.
#[derive(Debug, Clone)]
pub struct RunSpec {
    scenario: Scenario,
    cluster: Option<ClusterSpec>,
    queue: Option<QueuePolicyKind>,
    preemption: Option<bool>,
    preemption_policy: Option<PreemptionPolicy>,
    engine: Option<PlacementEngineKind>,
    walltime_error_factor: Option<f64>,
    pipeline: Option<PipelineConfig>,
    tenant_weights: Vec<(TenantId, f64)>,
    tenant_quotas: Vec<(TenantId, Resources)>,
    force_legacy: bool,
    force_linear_earliest_fit: bool,
    force_stepped_clock: bool,
    shards: usize,
    threads: Option<usize>,
    seed: u64,
    base_work: Option<BTreeMap<Benchmark, f64>>,
}

impl RunSpec {
    pub fn new(scenario: Scenario) -> RunSpec {
        RunSpec {
            scenario,
            cluster: None,
            queue: None,
            preemption: None,
            preemption_policy: None,
            engine: None,
            walltime_error_factor: None,
            pipeline: None,
            tenant_weights: Vec::new(),
            tenant_quotas: Vec::new(),
            force_legacy: false,
            force_linear_earliest_fit: false,
            force_stepped_clock: false,
            shards: 1,
            threads: None,
            seed: DEFAULT_SEED,
            base_work: None,
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Cluster to run on (default: the paper's 4-worker cluster).
    pub fn cluster(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = Some(cluster);
        self
    }

    pub fn queue(mut self, queue: QueuePolicyKind) -> Self {
        self.queue = Some(queue);
        self
    }

    pub fn preemption(mut self, preemption: bool) -> Self {
        self.preemption = Some(preemption);
        self
    }

    pub fn preemption_policy(mut self, policy: PreemptionPolicy) -> Self {
        self.preemption_policy = Some(policy);
        self
    }

    pub fn engine(mut self, engine: PlacementEngineKind) -> Self {
        self.engine = Some(engine);
        self
    }

    pub fn walltime_error_factor(mut self, factor: f64) -> Self {
        self.walltime_error_factor = Some(factor);
        self
    }

    pub fn pipeline(mut self, pipeline: PipelineConfig) -> Self {
        self.pipeline = Some(pipeline);
        self
    }

    pub fn tenant_weight(mut self, tenant: TenantId, weight: f64) -> Self {
        self.tenant_weights.push((tenant, weight));
        self
    }

    pub fn tenant_weights(mut self, weights: &[(TenantId, f64)]) -> Self {
        self.tenant_weights.extend_from_slice(weights);
        self
    }

    pub fn tenant_quota(mut self, tenant: TenantId, quota: Resources) -> Self {
        self.tenant_quotas.push((tenant, quota));
        self
    }

    /// Pin the scheduler to the pre-pipeline legacy cycle (the
    /// differential harness's reference path).
    pub fn legacy_scheduler(mut self, force: bool) -> Self {
        self.force_legacy = force;
        self
    }

    /// Pin `earliest_fit` to the linear reference scan (the segment
    /// tree's pinned reference — property tests compare whole runs).
    pub fn linear_earliest_fit(mut self, force: bool) -> Self {
        self.force_linear_earliest_fit = force;
        self
    }

    /// Pin the simulator to the retired stepped clock (the epoch
    /// ledger's pinned reference — the bounded-divergence property and
    /// the `sim_core` bench compare whole runs).
    pub fn stepped_clock(mut self, force: bool) -> Self {
        self.force_stepped_clock = force;
        self
    }

    /// Number of scheduler domains to shard the cluster into (clamped to
    /// the number of worker capacity classes; default 1 = today's single
    /// scheduler).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Worker threads for a sharded run (default: one per domain). Has
    /// no effect on the outputs — only on wall time.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Per-benchmark base-work overrides (the e2e driver passes
    /// PJRT-measured times).
    pub fn base_work(mut self, base_work: &BTreeMap<Benchmark, f64>) -> Self {
        self.base_work = Some(base_work.clone());
        self
    }

    fn effective_cluster(&self) -> ClusterSpec {
        self.cluster.clone().unwrap_or_else(ClusterSpec::paper)
    }

    /// Build the fully configured single-domain [`Simulation`] this spec
    /// describes (the config file's `build_simulation` delegates here).
    pub fn simulation(&self) -> Simulation {
        self.simulation_on(self.effective_cluster(), self.seed)
    }

    fn simulation_on(&self, cluster: ClusterSpec, seed: u64) -> Simulation {
        let queue = self.queue.unwrap_or_else(|| self.scenario.queue());
        let preemption = self.preemption.unwrap_or_else(|| self.scenario.preemption());
        let mut cfg =
            self.scenario.scheduler(seed).with_queue(queue).with_preemption(preemption);
        if let Some(policy) = self.preemption_policy {
            cfg = cfg.with_preemption_policy(policy);
        }
        if let Some(engine) = self.engine {
            cfg = cfg.with_engine(engine);
        }
        if let Some(factor) = self.walltime_error_factor {
            cfg = cfg.with_walltime_error_factor(factor);
        }
        if let Some(pipeline) = self.pipeline {
            cfg = cfg.with_pipeline(pipeline);
        }
        let mut sim = Simulation::new(
            cluster,
            self.scenario.kubelet(),
            self.scenario.policy(),
            self.scenario.controller(),
            cfg,
            Calibration::default(),
            seed,
        );
        sim.set_force_legacy_scheduler(self.force_legacy);
        sim.set_force_linear_earliest_fit(self.force_linear_earliest_fit);
        sim.set_force_stepped_clock(self.force_stepped_clock);
        for &(tenant, weight) in &self.tenant_weights {
            sim.api.set_tenant_weight(tenant, weight);
        }
        for &(tenant, quota) in &self.tenant_quotas {
            sim.api.set_tenant_quota(tenant, quota);
        }
        if let Some(bw) = &self.base_work {
            sim.base_work = bw.clone();
        }
        sim
    }

    /// Run the experiment. Single-domain specs (the default) run exactly
    /// the historical path; sharded specs fan the domains out over a
    /// thread pool and collect per-domain outputs in stable domain order.
    pub fn run(&self, trace: &[JobSpec]) -> RunOutput {
        let cluster = self.effective_cluster();
        let domains = cluster.shard_domains(self.shards);
        if self.shards <= 1 || domains.len() <= 1 {
            // Delegate to the plain path on the base seed and the
            // *original* cluster — provably bit-identical to the
            // pre-RunSpec runners (property-pinned).
            let out = self.simulation_on(cluster, self.seed).run(trace);
            return RunOutput { shards: vec![out] };
        }
        let assignments = shard::dispatch(&domains, trace);
        let threads = self.threads.unwrap_or(domains.len()).clamp(1, domains.len());
        let slots: Vec<Mutex<Option<SimOutput>>> =
            domains.iter().map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= domains.len() {
                        break;
                    }
                    // The Simulation (trait objects inside) is built and
                    // consumed entirely on this thread; only the plain-data
                    // SimOutput crosses back via its slot.
                    let out = self
                        .simulation_on(domains[i].clone(), shard::shard_seed(self.seed, i))
                        .run(&assignments[i]);
                    *slots[i].lock().unwrap() = Some(out);
                });
            }
        });
        RunOutput {
            shards: slots
                .into_iter()
                .map(|slot| slot.into_inner().unwrap().expect("every shard slot is filled"))
                .collect(),
        }
    }
}

/// Output of a [`RunSpec`] run: one [`SimOutput`] per scheduler domain,
/// in stable domain order (exactly one for unsharded runs).
pub struct RunOutput {
    pub shards: Vec<SimOutput>,
}

impl RunOutput {
    /// The sole output of an unsharded run (panics on a sharded one —
    /// the legacy wrappers and all single-scheduler callers use this).
    pub fn single(mut self) -> SimOutput {
        assert_eq!(self.shards.len(), 1, "single() on a sharded RunOutput");
        self.shards.pop().unwrap()
    }

    pub fn is_sharded(&self) -> bool {
        self.shards.len() > 1
    }

    /// All per-job records across shards, sorted by job id.
    pub fn records(&self) -> Vec<JobRecord> {
        let mut records: Vec<JobRecord> =
            self.shards.iter().flat_map(|s| s.records.iter().cloned()).collect();
        records.sort_by_key(|r| r.id);
        records
    }

    /// All unschedulable job ids across shards, sorted.
    pub fn unschedulable(&self) -> Vec<JobId> {
        let mut ids: Vec<JobId> =
            self.shards.iter().flat_map(|s| s.unschedulable.iter().copied()).collect();
        ids.sort();
        ids
    }

    /// `T = Σ T_i` over every record of every shard (additive, so the
    /// sharded sum equals the metric of the merged record set).
    pub fn overall_response(&self) -> f64 {
        self.shards.iter().map(SimOutput::overall_response).sum()
    }

    /// Makespan of the merged record set: last finish minus first submit
    /// across all shards (0 for an empty run).
    pub fn makespan(&self) -> f64 {
        let records = self.records();
        if records.is_empty() {
            return 0.0;
        }
        let first = records.iter().map(|r| r.submit_time).fold(f64::INFINITY, f64::min);
        let last = records.iter().map(|r| r.finish_time).fold(0.0, f64::max);
        last - first
    }

    /// Per-shard digests in stable domain order.
    pub fn digests(&self) -> Vec<SimDigest> {
        self.shards.iter().map(SimOutput::digest).collect()
    }

    /// One fingerprint for the whole run ([`shard::combined_digest`]).
    pub fn combined_digest(&self) -> u64 {
        shard::combined_digest(&self.digests())
    }

    /// Scheduler-throughput counters summed over the shards.
    pub fn sched_stats(&self) -> SchedulerStats {
        let mut total = SchedulerStats::default();
        for s in &self.shards {
            total.sessions += s.sched_stats.sessions;
            total.decisions += s.sched_stats.decisions;
        }
        total
    }

    /// Simulator-core throughput counters summed over the shards.
    pub fn core_stats(&self) -> SimCoreStats {
        let mut total = SimCoreStats::default();
        for s in &self.shards {
            total.merge(&s.core_stats);
        }
        total
    }
}

// ---------------------------------------------------------------------
// Legacy run helpers — thin wrappers over RunSpec, kept so existing
// call sites (and muscle memory) continue to work unchanged.
// ---------------------------------------------------------------------

/// Run one scenario over a trace, with optional per-benchmark base-work
/// overrides (the e2e driver passes PJRT-measured times). Wrapper over
/// [`RunSpec`].
pub fn run_scenario(
    scenario: Scenario,
    trace: &[JobSpec],
    seed: u64,
    base_work: Option<&BTreeMap<Benchmark, f64>>,
) -> SimOutput {
    let mut spec = RunSpec::new(scenario).seed(seed);
    if let Some(bw) = base_work {
        spec = spec.base_work(bw);
    }
    spec.run(trace).single()
}

/// One scenario's aggregated metrics for a trace.
pub fn run_metrics(scenario: Scenario, trace: &[JobSpec], seed: u64) -> ExperimentMetrics {
    ExperimentMetrics::from(&run_scenario(scenario, trace, seed, None))
}

/// Run one scenario with its queue discipline overridden. Wrapper over
/// [`RunSpec`].
pub fn run_scenario_with_queue(
    scenario: Scenario,
    queue: QueuePolicyKind,
    trace: &[JobSpec],
    seed: u64,
) -> SimOutput {
    RunSpec::new(scenario).seed(seed).queue(queue).run(trace).single()
}

/// Run one scenario with queue discipline, preemption, placement engine,
/// and per-tenant fair-share weights all overridden (the fairness
/// ablation and the CLI `run --preempt` / `run --engine` paths).
/// Wrapper over [`RunSpec`].
pub fn run_scenario_configured(
    scenario: Scenario,
    queue: QueuePolicyKind,
    preemption: bool,
    engine: PlacementEngineKind,
    tenant_weights: &[(TenantId, f64)],
    trace: &[JobSpec],
    seed: u64,
) -> SimOutput {
    run_scenario_pinned(scenario, queue, preemption, engine, tenant_weights, trace, seed, false)
}

/// Same as [`run_scenario_configured`], with the scheduler optionally
/// pinned to the pre-pipeline legacy cycle (the differential harness's
/// reference path, surfaced on the CLI as `run --legacy-scheduler`).
/// Wrapper over [`RunSpec`].
#[allow(clippy::too_many_arguments)]
pub fn run_scenario_pinned(
    scenario: Scenario,
    queue: QueuePolicyKind,
    preemption: bool,
    engine: PlacementEngineKind,
    tenant_weights: &[(TenantId, f64)],
    trace: &[JobSpec],
    seed: u64,
    force_legacy: bool,
) -> SimOutput {
    RunSpec::new(scenario)
        .seed(seed)
        .queue(queue)
        .preemption(preemption)
        .engine(engine)
        .tenant_weights(tenant_weights)
        .legacy_scheduler(force_legacy)
        .run(trace)
        .single()
}

// ---------------------------------------------------------------------
// Queue-policy ablation — FIFO / strict FIFO / SJF / EASY backfill on a
// heavy mixed trace (the queue axis of the scenario matrix).
// ---------------------------------------------------------------------

/// The ablation's default trace shape: 200 mixed jobs, 60 s mean
/// inter-arrival — enough pressure that the queue discipline, not the
/// placement, dominates the overall response time.
pub const QUEUE_ABLATION_JOBS: usize = 200;
pub const QUEUE_ABLATION_INTERVAL: f64 = 60.0;

/// Run every queue policy over the same uniform trace on the CM_G_TG
/// placement configuration.
pub fn queue_ablation(
    seed: u64,
    jobs: usize,
    mean_interval: f64,
) -> Vec<(QueuePolicyKind, ExperimentMetrics)> {
    let trace = uniform_trace(jobs, mean_interval, seed);
    ALL_QUEUE_POLICIES
        .iter()
        .map(|&q| {
            let out = run_scenario_with_queue(Scenario::CmGTg, q, &trace, seed);
            (q, ExperimentMetrics::from(&out))
        })
        .collect()
}

/// Queue-ablation table: overall response, makespan, and average wait per
/// policy (+ response delta vs the seed's FIFO-skip behaviour).
pub fn queue_table(results: &[(QueuePolicyKind, ExperimentMetrics)]) -> String {
    let fifo = results
        .iter()
        .find(|(q, _)| *q == QueuePolicyKind::FifoSkip)
        .map(|(_, m)| m.overall_response)
        .unwrap_or(f64::NAN);
    let rows = results
        .iter()
        .map(|(q, m)| {
            vec![
                q.name().to_string(),
                format!("{:.0}", m.overall_response),
                format!("{:+.0}%", (m.overall_response / fifo - 1.0) * 100.0),
                format!("{:.0}", m.makespan),
                format!("{:.0}", m.avg_wait),
            ]
        })
        .collect::<Vec<_>>();
    report::table(
        &["queue policy", "overall response (s)", "vs fifo", "makespan (s)", "avg wait (s)"],
        &rows,
    )
}

/// Queue-ablation results as a JSON document (the CI perf-trajectory
/// artifact; hand-rendered — the substrate has no serde).
pub fn queue_json(seed: u64, jobs: usize, mean_interval: f64, results: &[(QueuePolicyKind, ExperimentMetrics)]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"ablation\": \"queues\", \"seed\": {seed}, \"jobs\": {jobs}, \"mean_interval_s\": {mean_interval},\n"
    ));
    out.push_str("  \"policies\": [\n");
    for (i, (q, m)) in results.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"policy\": \"{}\", \"overall_response_s\": {:.3}, \"makespan_s\": {:.3}, \"avg_wait_s\": {:.3}}}{}\n",
            q.name(),
            m.overall_response,
            m.makespan,
            m.avg_wait,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

// ---------------------------------------------------------------------
// Scaling sweep — queue-policy matrix across cluster sizes and
// heterogeneity mixes (the cluster-shape axis of the scenario matrix).
// ---------------------------------------------------------------------

/// Default sweep shape: per-worker job pressure is held constant (jobs
/// scale with the cluster, arrivals speed up proportionally), so the
/// curves isolate how each queue discipline *scales* rather than how the
/// offered load changes.
pub const SCALING_JOBS_PER_WORKER: usize = 4;
pub const SCALING_BASE_INTERVAL: f64 = 60.0;
/// Worker count at which the base interval applies (the queue ablation's
/// 8-worker cluster).
pub const SCALING_BASE_WORKERS: f64 = 8.0;
/// Default cluster sizes of the sweep (8 → 32; pass `--sizes` up to 128).
pub const SCALING_DEFAULT_SIZES: [usize; 3] = [8, 16, 32];
/// Default heterogeneity mixes of the sweep.
pub const SCALING_DEFAULT_MIXES: [HeterogeneityMix; 2] =
    [HeterogeneityMix::Uniform, HeterogeneityMix::FatThin];
/// Default shard counts of the sweep (single scheduler only; pass
/// `--shards 1,4` to exercise the sharded scale-out axis).
pub const SCALING_DEFAULT_SHARDS: [usize; 1] = [1];

/// One point of the scaling sweep: a queue policy on a cluster shape at
/// a shard count.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub mix: HeterogeneityMix,
    pub workers: usize,
    pub queue: QueuePolicyKind,
    /// Requested scheduler-domain count (the effective count is capped
    /// by the mix's worker-class count — uniform mixes always collapse
    /// to one domain, which is the shard-invariance property).
    pub shards: usize,
    pub jobs: usize,
    pub metrics: ExperimentMetrics,
    /// Core-seconds served over (makespan × total worker cores), in
    /// `[0, 1]`.
    pub utilization: f64,
    pub unschedulable: usize,
}

/// Fraction of the cluster's worker-core capacity kept busy over the
/// run's makespan (requested cores × in-service seconds, summed over the
/// completed jobs).
pub fn cluster_utilization(out: &SimOutput) -> f64 {
    let total_cores = out.api.spec.total_worker_cores() as f64;
    let makespan = out.makespan();
    if out.records.is_empty() || total_cores <= 0.0 || makespan <= 0.0 {
        return 0.0;
    }
    let core_secs: f64 = out
        .records
        .iter()
        .map(|r| {
            let cores = out.api.jobs[&r.id].planned.spec.resources.cpu_milli as f64 / 1000.0;
            cores * r.running_secs
        })
        .sum();
    (core_secs / (makespan * total_cores)).min(1.0)
}

/// [`cluster_utilization`] generalised to a (possibly sharded) run:
/// core-seconds served across every shard over (merged makespan × the
/// *whole* cluster's worker cores). Identical to `cluster_utilization`
/// for a single-shard run.
pub fn run_utilization(run: &RunOutput, cluster: &ClusterSpec) -> f64 {
    let total_cores = cluster.total_worker_cores() as f64;
    let makespan = run.makespan();
    if total_cores <= 0.0 || makespan <= 0.0 {
        return 0.0;
    }
    let core_secs: f64 = run
        .shards
        .iter()
        .map(|out| {
            out.records
                .iter()
                .map(|r| {
                    let cores =
                        out.api.jobs[&r.id].planned.spec.resources.cpu_milli as f64 / 1000.0;
                    cores * r.running_secs
                })
                .sum::<f64>()
        })
        .sum();
    (core_secs / (makespan * total_cores)).min(1.0)
}

/// Run the queue-policy matrix across cluster sizes, heterogeneity
/// mixes, and shard counts on the CM_G_TG placement configuration. Per
/// point: `workers × jobs_per_worker` jobs with the mean inter-arrival
/// shrunk by `workers / 8` so per-worker pressure is constant across
/// sizes.
pub fn scaling_sweep(
    seed: u64,
    sizes: &[usize],
    mixes: &[HeterogeneityMix],
    policies: &[QueuePolicyKind],
    shards_axis: &[usize],
    jobs_per_worker: usize,
    base_interval: f64,
) -> Vec<ScalingPoint> {
    let mut points = Vec::new();
    for &mix in mixes {
        for &workers in sizes {
            let jobs = jobs_per_worker * workers;
            let interval = base_interval * SCALING_BASE_WORKERS / workers as f64;
            let trace = uniform_trace(jobs, interval, seed);
            for &queue in policies {
                for &shards in shards_axis {
                    let cluster = ClusterSpec::mixed(workers, mix);
                    let run = RunSpec::new(Scenario::CmGTg)
                        .seed(seed)
                        .cluster(cluster.clone())
                        .queue(queue)
                        .shards(shards)
                        .run(&trace);
                    let metrics = if run.is_sharded() {
                        ExperimentMetrics::from_records(&run.records())
                    } else {
                        ExperimentMetrics::from(&run.shards[0])
                    };
                    points.push(ScalingPoint {
                        mix,
                        workers,
                        queue,
                        shards,
                        jobs,
                        utilization: run_utilization(&run, &cluster),
                        unschedulable: run.unschedulable().len(),
                        metrics,
                    });
                }
            }
        }
    }
    points
}

/// Scaling-sweep text table.
pub fn scaling_table(points: &[ScalingPoint]) -> String {
    let rows = points
        .iter()
        .map(|p| {
            vec![
                p.mix.name().to_string(),
                p.workers.to_string(),
                p.queue.name().to_string(),
                p.shards.to_string(),
                p.jobs.to_string(),
                format!("{:.0}", p.metrics.overall_response),
                format!("{:.0}", p.metrics.makespan),
                format!("{:.0}", p.metrics.avg_wait),
                format!("{:.3}", p.utilization),
            ]
        })
        .collect::<Vec<_>>();
    report::table(
        &[
            "mix",
            "workers",
            "queue policy",
            "shards",
            "jobs",
            "overall response (s)",
            "makespan (s)",
            "avg wait (s)",
            "utilization",
        ],
        &rows,
    )
}

/// Scaling-sweep CSV (the CI artifact next to the SVG curves).
pub fn scaling_csv(points: &[ScalingPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.mix.name().to_string(),
                p.workers.to_string(),
                p.queue.name().to_string(),
                p.shards.to_string(),
                p.jobs.to_string(),
                format!("{:.3}", p.metrics.overall_response),
                format!("{:.3}", p.metrics.makespan),
                format!("{:.3}", p.metrics.avg_wait),
                format!("{:.4}", p.utilization),
                p.unschedulable.to_string(),
            ]
        })
        .collect();
    report::csv(
        &[
            "mix",
            "workers",
            "queue_policy",
            "shards",
            "jobs",
            "overall_response_s",
            "makespan_s",
            "avg_wait_s",
            "utilization",
            "unschedulable",
        ],
        &rows,
    )
}

/// Scaling-sweep results as a JSON document (CI artifact; hand-rendered —
/// the substrate has no serde).
pub fn scaling_json(seed: u64, jobs_per_worker: usize, base_interval: f64, points: &[ScalingPoint]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"ablation\": \"scaling\", \"seed\": {seed}, \"jobs_per_worker\": {jobs_per_worker}, \"base_interval_s\": {base_interval},\n"
    ));
    out.push_str("  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mix\": \"{}\", \"workers\": {}, \"policy\": \"{}\", \"shards\": {}, \"jobs\": {}, \"overall_response_s\": {:.3}, \"makespan_s\": {:.3}, \"avg_wait_s\": {:.3}, \"utilization\": {:.4}, \"unschedulable\": {}}}{}\n",
            p.mix.name(),
            p.workers,
            p.queue.name(),
            p.shards,
            p.jobs,
            p.metrics.overall_response,
            p.metrics.makespan,
            p.metrics.avg_wait,
            p.utilization,
            p.unschedulable,
            if i + 1 < points.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

// ---------------------------------------------------------------------
// Fairness ablation — multi-tenant queues (FIFO / fair-share /
// fair-share+preemption / conservative backfill) on a two-tenant trace.
// ---------------------------------------------------------------------

/// The fairness ablation's default trace shape (same pressure as the
/// queue ablation, split across two tenants).
pub const FAIRNESS_JOBS: usize = 200;
pub const FAIRNESS_INTERVAL: f64 = 60.0;

/// Fair-share weight of the production tenant (batch keeps 1.0): prod is
/// entitled to 3× batch's share per unit weight.
pub const PROD_WEIGHT: f64 = 3.0;

/// Per-tenant aggregate of one run.
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub tenant: TenantId,
    pub jobs: usize,
    pub mean_response: f64,
    pub mean_wait: f64,
}

/// Group a run's per-job records by tenant.
pub fn tenant_stats(m: &ExperimentMetrics) -> Vec<TenantStats> {
    let mut grouped: BTreeMap<TenantId, Vec<&crate::simulator::JobRecord>> = BTreeMap::new();
    for r in &m.per_job {
        grouped.entry(r.tenant).or_default().push(r);
    }
    grouped
        .into_iter()
        .map(|(tenant, rs)| {
            let n = rs.len() as f64;
            TenantStats {
                tenant,
                jobs: rs.len(),
                mean_response: rs.iter().map(|r| r.response()).sum::<f64>() / n,
                mean_wait: rs.iter().map(|r| r.wait()).sum::<f64>() / n,
            }
        })
        .collect()
}

/// One row of the fairness ablation.
#[derive(Debug, Clone)]
pub struct FairnessRow {
    pub label: &'static str,
    pub queue: QueuePolicyKind,
    pub preemption: bool,
    pub metrics: ExperimentMetrics,
    pub per_tenant: Vec<TenantStats>,
    /// Jain fairness index over the tenants' mean response times
    /// (1.0 = every tenant sees the same mean response).
    pub jain: f64,
    /// Number of preemption events in the run.
    pub preemptions: usize,
}

impl FairnessRow {
    pub fn tenant(&self, t: TenantId) -> Option<&TenantStats> {
        self.per_tenant.iter().find(|s| s.tenant == t)
    }

    /// The standard six report cells (label, overall response, prod mean
    /// response, batch mean response, Jain index, preemptions) — shared by
    /// the text table and the figures CSV so the two can never drift.
    pub fn report_cells(&self) -> Vec<String> {
        let cell = |t: TenantId| {
            self.tenant(t).map(|s| format!("{:.0}", s.mean_response)).unwrap_or_else(|| "-".into())
        };
        vec![
            self.label.to_string(),
            format!("{:.0}", self.metrics.overall_response),
            cell(PROD_TENANT),
            cell(BATCH_TENANT),
            format!("{:.4}", self.jain),
            self.preemptions.to_string(),
        ]
    }
}

/// The fairness ablation: four queue configurations over the same
/// two-tenant trace on the CM_G_TG placement configuration, with the
/// production tenant weighted [`PROD_WEIGHT`].
pub fn fairness_ablation(seed: u64, jobs: usize, mean_interval: f64) -> Vec<FairnessRow> {
    let trace = two_tenant_trace(jobs, mean_interval, seed);
    let weights = [(BATCH_TENANT, 1.0), (PROD_TENANT, PROD_WEIGHT)];
    let configs: [(&'static str, QueuePolicyKind, bool); 4] = [
        ("fifo", QueuePolicyKind::FifoSkip, false),
        ("fair_share", QueuePolicyKind::FairShare, false),
        ("fair_share+preempt", QueuePolicyKind::FairShare, true),
        ("cons_backfill", QueuePolicyKind::ConservativeBackfill, false),
    ];
    configs
        .into_iter()
        .map(|(label, queue, preemption)| {
            let out = run_scenario_configured(
                Scenario::CmGTg,
                queue,
                preemption,
                PlacementEngineKind::Indexed,
                &weights,
                &trace,
                seed,
            );
            let preemptions = out.preemption_count();
            let metrics = ExperimentMetrics::from(&out);
            let per_tenant = tenant_stats(&metrics);
            let jain =
                jain_index(&per_tenant.iter().map(|s| s.mean_response).collect::<Vec<_>>());
            FairnessRow { label, queue, preemption, metrics, per_tenant, jain, preemptions }
        })
        .collect()
}

/// Fairness-ablation table: per-tenant mean response, evenness, and
/// preemption counts per configuration.
pub fn fairness_table(rows: &[FairnessRow]) -> String {
    let table_rows = rows.iter().map(FairnessRow::report_cells).collect::<Vec<_>>();
    report::table(
        &[
            "queue config",
            "overall response (s)",
            "prod mean resp (s)",
            "batch mean resp (s)",
            "jain",
            "preemptions",
        ],
        &table_rows,
    )
}

/// Fairness-ablation results as a JSON document (CI artifact).
pub fn fairness_json(seed: u64, jobs: usize, mean_interval: f64, rows: &[FairnessRow]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"ablation\": \"fairness\", \"seed\": {seed}, \"jobs\": {jobs}, \"mean_interval_s\": {mean_interval}, \"prod_weight\": {PROD_WEIGHT},\n"
    ));
    out.push_str("  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let tenant_json = |t: TenantId, name: &str| -> String {
            match r.tenant(t) {
                Some(s) => format!(
                    "\"{name}\": {{\"jobs\": {}, \"mean_response_s\": {:.3}, \"mean_wait_s\": {:.3}}}",
                    s.jobs, s.mean_response, s.mean_wait
                ),
                None => format!("\"{name}\": null"),
            }
        };
        out.push_str(&format!(
            "    {{\"config\": \"{}\", \"queue\": \"{}\", \"preemption\": {}, \"overall_response_s\": {:.3}, \"makespan_s\": {:.3}, \"jain\": {:.4}, \"preemptions\": {}, {}, {}}}{}\n",
            r.label,
            r.queue.name(),
            r.preemption,
            r.metrics.overall_response,
            r.metrics.makespan,
            r.jain,
            r.preemptions,
            tenant_json(PROD_TENANT, "prod"),
            tenant_json(BATCH_TENANT, "batch"),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

// ---------------------------------------------------------------------
// Elasticity ablation — rigid / moldable / malleable over the same
// elastic two-tenant trace (the resize axis of the scheduler pipeline).
// ---------------------------------------------------------------------

/// The elasticity ablation's default trace shape: 40 uniformly elastic
/// jobs at 25 s mean inter-arrival. On the paper's 4-node cluster each
/// preferred-width gang (8 × 2-core workers) fills an eighth of the
/// capacity, so arrivals outpace rigid departures and gangs queue —
/// exactly the fragmentation pressure mold/shrink/expand exist to absorb.
pub const ELASTICITY_JOBS: usize = 40;
pub const ELASTICITY_INTERVAL: f64 = 25.0;

/// One row of the elasticity ablation (one EL_* scenario on the trace).
#[derive(Debug, Clone)]
pub struct ElasticityRow {
    pub scenario: Scenario,
    /// Mode label: `rigid`, `moldable`, or `malleable`.
    pub label: &'static str,
    pub metrics: ExperimentMetrics,
    /// Core-seconds served over (makespan × worker cores), in `[0, 1]`.
    pub utilization: f64,
    /// Whole-job evictions in the run.
    pub preemptions: usize,
    /// Resize commits (molds, shrinks, and expands) in the run.
    pub resizes: usize,
}

impl ElasticityRow {
    /// The standard report cells (mode, overall response, makespan, avg
    /// wait, utilization, preemptions, resizes) — shared by the text
    /// table and the figures CSV so the two can never drift.
    pub fn report_cells(&self) -> Vec<String> {
        vec![
            self.label.to_string(),
            format!("{:.0}", self.metrics.overall_response),
            format!("{:.0}", self.metrics.makespan),
            format!("{:.0}", self.metrics.avg_wait),
            format!("{:.3}", self.utilization),
            self.preemptions.to_string(),
            self.resizes.to_string(),
        ]
    }
}

/// The elasticity ablation: the three EL_* scenarios (identical placement
/// configuration, only the elasticity plugin differs) over the same
/// elastic trace.
pub fn elasticity_ablation(seed: u64, jobs: usize, mean_interval: f64) -> Vec<ElasticityRow> {
    let trace = elastic_trace(jobs, mean_interval, seed);
    ELASTIC_SCENARIOS
        .into_iter()
        .map(|scenario| {
            let out = scenario.simulation(seed).run(&trace);
            let label = match scenario.elasticity() {
                None => "rigid",
                Some(ElasticityMode::Moldable) => "moldable",
                Some(ElasticityMode::Malleable) => "malleable",
            };
            ElasticityRow {
                scenario,
                label,
                utilization: cluster_utilization(&out),
                preemptions: out.preemption_count(),
                resizes: out.resize_count(),
                metrics: ExperimentMetrics::from(&out),
            }
        })
        .collect()
}

/// Elasticity-ablation table (+ response delta vs the rigid baseline).
pub fn elasticity_table(rows: &[ElasticityRow]) -> String {
    let rigid = rows
        .iter()
        .find(|r| r.label == "rigid")
        .map(|r| r.metrics.overall_response)
        .unwrap_or(f64::NAN);
    let table_rows = rows
        .iter()
        .map(|r| {
            let mut cells = r.report_cells();
            cells.insert(
                2,
                format!("{:+.0}%", (r.metrics.overall_response / rigid - 1.0) * 100.0),
            );
            cells
        })
        .collect::<Vec<_>>();
    report::table(
        &[
            "mode",
            "overall response (s)",
            "vs rigid",
            "makespan (s)",
            "avg wait (s)",
            "utilization",
            "preemptions",
            "resizes",
        ],
        &table_rows,
    )
}

/// Elasticity-ablation results as a JSON document (CI artifact;
/// hand-rendered — the substrate has no serde).
pub fn elasticity_json(
    seed: u64,
    jobs: usize,
    mean_interval: f64,
    rows: &[ElasticityRow],
) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"ablation\": \"elasticity\", \"seed\": {seed}, \"jobs\": {jobs}, \"mean_interval_s\": {mean_interval},\n"
    ));
    out.push_str("  \"modes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"scenario\": \"{}\", \"overall_response_s\": {:.3}, \"makespan_s\": {:.3}, \"avg_wait_s\": {:.3}, \"utilization\": {:.4}, \"preemptions\": {}, \"resizes\": {}}}{}\n",
            r.label,
            r.scenario.name(),
            r.metrics.overall_response,
            r.metrics.makespan,
            r.metrics.avg_wait,
            r.utilization,
            r.preemptions,
            r.resizes,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

// ---------------------------------------------------------------------
// Serve saturation sweep — open-loop production traffic
// (workload::arrivals) replayed at increasing rate multipliers to locate
// each policy's saturation knee (the serving axis of the roadmap).
// ---------------------------------------------------------------------

/// Default replay horizon of the serve sweep (two simulated days, so the
/// diurnal envelope completes whole periods).
pub const SERVE_HORIZON_HOURS: f64 = 48.0;
/// Default traffic multipliers of the sweep (pass `--multipliers` up to
/// 100× to chase a knee the defaults don't reach).
pub const SERVE_DEFAULT_MULTIPLIERS: [f64; 3] = [1.0, 4.0, 16.0];
/// Default policies of the (rigid) serve sweep: the coarse baseline vs
/// the paper's full fine-grained configuration.
pub const SERVE_DEFAULT_SCENARIOS: [Scenario; 2] = [Scenario::Cm, Scenario::CmGTg];
/// SLO-violation fraction at which a policy counts as saturated; the
/// knee is the interpolated multiplier where its curve crosses this.
pub const SERVE_KNEE_THRESHOLD: f64 = 0.5;

/// One point of the serve sweep: a policy replaying the serving mix at
/// one traffic multiplier.
#[derive(Debug, Clone)]
pub struct ServePoint {
    pub scenario: Scenario,
    pub multiplier: f64,
    /// Jobs submitted by the generator over the horizon.
    pub jobs: usize,
    pub unschedulable: usize,
    pub metrics: ExperimentMetrics,
    /// Per-class + overall latency/SLO accounting of the run.
    pub slo: SloReport,
    /// Core-seconds served over (makespan × worker cores), in `[0, 1]`.
    pub utilization: f64,
    pub preemptions: usize,
    pub resizes: usize,
    /// Simulator events processed for this point (summed over shards).
    pub events: u64,
    /// Simulator events per wall-clock second replaying this point —
    /// the throughput counter CI tracks next to `placement_bench.json`.
    /// Wall-clock derived, so never part of any digest or equality pin.
    pub events_per_sec: f64,
}

/// Replay the serving mix at every `scenarios × multipliers` grid point
/// over `horizon_secs` of open-loop traffic. `elastic` swaps in the
/// malleable-gang mix ([`serve_trace_elastic`]); `shards`/`threads`
/// compose with the scale-out axis exactly as `RunSpec` does (the trace
/// and the per-point accounting are shard-invariant on the homogeneous
/// paper cluster, which tests/properties.rs pins).
pub fn serve_sweep(
    seed: u64,
    scenarios: &[Scenario],
    multipliers: &[f64],
    horizon_secs: f64,
    shards: usize,
    threads: Option<usize>,
    elastic: bool,
) -> Vec<ServePoint> {
    let cluster = ClusterSpec::paper();
    let mut points = Vec::new();
    for &multiplier in multipliers {
        let trace = if elastic {
            serve_trace_elastic(horizon_secs, multiplier, seed)
        } else {
            serve_trace(horizon_secs, multiplier, seed)
        };
        for &scenario in scenarios {
            let mut spec = RunSpec::new(scenario).seed(seed).shards(shards);
            if let Some(t) = threads {
                spec = spec.threads(t);
            }
            let wall = std::time::Instant::now();
            let run = spec.run(&trace);
            let wall_secs = wall.elapsed().as_secs_f64();
            let events = run.core_stats().events;
            let records = run.records();
            let metrics = if run.is_sharded() {
                ExperimentMetrics::from_records(&records)
            } else {
                ExperimentMetrics::from(&run.shards[0])
            };
            points.push(ServePoint {
                scenario,
                multiplier,
                jobs: trace.len(),
                unschedulable: run.unschedulable().len(),
                slo: SloReport::from_records(&records),
                utilization: run_utilization(&run, &cluster),
                preemptions: run.shards.iter().map(SimOutput::preemption_count).sum(),
                resizes: run.shards.iter().map(SimOutput::resize_count).sum(),
                events,
                events_per_sec: if wall_secs > 0.0 { events as f64 / wall_secs } else { 0.0 },
                metrics,
            });
        }
    }
    points
}

/// A policy's saturation knee: the multiplier at which its SLO-violation
/// fraction crosses [`SERVE_KNEE_THRESHOLD`], linearly interpolated
/// between the surrounding sweep points. `None` means the policy never
/// saturated over the swept multipliers (an unbounded knee — compare
/// with `unwrap_or(f64::INFINITY)`).
pub fn serve_knee(points: &[ServePoint], scenario: Scenario) -> Option<f64> {
    let mut curve: Vec<(f64, f64)> = points
        .iter()
        .filter(|p| p.scenario == scenario)
        .map(|p| (p.multiplier, p.slo.violation_fraction()))
        .collect();
    curve.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut prev: Option<(f64, f64)> = None;
    for (m, v) in curve {
        if v >= SERVE_KNEE_THRESHOLD {
            return Some(match prev {
                Some((pm, pv)) if v > pv => {
                    pm + (SERVE_KNEE_THRESHOLD - pv) * (m - pm) / (v - pv)
                }
                _ => m,
            });
        }
        prev = Some((m, v));
    }
    None
}

/// The swept scenarios in first-appearance order with their knees.
pub fn serve_knees(points: &[ServePoint]) -> Vec<(Scenario, Option<f64>)> {
    let mut scenarios: Vec<Scenario> = Vec::new();
    for p in points {
        if !scenarios.contains(&p.scenario) {
            scenarios.push(p.scenario);
        }
    }
    scenarios.into_iter().map(|s| (s, serve_knee(points, s))).collect()
}

/// Serve-sweep text table (one row per policy × multiplier), followed by
/// the knee summary via [`serve_knees`] in the CLI.
pub fn serve_table(points: &[ServePoint]) -> String {
    let rows = points
        .iter()
        .map(|p| {
            vec![
                p.scenario.name().to_string(),
                p.multiplier.to_string(),
                p.jobs.to_string(),
                format!("{:.0}", p.slo.overall.p50),
                format!("{:.0}", p.slo.overall.p95),
                format!("{:.0}", p.slo.overall.p99),
                p.slo.violations.to_string(),
                format!("{:.1}%", p.slo.violation_fraction() * 100.0),
                format!("{:.3}", p.utilization),
            ]
        })
        .collect::<Vec<_>>();
    report::table(
        &[
            "scenario",
            "multiplier",
            "jobs",
            "p50 (s)",
            "p95 (s)",
            "p99 (s)",
            "SLO viol",
            "viol %",
            "utilization",
        ],
        &rows,
    )
}

/// Serve-sweep CSV (overall percentiles + per-class breakdown per point).
pub fn serve_csv(points: &[ServePoint]) -> String {
    let mut headers = vec![
        "scenario".to_string(),
        "multiplier".to_string(),
        "jobs".to_string(),
        "unschedulable".to_string(),
        "p50_s".to_string(),
        "p95_s".to_string(),
        "p99_s".to_string(),
        "violations".to_string(),
        "violation_fraction".to_string(),
        "utilization".to_string(),
        "preemptions".to_string(),
        "resizes".to_string(),
        "events".to_string(),
        "events_per_sec".to_string(),
    ];
    if let Some(first) = points.first() {
        for c in &first.slo.per_class {
            let name = c.class.name();
            headers.push(format!("{name}_jobs"));
            headers.push(format!("{name}_violations"));
            headers.push(format!("{name}_p99_s"));
        }
    }
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            let mut row = vec![
                p.scenario.name().to_string(),
                p.multiplier.to_string(),
                p.jobs.to_string(),
                p.unschedulable.to_string(),
                format!("{:.3}", p.slo.overall.p50),
                format!("{:.3}", p.slo.overall.p95),
                format!("{:.3}", p.slo.overall.p99),
                p.slo.violations.to_string(),
                format!("{:.4}", p.slo.violation_fraction()),
                format!("{:.4}", p.utilization),
                p.preemptions.to_string(),
                p.resizes.to_string(),
                p.events.to_string(),
                format!("{:.0}", p.events_per_sec),
            ];
            for c in &p.slo.per_class {
                row.push(c.jobs.to_string());
                row.push(c.violations.to_string());
                row.push(format!("{:.3}", c.percentiles.p99));
            }
            row
        })
        .collect();
    report::csv(&headers_ref, &rows)
}

/// Serve-sweep results as a JSON document (CI artifact; hand-rendered —
/// the substrate has no serde): per policy, the knee plus the full
/// multiplier curve with per-class SLO accounting.
pub fn serve_json(
    seed: u64,
    horizon_hours: f64,
    elastic: bool,
    points: &[ServePoint],
) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"ablation\": \"serve\", \"seed\": {seed}, \"horizon_hours\": {horizon_hours}, \"elastic\": {elastic}, \"knee_threshold\": {SERVE_KNEE_THRESHOLD},\n"
    ));
    out.push_str("  \"policies\": [\n");
    let knees = serve_knees(points);
    for (si, (scenario, knee)) in knees.iter().enumerate() {
        let knee_json =
            knee.map(|k| format!("{k:.4}")).unwrap_or_else(|| "null".to_string());
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"knee_multiplier\": {knee_json}, \"points\": [\n",
            scenario.name()
        ));
        let of_scenario: Vec<&ServePoint> =
            points.iter().filter(|p| p.scenario == *scenario).collect();
        for (i, p) in of_scenario.iter().enumerate() {
            let classes = p
                .slo
                .per_class
                .iter()
                .map(|c| {
                    format!(
                        "{{\"class\": \"{}\", \"slo_s\": {}, \"jobs\": {}, \"violations\": {}, \"p50_s\": {:.3}, \"p99_s\": {:.3}}}",
                        c.class.name(),
                        c.slo_secs,
                        c.jobs,
                        c.violations,
                        c.percentiles.p50,
                        c.percentiles.p99,
                    )
                })
                .collect::<Vec<_>>()
                .join(", ");
            out.push_str(&format!(
                "      {{\"multiplier\": {}, \"jobs\": {}, \"unschedulable\": {}, \"p50_s\": {:.3}, \"p95_s\": {:.3}, \"p99_s\": {:.3}, \"violations\": {}, \"violation_fraction\": {:.4}, \"utilization\": {:.4}, \"preemptions\": {}, \"resizes\": {}, \"events\": {}, \"events_per_sec\": {:.0}, \"classes\": [{classes}]}}{}\n",
                p.multiplier,
                p.jobs,
                p.unschedulable,
                p.slo.overall.p50,
                p.slo.overall.p95,
                p.slo.overall.p99,
                p.slo.violations,
                p.slo.violation_fraction(),
                p.utilization,
                p.preemptions,
                p.resizes,
                p.events,
                p.events_per_sec,
                if i + 1 < of_scenario.len() { "," } else { "" },
            ));
        }
        out.push_str(&format!(
            "    ]}}{}\n",
            if si + 1 < knees.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

// ---------------------------------------------------------------------
// Fig. 3 — Benchmarks MPI profiling analysis.
// ---------------------------------------------------------------------

/// The Fig.-3 table: per-benchmark compute/MPI split and dominant
/// operation (the classification input to Algorithm 1).
pub fn fig3_rows() -> Vec<Vec<String>> {
    ALL_BENCHMARKS
        .iter()
        .map(|b| {
            let p = b.mpi_profile();
            vec![
                b.name().to_string(),
                format!("{:.0}%", (1.0 - p.comm_fraction) * 100.0),
                format!("{:.0}%", p.comm_fraction * 100.0),
                p.dominant_op.to_string(),
                format!("{:.0}%", p.collective_share * 100.0),
                b.profile().as_str().to_string(),
            ]
        })
        .collect()
}

pub fn fig3_table() -> String {
    report::table(
        &["benchmark", "compute", "MPI", "dominant op", "collective", "profile"],
        &fig3_rows(),
    )
}

// ---------------------------------------------------------------------
// Experiment 1 (Figs. 4–5) — 10 EP-DGEMM jobs, 60 s interval.
// ---------------------------------------------------------------------

pub fn exp1_all_scenarios(seed: u64) -> Vec<(Scenario, ExperimentMetrics)> {
    TABLE2_SCENARIOS
        .iter()
        .map(|&s| (s, run_metrics(s, &exp1_trace(), seed)))
        .collect()
}

/// Fig. 4: average job running time of the 10 EP-DGEMM jobs per scenario.
pub fn fig4_table(results: &[(Scenario, ExperimentMetrics)]) -> String {
    let rows = results
        .iter()
        .map(|(s, m)| {
            vec![
                s.name().to_string(),
                format!("{:.1}", m.avg_running[&Benchmark::EpDgemm]),
            ]
        })
        .collect::<Vec<_>>();
    report::table(&["scenario", "avg running time (s)"], &rows)
}

/// Fig. 5: overall response time per scenario (+ deltas vs NONE and CM).
pub fn fig5_table(results: &[(Scenario, ExperimentMetrics)]) -> String {
    let baseline = |name: &str| {
        results
            .iter()
            .find(|(s, _)| s.name() == name)
            .map(|(_, m)| m.overall_response)
            .unwrap_or(f64::NAN)
    };
    let none = baseline("NONE");
    let cm = baseline("CM");
    let rows = results
        .iter()
        .map(|(s, m)| {
            vec![
                s.name().to_string(),
                format!("{:.0}", m.overall_response),
                format!("{:+.0}%", (1.0 - m.overall_response / none) * 100.0),
                format!("{:+.0}%", (1.0 - m.overall_response / cm) * 100.0),
            ]
        })
        .collect::<Vec<_>>();
    report::table(&["scenario", "overall response (s)", "vs NONE", "vs CM"], &rows)
}

// ---------------------------------------------------------------------
// Experiment 2 (Figs. 6–7) — 20 mixed jobs in [0, 1200] s.
// ---------------------------------------------------------------------

pub fn exp2_all_scenarios(seed: u64) -> Vec<(Scenario, ExperimentMetrics)> {
    TABLE2_SCENARIOS
        .iter()
        .map(|&s| (s, run_metrics(s, &exp2_trace(seed), seed)))
        .collect()
}

/// Fig. 6: per-benchmark average running time per scenario, plus the
/// overall response row.
pub fn fig6_table(results: &[(Scenario, ExperimentMetrics)]) -> String {
    let mut headers: Vec<String> = vec!["metric".into()];
    headers.extend(results.iter().map(|(s, _)| s.name().to_string()));
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut rows = Vec::new();
    for b in ALL_BENCHMARKS {
        let mut row = vec![format!("{} avg run (s)", b.name())];
        for (_, m) in results {
            row.push(format!("{:.0}", m.avg_running.get(&b).copied().unwrap_or(0.0)));
        }
        rows.push(row);
    }
    let mut t_row = vec!["overall response (s)".to_string()];
    for (_, m) in results {
        t_row.push(format!("{:.0}", m.overall_response));
    }
    rows.push(t_row);
    report::table(&headers_ref, &rows)
}

/// Fig. 7: makespan per scenario (+ deltas vs NONE and CM).
pub fn fig7_table(results: &[(Scenario, ExperimentMetrics)]) -> String {
    let baseline = |name: &str| {
        results
            .iter()
            .find(|(s, _)| s.name() == name)
            .map(|(_, m)| m.makespan)
            .unwrap_or(f64::NAN)
    };
    let none = baseline("NONE");
    let cm = baseline("CM");
    let rows = results
        .iter()
        .map(|(s, m)| {
            vec![
                s.name().to_string(),
                format!("{:.0}", m.makespan),
                format!("{:+.0}%", (1.0 - m.makespan / none) * 100.0),
                format!("{:+.0}%", (1.0 - m.makespan / cm) * 100.0),
            ]
        })
        .collect::<Vec<_>>();
    report::table(&["scenario", "makespan (s)", "vs NONE", "vs CM"], &rows)
}

// ---------------------------------------------------------------------
// Experiment 3 (Table III, Figs. 8–9) — framework comparison.
// ---------------------------------------------------------------------

pub fn exp3_all_scenarios(seed: u64) -> Vec<(Scenario, ExperimentMetrics)> {
    EXP3_SCENARIOS
        .iter()
        .map(|&s| (s, run_metrics(s, &exp2_trace(seed), seed)))
        .collect()
}

/// Table III: makespan comparison in the paper's exact format.
pub fn table3(results: &[(Scenario, ExperimentMetrics)]) -> String {
    let rows = results
        .iter()
        .map(|(s, m)| vec![s.name().to_string(), report::fmt_makespan(m.makespan)])
        .collect::<Vec<_>>();
    report::table(&["Scenarios", "Makespan"], &rows)
}

/// Figs. 8/9: per-job running or response time across frameworks.
pub fn per_job_table(
    results: &[(Scenario, ExperimentMetrics)],
    metric: fn(&crate::simulator::JobRecord) -> f64,
    label: &str,
) -> String {
    let mut headers: Vec<String> = vec!["job".into()];
    headers.extend(results.iter().map(|(s, _)| s.name().to_string()));
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let njobs = results[0].1.per_job.len();
    let mut rows = Vec::new();
    for i in 0..njobs {
        let mut row = vec![format!(
            "{}-{}",
            results[0].1.per_job[i].benchmark.name(),
            results[0].1.per_job[i].id.0
        )];
        for (_, m) in results {
            row.push(format!("{:.0}", metric(&m.per_job[i])));
        }
        rows.push(row);
    }
    format!("{label}\n{}", report::table(&headers_ref, &rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_covers_all_benchmarks() {
        let rows = fig3_rows();
        assert_eq!(rows.len(), 5);
        let t = fig3_table();
        assert!(t.contains("EP-DGEMM") && t.contains("MPI_Alltoall(large)"));
    }

    #[test]
    fn exp1_produces_six_scenarios() {
        let results = exp1_all_scenarios(DEFAULT_SEED);
        assert_eq!(results.len(), 6);
        for (s, m) in &results {
            assert_eq!(m.per_job.len(), 10, "{s}");
            assert!(m.overall_response > 0.0);
        }
        // Smoke the renderers.
        assert!(fig4_table(&results).contains("NONE"));
        assert!(fig5_table(&results).contains("vs CM"));
    }

    #[test]
    fn queue_ablation_easy_backfill_beats_strict_fifo() {
        let results =
            queue_ablation(DEFAULT_SEED, QUEUE_ABLATION_JOBS, QUEUE_ABLATION_INTERVAL);
        assert_eq!(results.len(), ALL_QUEUE_POLICIES.len());
        let get = |k: QueuePolicyKind| {
            results.iter().find(|(q, _)| *q == k).map(|(_, m)| m.overall_response).unwrap()
        };
        // Head-blocking wastes the fragmented capacity the fine-grained
        // placement creates; EASY backfills it without starving the head.
        assert!(
            get(QueuePolicyKind::EasyBackfill) < get(QueuePolicyKind::FifoStrict),
            "EASY {} !< strict {}",
            get(QueuePolicyKind::EasyBackfill),
            get(QueuePolicyKind::FifoStrict)
        );
        // Every policy completes the whole trace (nothing starves forever).
        for (q, m) in &results {
            assert_eq!(m.per_job.len(), QUEUE_ABLATION_JOBS, "{q}");
        }
        let table = queue_table(&results);
        assert!(table.contains("easy_backfill") && table.contains("vs fifo"));
    }

    #[test]
    fn fairness_ablation_shape_and_json_render() {
        // Small trace: shape checks only (the 200-job acceptance assertion
        // lives in tests/integration.rs).
        let rows = fairness_ablation(DEFAULT_SEED, 30, 60.0);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert_eq!(r.metrics.per_job.len(), 30, "{}", r.label);
            assert!(r.jain > 0.0 && r.jain <= 1.0 + 1e-12, "{}: jain {}", r.label, r.jain);
            if !r.preemption {
                assert_eq!(r.preemptions, 0, "{}", r.label);
            }
        }
        let fifo = &rows[0];
        assert_eq!(fifo.queue, QueuePolicyKind::FifoSkip);
        assert!(!fifo.preemption);
        let table = fairness_table(&rows);
        assert!(table.contains("fair_share+preempt") && table.contains("jain"));
        let json = fairness_json(DEFAULT_SEED, 30, 60.0, &rows);
        assert!(json.contains("\"ablation\": \"fairness\""));
        assert!(json.contains("\"prod\""));
        let qres = queue_ablation(DEFAULT_SEED, 10, 60.0);
        let qjson = queue_json(DEFAULT_SEED, 10, 60.0, &qres);
        assert!(qjson.contains("\"policy\": \"easy_backfill\""));
        // Both documents must parse with the crate's own JSON substrate.
        assert!(crate::util::Json::parse(&json).is_ok(), "fairness json invalid");
        assert!(crate::util::Json::parse(&qjson).is_ok(), "queues json invalid");
    }

    #[test]
    fn elasticity_ablation_shape_and_renderers() {
        // Small trace: shape checks only (the dominance acceptance
        // assertion at the default 40-job pressure lives in
        // tests/integration.rs).
        let rows = elasticity_ablation(DEFAULT_SEED, 12, 20.0);
        assert_eq!(rows.len(), 3);
        assert_eq!(
            rows.iter().map(|r| r.label).collect::<Vec<_>>(),
            ["rigid", "moldable", "malleable"]
        );
        for r in &rows {
            assert_eq!(r.metrics.per_job.len(), 12, "{}: every job completes", r.label);
            assert!(
                r.utilization > 0.0 && r.utilization <= 1.0,
                "{}: utilization {}",
                r.label,
                r.utilization
            );
        }
        assert_eq!(rows[0].resizes, 0, "the rigid baseline never resizes");
        let table = elasticity_table(&rows);
        assert!(table.contains("malleable") && table.contains("vs rigid"));
        let json = elasticity_json(DEFAULT_SEED, 12, 20.0, &rows);
        assert!(json.contains("\"ablation\": \"elasticity\""));
        assert!(json.contains("\"scenario\": \"EL_MALL\""));
        assert!(crate::util::Json::parse(&json).is_ok(), "elasticity json invalid");
    }

    #[test]
    fn scaling_sweep_shape_and_renderers() {
        // Small sweep: 3 sizes × 2 mixes × 2 policies — the acceptance
        // matrix shape (the CLI defaults run it at 8→32 workers); pins
        // point shape, utilization bounds, and that every renderer agrees.
        let sizes = [2usize, 4, 8];
        let mixes = [HeterogeneityMix::Uniform, HeterogeneityMix::FatThin];
        let policies = [QueuePolicyKind::FifoSkip, QueuePolicyKind::EasyBackfill];
        let points = scaling_sweep(DEFAULT_SEED, &sizes, &mixes, &policies, &[1], 2, 30.0);
        assert_eq!(points.len(), sizes.len() * mixes.len() * policies.len());
        for p in &points {
            assert_eq!(p.jobs, 2 * p.workers);
            assert_eq!(
                p.metrics.per_job.len() + p.unschedulable,
                p.jobs,
                "{} {} {}: every job accounted for",
                p.mix,
                p.workers,
                p.queue
            );
            assert!(
                p.utilization > 0.0 && p.utilization <= 1.0,
                "{} {} {}: utilization {}",
                p.mix,
                p.workers,
                p.queue,
                p.utilization
            );
        }
        // Same policy, same mix, more workers at constant per-worker
        // pressure: the sweep must produce a point for each size.
        let uniform_fifo: Vec<&ScalingPoint> = points
            .iter()
            .filter(|p| p.mix == HeterogeneityMix::Uniform && p.queue == QueuePolicyKind::FifoSkip)
            .collect();
        assert_eq!(uniform_fifo.len(), sizes.len());
        let table = scaling_table(&points);
        assert!(table.contains("fat_thin") && table.contains("utilization"));
        let csv = scaling_csv(&points);
        assert!(csv.lines().count() == points.len() + 1, "csv rows");
        let json = scaling_json(DEFAULT_SEED, 2, 30.0, &points);
        assert!(json.contains("\"ablation\": \"scaling\""));
        assert!(json.contains("\"shards\": 1"));
        assert!(crate::util::Json::parse(&json).is_ok(), "scaling json invalid");
    }

    #[test]
    fn scaling_sweep_shards_axis_is_invariant_on_uniform_mixes() {
        // The shards axis multiplies the point count, and on a uniform
        // mix (one worker class — the partition collapses) every shard
        // count reproduces the single-scheduler numbers bit for bit.
        let sizes = [4usize];
        let mixes = [HeterogeneityMix::Uniform];
        let policies = [QueuePolicyKind::FifoSkip];
        let points =
            scaling_sweep(DEFAULT_SEED, &sizes, &mixes, &policies, &[1, 4], 2, 30.0);
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].shards, 1);
        assert_eq!(points[1].shards, 4);
        assert_eq!(
            points[0].metrics.overall_response.to_bits(),
            points[1].metrics.overall_response.to_bits(),
            "uniform mixes are shard-invariant"
        );
        assert_eq!(
            points[0].metrics.makespan.to_bits(),
            points[1].metrics.makespan.to_bits()
        );
        assert_eq!(points[0].utilization.to_bits(), points[1].utilization.to_bits());
    }

    /// Synthetic point with `viol` of `jobs` microservice records
    /// violating their SLO — for exercising the knee math in isolation.
    fn synthetic_point(scenario: Scenario, multiplier: f64, viol: usize, jobs: usize) -> ServePoint {
        use crate::workload::ServeClass;
        let records: Vec<JobRecord> = (0..jobs)
            .map(|i| {
                let finish = if i < viol { 1000.0 } else { 100.0 };
                JobRecord {
                    id: JobId(i as u64 + 1),
                    benchmark: Benchmark::GRandomRing,
                    tenant: ServeClass::Microservice.tenant(),
                    priority: ServeClass::Microservice.priority(),
                    submit_time: 0.0,
                    start_time: 0.0,
                    finish_time: finish,
                    running_secs: finish,
                }
            })
            .collect();
        ServePoint {
            scenario,
            multiplier,
            jobs,
            unschedulable: 0,
            metrics: ExperimentMetrics::from_records(&records),
            slo: SloReport::from_records(&records),
            utilization: 0.5,
            preemptions: 0,
            resizes: 0,
            events: 0,
            events_per_sec: 0.0,
        }
    }

    #[test]
    fn serve_knee_interpolates_threshold_crossing() {
        let s = Scenario::CmGTg;
        // Fractions 0/4, 1/4, 3/4 at multipliers 1, 2, 4: the 0.5
        // crossing interpolates to 2 + (0.5-0.25)·(4-2)/(0.75-0.25) = 3.
        let points = vec![
            synthetic_point(s, 1.0, 0, 4),
            synthetic_point(s, 2.0, 1, 4),
            synthetic_point(s, 4.0, 3, 4),
        ];
        let knee = serve_knee(&points, s).unwrap();
        assert!((knee - 3.0).abs() < 1e-9, "knee={knee}");
        // Never saturating ⇒ None.
        let calm = vec![synthetic_point(s, 1.0, 0, 4), synthetic_point(s, 8.0, 1, 4)];
        assert_eq!(serve_knee(&calm, s), None);
        // Saturated from the first point ⇒ that multiplier.
        let hot = vec![synthetic_point(s, 2.0, 4, 4)];
        assert_eq!(serve_knee(&hot, s), Some(2.0));
        // Unknown scenario ⇒ no curve ⇒ None.
        assert_eq!(serve_knee(&points, Scenario::Cm), None);
        let knees = serve_knees(&points);
        assert_eq!(knees.len(), 1);
        assert_eq!(knees[0].0, s);
    }

    #[test]
    fn serve_sweep_shape_and_renderers() {
        // Tiny sweep: 1 h at 1× and 3× — shape checks only (the
        // monotonicity/knee acceptance lives in tests/integration.rs).
        let points =
            serve_sweep(DEFAULT_SEED, &[Scenario::CmGTg], &[1.0, 3.0], 3600.0, 1, None, false);
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(p.jobs > 0, "open-loop trace submits jobs");
            assert_eq!(
                p.metrics.per_job.len() + p.unschedulable,
                p.jobs,
                "every job accounted for"
            );
            assert!(p.utilization > 0.0 && p.utilization <= 1.0);
            assert_eq!(p.slo.per_class.len(), 3, "all three serve classes reported");
            assert!(p.slo.per_class.iter().any(|c| c.jobs > 0));
            assert!(p.events > 0, "simulator-core event counter wired through");
        }
        assert!(points[1].jobs > points[0].jobs, "multiplier raises volume");
        let table = serve_table(&points);
        assert!(table.contains("CM_G_TG") && table.contains("p99 (s)"));
        let csv = serve_csv(&points);
        assert_eq!(csv.lines().count(), points.len() + 1);
        assert!(csv.lines().next().unwrap().contains("microservice_p99_s"));
        let json = serve_json(DEFAULT_SEED, 1.0, false, &points);
        assert!(json.contains("\"ablation\": \"serve\""));
        assert!(json.contains("\"knee_multiplier\""));
        assert!(json.contains("\"class\": \"hpc_gang\""));
        assert!(crate::util::Json::parse(&json).is_ok(), "serve json invalid");
    }

    #[test]
    fn serve_sweep_elastic_mix_runs_elastic_scenarios() {
        let points =
            serve_sweep(DEFAULT_SEED, &[Scenario::ElMall], &[2.0], 3600.0, 1, None, true);
        assert_eq!(points.len(), 1);
        let p = &points[0];
        assert_eq!(p.metrics.per_job.len() + p.unschedulable, p.jobs);
        let gang = p
            .slo
            .per_class
            .iter()
            .find(|c| c.class == crate::workload::ServeClass::HpcGang)
            .unwrap();
        assert!(gang.jobs > 0, "elastic mix still carries gangs");
    }

    #[test]
    fn explicit_fifo_skip_is_bit_identical_to_seed_behaviour() {
        let trace = exp2_trace(DEFAULT_SEED);
        let a = run_scenario(Scenario::CmGTg, &trace, DEFAULT_SEED, None);
        let b = run_scenario_with_queue(
            Scenario::CmGTg,
            QueuePolicyKind::FifoSkip,
            &trace,
            DEFAULT_SEED,
        );
        let key = |o: &SimOutput| {
            o.records
                .iter()
                .map(|r| (r.id, r.start_time.to_bits(), r.finish_time.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn exp1_fine_grained_beats_baselines() {
        let results = exp1_all_scenarios(DEFAULT_SEED);
        let get = |name: &str| {
            results
                .iter()
                .find(|(s, _)| s.name() == name)
                .map(|(_, m)| m.overall_response)
                .unwrap()
        };
        // The paper's headline ordering for Exp 1 (Fig. 5): CM_G* < CM_S*
        // < CM < NONE.
        assert!(get("CM") < get("NONE"));
        assert!(get("CM_G") < get("CM"));
        assert!(get("CM_G_TG") < get("CM"));
        assert!(get("CM_S") < get("CM"));
    }
}
