//! Application-layer granularity selection — the Scanflow(MPI) planner
//! agent (paper Algorithm 1).
//!
//! The agent follows Scanflow's sensor/rule/actuator structure: the sensor
//! reads the job spec and system information (node counts, from the metrics
//! registry standing in for Prometheus), the rule computes the granularity
//! `(N_n, N_w, N_g)` from the admin-set policy and the application profile,
//! and the actuator submits the updated job to the API server (done by the
//! scenario driver, which couples the planner to the controller).

use crate::cluster::ClusterSpec;
use crate::workload::{Granularity, JobSpec, PlannedJob};

/// Admin-set granularity policy (paper §IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GranularityPolicy {
    /// No policy: keep the user's default worker count on a single node.
    None,
    /// "scale": one worker per node (`N_w = N_n`).
    Scale,
    /// "granularity": one worker per task (`N_w = N_t`).
    Granularity,
}

/// System information the agent senses (the Prometheus query surface).
#[derive(Debug, Clone, Copy)]
pub struct SystemInfo {
    /// Worker nodes available for MPI workloads.
    pub available_nodes: u32,
    /// Allocatable cores of the *smallest* worker node class. On the
    /// paper's homogeneous testbed this is 32; on heterogeneous clusters
    /// the planner sizes workers to fit it so thin nodes stay usable.
    pub min_node_cores: u32,
}

impl SystemInfo {
    /// Homogeneous paper-shape cluster of `n` workers (32-core nodes).
    pub const fn homogeneous(n: u32) -> SystemInfo {
        SystemInfo { available_nodes: n, min_node_cores: 32 }
    }

    /// Sense a (possibly heterogeneous) cluster spec.
    pub fn of(spec: &ClusterSpec) -> SystemInfo {
        SystemInfo {
            available_nodes: spec.worker_count() as u32,
            min_node_cores: spec.min_worker_cores(),
        }
    }
}

/// Algorithm 1: Granularity Selection (Planner agent).
///
/// Line-by-line transcription of the paper's pseudocode:
/// - network profile  => `N_n = 1, N_w = 1, N_g = 1` (both policies);
/// - CPU/memory profile, "scale"       => `N_n = min(N_n, N_t), N_w = N_n, N_g = N_n`;
/// - CPU/memory profile, "granularity" => `N_n = min(N_n, N_t), N_w = N_t, N_g = N_n`;
/// - no policy => `N_n = 1`, keep the user's `N_w`, `N_g = N_n`.
///
/// Node-class awareness (heterogeneous clusters): under "scale" the worker
/// count is raised above `N_n` when `N_t / N_n` tasks per worker would
/// exceed the smallest worker class's allocatable cores, so every worker
/// fits every class and thin nodes stay schedulable. On the homogeneous
/// paper testbed (`min_node_cores = 32`, 16-task jobs) this never fires.
pub fn plan(job: &JobSpec, policy: GranularityPolicy, info: SystemInfo) -> PlannedJob {
    // % Agent Sensor: get job specs and system information.
    let n_t = job.ntasks;
    let n_w_user = job.default_workers;
    let n_n_max = info.available_nodes.max(1);
    let min_cores = info.min_node_cores.max(1);
    let profile = job.benchmark.profile();

    // Elastic jobs carry their width in the spec: the profile-preferred
    // worker count is the *moldable plan's* starting point (the scheduler
    // may admit at any width down to `min` and resize between `min` and
    // `max` at runtime). Workers are homogeneous — `preferred | ntasks` —
    // so the controller's round-robin split is even by construction.
    if let Some(e) = job.elasticity {
        let n_w = e.preferred.max(1);
        let n_n = n_n_max.min(n_w);
        return PlannedJob {
            spec: job.clone(),
            granularity: Granularity { n_nodes: n_n, n_workers: n_w, n_groups: n_n },
        };
    }

    // % Agent Rule: set granularity according to job profile.
    let granularity = match policy {
        GranularityPolicy::Scale => {
            if profile.is_network() {
                Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 }
            } else {
                let n_n = n_n_max.min(n_t);
                // Tasks per worker at N_w = N_n, rounded up (RoundRobin
                // gives the first workers the remainder).
                let per_worker = n_t.div_ceil(n_n);
                let n_w = if per_worker > min_cores {
                    // Split finer so the widest worker fits the smallest
                    // node class (workers may share nodes).
                    n_t.div_ceil(min_cores).max(n_n).min(n_t)
                } else {
                    n_n
                };
                Granularity { n_nodes: n_n, n_workers: n_w, n_groups: n_n }
            }
        }
        GranularityPolicy::Granularity => {
            if profile.is_network() {
                Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 }
            } else {
                let n_n = n_n_max.min(n_t);
                Granularity { n_nodes: n_n, n_workers: n_t, n_groups: n_n }
            }
        }
        GranularityPolicy::None => Granularity {
            n_nodes: 1,
            n_workers: n_w_user.max(1),
            n_groups: 1,
        },
    };

    // % Agent Actuator: update and submit the job (caller submits).
    PlannedJob { spec: job.clone(), granularity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Benchmark;

    const INFO: SystemInfo = SystemInfo::homogeneous(4);

    fn job(bench: Benchmark) -> JobSpec {
        JobSpec::paper_job(1, bench, 0.0)
    }

    #[test]
    fn scale_policy_cpu_job_one_worker_per_node() {
        let p = plan(&job(Benchmark::EpDgemm), GranularityPolicy::Scale, INFO);
        assert_eq!(
            p.granularity,
            Granularity { n_nodes: 4, n_workers: 4, n_groups: 4 }
        );
    }

    #[test]
    fn granularity_policy_cpu_job_one_worker_per_task() {
        let p = plan(&job(Benchmark::EpDgemm), GranularityPolicy::Granularity, INFO);
        assert_eq!(
            p.granularity,
            Granularity { n_nodes: 4, n_workers: 16, n_groups: 4 }
        );
    }

    #[test]
    fn network_jobs_stay_in_single_container_under_both_policies() {
        for bench in [Benchmark::GFft, Benchmark::GRandomRing] {
            for pol in [GranularityPolicy::Scale, GranularityPolicy::Granularity] {
                let p = plan(&job(bench), pol, INFO);
                assert_eq!(
                    p.granularity,
                    Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 },
                    "{bench} under {pol:?}"
                );
            }
        }
    }

    #[test]
    fn memory_and_cpumem_profiles_are_split() {
        for bench in [Benchmark::EpStream, Benchmark::MiniFe] {
            let p = plan(&job(bench), GranularityPolicy::Scale, INFO);
            assert_eq!(p.granularity.n_workers, 4, "{bench}");
        }
    }

    #[test]
    fn no_policy_keeps_user_default() {
        let mut j = job(Benchmark::EpStream);
        j.default_workers = 2;
        let p = plan(&j, GranularityPolicy::None, INFO);
        assert_eq!(
            p.granularity,
            Granularity { n_nodes: 1, n_workers: 2, n_groups: 1 }
        );
    }

    #[test]
    fn nodes_clamped_by_task_count() {
        // 2-task job on a 4-node cluster: N_n = min(N_n, N_t) = 2.
        let mut j = job(Benchmark::EpDgemm);
        j.ntasks = 2;
        let p = plan(&j, GranularityPolicy::Scale, INFO);
        assert_eq!(p.granularity.n_nodes, 2);
        assert_eq!(p.granularity.n_workers, 2);
    }

    #[test]
    fn scale_splits_finer_to_fit_the_smallest_node_class() {
        // 16 tasks over 2 nodes would mean 8-task (8-core) workers; with a
        // smallest class of 4 allocatable cores the planner splits into
        // ceil(16/4) = 4 workers so every worker fits every class.
        let info = SystemInfo { available_nodes: 2, min_node_cores: 4 };
        let p = plan(&job(Benchmark::EpDgemm), GranularityPolicy::Scale, info);
        assert_eq!(
            p.granularity,
            Granularity { n_nodes: 2, n_workers: 4, n_groups: 2 }
        );
        // Homogeneous paper shape: unchanged (8 tasks/worker fit 32 cores).
        let wide = SystemInfo { available_nodes: 2, min_node_cores: 32 };
        let q = plan(&job(Benchmark::EpDgemm), GranularityPolicy::Scale, wide);
        assert_eq!(q.granularity.n_workers, 2);
    }

    #[test]
    fn system_info_senses_heterogeneous_clusters() {
        use crate::cluster::{ClusterSpec, HeterogeneityMix};
        let hom = SystemInfo::of(&ClusterSpec::with_workers(8));
        assert_eq!(hom.available_nodes, 8);
        assert_eq!(hom.min_node_cores, 32);
        let het = SystemInfo::of(&ClusterSpec::mixed(8, HeterogeneityMix::FatThin));
        assert_eq!(het.available_nodes, 8);
        assert_eq!(het.min_node_cores, 16, "thin class bounds the split");
    }

    #[test]
    fn elastic_jobs_plan_at_preferred_width_under_every_policy() {
        use crate::workload::Elasticity;
        let j = job(Benchmark::EpDgemm)
            .with_elasticity(Elasticity { min: 2, max: 16, preferred: 8 });
        for pol in
            [GranularityPolicy::None, GranularityPolicy::Scale, GranularityPolicy::Granularity]
        {
            let p = plan(&j, pol, INFO);
            assert_eq!(
                p.granularity,
                Granularity { n_nodes: 4, n_workers: 8, n_groups: 4 },
                "{pol:?}"
            );
        }
    }

    #[test]
    fn zero_available_nodes_clamped_to_one() {
        let p = plan(
            &job(Benchmark::EpDgemm),
            GranularityPolicy::Scale,
            SystemInfo { available_nodes: 0, min_node_cores: 32 },
        );
        assert_eq!(p.granularity.n_nodes, 1);
    }
}
