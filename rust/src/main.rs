//! kube-fgs CLI — the L3 coordinator entrypoint.
//!
//! Subcommands map 1:1 to the paper's evaluation artefacts:
//!   profiles                     Fig. 3 benchmark profiling table
//!   exp1 [--seed N]              Figs. 4–5 (10 EP-DGEMM jobs, 6 scenarios)
//!   exp2 [--seed N] [--gantt]    Figs. 6–7 (20 mixed jobs, 6 scenarios)
//!   exp3 [--seed N]              Table III + Figs. 8–9 (frameworks)
//!   run --scenario S [--jobs N]  one scenario on a uniform trace
//!   queues [--jobs N]            queue-policy ablation (FIFO / strict /
//!                                SJF / EASY / conservative / fair-share)
//!   scaling [--sizes ...]        queue-policy × cluster-size scaling
//!                                curves across heterogeneity mixes
//!   fairness [--jobs N]          multi-tenant fairness ablation on a
//!                                two-tenant trace (priority + preemption)
//!   elasticity [--jobs N]        rigid / moldable / malleable ablation on
//!                                an elastic trace (the resize pipeline)
//!   serve [--multipliers ...]    open-loop serving sweep: replay the mixed
//!                                production trace at rising traffic
//!                                multipliers to find each policy's knee
//!   e2e [--steps N]              end-to-end: PJRT payload execution feeds
//!                                the simulator's base rates
//!
//! A scenario name pins all six knobs of the experiment matrix:
//! (kubelet, planner, controller, scheduler, queue, preemption). The
//! Table-II names (NONE, CM, CM_S, CM_G, CM_S_TG, CM_G_TG) keep the
//! seed's FIFO-skip queue; the `*_SJF` / `*_BF` / `*_FS` / `*_CBF`
//! variants swap the queue discipline, CM_G_TG_PRE adds fair-share +
//! priority preemption, and `--queue` / `--preempt` override the knobs on
//! any scenario.
//!
//! (The vendored offline registry has no clap; argument parsing is a small
//! hand-rolled layer — see DESIGN.md §Dependencies.)

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use kube_fgs::cluster::HeterogeneityMix;
use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::metrics::ExperimentMetrics;
use kube_fgs::report;
use kube_fgs::runtime::{default_artifacts_dir, Runtime};
use kube_fgs::scenario::Scenario;
use kube_fgs::scheduler::QueuePolicyKind;
use kube_fgs::simulator::JobRecord;
use kube_fgs::workload::{exp2_trace, uniform_trace, Benchmark, ALL_BENCHMARKS};

/// Minimal flag parser: `--key value` and `--flag` forms.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn seed(&self) -> u64 {
        self.flags
            .get("seed")
            .and_then(|s| s.parse().ok())
            .unwrap_or(DEFAULT_SEED)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "kube-fgs — fine-grained scheduling for containerized HPC workloads

USAGE: kube-fgs <command> [flags]

COMMANDS:
  profiles              Fig. 3: benchmark MPI profiling analysis
  exp1 [--seed N]       Figs. 4-5: schedule 10 EP-DGEMM jobs, 6 scenarios
  exp2 [--seed N] [--gantt] [--csv]
                        Figs. 6-7: 20 mixed jobs, 6 scenarios
  exp3 [--seed N]       Table III + Figs. 8-9: framework comparison
  run --scenario NAME [--jobs N] [--interval S] [--seed N] [--queue POLICY]
      [--preempt] [--two-tenant] [--engine linear|indexed]
      [--legacy-scheduler] [--stepped-clock] [--digest] [--workers N]
      [--mix NAME] [--shards N] [--threads N]
                        one scenario on a uniform random trace; POLICY is
                        fifo | fifo_strict | sjf | easy_backfill |
                        cons_backfill | fair_share and overrides the
                        scenario's queue discipline; --preempt enables
                        priority preemption; --two-tenant swaps in the
                        two-tenant trace (batch + high-priority prod);
                        --engine picks the placement engine (default
                        indexed — bit-identical to linear, just faster);
                        --legacy-scheduler pins the pre-pipeline scheduler
                        cycle (the differential harness's reference path);
                        --stepped-clock pins the retired per-event stepped
                        simulator clock (the epoch ledger's reference path;
                        event times agree to < 1e-6 s);
                        --digest prints the run's FNV-1a trace digest
                        (per-shard + combined on sharded runs);
                        --workers/--mix size and shape the cluster
                        (default: the paper's 4 uniform workers);
                        --shards partitions it into per-class scheduler
                        domains run in parallel (clamped to the worker-
                        class count — uniform mixes always collapse to 1,
                        bit-identical to the single scheduler); --threads
                        caps the sharded thread pool (outputs are
                        thread-count-invariant)
  queues [--jobs N] [--interval S] [--seed N] [--json PATH]
                        queue-policy ablation table on CM_G_TG placement
                        (default: 200 jobs, 60 s mean interval)
  scaling [--sizes 8,16,32] [--mixes uniform,fat_thin] [--policies LIST]
          [--shards 1,4] [--jobs-per-worker N] [--interval S] [--seed N]
          [--out DIR] [--json PATH]
                        queue-policy x cluster-size scaling sweep across
                        heterogeneity mixes (uniform | fat_thin | tiered)
                        and scheduler-shard counts; per-worker pressure is
                        held constant across sizes.
                        --out writes scaling_sweep.csv + per-mix SVG
                        response/makespan/utilization curves
  fairness [--jobs N] [--interval S] [--seed N] [--json PATH]
                        multi-tenant fairness ablation: FIFO vs fair-share
                        (+preemption) vs conservative backfill on a
                        two-tenant trace; reports per-tenant response and
                        Jain's fairness index
  elasticity [--jobs N] [--interval S] [--seed N] [--json PATH] [--out DIR]
                        elasticity ablation: the EL_RIGID / EL_MOLD /
                        EL_MALL scenarios over one elastic trace (jobs that
                        can run at 2..=16 workers, preferred 8); reports
                        response, makespan, utilization, preemptions, and
                        resize counts; --out writes elasticity.csv + SVG
                        bar charts
  serve [--multipliers 1,4,16] [--horizon-hours H] [--policies LIST]
        [--elastic] [--shards N] [--threads N] [--seed N] [--json PATH]
        [--out DIR]
                        open-loop serving sweep: replay the mixed
                        production-traffic trace (diurnal HPC gangs + bursty
                        AI inference + steady microservices, workload::
                        arrivals) at each traffic multiplier and report
                        p50/p95/p99 response, per-class SLO violations, and
                        each policy's saturation knee (the multiplier where
                        its violation fraction crosses 0.5); --elastic swaps
                        in malleable gangs and defaults --policies to
                        EL_RIGID,EL_MOLD,EL_MALL (rigid default: CM,CM_G_TG);
                        --shards/--threads compose with the scale-out axis;
                        --out writes serve_sweep.csv + SVG latency/violation
                        curves
  e2e [--steps N] [--seed N]
                        end-to-end: execute AOT payloads via PJRT and feed
                        measured step times into the simulator
  figures --out DIR [--seed N]
                        render every paper figure as SVG into DIR
  config PATH           run an experiment described by a JSON config file
                        (keys: scenario, seed, queue, preemption, pipeline,
                        tenants, cluster (incl. cluster.shards), trace,
                        output)

SCENARIOS (each pins kubelet, planner, controller, scheduler, queue,
preemption):
  NONE CM CM_S CM_G CM_S_TG CM_G_TG          Table II (FIFO-skip queue)
  Kubeflow Volcano                           SS V-E framework baselines
  CM_SJF CM_BF CM_G_TG_SJF CM_G_TG_BF       queue-policy variants
  CM_FS CM_CBF CM_G_TG_FS CM_G_TG_CBF       fair-share / conservative
  CM_G_TG_PRE                               fair-share + preemption
  EL_RIGID EL_MOLD EL_MALL                  elasticity modes (preemption on)
";

fn main() {
    // Exit quietly when stdout is closed early (e.g. `kube-fgs exp2 | head`).
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let msg = info.payload().downcast_ref::<String>().map(String::as_str).unwrap_or("");
        if msg.contains("Broken pipe") {
            std::process::exit(0);
        }
        default_hook(info);
    }));
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "profiles" => cmd_profiles(),
        "exp1" => cmd_exp1(args),
        "exp2" => cmd_exp2(args),
        "exp3" => cmd_exp3(args),
        "run" => cmd_run(args),
        "queues" => cmd_queues(args),
        "scaling" => cmd_scaling(args),
        "fairness" => cmd_fairness(args),
        "elasticity" => cmd_elasticity(args),
        "serve" => cmd_serve(args),
        "e2e" => cmd_e2e(args),
        "figures" => cmd_figures(args),
        "config" => cmd_config(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_profiles() -> Result<()> {
    println!("Fig. 3 — Benchmarks MPI profiling analysis\n");
    print!("{}", experiments::fig3_table());
    Ok(())
}

fn cmd_exp1(args: &Args) -> Result<()> {
    let seed = args.seed();
    println!("Experiment 1 — 10 EP-DGEMM jobs, 60 s interval (seed {seed})\n");
    let results = experiments::exp1_all_scenarios(seed);
    println!("Fig. 4 — average job running time:");
    print!("{}", experiments::fig4_table(&results));
    println!("\nFig. 5 — overall response time:");
    print!("{}", experiments::fig5_table(&results));
    Ok(())
}

fn cmd_exp2(args: &Args) -> Result<()> {
    let seed = args.seed();
    println!("Experiment 2 — 20 mixed jobs in [0, 1200] s (seed {seed})\n");
    let results = experiments::exp2_all_scenarios(seed);
    println!("Fig. 6 — per-benchmark avg running time + overall response:");
    print!("{}", experiments::fig6_table(&results));
    println!("\nFig. 7 — makespan:");
    print!("{}", experiments::fig7_table(&results));
    if args.has("gantt") {
        for (s, _) in &results {
            let out = experiments::run_scenario(*s, &exp2_trace(seed), seed, None);
            println!("\nFig. 7 — scheduling process, scenario {s}:");
            print!("{}", report::gantt(&out, 100));
        }
    }
    if args.has("csv") {
        let headers = ["scenario", "job", "benchmark", "submit", "start", "finish"];
        let mut rows = Vec::new();
        for (s, m) in &results {
            for r in &m.per_job {
                rows.push(vec![
                    s.name().to_string(),
                    r.id.0.to_string(),
                    r.benchmark.name().to_string(),
                    format!("{:.1}", r.submit_time),
                    format!("{:.1}", r.start_time),
                    format!("{:.1}", r.finish_time),
                ]);
            }
        }
        print!("\n{}", report::csv(&headers, &rows));
    }
    Ok(())
}

fn cmd_exp3(args: &Args) -> Result<()> {
    let seed = args.seed();
    println!("Experiment 3 — framework comparison (seed {seed})\n");
    let results = experiments::exp3_all_scenarios(seed);
    println!("Table III — makespan comparison:");
    print!("{}", experiments::table3(&results));
    println!();
    print!(
        "{}",
        experiments::per_job_table(&results, JobRecord::running, "Fig. 8 — job running time (s):")
    );
    println!();
    print!(
        "{}",
        experiments::per_job_table(
            &results,
            JobRecord::response,
            "Fig. 9 — job response time (s):"
        )
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args
        .flags
        .get("scenario")
        .or_else(|| args.positional.first())
        .ok_or_else(|| anyhow!("--scenario required (e.g. CM_G_TG)"))?;
    let scenario =
        Scenario::parse(name).ok_or_else(|| anyhow!("unknown scenario {name:?}"))?;
    let seed = args.seed();
    let jobs = args.get_usize("jobs", 20);
    let interval = args.get_usize("interval", 60) as f64;
    let trace = if args.has("two-tenant") {
        kube_fgs::workload::two_tenant_trace(jobs, interval, seed)
    } else {
        uniform_trace(jobs, interval, seed)
    };
    let queue = match args.flags.get("queue") {
        Some(q) => QueuePolicyKind::parse(q).ok_or_else(|| {
            anyhow!(
                "unknown queue policy {q:?} (fifo | fifo_strict | sjf | easy_backfill | \
                 cons_backfill | fair_share)"
            )
        })?,
        None => scenario.queue(),
    };
    // Block/reserve semantics need gang all-or-nothing; on a no-gang
    // scenario they would silently run as FIFO-skip.
    if !scenario.scheduler(seed).gang && queue.requires_gang() {
        bail!(
            "queue policy {} requires a gang scheduler (scenario {} has gang=false)",
            queue.name(),
            scenario.name()
        );
    }
    let preempt = args.has("preempt") || scenario.preemption();
    if preempt && !scenario.scheduler(seed).gang {
        bail!("--preempt requires a gang scheduler (scenario {} has gang=false)", scenario.name());
    }
    let engine = match args.flags.get("engine") {
        Some(e) => kube_fgs::scheduler::PlacementEngineKind::parse(e)
            .ok_or_else(|| anyhow!("unknown engine {e:?} (linear | indexed)"))?,
        None => kube_fgs::scheduler::PlacementEngineKind::Indexed,
    };
    let mix = match args.flags.get("mix") {
        Some(m) => Some(
            HeterogeneityMix::parse(m)
                .ok_or_else(|| anyhow!("unknown mix {m:?} (uniform | fat_thin | tiered)"))?,
        ),
        None => None,
    };
    let workers = match args.flags.get("workers") {
        Some(w) => Some(
            w.parse::<usize>()
                .ok()
                .filter(|&n| n > 0)
                .ok_or_else(|| anyhow!("bad --workers {w:?} (positive integer)"))?,
        ),
        None => None,
    };
    // No shape flags -> the paper's 4-worker cluster, bit-identical to
    // the historical `run`. Uniform shapes go through `with_workers` so
    // homogeneous runs stay on the same constructor as the seed.
    let cluster = match (workers, mix) {
        (None, None) => None,
        (w, m) => {
            let w = w.unwrap_or(4);
            Some(match m {
                Some(HeterogeneityMix::Uniform) | None => {
                    kube_fgs::cluster::ClusterSpec::with_workers(w)
                }
                Some(m) => kube_fgs::cluster::ClusterSpec::mixed(w, m),
            })
        }
    };
    let mut spec = experiments::RunSpec::new(scenario)
        .seed(seed)
        .queue(queue)
        .preemption(preempt)
        .engine(engine)
        .legacy_scheduler(args.has("legacy-scheduler"))
        .stepped_clock(args.has("stepped-clock"))
        .shards(args.get_usize("shards", 1));
    if let Some(cluster) = cluster {
        spec = spec.cluster(cluster);
    }
    if let Some(threads) = args.flags.get("threads") {
        let threads = threads
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("bad --threads {threads:?} (positive integer)"))?;
        spec = spec.threads(threads);
    }
    let run = spec.run(&trace);
    if run.is_sharded() {
        let m = ExperimentMetrics::from_records(&run.records());
        print!("{}", report::scenario_summary(scenario.name(), &m));
        let stats = run.sched_stats();
        println!(
            "shards: {} domains ({} sessions, {} decisions total)",
            run.shards.len(),
            stats.sessions,
            stats.decisions
        );
        let cs = run.core_stats();
        println!(
            "sim core: {} events ({} arrivals, {} completions), {:.0} ns/event",
            cs.events,
            cs.arrivals,
            cs.completions,
            cs.nanos_per_event()
        );
        if args.has("digest") {
            for (i, d) in run.digests().iter().enumerate() {
                println!("digest[shard {i}]: {}", d.to_json());
            }
            println!("combined digest: {:#018x}", run.combined_digest());
        }
        let unschedulable = run.unschedulable();
        if !unschedulable.is_empty() {
            println!("unschedulable jobs: {unschedulable:?}");
        }
        return Ok(());
    }
    let out = run.single();
    let m = ExperimentMetrics::from(&out);
    print!("{}", report::scenario_summary(scenario.name(), &m));
    if args.has("digest") {
        println!("digest: {}", kube_fgs::simulator::SimDigest::of(&out).to_json());
    }
    if !out.unschedulable.is_empty() {
        println!("unschedulable jobs: {:?}", out.unschedulable);
    }
    let preemptions = out.preemption_count();
    if preemptions > 0 {
        println!("preemptions: {preemptions}");
    }
    let cs = out.core_stats;
    println!(
        "sim core: {} events ({} arrivals, {} completions), {:.0} ns/event",
        cs.events,
        cs.arrivals,
        cs.completions,
        cs.nanos_per_event()
    );
    println!("\nScheduling process:");
    print!("{}", report::gantt(&out, 100));
    println!("\nPod placements:");
    print!("{}", report::node_timeline(&out));
    Ok(())
}

fn cmd_queues(args: &Args) -> Result<()> {
    let seed = args.seed();
    let jobs = args.get_usize("jobs", experiments::QUEUE_ABLATION_JOBS);
    let interval = args
        .flags
        .get("interval")
        .and_then(|s| s.parse().ok())
        .unwrap_or(experiments::QUEUE_ABLATION_INTERVAL);
    println!(
        "Queue-policy ablation — {jobs} mixed jobs, {interval} s mean interval, \
         CM_G_TG placement (seed {seed})\n"
    );
    let results = experiments::queue_ablation(seed, jobs, interval);
    print!("{}", experiments::queue_table(&results));
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, experiments::queue_json(seed, jobs, interval, &results))
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let seed = args.seed();
    let sizes: Vec<usize> = match args.flags.get("sizes") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n > 0)
                    .ok_or_else(|| anyhow!("bad --sizes entry {x:?} (positive integers)"))
            })
            .collect::<Result<_>>()?,
        None => kube_fgs::experiments::SCALING_DEFAULT_SIZES.to_vec(),
    };
    let mixes: Vec<HeterogeneityMix> = match args.flags.get("mixes") {
        Some(s) => s
            .split(',')
            .map(|x| {
                HeterogeneityMix::parse(x.trim()).ok_or_else(|| {
                    anyhow!("unknown mix {x:?} (uniform | fat_thin | tiered)")
                })
            })
            .collect::<Result<_>>()?,
        None => kube_fgs::experiments::SCALING_DEFAULT_MIXES.to_vec(),
    };
    let policies: Vec<QueuePolicyKind> = match args.flags.get("policies") {
        Some(s) => s
            .split(',')
            .map(|x| {
                QueuePolicyKind::parse(x.trim())
                    .ok_or_else(|| anyhow!("unknown queue policy {x:?}"))
            })
            .collect::<Result<_>>()?,
        None => kube_fgs::scheduler::ALL_QUEUE_POLICIES.to_vec(),
    };
    let shards_axis: Vec<usize> = match args.flags.get("shards") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n > 0)
                    .ok_or_else(|| anyhow!("bad --shards entry {x:?} (positive integers)"))
            })
            .collect::<Result<_>>()?,
        None => kube_fgs::experiments::SCALING_DEFAULT_SHARDS.to_vec(),
    };
    // Unlike the older ablation commands, every flag of this subcommand
    // fails loudly on a typo — a sweep silently run at defaults would be
    // mislabeled data.
    let jobs_per_worker = match args.flags.get("jobs-per-worker") {
        Some(s) => s
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("bad --jobs-per-worker {s:?} (positive integer)"))?,
        None => kube_fgs::experiments::SCALING_JOBS_PER_WORKER,
    };
    let interval = match args.flags.get("interval") {
        Some(s) => s
            .parse::<f64>()
            .ok()
            .filter(|&x| x > 0.0)
            .ok_or_else(|| anyhow!("bad --interval {s:?} (positive seconds)"))?,
        None => kube_fgs::experiments::SCALING_BASE_INTERVAL,
    };
    println!(
        "Scaling sweep — sizes {sizes:?}, mixes {}, {} policies, shards {shards_axis:?}, \
         {jobs_per_worker} jobs/worker, base interval {interval} s at 8 workers (seed {seed})\n",
        mixes.iter().map(|m| m.name()).collect::<Vec<_>>().join(","),
        policies.len(),
    );
    let points = kube_fgs::experiments::scaling_sweep(
        seed,
        &sizes,
        &mixes,
        &policies,
        &shards_axis,
        jobs_per_worker,
        interval,
    );
    print!("{}", kube_fgs::experiments::scaling_table(&points));
    if let Some(path) = args.flags.get("json") {
        std::fs::write(
            path,
            kube_fgs::experiments::scaling_json(seed, jobs_per_worker, interval, &points),
        )
        .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    if let Some(dir) = args.flags.get("out") {
        kube_fgs::report::figures::write_scaling(std::path::Path::new(dir), &points)?;
    }
    Ok(())
}

fn cmd_fairness(args: &Args) -> Result<()> {
    let seed = args.seed();
    let jobs = args.get_usize("jobs", experiments::FAIRNESS_JOBS);
    let interval = args
        .flags
        .get("interval")
        .and_then(|s| s.parse().ok())
        .unwrap_or(experiments::FAIRNESS_INTERVAL);
    println!(
        "Fairness ablation — {jobs} two-tenant jobs ({}% high-priority prod, weight {}), \
         {interval} s mean interval, CM_G_TG placement (seed {seed})\n",
        (kube_fgs::workload::PROD_SHARE * 100.0) as u32,
        experiments::PROD_WEIGHT,
    );
    let rows = experiments::fairness_ablation(seed, jobs, interval);
    print!("{}", experiments::fairness_table(&rows));
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, experiments::fairness_json(seed, jobs, interval, &rows))
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_elasticity(args: &Args) -> Result<()> {
    let seed = args.seed();
    let jobs = args.get_usize("jobs", experiments::ELASTICITY_JOBS);
    let interval = args
        .flags
        .get("interval")
        .and_then(|s| s.parse().ok())
        .unwrap_or(experiments::ELASTICITY_INTERVAL);
    println!(
        "Elasticity ablation — {jobs} elastic jobs (2..=16 workers, preferred 8), \
         {interval} s mean interval, fine-grained placement + preemption (seed {seed})\n"
    );
    let rows = experiments::elasticity_ablation(seed, jobs, interval);
    print!("{}", experiments::elasticity_table(&rows));
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, experiments::elasticity_json(seed, jobs, interval, &rows))
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    if let Some(dir) = args.flags.get("out") {
        kube_fgs::report::figures::write_elasticity(std::path::Path::new(dir), &rows)?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let seed = args.seed();
    let elastic = args.has("elastic");
    let multipliers: Vec<f64> = match args.flags.get("multipliers") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|&m| m.is_finite() && m > 0.0)
                    .ok_or_else(|| {
                        anyhow!("bad --multipliers entry {x:?} (positive traffic multipliers)")
                    })
            })
            .collect::<Result<_>>()?,
        None => experiments::SERVE_DEFAULT_MULTIPLIERS.to_vec(),
    };
    let horizon_hours = match args.flags.get("horizon-hours") {
        Some(s) => s
            .parse::<f64>()
            .ok()
            .filter(|&h| h.is_finite() && h > 0.0)
            .ok_or_else(|| anyhow!("bad --horizon-hours {s:?} (positive hours)"))?,
        None => experiments::SERVE_HORIZON_HOURS,
    };
    let policies: Vec<Scenario> = match args.flags.get("policies") {
        Some(s) => s
            .split(',')
            .map(|x| {
                Scenario::parse(x.trim()).ok_or_else(|| anyhow!("unknown scenario {x:?}"))
            })
            .collect::<Result<_>>()?,
        None if elastic => kube_fgs::scenario::ELASTIC_SCENARIOS.to_vec(),
        None => experiments::SERVE_DEFAULT_SCENARIOS.to_vec(),
    };
    let shards = args.get_usize("shards", 1);
    let threads = match args.flags.get("threads") {
        Some(s) => Some(
            s.parse::<usize>()
                .ok()
                .filter(|&n| n > 0)
                .ok_or_else(|| anyhow!("bad --threads {s:?} (positive integer)"))?,
        ),
        None => None,
    };
    println!(
        "Serve saturation sweep — {horizon_hours} h open-loop horizon, multipliers \
         {multipliers:?}, {} policies{} (seed {seed})\n",
        policies.len(),
        if elastic { ", elastic gang mix" } else { "" },
    );
    let points = experiments::serve_sweep(
        seed,
        &policies,
        &multipliers,
        horizon_hours * 3600.0,
        shards,
        threads,
        elastic,
    );
    print!("{}", experiments::serve_table(&points));
    let total_events: u64 = points.iter().map(|p| p.events).sum();
    let peak_rate = points.iter().map(|p| p.events_per_sec).fold(0.0, f64::max);
    println!(
        "\nsim core: {total_events} events total, peak {peak_rate:.0} events/sec"
    );
    println!("\nSaturation knees (violation fraction crosses {}):", experiments::SERVE_KNEE_THRESHOLD);
    for (scenario, knee) in experiments::serve_knees(&points) {
        match knee {
            Some(k) => println!("  {:<12} {k:.2}x", scenario.name()),
            None => println!("  {:<12} not reached over the swept multipliers", scenario.name()),
        }
    }
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, experiments::serve_json(seed, horizon_hours, elastic, &points))
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    if let Some(dir) = args.flags.get("out") {
        kube_fgs::report::figures::write_serve(std::path::Path::new(dir), &points)?;
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = args
        .flags
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("figures"));
    kube_fgs::report::figures::write_all(&out, args.seed())
}

fn cmd_config(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .or_else(|| args.flags.get("file"))
        .ok_or_else(|| anyhow!("usage: kube-fgs config <path.json>"))?;
    let cfg = kube_fgs::config::ExperimentConfig::load(std::path::Path::new(path))?;
    println!(
        "config: scenario {} queue {} preemption {} seed {} workers {} shards {} trace {:?}\n",
        cfg.scenario,
        cfg.queue,
        cfg.preemption,
        cfg.seed,
        // The built cluster's own count — explicit `cluster.classes` may
        // size the cluster independently of the `worker_nodes` default.
        cfg.cluster().worker_count(),
        cfg.shards,
        cfg.trace
    );
    let run = cfg.run_spec().run(&cfg.build_trace());
    if run.is_sharded() {
        let m = ExperimentMetrics::from_records(&run.records());
        print!("{}", report::scenario_summary(cfg.scenario.name(), &m));
        println!("shards: {} domains", run.shards.len());
        if cfg.csv {
            print_job_csv(&m);
        }
        return Ok(());
    }
    let out = run.single();
    let m = ExperimentMetrics::from(&out);
    print!("{}", report::scenario_summary(cfg.scenario.name(), &m));
    if cfg.gantt {
        println!("\nScheduling process:");
        print!("{}", report::gantt(&out, 100));
    }
    if cfg.csv {
        print_job_csv(&m);
    }
    Ok(())
}

fn print_job_csv(m: &ExperimentMetrics) {
    let headers = ["job", "benchmark", "submit", "start", "finish"];
    let rows: Vec<Vec<String>> = m
        .per_job
        .iter()
        .map(|r| {
            vec![
                r.id.0.to_string(),
                r.benchmark.name().to_string(),
                format!("{:.1}", r.submit_time),
                format!("{:.1}", r.start_time),
                format!("{:.1}", r.finish_time),
            ]
        })
        .collect();
    print!("\n{}", report::csv(&headers, &rows));
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let seed = args.seed();
    let steps = args.get_usize("steps", 5);
    println!("End-to-end driver: PJRT payload execution -> simulator base rates\n");
    let rt = Runtime::load(&default_artifacts_dir())?;
    println!("PJRT platform: {}", rt.client_platform);

    // Measure each payload and scale it to the paper's base running times
    // (the artifacts are scaled-down problems; the *ratios* between the
    // measured kernels drive the simulated workload mix).
    let mut measured = BTreeMap::new();
    for &b in &ALL_BENCHMARKS {
        let secs = rt.measure(b, 1, steps)?;
        println!("  {:<14} {:>10.3} ms/step", b.name(), secs * 1e3);
        measured.insert(b, secs);
    }
    // Normalize so EP-DGEMM keeps its calibrated base time.
    let scale = Benchmark::EpDgemm.base_running_secs() / measured[&Benchmark::EpDgemm];
    let base_work: BTreeMap<Benchmark, f64> =
        measured.iter().map(|(&b, &s)| (b, s * scale)).collect();
    println!("\nscaled base work (s): ");
    for (b, w) in &base_work {
        println!("  {:<14} {:>8.1}", b.name(), w);
    }

    println!("\nExperiment 2 under measured kernel times:");
    let trace = exp2_trace(seed);
    let mut rows = Vec::new();
    for s in kube_fgs::scenario::TABLE2_SCENARIOS {
        let out = experiments::run_scenario(s, &trace, seed, Some(&base_work));
        let m = ExperimentMetrics::from(&out);
        rows.push(vec![
            s.name().to_string(),
            format!("{:.0}", m.overall_response),
            format!("{:.0}", m.makespan),
        ]);
    }
    print!(
        "{}",
        report::table(&["scenario", "overall response (s)", "makespan (s)"], &rows)
    );
    Ok(())
}
