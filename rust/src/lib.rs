//! kube-fgs — Fine-Grained Scheduling for Containerized HPC Workloads in
//! Kubernetes Clusters (Liu & Guitart, 2022): full-system reproduction.
//!
//! Three-layer architecture (DESIGN.md):
//! - L3 (this crate): the paper's two-layer scheduling contribution plus
//!   every substrate it depends on (cluster/kubelet/API-server models, a
//!   Volcano-style scheduling framework, the MPI performance model, and a
//!   discrete-event simulator), and the PJRT runtime that executes the
//!   AOT-compiled benchmark payloads.
//! - L2/L1 (python/compile): JAX step functions + Pallas kernels, lowered
//!   once to `artifacts/*.hlo.txt`; Python never runs on the request path.

// Style lints silenced crate-wide (CI runs `clippy -- -D warnings`): the
// substrate favours explicit constructor args and tuple-heavy internal
// plumbing over Default impls and type aliases.
#![allow(
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::needless_range_loop
)]

pub mod apiserver;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod cluster;
pub mod config;
pub mod kubelet;
pub mod util;
pub mod controller;
pub mod experiments;
pub mod perfmodel;
pub mod planner;
pub mod scheduler;
pub mod simulator;
pub mod workload;
