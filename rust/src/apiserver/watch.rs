//! Watch bus — the Kubernetes-style list/watch surface of the API server.
//!
//! Kubernetes controllers react to object events through watches; our job
//! controllers and scheduler are driven synchronously by the simulator,
//! but the watch bus exposes the same reactive surface for tooling (the
//! metrics exporter subscribes to it, and external consumers can replay
//! the full event history the way `kubectl get events --watch` would).

use std::collections::BTreeMap;

use super::Event;

/// Filter selecting which events a subscription receives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WatchFilter {
    All,
    Jobs,
    Pods,
}

impl WatchFilter {
    pub fn matches(&self, event: &Event) -> bool {
        match self {
            WatchFilter::All => true,
            WatchFilter::Jobs => matches!(
                event,
                Event::JobSubmitted { .. }
                    | Event::JobStarted { .. }
                    | Event::JobFinished { .. }
                    | Event::JobPreempted { .. }
                    | Event::JobUnschedulable { .. }
            ),
            WatchFilter::Pods => matches!(event, Event::PodBound { .. }),
        }
    }
}

/// Handle identifying one subscription.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct WatchId(u64);

/// A bookmark-based watch bus: subscribers poll for events after their
/// last-seen resource version (deterministic, no threads — matching the
/// simulator's synchronous world).
#[derive(Debug, Default)]
pub struct WatchBus {
    log: Vec<Event>,
    subscriptions: BTreeMap<WatchId, (WatchFilter, usize)>,
    next_id: u64,
}

impl WatchBus {
    pub fn new() -> WatchBus {
        WatchBus::default()
    }

    /// Append an event (the API server calls this on every mutation).
    pub fn publish(&mut self, event: Event) {
        self.log.push(event);
    }

    /// Open a watch from the current resource version (future events only)
    /// or from the beginning (`from_start`) to replay history.
    pub fn subscribe(&mut self, filter: WatchFilter, from_start: bool) -> WatchId {
        self.next_id += 1;
        let id = WatchId(self.next_id);
        let pos = if from_start { 0 } else { self.log.len() };
        self.subscriptions.insert(id, (filter, pos));
        id
    }

    /// Drain the pending events for a subscription, advancing its bookmark.
    pub fn poll(&mut self, id: WatchId) -> Vec<Event> {
        let Some((filter, pos)) = self.subscriptions.get_mut(&id) else {
            return Vec::new();
        };
        let events: Vec<Event> = self.log[*pos..]
            .iter()
            .filter(|e| filter.matches(e))
            .cloned()
            .collect();
        *pos = self.log.len();
        events
    }

    pub fn unsubscribe(&mut self, id: WatchId) {
        self.subscriptions.remove(&id);
    }

    /// Current resource version (log length).
    pub fn resource_version(&self) -> usize {
        self.log.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{JobId, NodeId, PodId};

    fn submit(t: f64) -> Event {
        Event::JobSubmitted { t, job: JobId(1) }
    }

    fn bound(t: f64) -> Event {
        Event::PodBound { t, pod: PodId(1), node: NodeId(1) }
    }

    #[test]
    fn subscriber_sees_only_future_events_by_default() {
        let mut bus = WatchBus::new();
        bus.publish(submit(0.0));
        let id = bus.subscribe(WatchFilter::All, false);
        assert!(bus.poll(id).is_empty());
        bus.publish(bound(1.0));
        assert_eq!(bus.poll(id).len(), 1);
        assert!(bus.poll(id).is_empty(), "bookmark advanced");
    }

    #[test]
    fn from_start_replays_history() {
        let mut bus = WatchBus::new();
        bus.publish(submit(0.0));
        bus.publish(bound(1.0));
        let id = bus.subscribe(WatchFilter::All, true);
        assert_eq!(bus.poll(id).len(), 2);
    }

    #[test]
    fn filters_select_event_kinds() {
        let mut bus = WatchBus::new();
        let jobs = bus.subscribe(WatchFilter::Jobs, true);
        let pods = bus.subscribe(WatchFilter::Pods, true);
        bus.publish(submit(0.0));
        bus.publish(bound(1.0));
        bus.publish(Event::JobStarted { t: 1.0, job: JobId(1) });
        assert_eq!(bus.poll(jobs).len(), 2);
        assert_eq!(bus.poll(pods).len(), 1);
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let mut bus = WatchBus::new();
        let id = bus.subscribe(WatchFilter::All, false);
        bus.unsubscribe(id);
        bus.publish(submit(0.0));
        assert!(bus.poll(id).is_empty());
    }

    #[test]
    fn independent_bookmarks_per_subscriber() {
        let mut bus = WatchBus::new();
        let a = bus.subscribe(WatchFilter::All, false);
        bus.publish(submit(0.0));
        let b = bus.subscribe(WatchFilter::All, false);
        bus.publish(bound(1.0));
        assert_eq!(bus.poll(a).len(), 2);
        assert_eq!(bus.poll(b).len(), 1);
        assert_eq!(bus.resource_version(), 2);
    }
}
