//! API-server substrate: the typed object store + event log that stands in
//! for Kubernetes' API server/etcd (DESIGN.md §1).
//!
//! In the paper's multi-layer design this is the shared control-plane
//! state every other layer converges on: controllers create job/pod
//! objects here, the scheduler binds pods, kubelets admit them, and the
//! simulator drives the lifecycle; every mutation appends to the event
//! log, which the report module replays to draw the Fig.-7 Gantt chart.
//!
//! Views the hot paths read every session are maintained incrementally on
//! the mutation events instead of recomputed from the object store: the
//! pending queue, the task-group placement ([`ApiServer::group_placement`]),
//! the per-tenant service ledgers behind [`ApiServer::tenant_usage`], and
//! the quota-admission ledger behind [`ApiServer::quota_admits`] — each
//! pinned to its full-recompute reference by a property test. The
//! allocation-touch log ([`ApiServer::alloc_touched_since`]) is the event
//! hook external incremental structures (the scheduler's indexed placement
//! engine, the persistent backfill timeline) replay from a cursor instead
//! of rescanning every node.

pub mod watch;

pub use watch::{WatchBus, WatchFilter, WatchId};

use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::{
    ClusterSpec, HostfileEntry, JobId, NodeId, Pod, PodId, PodPhase, PodRole, Resources,
};
use crate::kubelet::{Kubelet, KubeletConfig};
use crate::scheduler::score::GroupPlacement;
use crate::workload::{PlannedJob, TenantId};

/// Lifecycle of a job (podgroup) object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    /// Created, waiting for the gang to be scheduled.
    Pending,
    /// All pods bound and admitted; MPI processes running.
    Running,
    Succeeded,
    /// Evicted by priority preemption: pods released, waiting to be
    /// re-queued (checkpoint-restart) by the simulator.
    Preempted,
    /// Gang can never fit the cluster (detected at submit, or by the
    /// simulator's stall guard); removed from the scheduling queue.
    Unschedulable,
}

/// The job object stored in the API server (Volcano Job + PodGroup merged).
#[derive(Debug, Clone)]
pub struct JobObject {
    pub planned: PlannedJob,
    pub pods: Vec<PodId>,
    pub hostfile: Vec<HostfileEntry>,
    pub phase: JobPhase,
    pub submit_time: f64,
    /// Start of the current/most recent stint (cleared on requeue).
    pub start_time: Option<f64>,
    /// First time the job ever started (survives preemption).
    pub first_start_time: Option<f64>,
    /// Wall-clock seconds of *completed* stints (preempted runs); the
    /// current stint is added at finish/preempt time, so after completion
    /// this is the job's total in-service time.
    pub served_secs: f64,
    pub finish_time: Option<f64>,
}

/// Audit/event log entry (consumed by report::gantt and the metrics).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    JobSubmitted { t: f64, job: JobId },
    PodBound { t: f64, pod: PodId, node: NodeId },
    JobStarted { t: f64, job: JobId },
    JobFinished { t: f64, job: JobId },
    JobPreempted { t: f64, job: JobId },
    JobUnschedulable { t: f64, job: JobId },
    /// An elastic job changed width (expand, shrink, or a pre-start mold):
    /// `workers` is the job's worker count *after* the resize. Only ever
    /// emitted for jobs carrying an `elasticity` spec — rigid traces never
    /// see this event, which keeps their digests byte-identical.
    JobResized { t: f64, job: JobId, workers: u32 },
}

impl Event {
    pub fn time(&self) -> f64 {
        match self {
            Event::JobSubmitted { t, .. }
            | Event::PodBound { t, .. }
            | Event::JobStarted { t, .. }
            | Event::JobFinished { t, .. }
            | Event::JobPreempted { t, .. }
            | Event::JobUnschedulable { t, .. }
            | Event::JobResized { t, .. } => *t,
        }
    }
}

/// The cluster control-plane state: object store + per-node kubelets +
/// request accounting.
pub struct ApiServer {
    pub spec: ClusterSpec,
    pub kubelets: Vec<Kubelet>,
    pub pods: BTreeMap<PodId, Pod>,
    pub jobs: BTreeMap<JobId, JobObject>,
    /// Scheduler-view requested-resource accounting per node.
    pub allocated: Vec<Resources>,
    pub events: Vec<Event>,
    /// Kubernetes-style list/watch surface over the event log.
    pub watch: WatchBus,
    /// Pending-job queue, kept ordered by (submit_time, id) incrementally
    /// (§Perf: recomputing it by filter+sort of the whole job map on every
    /// scheduling session dominated large queues, and `partial_cmp`
    /// panicked on NaN submit times).
    pending: Vec<JobId>,
    /// Running-job index, maintained on start/preempt/complete (§Perf:
    /// `running_jobs` was a full job-map scan per preemption pass; a
    /// `BTreeSet` iterates in the same ascending-`JobId` order the scan
    /// produced, so consumers — and the RNG-sensitive victim ordering —
    /// see an identical sequence). Pinned to
    /// [`ApiServer::running_jobs_reference`] by a property test.
    running: BTreeSet<JobId>,
    /// Cluster-wide task-group placement view, maintained incrementally on
    /// bind/finish/preempt (§Perf: `Scheduler::rebuild_placement` scanned
    /// every pod — including succeeded ones — once per scheduling session).
    placement: GroupPlacement,
    /// Fair-share weight per tenant (PriorityClass stand-in); unknown
    /// tenants default to weight 1.0.
    tenant_weights: BTreeMap<TenantId, f64>,
    /// Maintained per-tenant service accumulators, updated on job
    /// start/preempt/complete (§Perf: `tenant_usage` was a full job-map
    /// scan per fair-share ordering; it is now O(tenants)).
    tenant_service: BTreeMap<TenantId, TenantService>,
    /// ResourceQuota per tenant (absent = unlimited): an aggregate cap on
    /// the requested resources of the tenant's *running* jobs, enforced at
    /// admission ([`ApiServer::quota_admits`]) — over-quota jobs are held
    /// `Pending`, never `Unschedulable` (capacity frees when the tenant's
    /// running jobs end).
    tenant_quotas: BTreeMap<TenantId, Resources>,
    /// Aggregate requested resources of each tenant's running jobs (the
    /// quota-admission ledger, maintained on start/preempt/complete).
    tenant_running: BTreeMap<TenantId, Resources>,
    /// Nodes whose allocated-resource accounting changed, in mutation
    /// order (bind/release — covering start, finish, preempt, requeue and
    /// unschedulable cleanup). Incremental consumers (the scheduler's
    /// indexed placement engine, the persistent backfill timeline) replay
    /// this from a cursor instead of rescanning every node.
    alloc_touched: Vec<NodeId>,
    /// Process-unique instance id: stateful consumers holding a cursor
    /// compare it to detect being re-pointed at a *different* API server
    /// (log length and node count alone cannot distinguish same-shape
    /// servers) and rebuild instead of replaying a wrong cursor.
    instance_id: u64,
    next_pod_id: u64,
}

/// Source of [`ApiServer::instance_id`] values.
static NEXT_API_INSTANCE_ID: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(1);

/// One tenant's maintained service ledger: core-seconds consumed through
/// `last_t`, plus the aggregate core rate of its currently running jobs —
/// enough to answer `tenant_usage(now)` without touching the job map.
#[derive(Debug, Clone, Copy, Default)]
struct TenantService {
    /// Core-seconds of service accumulated up to `last_t`.
    accum: f64,
    /// Cores currently in service (sum over the tenant's running jobs).
    rate_cores: f64,
    /// Time of the last start/preempt/complete event folded into `accum`.
    last_t: f64,
}

impl TenantService {
    /// Fold the elapsed service since the last event into the
    /// accumulator. Out-of-order bookkeeping calls (possible through the
    /// public API, not from the simulator) accrue nothing and must not
    /// rewind `last_t` — that would double-count the interval on the next
    /// fold.
    fn touch(&mut self, now: f64) {
        self.accum += self.rate_cores * (now - self.last_t).max(0.0);
        self.last_t = self.last_t.max(now);
    }

    /// Service consumed as of `now` (without folding).
    fn at(&self, now: f64) -> f64 {
        self.accum + self.rate_cores * (now - self.last_t).max(0.0)
    }
}

impl ApiServer {
    pub fn new(spec: ClusterSpec, kubelet_config: KubeletConfig) -> ApiServer {
        let kubelets = spec
            .nodes
            .iter()
            .map(|n| Kubelet::new(n.clone(), kubelet_config))
            .collect();
        let allocated = vec![Resources::ZERO; spec.nodes.len()];
        ApiServer {
            spec,
            kubelets,
            pods: BTreeMap::new(),
            jobs: BTreeMap::new(),
            allocated,
            events: Vec::new(),
            watch: WatchBus::new(),
            pending: Vec::new(),
            running: BTreeSet::new(),
            placement: GroupPlacement::default(),
            tenant_weights: BTreeMap::new(),
            tenant_service: BTreeMap::new(),
            tenant_quotas: BTreeMap::new(),
            tenant_running: BTreeMap::new(),
            alloc_touched: Vec::new(),
            instance_id: NEXT_API_INSTANCE_ID
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            next_pod_id: 0,
        }
    }

    /// Process-unique id of this API server instance (see the field docs).
    pub fn instance_id(&self) -> u64 {
        self.instance_id
    }

    /// Length of the allocation-touch log — the cursor value an
    /// incremental consumer should store after catching up.
    pub fn alloc_version(&self) -> usize {
        self.alloc_touched.len()
    }

    /// Nodes whose allocated-resource accounting changed since `cursor`
    /// (a prior [`ApiServer::alloc_version`] value). Nodes may repeat;
    /// consumers re-read [`ApiServer::free_on`] per entry, so replay is
    /// idempotent.
    pub fn alloc_touched_since(&self, cursor: usize) -> &[NodeId] {
        &self.alloc_touched[cursor.min(self.alloc_touched.len())..]
    }

    /// The incrementally maintained task-group placement view (equal, at
    /// all times, to `Scheduler::rebuild_placement`'s full pod scan —
    /// guarded by a property test).
    pub fn group_placement(&self) -> &GroupPlacement {
        &self.placement
    }

    /// Register a tenant's fair-share weight (default 1.0 when unset).
    pub fn set_tenant_weight(&mut self, tenant: TenantId, weight: f64) {
        assert!(weight > 0.0, "tenant weight must be positive");
        self.tenant_weights.insert(tenant, weight);
    }

    pub fn tenant_weight(&self, tenant: TenantId) -> f64 {
        self.tenant_weights.get(&tenant).copied().unwrap_or(1.0)
    }

    /// Register a tenant's ResourceQuota: an aggregate cap on the
    /// requested resources of its running jobs.
    pub fn set_tenant_quota(&mut self, tenant: TenantId, quota: Resources) {
        self.tenant_quotas.insert(tenant, quota);
    }

    pub fn tenant_quota(&self, tenant: TenantId) -> Option<Resources> {
        self.tenant_quotas.get(&tenant).copied()
    }

    /// Requested resources of a tenant's currently running jobs (the
    /// quota-admission ledger).
    pub fn tenant_running_requests(&self, tenant: TenantId) -> Resources {
        self.tenant_running.get(&tenant).copied().unwrap_or(Resources::ZERO)
    }

    /// ResourceQuota admission: would starting `job` keep its tenant's
    /// aggregate running requests within quota? The scheduler holds
    /// over-quota jobs as `Pending` (not `Unschedulable`) — they retry as
    /// the tenant's running jobs complete or are preempted.
    pub fn quota_admits(&self, job: JobId) -> bool {
        let spec = &self.jobs[&job].planned.spec;
        match self.tenant_quotas.get(&spec.tenant) {
            None => true,
            Some(quota) => {
                let used = self.tenant_running_requests(spec.tenant);
                (used + spec.resources).fits_within(quota)
            }
        }
    }

    /// Core-seconds of service each tenant has received up to `now`
    /// (terminated runs plus the live elapsed time of running jobs) — the
    /// deficit counter the fair-share queue orders by. O(tenants): read
    /// from the maintained ledgers, not the job map (§Perf; the full
    /// recompute survives as [`ApiServer::tenant_usage_reference`], pinned
    /// equal by a randomized property test).
    pub fn tenant_usage(&self, now: f64) -> BTreeMap<TenantId, f64> {
        self.tenant_service.iter().map(|(&t, s)| (t, s.at(now))).collect()
    }

    /// Reference implementation of [`ApiServer::tenant_usage`]: recompute
    /// every tenant's service from first principles by scanning the whole
    /// job map (completed stints from `served_secs`, running stints live).
    pub fn tenant_usage_reference(&self, now: f64) -> BTreeMap<TenantId, f64> {
        let mut usage: BTreeMap<TenantId, f64> = BTreeMap::new();
        for job in self.jobs.values() {
            let cores = job.planned.spec.resources.cpu_milli as f64 / 1000.0;
            let mut service = job.served_secs;
            if job.phase == JobPhase::Running {
                service += (now - job.start_time.unwrap_or(now)).max(0.0);
            }
            if job.phase == JobPhase::Running || job.served_secs > 0.0 {
                *usage.entry(job.planned.spec.tenant).or_insert(0.0) += service * cores;
            }
        }
        usage
    }

    /// Fold a tenant's elapsed service into its ledger and adjust the
    /// in-service core rate by `delta_cores` (positive on start, negative
    /// on preempt/complete).
    fn adjust_tenant_rate(&mut self, tenant: TenantId, now: f64, delta_cores: f64) {
        let ledger = self.tenant_service.entry(tenant).or_default();
        ledger.touch(now);
        ledger.rate_cores = (ledger.rate_cores + delta_cores).max(0.0);
    }

    /// Record a finished stint of `job` (started .. now) into the job's
    /// served-time, the tenant's service ledger, and the quota-admission
    /// ledger (the stint's requests leave the tenant's running aggregate).
    fn account_service(&mut self, job_id: JobId, now: f64) {
        let job = self.jobs.get_mut(&job_id).expect("service of unknown job");
        let requests = job.planned.spec.resources;
        let cores = requests.cpu_milli as f64 / 1000.0;
        let elapsed = (now - job.start_time.expect("service of unstarted job")).max(0.0);
        let tenant = job.planned.spec.tenant;
        job.served_secs += elapsed;
        self.adjust_tenant_rate(tenant, now, -cores);
        let running = self
            .tenant_running
            .get_mut(&tenant)
            .expect("quota ledger missing for a running tenant");
        *running = running.saturating_sub(&requests);
    }

    /// Release one bound/running pod's node resources, cpuset grant, and
    /// group-placement entry (shared by finish/preempt/unschedulable —
    /// callers decide the pod's next phase and whether the historical
    /// node/cpuset stay on the object for post-mortem reporting).
    fn release_pod_resources(&mut self, pid: PodId, job_id: JobId) {
        let pod = &self.pods[&pid];
        let node = pod.node.expect("release of unbound pod");
        let snapshot = pod.clone();
        self.allocated[node.0] -= snapshot.requests;
        self.alloc_touched.push(node);
        self.kubelets[node.0].terminate(&snapshot);
        if let Some(g) = snapshot.group {
            self.placement.remove((job_id, g), node);
        }
    }

    pub fn fresh_pod_id(&mut self) -> PodId {
        self.next_pod_id += 1;
        PodId(self.next_pod_id)
    }

    /// Register a job object with its (already generated) pods + hostfile.
    pub fn create_job(
        &mut self,
        planned: PlannedJob,
        pods: Vec<Pod>,
        hostfile: Vec<HostfileEntry>,
        now: f64,
    ) {
        let job_id = planned.spec.id;
        let pod_ids: Vec<PodId> = pods.iter().map(|p| p.id).collect();
        for pod in pods {
            debug_assert_eq!(pod.job, job_id);
            self.pods.insert(pod.id, pod);
        }
        self.jobs.insert(
            job_id,
            JobObject {
                planned,
                pods: pod_ids,
                hostfile,
                phase: JobPhase::Pending,
                submit_time: now,
                start_time: None,
                first_start_time: None,
                served_secs: 0.0,
                finish_time: None,
            },
        );
        // Keep the pending queue ordered by (submit_time, id); total_cmp
        // gives a total order even for pathological (NaN) submit times.
        let pos = self.pending.partition_point(|&id| {
            match self.jobs[&id].submit_time.total_cmp(&now) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => id < job_id,
            }
        });
        self.pending.insert(pos, job_id);
        self.events.push(Event::JobSubmitted { t: now, job: job_id });
        self.watch.publish(Event::JobSubmitted { t: now, job: job_id });
    }

    /// Free (unrequested) resources on a node, from the scheduler's
    /// request-accounting view.
    pub fn free_on(&self, node: NodeId) -> Resources {
        self.spec.node(node).allocatable().saturating_sub(&self.allocated[node.0])
    }

    /// Bind a pod to a node and run kubelet admission. Panics on
    /// double-bind; returns false if the kubelet cannot grant the cpuset
    /// (callers must re-schedule — with correct predicates this should not
    /// happen, and the integration tests assert it does not).
    pub fn bind_pod(&mut self, pod_id: PodId, node: NodeId, now: f64) -> bool {
        let pod = self.pods.get_mut(&pod_id).expect("bind of unknown pod");
        assert_eq!(pod.phase, PodPhase::Pending, "double bind of {pod_id:?}");
        if !self.kubelets[node.0].admit(pod) {
            return false;
        }
        pod.node = Some(node);
        pod.phase = PodPhase::Bound;
        let requests = pod.requests;
        let group = pod.group.map(|g| (pod.job, g));
        self.allocated[node.0] += requests;
        self.alloc_touched.push(node);
        if let Some(key) = group {
            self.placement.record(key, node);
        }
        self.events.push(Event::PodBound { t: now, pod: pod_id, node });
        self.watch.publish(Event::PodBound { t: now, pod: pod_id, node });
        true
    }

    /// Mark a fully-bound job as running (gang start).
    pub fn start_job(&mut self, job_id: JobId, now: f64) {
        let job = self.jobs.get_mut(&job_id).expect("start of unknown job");
        debug_assert_eq!(job.phase, JobPhase::Pending);
        for pid in &job.pods {
            let pod = self.pods.get_mut(pid).unwrap();
            debug_assert_eq!(pod.phase, PodPhase::Bound);
            pod.phase = PodPhase::Running;
        }
        let job = self.jobs.get_mut(&job_id).unwrap();
        job.phase = JobPhase::Running;
        job.start_time = Some(now);
        if job.first_start_time.is_none() {
            job.first_start_time = Some(now);
        }
        let tenant = job.planned.spec.tenant;
        let requests = job.planned.spec.resources;
        let cores = requests.cpu_milli as f64 / 1000.0;
        self.adjust_tenant_rate(tenant, now, cores);
        *self.tenant_running.entry(tenant).or_insert(Resources::ZERO) += requests;
        self.pending.retain(|&id| id != job_id);
        self.running.insert(job_id);
        self.events.push(Event::JobStarted { t: now, job: job_id });
        self.watch.publish(Event::JobStarted { t: now, job: job_id });
    }

    /// Mark a pending job as unschedulable (its gang can never fit the
    /// cluster, or it deadlocked under a no-gang scheduler). Removed from
    /// the scheduling queue; any pods a no-gang scheduler already bound
    /// are released back to Pending so the job pins no resources.
    pub fn mark_unschedulable(&mut self, job_id: JobId, now: f64) {
        let job = self.jobs.get_mut(&job_id).expect("mark of unknown job");
        debug_assert_eq!(job.phase, JobPhase::Pending);
        job.phase = JobPhase::Unschedulable;
        let pods = job.pods.clone();
        for pid in pods {
            if self.pods[&pid].phase == PodPhase::Bound {
                self.release_pod_resources(pid, job_id);
                let pod = self.pods.get_mut(&pid).unwrap();
                pod.phase = PodPhase::Pending;
                pod.node = None;
                pod.cpuset = None;
                pod.spans_numa = false;
                pod.group = None;
            }
        }
        self.pending.retain(|&id| id != job_id);
        self.events.push(Event::JobUnschedulable { t: now, job: job_id });
        self.watch.publish(Event::JobUnschedulable { t: now, job: job_id });
    }

    /// Complete a job: release every pod's resources and cpusets.
    pub fn finish_job(&mut self, job_id: JobId, now: f64) {
        self.account_service(job_id, now);
        self.running.remove(&job_id);
        let job = self.jobs.get_mut(&job_id).expect("finish of unknown job");
        debug_assert_eq!(job.phase, JobPhase::Running);
        job.phase = JobPhase::Succeeded;
        job.finish_time = Some(now);
        let pods = job.pods.clone();
        for pid in pods {
            self.release_pod_resources(pid, job_id);
            // Node/cpuset stay on the object for post-mortem reporting.
            self.pods.get_mut(&pid).unwrap().phase = PodPhase::Succeeded;
        }
        self.events.push(Event::JobFinished { t: now, job: job_id });
        self.watch.publish(Event::JobFinished { t: now, job: job_id });
    }

    /// Priority preemption: evict a running job, releasing every pod's
    /// resources and cpusets back to the cluster. The job lands in
    /// [`JobPhase::Preempted`] — off the scheduling queue — until
    /// [`ApiServer::requeue_job`] returns it to Pending (the simulator does
    /// this immediately, charging the checkpoint-restart cost to the job's
    /// remaining work).
    pub fn preempt_job(&mut self, job_id: JobId, now: f64) {
        assert_eq!(
            self.jobs.get(&job_id).expect("preempt of unknown job").phase,
            JobPhase::Running,
            "preempt of non-running {job_id:?}"
        );
        self.account_service(job_id, now);
        self.running.remove(&job_id);
        let job = self.jobs.get_mut(&job_id).expect("preempt of unknown job");
        job.phase = JobPhase::Preempted;
        let pods = job.pods.clone();
        for pid in pods {
            self.release_pod_resources(pid, job_id);
            let pod = self.pods.get_mut(&pid).unwrap();
            pod.phase = PodPhase::Pending;
            pod.node = None;
            pod.cpuset = None;
            pod.spans_numa = false;
            pod.group = None;
        }
        self.events.push(Event::JobPreempted { t: now, job: job_id });
        self.watch.publish(Event::JobPreempted { t: now, job: job_id });
    }

    /// Return a preempted job to the pending queue (checkpoint-restart).
    /// The queue position is by the job's *original* submit time, so a
    /// preempted job goes back near the head rather than to the tail.
    pub fn requeue_job(&mut self, job_id: JobId, _now: f64) {
        let submit;
        {
            let job = self.jobs.get_mut(&job_id).expect("requeue of unknown job");
            assert_eq!(job.phase, JobPhase::Preempted, "requeue of non-preempted {job_id:?}");
            job.phase = JobPhase::Pending;
            job.start_time = None;
            submit = job.submit_time;
        }
        let pos = self.pending.partition_point(|&id| {
            match self.jobs[&id].submit_time.total_cmp(&submit) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => id < job_id,
            }
        });
        self.pending.insert(pos, job_id);
    }

    /// Pending jobs in FIFO (submit-time) order — the scheduler queue,
    /// maintained incrementally by create/start/mark_unschedulable.
    pub fn pending_jobs(&self) -> Vec<JobId> {
        self.pending.clone()
    }

    /// Running jobs in ascending-id order, from the maintained index
    /// (§Perf: the old full job-map scan — kept as
    /// [`ApiServer::running_jobs_reference`] — cost O(jobs) per preemption
    /// pass; the set costs O(running) and iterates identically).
    pub fn running_jobs(&self) -> Vec<JobId> {
        self.running.iter().copied().collect()
    }

    /// Reference implementation of [`ApiServer::running_jobs`]: filter the
    /// whole job map (the pre-index behaviour, pinned equal by a property
    /// test and benched against the index in `benches/scheduler_micro.rs`).
    pub fn running_jobs_reference(&self) -> Vec<JobId> {
        self.jobs
            .iter()
            .filter(|(_, j)| j.phase == JobPhase::Running)
            .map(|(&id, _)| id)
            .collect()
    }

    /// Worker pods of a job.
    pub fn worker_pods_of(&self, job_id: JobId) -> Vec<&Pod> {
        self.jobs[&job_id]
            .pods
            .iter()
            .map(|pid| &self.pods[pid])
            .filter(|p| p.is_worker())
            .collect()
    }

    /// All running worker pods resident on a node (the co-location view the
    /// performance model consumes).
    pub fn running_workers_on(&self, node: NodeId) -> Vec<&Pod> {
        self.pods
            .values()
            .filter(|p| {
                p.is_worker() && p.phase == PodPhase::Running && p.node == Some(node)
            })
            .collect()
    }

    // --- Elastic resize verbs (Kub-style malleable jobs) ---------------
    //
    // Only jobs carrying an `elasticity` spec ever pass through these:
    // every verb asserts it, so rigid traces cannot acquire `JobResized`
    // events (or extra allocation touches) by accident. Resource release
    // and binding go through the same `release_pod_resources`/`bind_pod`
    // paths as the ordinary lifecycle, so the allocation-touch log — and
    // with it the indexed placement engine and the persistent backfill
    // timeline — see resizes exactly like any other (un)bind.

    /// Current worker count of a job (its live width).
    pub fn worker_width(&self, job_id: JobId) -> u32 {
        self.jobs[&job_id]
            .pods
            .iter()
            .filter(|pid| self.pods[*pid].is_worker())
            .count() as u32
    }

    /// Sum of MPI tasks in the job's current worker pods: `spec.ntasks`
    /// for rigid jobs; `w · ntasks / preferred` for an elastic job at
    /// width `w` — the numerator of the simulator's progress-rate scale.
    pub fn active_tasks_of(&self, job_id: JobId) -> u32 {
        self.jobs[&job_id]
            .pods
            .iter()
            .map(|pid| &self.pods[pid])
            .filter(|p| p.is_worker())
            .map(|p| p.ntasks)
            .sum()
    }

    /// Mold a still-pending elastic job down to `new_workers`: drop its
    /// unbound tail worker pods so the gang to place is smaller. Used by
    /// the `resize` action when the preferred-width gang does not fit.
    pub fn mold_job(&mut self, job_id: JobId, new_workers: u32, now: f64) {
        let job = self.jobs.get(&job_id).expect("mold of unknown job");
        assert_eq!(job.phase, JobPhase::Pending, "mold of non-pending {job_id:?}");
        let e = job.planned.spec.elasticity.expect("mold of a rigid job");
        assert!(
            new_workers >= e.min && new_workers < job.planned.granularity.n_workers,
            "mold of {job_id:?} to invalid width {new_workers}"
        );
        let dropped: Vec<PodId> = job
            .pods
            .iter()
            .map(|pid| &self.pods[pid])
            .filter(|p| matches!(p.worker_index(), Some(i) if i >= new_workers))
            .map(|p| p.id)
            .collect();
        for pid in dropped {
            let pod = self.pods.remove(&pid).expect("mold of unknown pod");
            assert_eq!(pod.phase, PodPhase::Pending, "mold of a bound pod");
            debug_assert!(pod.node.is_none());
            let job = self.jobs.get_mut(&job_id).unwrap();
            job.pods.retain(|p| *p != pid);
            job.hostfile.retain(|h| h.hostname != pod.name);
        }
        let job = self.jobs.get_mut(&job_id).unwrap();
        job.planned.granularity.n_workers = new_workers;
        job.planned.granularity.n_nodes = job.planned.granularity.n_nodes.min(new_workers);
        job.planned.granularity.n_groups = job.planned.granularity.n_groups.min(new_workers);
        self.events.push(Event::JobResized { t: now, job: job_id, workers: new_workers });
        self.watch.publish(Event::JobResized { t: now, job: job_id, workers: new_workers });
    }

    /// Shrink a *running* elastic job by `remove` tail workers, releasing
    /// their resources and cpusets (shrink-before-preempt: cheaper than
    /// evicting the whole gang). Returns the memory bytes of the dropped
    /// workers — the image the resize cost is charged on.
    pub fn shrink_job(&mut self, job_id: JobId, remove: u32, now: f64) -> u64 {
        let job = self.jobs.get(&job_id).expect("shrink of unknown job");
        assert_eq!(job.phase, JobPhase::Running, "shrink of non-running {job_id:?}");
        job.planned.spec.elasticity.expect("shrink of a rigid job");
        let mut workers: Vec<(u32, PodId)> = job
            .pods
            .iter()
            .map(|pid| &self.pods[pid])
            .filter_map(|p| p.worker_index().map(|i| (i, p.id)))
            .collect();
        workers.sort_unstable();
        assert!(
            remove >= 1 && (remove as usize) < workers.len(),
            "shrink of {job_id:?} by {remove} of {} workers",
            workers.len()
        );
        let width = workers.len() as u32 - remove;
        let mut freed_mem = 0u64;
        for &(_, pid) in &workers[width as usize..] {
            assert_eq!(self.pods[&pid].phase, PodPhase::Running, "shrink of an idle pod");
            self.release_pod_resources(pid, job_id);
            let pod = self.pods.remove(&pid).unwrap();
            freed_mem += pod.requests.mem_bytes;
            let job = self.jobs.get_mut(&job_id).unwrap();
            job.pods.retain(|p| *p != pid);
            job.hostfile.retain(|h| h.hostname != pod.name);
        }
        let job = self.jobs.get_mut(&job_id).unwrap();
        job.planned.granularity.n_workers = width;
        self.events.push(Event::JobResized { t: now, job: job_id, workers: width });
        self.watch.publish(Event::JobResized { t: now, job: job_id, workers: width });
        freed_mem
    }

    /// Create one fresh (pending, unbound) tail worker pod for a running
    /// elastic job — the expand half of a resize. The caller places and
    /// binds it like any other pod, then seals the resize with
    /// [`ApiServer::complete_expand`]; if no node fits, it must retract
    /// the pod with [`ApiServer::cancel_expand`].
    pub fn expand_job(&mut self, job_id: JobId) -> PodId {
        let job = &self.jobs[&job_id];
        assert_eq!(job.phase, JobPhase::Running, "expand of non-running {job_id:?}");
        job.planned.spec.elasticity.expect("expand of a rigid job");
        let template = job
            .pods
            .iter()
            .map(|pid| &self.pods[pid])
            .find(|p| p.is_worker())
            .expect("expand of a job with no workers")
            .clone();
        let next_index = job
            .pods
            .iter()
            .map(|pid| &self.pods[pid])
            .filter_map(|p| p.worker_index())
            .max()
            .map_or(0, |i| i + 1);
        let name = format!("{}-worker-{}", job.planned.spec.name, next_index);
        let id = self.fresh_pod_id();
        let mut pod = Pod::new(id, job_id, name.clone(), PodRole::Worker { index: next_index });
        pod.ntasks = template.ntasks;
        pod.requests = template.requests;
        pod.limits = template.limits;
        self.pods.insert(id, pod);
        let job = self.jobs.get_mut(&job_id).unwrap();
        job.pods.push(id);
        job.hostfile.push(HostfileEntry { hostname: name, slots: template.ntasks });
        id
    }

    /// Retract an expansion pod that found no node (inverse of
    /// [`ApiServer::expand_job`]; the pod must still be pending/unbound).
    pub fn cancel_expand(&mut self, job_id: JobId, pid: PodId) {
        let pod = self.pods.remove(&pid).expect("cancel of unknown pod");
        assert_eq!(pod.phase, PodPhase::Pending, "cancel of a bound expansion pod");
        debug_assert!(pod.node.is_none());
        let job = self.jobs.get_mut(&job_id).expect("cancel on unknown job");
        job.pods.retain(|p| *p != pid);
        job.hostfile.retain(|h| h.hostname != pod.name);
    }

    /// Seal an expand: flip the freshly bound pods to running, set the
    /// job's new width, and log the `JobResized` event.
    pub fn complete_expand(&mut self, job_id: JobId, now: f64) {
        assert_eq!(self.jobs[&job_id].phase, JobPhase::Running);
        let pods = self.jobs[&job_id].pods.clone();
        let mut width = 0u32;
        for pid in pods {
            let pod = self.pods.get_mut(&pid).unwrap();
            if pod.phase == PodPhase::Bound {
                pod.phase = PodPhase::Running;
            }
            if pod.is_worker() {
                width += 1;
            }
        }
        let job = self.jobs.get_mut(&job_id).unwrap();
        job.planned.granularity.n_workers = width;
        self.events.push(Event::JobResized { t: now, job: job_id, workers: width });
        self.watch.publish(Event::JobResized { t: now, job: job_id, workers: width });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{gib, PodRole};
    use crate::workload::{Benchmark, Granularity, JobSpec};

    fn planned(id: u64) -> PlannedJob {
        PlannedJob {
            spec: JobSpec::paper_job(id, Benchmark::EpDgemm, 0.0),
            granularity: Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 },
        }
    }

    fn api() -> ApiServer {
        ApiServer::new(ClusterSpec::paper(), KubeletConfig::cpu_mem_affinity())
    }

    fn make_worker(api: &mut ApiServer, job: JobId, idx: u32, cores: u64) -> Pod {
        let id = api.fresh_pod_id();
        let mut p = Pod::new(id, job, format!("j{}-w{idx}", job.0), PodRole::Worker { index: idx });
        p.requests = Resources::new(cores * 1000, cores * gib(2));
        p.limits = p.requests;
        p.ntasks = cores as u32;
        p
    }

    #[test]
    fn job_lifecycle_conserves_resources() {
        let mut api = api();
        let pj = planned(1);
        let job_id = pj.spec.id;
        let w = make_worker(&mut api, job_id, 0, 16);
        let wid = w.id;
        api.create_job(pj, vec![w], vec![], 0.0);
        assert_eq!(api.pending_jobs(), vec![job_id]);

        let node = NodeId(1);
        let before = api.free_on(node);
        assert!(api.bind_pod(wid, node, 1.0));
        assert_eq!(api.free_on(node).cpu_milli, before.cpu_milli - 16_000);

        api.start_job(job_id, 1.0);
        assert_eq!(api.running_jobs(), vec![job_id]);
        assert_eq!(api.running_workers_on(node).len(), 1);

        api.finish_job(job_id, 100.0);
        assert_eq!(api.free_on(node), before);
        assert!(api.running_jobs().is_empty());
        assert_eq!(api.jobs[&job_id].finish_time, Some(100.0));
    }

    #[test]
    fn pending_queue_is_fifo_by_submit_time() {
        let mut api = api();
        for (id, t) in [(1u64, 5.0), (2, 1.0), (3, 3.0)] {
            let mut pj = planned(id);
            pj.spec.submit_time = t;
            api.create_job(pj, vec![], vec![], t);
        }
        assert_eq!(api.pending_jobs(), vec![JobId(2), JobId(3), JobId(1)]);
    }

    #[test]
    fn pending_queue_matches_reference_under_random_churn() {
        // The incrementally maintained queue must always equal the old
        // filter+sort reference computation.
        let reference = |api: &ApiServer| -> Vec<JobId> {
            let mut v: Vec<(f64, JobId)> = api
                .jobs
                .iter()
                .filter(|(_, j)| j.phase == JobPhase::Pending)
                .map(|(&id, j)| (j.submit_time, id))
                .collect();
            v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            v.into_iter().map(|(_, id)| id).collect()
        };
        let mut rng = crate::util::Rng::seed_from_u64(88);
        let mut api = api();
        let mut created: Vec<JobId> = Vec::new();
        for step in 0..200u64 {
            let roll = rng.f64();
            if created.len() < 3 || roll < 0.5 {
                let id = step + 1;
                let t = rng.range_f64(0.0, 100.0);
                let mut pj = planned(id);
                pj.spec.submit_time = t;
                api.create_job(pj, vec![], vec![], t);
                created.push(JobId(id));
            } else if roll < 0.8 {
                // Start (and immediately finish) a random pending job.
                let pending = api.pending_jobs();
                if !pending.is_empty() {
                    let id = pending[rng.range_usize(0, pending.len())];
                    api.start_job(id, 100.0);
                    api.finish_job(id, 200.0);
                }
            } else {
                let pending = api.pending_jobs();
                if !pending.is_empty() {
                    let id = pending[rng.range_usize(0, pending.len())];
                    api.mark_unschedulable(id, 100.0);
                }
            }
            assert_eq!(api.pending_jobs(), reference(&api), "step {step}");
        }
    }

    #[test]
    fn unschedulable_job_leaves_queue_and_logs_event() {
        let mut api = api();
        let pj = planned(1);
        let job_id = pj.spec.id;
        api.create_job(pj, vec![], vec![], 0.0);
        assert_eq!(api.pending_jobs(), vec![job_id]);
        api.mark_unschedulable(job_id, 3.0);
        assert!(api.pending_jobs().is_empty());
        assert_eq!(api.jobs[&job_id].phase, JobPhase::Unschedulable);
        assert!(api
            .events
            .iter()
            .any(|e| matches!(e, Event::JobUnschedulable { t, job } if *t == 3.0 && *job == job_id)));
    }

    #[test]
    fn unschedulable_releases_partially_bound_pods() {
        // A no-gang scheduler can leave a deadlocked job partially bound;
        // marking it unschedulable must return those resources and cpusets.
        let mut api = api();
        let pj = planned(1);
        let job_id = pj.spec.id;
        let a = make_worker(&mut api, job_id, 0, 16);
        let b = make_worker(&mut api, job_id, 1, 32);
        let aid = a.id;
        api.create_job(pj, vec![a, b], vec![], 0.0);
        let node = NodeId(1);
        let before = api.free_on(node);
        assert!(api.bind_pod(aid, node, 1.0));
        api.mark_unschedulable(job_id, 2.0);
        assert_eq!(api.free_on(node), before, "bound pod's resources returned");
        let pod = &api.pods[&aid];
        assert_eq!(pod.phase, PodPhase::Pending);
        assert_eq!(pod.node, None);
        assert!(pod.cpuset.is_none(), "exclusive cpuset released");
        // The freed cpuset is actually reusable: an equal-size pod admits.
        let pj2 = planned(2);
        let c = make_worker(&mut api, JobId(2), 0, 16);
        let cid = c.id;
        api.create_job(pj2, vec![c], vec![], 3.0);
        assert!(api.bind_pod(cid, node, 3.0));
    }

    #[test]
    fn preempt_releases_resources_and_requeue_restores_queue_position() {
        let mut api = api();
        // Two jobs: an old one (submit 0) and a newer one (submit 5).
        let pj1 = planned(1);
        let w1 = make_worker(&mut api, JobId(1), 0, 16);
        let w1id = w1.id;
        api.create_job(pj1, vec![w1], vec![], 0.0);
        let mut pj2 = planned(2);
        pj2.spec.submit_time = 5.0;
        api.create_job(pj2, vec![], vec![], 5.0);

        let node = NodeId(1);
        let before = api.free_on(node);
        assert!(api.bind_pod(w1id, node, 1.0));
        api.start_job(JobId(1), 1.0);
        assert_eq!(api.pending_jobs(), vec![JobId(2)]);

        api.preempt_job(JobId(1), 10.0);
        assert_eq!(api.jobs[&JobId(1)].phase, JobPhase::Preempted);
        assert_eq!(api.free_on(node), before, "preempted pod's resources returned");
        let pod = &api.pods[&w1id];
        assert_eq!(pod.phase, PodPhase::Pending);
        assert_eq!(pod.node, None);
        assert!(pod.cpuset.is_none(), "exclusive cpuset released");
        assert!(api
            .events
            .iter()
            .any(|e| matches!(e, Event::JobPreempted { t, job } if *t == 10.0 && *job == JobId(1))));
        // Not in the queue until requeued.
        assert_eq!(api.pending_jobs(), vec![JobId(2)]);

        api.requeue_job(JobId(1), 10.0);
        assert_eq!(api.jobs[&JobId(1)].phase, JobPhase::Pending);
        assert_eq!(api.jobs[&JobId(1)].start_time, None);
        // Original submit time (0.0) puts it ahead of the newer job.
        assert_eq!(api.pending_jobs(), vec![JobId(1), JobId(2)]);
        // And it can start again.
        assert!(api.bind_pod(w1id, node, 11.0));
        api.start_job(JobId(1), 11.0);
        api.finish_job(JobId(1), 20.0);
        assert_eq!(api.free_on(node), before);
    }

    #[test]
    fn tenant_usage_accumulates_over_runs_and_preemptions() {
        let mut api = api();
        let mut pj = planned(1);
        pj.spec.tenant = crate::workload::TenantId(3);
        let w = make_worker(&mut api, JobId(1), 0, 16);
        let wid = w.id;
        api.create_job(pj, vec![w], vec![], 0.0);
        assert!(api.tenant_usage(0.0).is_empty());

        api.bind_pod(wid, NodeId(1), 0.0);
        api.start_job(JobId(1), 0.0);
        // Live usage: 10 s × 16 cores.
        let live = api.tenant_usage(10.0);
        assert!((live[&crate::workload::TenantId(3)] - 160.0).abs() < 1e-9);

        api.preempt_job(JobId(1), 10.0);
        // Preempted stint persisted into the accumulator.
        let after = api.tenant_usage(100.0);
        assert!((after[&crate::workload::TenantId(3)] - 160.0).abs() < 1e-9);

        // Weights default to 1.0 and are settable.
        assert_eq!(api.tenant_weight(crate::workload::TenantId(3)), 1.0);
        api.set_tenant_weight(crate::workload::TenantId(3), 2.5);
        assert_eq!(api.tenant_weight(crate::workload::TenantId(3)), 2.5);
    }

    /// Property: the maintained tenant-service ledgers equal the
    /// full-job-map recompute at every step of a randomized multi-tenant
    /// create → start → preempt/requeue → finish churn (missing entries
    /// count as zero; tolerance covers the differing fp accumulation
    /// order).
    #[test]
    fn prop_tenant_usage_matches_reference_under_churn() {
        let close = |a: &BTreeMap<TenantId, f64>, b: &BTreeMap<TenantId, f64>| {
            let tenants: std::collections::BTreeSet<TenantId> =
                a.keys().chain(b.keys()).copied().collect();
            tenants.into_iter().all(|t| {
                let (x, y) = (
                    a.get(&t).copied().unwrap_or(0.0),
                    b.get(&t).copied().unwrap_or(0.0),
                );
                (x - y).abs() <= 1e-6 * y.abs().max(1.0)
            })
        };
        for case in 0..10u64 {
            let mut rng = crate::util::Rng::seed_from_u64(4300 + case);
            let mut api = api();
            let mut t = 0.0;
            let mut next_id = 0u64;
            for step in 0..120 {
                t += rng.range_f64(0.0, 10.0);
                let roll = rng.f64();
                if roll < 0.4 {
                    next_id += 1;
                    let mut pj = planned(next_id);
                    pj.spec.tenant = TenantId(rng.range_usize(0, 3) as u32);
                    pj.spec.submit_time = t;
                    let cores = 1 + rng.range_usize(0, 16) as u64;
                    let w = make_worker(&mut api, JobId(next_id), 0, cores);
                    let wid = w.id;
                    api.create_job(pj, vec![w], vec![], t);
                    // Start it right away if it fits somewhere.
                    for node in api.spec.worker_ids() {
                        if api.free_on(node).cpu_milli >= cores * 1000
                            && api.bind_pod(wid, node, t)
                        {
                            api.start_job(JobId(next_id), t);
                            break;
                        }
                    }
                } else if roll < 0.6 {
                    let running = api.running_jobs();
                    if !running.is_empty() {
                        let id = running[rng.range_usize(0, running.len())];
                        api.preempt_job(id, t);
                        api.requeue_job(id, t);
                    }
                } else {
                    let running = api.running_jobs();
                    if !running.is_empty() {
                        let id = running[rng.range_usize(0, running.len())];
                        api.finish_job(id, t);
                    }
                }
                let probe = t + rng.range_f64(0.0, 50.0);
                assert!(
                    close(&api.tenant_usage(probe), &api.tenant_usage_reference(probe)),
                    "case {case} step {step}: {:?} vs {:?}",
                    api.tenant_usage(probe),
                    api.tenant_usage_reference(probe)
                );
            }
        }
    }

    #[test]
    fn quota_ledger_tracks_running_requests_and_admission() {
        use crate::workload::TenantId;
        let tenant = TenantId(2);
        let mut api = api();
        // Two 16-core jobs for the tenant; quota admits exactly one.
        for id in [1u64, 2] {
            let mut pj = planned(id);
            pj.spec.tenant = tenant;
            pj.spec.resources = Resources::new(16_000, 16 * gib(2));
            let w = make_worker(&mut api, JobId(id), 0, 16);
            let wid = w.id;
            api.create_job(pj, vec![w], vec![], 0.0);
            assert!(api.bind_pod(wid, NodeId(id as usize), 0.0));
        }
        api.set_tenant_quota(tenant, Resources::new(20_000, gib(256)));
        assert!(api.quota_admits(JobId(1)), "idle tenant is under quota");
        api.start_job(JobId(1), 0.0);
        assert_eq!(api.tenant_running_requests(tenant).cpu_milli, 16_000);
        assert!(!api.quota_admits(JobId(2)), "16 + 16 cores exceed the 20-core quota");
        // Completion returns the requests to the quota pool.
        api.finish_job(JobId(1), 10.0);
        assert_eq!(api.tenant_running_requests(tenant), Resources::ZERO);
        assert!(api.quota_admits(JobId(2)));
        // Preemption also returns them.
        api.start_job(JobId(2), 11.0);
        assert!(!api.quota_admits(JobId(2)));
        api.preempt_job(JobId(2), 12.0);
        assert_eq!(api.tenant_running_requests(tenant), Resources::ZERO);
        // Tenants without a quota are unlimited.
        assert_eq!(api.tenant_quota(TenantId(9)), None);
    }

    #[test]
    fn alloc_touch_log_replays_to_the_live_free_view() {
        let mut api = api();
        let pj = planned(1);
        let w = make_worker(&mut api, JobId(1), 0, 16);
        let wid = w.id;
        api.create_job(pj, vec![w], vec![], 0.0);
        let cursor = api.alloc_version();
        assert!(api.alloc_touched_since(cursor).is_empty());
        api.bind_pod(wid, NodeId(2), 0.0);
        api.start_job(JobId(1), 0.0);
        assert_eq!(api.alloc_touched_since(cursor), &[NodeId(2)], "bind logged");
        api.finish_job(JobId(1), 5.0);
        assert_eq!(api.alloc_touched_since(cursor), &[NodeId(2), NodeId(2)], "release logged");
        // A consumer that replays free_on per entry converges to the live
        // view; a stale (too-large) cursor yields an empty slice, not a
        // panic.
        assert!(api.alloc_touched_since(api.alloc_version() + 10).is_empty());
    }

    fn elastic_planned(id: u64, workers: u32) -> PlannedJob {
        use crate::workload::Elasticity;
        PlannedJob {
            spec: JobSpec::paper_job(id, Benchmark::EpDgemm, 0.0)
                .with_elasticity(Elasticity { min: 2, max: 16, preferred: 8 }),
            granularity: Granularity { n_nodes: 4, n_workers: workers, n_groups: 4 },
        }
    }

    /// Create + bind + start an elastic job of `workers` 2-task workers.
    fn start_elastic(api: &mut ApiServer, id: u64, workers: u32) -> JobId {
        let pj = elastic_planned(id, workers);
        let job_id = pj.spec.id;
        let mut pods = Vec::new();
        let mut hostfile = Vec::new();
        for i in 0..workers {
            let pid = api.fresh_pod_id();
            let name = format!("{}-worker-{i}", pj.spec.name);
            let mut p = Pod::new(pid, job_id, name.clone(), PodRole::Worker { index: i });
            p.ntasks = 2;
            p.requests = Resources::new(2000, 2 * gib(2));
            p.limits = p.requests;
            hostfile.push(HostfileEntry { hostname: name, slots: 2 });
            pods.push(p);
        }
        let pod_ids: Vec<PodId> = pods.iter().map(|p| p.id).collect();
        api.create_job(pj, pods, hostfile, 0.0);
        for (i, pid) in pod_ids.iter().enumerate() {
            let node = NodeId(1 + i % 4);
            assert!(api.bind_pod(*pid, node, 0.0), "worker {i} admits");
        }
        api.start_job(job_id, 0.0);
        job_id
    }

    #[test]
    fn shrink_releases_tail_workers_and_logs_resize() {
        let mut api = api();
        let job_id = start_elastic(&mut api, 1, 8);
        let before: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        assert_eq!(api.worker_width(job_id), 8);
        assert_eq!(api.active_tasks_of(job_id), 16);

        let cursor = api.alloc_version();
        let freed = api.shrink_job(job_id, 6, 10.0);
        assert_eq!(freed, 6 * 2 * gib(2), "six 2-task workers' memory");
        assert_eq!(api.worker_width(job_id), 2);
        assert_eq!(api.active_tasks_of(job_id), 4);
        assert_eq!(api.jobs[&job_id].hostfile.len(), 2);
        assert_eq!(api.jobs[&job_id].planned.granularity.n_workers, 2);
        assert_eq!(api.alloc_touched_since(cursor).len(), 6, "every release logged");
        assert!(api
            .events
            .iter()
            .any(|e| matches!(e, Event::JobResized { t, job, workers }
                if *t == 10.0 && *job == job_id && *workers == 2)));
        // The job still accounts and finishes cleanly at the new width.
        api.finish_job(job_id, 20.0);
        let after: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        for (i, (b, a)) in before.iter().zip(after.iter()).enumerate() {
            // `before` was sampled while the job ran, so after completion
            // every node has at least that much free again.
            assert!(a.cpu_milli >= b.cpu_milli, "node {i}");
        }
        for n in api.spec.node_ids() {
            assert_eq!(api.free_on(n), api.spec.node(n).allocatable(), "node {n:?} leaked");
        }
    }

    #[test]
    fn expand_binds_a_fresh_tail_worker_and_logs_resize() {
        let mut api = api();
        let job_id = start_elastic(&mut api, 1, 2);
        assert_eq!(api.active_tasks_of(job_id), 4);

        let pid = api.expand_job(job_id);
        assert_eq!(api.worker_width(job_id), 3);
        let pod = &api.pods[&pid];
        assert_eq!(pod.phase, PodPhase::Pending);
        assert_eq!(pod.ntasks, 2, "clones the worker template");
        assert_eq!(pod.worker_index(), Some(2), "indexes continue past the tail");
        assert!(api.bind_pod(pid, NodeId(3), 5.0));
        api.complete_expand(job_id, 5.0);
        assert_eq!(api.pods[&pid].phase, PodPhase::Running);
        assert_eq!(api.active_tasks_of(job_id), 6);
        assert_eq!(api.jobs[&job_id].planned.granularity.n_workers, 3);
        assert!(api
            .events
            .iter()
            .any(|e| matches!(e, Event::JobResized { workers: 3, .. })));

        // A retracted expansion leaves no trace.
        let ghost = api.expand_job(job_id);
        api.cancel_expand(job_id, ghost);
        assert_eq!(api.worker_width(job_id), 3);
        assert!(!api.pods.contains_key(&ghost));

        api.finish_job(job_id, 30.0);
        for n in api.spec.node_ids() {
            assert_eq!(api.free_on(n), api.spec.node(n).allocatable());
        }
    }

    #[test]
    fn mold_drops_unbound_tail_workers_before_start() {
        let mut api = api();
        let pj = elastic_planned(1, 8);
        let job_id = pj.spec.id;
        let mut pods = Vec::new();
        let mut hostfile = Vec::new();
        for i in 0..8u32 {
            let pid = api.fresh_pod_id();
            let name = format!("{}-worker-{i}", pj.spec.name);
            let mut p = Pod::new(pid, job_id, name.clone(), PodRole::Worker { index: i });
            p.ntasks = 2;
            p.requests = Resources::new(2000, 2 * gib(2));
            p.limits = p.requests;
            hostfile.push(HostfileEntry { hostname: name, slots: 2 });
            pods.push(p);
        }
        api.create_job(pj, pods, hostfile, 0.0);
        api.mold_job(job_id, 3, 1.0);
        assert_eq!(api.worker_width(job_id), 3);
        assert_eq!(api.jobs[&job_id].hostfile.len(), 3);
        assert_eq!(api.jobs[&job_id].planned.granularity.n_workers, 3);
        assert_eq!(api.jobs[&job_id].planned.granularity.n_groups, 3, "groups clamped");
        assert!(api
            .events
            .iter()
            .any(|e| matches!(e, Event::JobResized { workers: 3, .. })));
        // Still pending — molding never touches node allocations.
        assert_eq!(api.jobs[&job_id].phase, JobPhase::Pending);
        for n in api.spec.node_ids() {
            assert_eq!(api.free_on(n), api.spec.node(n).allocatable());
        }
    }

    /// Property (perf satellite): the maintained running-set equals the
    /// full job-map scan after every lifecycle mutation of a randomized
    /// create → start → preempt/requeue → finish churn.
    #[test]
    fn prop_running_set_matches_reference_under_churn() {
        for case in 0..8u64 {
            let mut rng = crate::util::Rng::seed_from_u64(9100 + case);
            let mut api = api();
            let mut t = 0.0;
            let mut next_id = 0u64;
            for step in 0..150 {
                t += rng.range_f64(0.0, 5.0);
                let roll = rng.f64();
                if roll < 0.4 {
                    next_id += 1;
                    let mut pj = planned(next_id);
                    pj.spec.submit_time = t;
                    let cores = 1 + rng.range_usize(0, 8) as u64;
                    let w = make_worker(&mut api, JobId(next_id), 0, cores);
                    let wid = w.id;
                    api.create_job(pj, vec![w], vec![], t);
                    for node in api.spec.worker_ids() {
                        if api.free_on(node).cpu_milli >= cores * 1000
                            && api.bind_pod(wid, node, t)
                        {
                            api.start_job(JobId(next_id), t);
                            break;
                        }
                    }
                } else if roll < 0.6 {
                    let running = api.running_jobs();
                    if !running.is_empty() {
                        let id = running[rng.range_usize(0, running.len())];
                        api.preempt_job(id, t);
                        api.requeue_job(id, t);
                    }
                } else if roll < 0.8 {
                    let running = api.running_jobs();
                    if !running.is_empty() {
                        let id = running[rng.range_usize(0, running.len())];
                        api.finish_job(id, t);
                    }
                } else {
                    let pending = api.pending_jobs();
                    if !pending.is_empty() {
                        let id = pending[rng.range_usize(0, pending.len())];
                        api.mark_unschedulable(id, t);
                    }
                }
                assert_eq!(
                    api.running_jobs(),
                    api.running_jobs_reference(),
                    "case {case} step {step}"
                );
            }
        }
    }

    #[test]
    fn bind_fails_if_kubelet_cannot_admit() {
        let mut api = api();
        let pj = planned(1);
        let job_id = pj.spec.id;
        let a = make_worker(&mut api, job_id, 0, 32);
        let b = make_worker(&mut api, job_id, 1, 32);
        let (aid, bid) = (a.id, b.id);
        api.create_job(pj, vec![a, b], vec![], 0.0);
        assert!(api.bind_pod(aid, NodeId(1), 0.0));
        // Node 1 has no exclusive CPUs left.
        assert!(!api.bind_pod(bid, NodeId(1), 0.0));
    }

    #[test]
    fn event_log_records_lifecycle_in_order() {
        let mut api = api();
        let pj = planned(1);
        let job_id = pj.spec.id;
        let w = make_worker(&mut api, job_id, 0, 4);
        let wid = w.id;
        api.create_job(pj, vec![w], vec![], 0.0);
        api.bind_pod(wid, NodeId(2), 0.5);
        api.start_job(job_id, 0.5);
        api.finish_job(job_id, 9.0);
        let kinds: Vec<&'static str> = api
            .events
            .iter()
            .map(|e| match e {
                Event::JobSubmitted { .. } => "submit",
                Event::PodBound { .. } => "bind",
                Event::JobStarted { .. } => "start",
                Event::JobFinished { .. } => "finish",
                Event::JobPreempted { .. } => "preempt",
                Event::JobUnschedulable { .. } => "unschedulable",
                Event::JobResized { .. } => "resize",
            })
            .collect();
        assert_eq!(kinds, vec!["submit", "bind", "start", "finish"]);
        assert!(api.events.windows(2).all(|w| w[0].time() <= w[1].time()));
    }
}
