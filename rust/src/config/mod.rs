//! Experiment configuration files — a declarative JSON surface over the
//! scenario/trace/calibration knobs, so operators can describe a run the
//! way they would write a Kubernetes manifest (parsed with the crate's own
//! JSON substrate; the vendored registry has no serde).
//!
//! ```json
//! {
//!   "scenario": "CM_G_TG",
//!   "seed": 2,
//!   "queue": "fair_share",
//!   "preemption": true,
//!   "preemption_policy": "least_work_lost",
//!   "engine": "indexed",
//!   "walltime_error_factor": 1.5,
//!   "force_stepped_clock": false,
//!   "pipeline": {
//!     "actions": ["enqueue", "allocate", "preempt", "backfill"],
//!     "plugins": [
//!       { "name": "aging", "threshold_secs": 300 },
//!       { "name": "preemption_budget", "window_secs": 600, "max_evictions": 2 }
//!     ]
//!   },
//!   "tenants": [
//!     { "id": 0, "weight": 1.0, "quota": { "cores": 64 } },
//!     { "id": 1, "weight": 3.0 }
//!   ],
//!   "cluster": { "worker_nodes": 4, "shards": 1 },
//!   "trace": { "kind": "two_tenant", "jobs": 200, "mean_interval": 60 },
//!   "output": { "gantt": true, "csv": false }
//! }
//! ```
//!
//! Cluster shape: `cluster.mix` picks a preset heterogeneity mix
//! (`uniform | fat_thin | tiered`) at `worker_nodes` size, or
//! `cluster.classes` lists explicit `{"class": "fat"|"balanced"|"thin",
//! "count": N}` groups (mutually exclusive with `mix`; when
//! `worker_nodes` is also given it must equal the classes' total).
//! `cluster.shards` (default 1) partitions the cluster into per-class
//! scheduler domains run in parallel — clamped to the worker-class
//! count, so a homogeneous cluster always runs the single scheduler.

use anyhow::{anyhow, bail, Result};

use crate::cluster::{gib, ClusterSpec, HeterogeneityMix, NodeClass, Resources};
use crate::experiments::RunSpec;
use crate::scenario::Scenario;
use crate::scheduler::{
    ActionKind, ActionList, ElasticityMode, PipelineConfig, PlacementEngineKind,
    PreemptionPolicy, QueuePolicyKind,
};
use crate::simulator::Simulation;
use crate::util::Json;
use crate::workload::{
    elastic_trace, exp1_trace, exp2_trace, serve_trace, serve_trace_elastic, two_tenant_trace,
    uniform_trace, JobSpec, TenantId,
};

/// Parsed experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub scenario: Scenario,
    pub seed: u64,
    /// Queue discipline; defaults to the scenario's own (FIFO-skip for
    /// the Table-II names).
    pub queue: QueuePolicyKind,
    /// Priority preemption; defaults to the scenario's own (only
    /// CM_G_TG_PRE enables it).
    pub preemption: bool,
    /// Victim-selection policy (`preemption_policy`); defaults to
    /// minimal-victim.
    pub preemption_policy: PreemptionPolicy,
    /// Placement engine (`engine`); defaults to `indexed` (bit-identical
    /// to `linear`, property-pinned).
    pub engine: PlacementEngineKind,
    /// Walltime-estimate error multiplier (`walltime_error_factor`);
    /// applied to queue estimates only, defaults to 1.0.
    pub walltime_error_factor: f64,
    /// Pin the simulator to the retired per-event stepped clock
    /// (`force_stepped_clock`, default false) instead of the epoch-based
    /// completion ledger — the pinned reference escape hatch; event
    /// times agree to < 1e-6 s.
    pub force_stepped_clock: bool,
    /// Action/plugin pipeline (`pipeline`); defaults to the scenario's own
    /// (the legacy-equivalent action list — bit-identical to the
    /// pre-pipeline scheduler — everywhere except the EL_MOLD/EL_MALL
    /// scenarios, which carry an elasticity plugin).
    pub pipeline: PipelineConfig,
    /// Per-tenant fair-share weights, applied to the API server before
    /// the run (unlisted tenants weigh 1.0).
    pub tenants: Vec<(TenantId, f64)>,
    /// Per-tenant ResourceQuota caps (`tenants[].quota`), enforced at
    /// admission (over-quota jobs are held `Pending`).
    pub quotas: Vec<(TenantId, Resources)>,
    pub worker_nodes: usize,
    /// Preset heterogeneity mix (`cluster.mix`); `None` keeps the paper's
    /// homogeneous workers. Mutually exclusive with `classes`.
    pub mix: Option<HeterogeneityMix>,
    /// Explicit node classes (`cluster.classes`: `[{"class": "fat",
    /// "count": 2}, ...]`); empty keeps the mix/homogeneous shape.
    pub classes: Vec<NodeClass>,
    /// Scheduler-domain count (`cluster.shards`, default 1): the cluster
    /// is partitioned by worker capacity class into up to this many
    /// domains, each scheduled by its own simulation on its own thread.
    /// Clamped to the worker-class count, so homogeneous clusters always
    /// run the single scheduler bit-identically.
    pub shards: usize,
    pub trace: TraceConfig,
    pub gantt: bool,
    pub csv: bool,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TraceConfig {
    Exp1,
    Exp2,
    Uniform { jobs: usize, mean_interval: f64 },
    TwoTenant { jobs: usize, mean_interval: f64 },
    /// Two-tenant trace of uniformly elastic jobs (`min 2 / preferred 8 /
    /// max 16` workers) — the elasticity ablation's workload.
    Elastic { jobs: usize, mean_interval: f64 },
    /// Open-loop production-serving trace (`workload::arrivals`): diurnal
    /// HPC gangs + bursty (MMPP) AI inference + steady microservices over
    /// `horizon_hours`, scaled by the traffic `multiplier`; `elastic`
    /// swaps the gangs for malleable ones.
    Serve { horizon_hours: f64, multiplier: f64, elastic: bool },
}

impl ExperimentConfig {
    pub fn parse(text: &str) -> Result<ExperimentConfig> {
        let json = Json::parse(text).map_err(|e| anyhow!("config: {e}"))?;
        if json.as_obj().is_none() {
            bail!("config must be a JSON object");
        }

        let scenario_name = json
            .get("scenario")
            .as_str()
            .ok_or_else(|| anyhow!("config: missing \"scenario\""))?;
        let scenario = Scenario::parse(scenario_name)
            .ok_or_else(|| anyhow!("config: unknown scenario {scenario_name:?}"))?;

        let seed = json.get("seed").as_u64().unwrap_or(crate::experiments::DEFAULT_SEED);
        let queue = match json.get("queue").as_str() {
            Some(q) => QueuePolicyKind::parse(q)
                .ok_or_else(|| anyhow!("config: unknown queue policy {q:?}"))?,
            None => scenario.queue(),
        };
        // Block/reserve semantics only exist for gang schedulers; a no-gang
        // profile would silently degrade to FIFO-skip, so reject it.
        if !scenario.scheduler(0).gang && queue.requires_gang() {
            bail!(
                "config: queue policy {} requires a gang scheduler (scenario {} has gang=false)",
                queue.name(),
                scenario.name()
            );
        }
        let preemption = match json.get("preemption") {
            Json::Bool(b) => *b,
            Json::Null => scenario.preemption(),
            other => bail!("config: \"preemption\" must be a bool, got {other:?}"),
        };
        if preemption && !scenario.scheduler(0).gang {
            bail!(
                "config: preemption requires a gang scheduler (scenario {} has gang=false)",
                scenario.name()
            );
        }
        let preemption_policy = match json.get("preemption_policy").as_str() {
            Some(p) => PreemptionPolicy::parse(p).ok_or_else(|| {
                anyhow!(
                    "config: unknown preemption_policy {p:?} (minimal_victim | least_work_lost)"
                )
            })?,
            None => PreemptionPolicy::MinimalVictim,
        };
        let engine = match json.get("engine").as_str() {
            Some(e) => PlacementEngineKind::parse(e)
                .ok_or_else(|| anyhow!("config: unknown engine {e:?} (linear | indexed)"))?,
            None => PlacementEngineKind::Indexed,
        };
        let walltime_error_factor = match json.get("walltime_error_factor") {
            Json::Null => 1.0,
            w => {
                let f = w.as_f64().ok_or_else(|| {
                    anyhow!("config: walltime_error_factor must be a number")
                })?;
                if f <= 0.0 || !f.is_finite() {
                    bail!("config: walltime_error_factor must be positive");
                }
                f
            }
        };
        let force_stepped_clock = match json.get("force_stepped_clock") {
            Json::Bool(b) => *b,
            Json::Null => false,
            other => bail!("config: \"force_stepped_clock\" must be a bool, got {other:?}"),
        };
        // Action/plugin pipeline: `{"actions": [...], "plugins": [{"name":
        // "aging", "threshold_secs": N} | {"name": "preemption_budget",
        // "window_secs": N, "max_evictions": N} | {"name": "elasticity",
        // "mode": "moldable"|"malleable"}]}`. Either key may be omitted; an
        // omitted `pipeline` keeps the scenario's own (legacy-equivalent
        // for every scenario except EL_MOLD/EL_MALL, which carry their
        // elasticity plugin), while an explicit object fully replaces it.
        let pipeline = match json.get("pipeline") {
            Json::Null => scenario.scheduler(0).pipeline,
            p if p.as_obj().is_some() => {
                let mut cfg = PipelineConfig::legacy_equivalent();
                match p.get("actions") {
                    Json::Null => {}
                    Json::Arr(entries) => {
                        let mut kinds = Vec::new();
                        for e in entries {
                            let name = e.as_str().ok_or_else(|| {
                                anyhow!("config: pipeline.actions[] must be strings")
                            })?;
                            kinds.push(ActionKind::parse(name).ok_or_else(|| {
                                anyhow!(
                                    "config: unknown pipeline action {name:?} \
                                     (enqueue | allocate | preempt | resize | reclaim | \
                                     backfill)"
                                )
                            })?);
                        }
                        cfg = cfg.with_actions(
                            ActionList::of(&kinds)
                                .map_err(|e| anyhow!("config: pipeline.actions: {e}"))?,
                        );
                    }
                    other => {
                        bail!("config: \"pipeline.actions\" must be an array, got {other:?}")
                    }
                }
                match p.get("plugins") {
                    Json::Null => {}
                    Json::Arr(entries) => {
                        for e in entries {
                            let name = e.get("name").as_str().ok_or_else(|| {
                                anyhow!("config: pipeline.plugins[].name must be a string")
                            })?;
                            match name {
                                "aging" => {
                                    let threshold =
                                        e.get("threshold_secs").as_f64().ok_or_else(|| {
                                            anyhow!(
                                                "config: aging plugin needs a numeric \
                                                 \"threshold_secs\""
                                            )
                                        })?;
                                    cfg = cfg.with_aging(threshold);
                                }
                                "preemption_budget" => {
                                    let window =
                                        e.get("window_secs").as_f64().ok_or_else(|| {
                                            anyhow!(
                                                "config: preemption_budget plugin needs a \
                                                 numeric \"window_secs\""
                                            )
                                        })?;
                                    let max =
                                        e.get("max_evictions").as_u64().ok_or_else(|| {
                                            anyhow!(
                                                "config: preemption_budget plugin needs an \
                                                 integer \"max_evictions\""
                                            )
                                        })?;
                                    cfg = cfg.with_budget(window, max as u32);
                                }
                                "elasticity" => {
                                    let mode = e.get("mode").as_str().ok_or_else(|| {
                                        anyhow!(
                                            "config: elasticity plugin needs a \"mode\" \
                                             (moldable | malleable)"
                                        )
                                    })?;
                                    let mode = ElasticityMode::parse(mode).ok_or_else(|| {
                                        anyhow!(
                                            "config: unknown elasticity mode {mode:?} \
                                             (moldable | malleable)"
                                        )
                                    })?;
                                    cfg = cfg.with_elasticity(mode);
                                }
                                other => bail!(
                                    "config: unknown pipeline plugin {other:?} \
                                     (aging | preemption_budget | elasticity)"
                                ),
                            }
                        }
                    }
                    other => {
                        bail!("config: \"pipeline.plugins\" must be an array, got {other:?}")
                    }
                }
                cfg.validate().map_err(|e| anyhow!("config: pipeline: {e}"))?;
                cfg
            }
            other => bail!("config: \"pipeline\" must be an object, got {other:?}"),
        };
        // Resize commits rebind gang members atomically; per-pod no-gang
        // schedulers have no gang to mold or shrink, so elasticity there
        // is a contradiction, not a degradation.
        if pipeline.elasticity.is_some() && !scenario.scheduler(0).gang {
            bail!(
                "config: the elasticity plugin requires a gang scheduler (scenario {} has \
                 gang=false)",
                scenario.name()
            );
        }
        let mut tenants = Vec::new();
        let mut quotas = Vec::new();
        match json.get("tenants") {
            Json::Null => {}
            Json::Arr(entries) => {
                for e in entries {
                    let id = e
                        .get("id")
                        .as_u64()
                        .ok_or_else(|| anyhow!("config: tenants[].id must be an integer"))?;
                    let weight = match e.get("weight") {
                        Json::Null => 1.0,
                        w => w.as_f64().ok_or_else(|| {
                            anyhow!("config: tenants[].weight must be a number")
                        })?,
                    };
                    if weight <= 0.0 {
                        bail!("config: tenants[].weight must be positive");
                    }
                    tenants.push((TenantId(id as u32), weight));
                    // ResourceQuota: {"cores": N, "mem_gib": M} — either
                    // axis may be omitted (unlimited on that axis); an
                    // empty object is rejected as a likely typo.
                    match e.get("quota") {
                        Json::Null => {}
                        q if q.as_obj().is_some() => {
                            let cores = match q.get("cores") {
                                Json::Null => None,
                                c => Some(c.as_u64().ok_or_else(|| {
                                    anyhow!(
                                        "config: tenants[].quota.cores must be an integer"
                                    )
                                })?),
                            };
                            let mem_gib = match q.get("mem_gib") {
                                Json::Null => None,
                                m => Some(m.as_u64().ok_or_else(|| {
                                    anyhow!(
                                        "config: tenants[].quota.mem_gib must be an integer"
                                    )
                                })?),
                            };
                            if cores.is_none() && mem_gib.is_none() {
                                bail!(
                                    "config: tenants[].quota needs \"cores\" and/or \"mem_gib\""
                                );
                            }
                            let cores_milli = match cores {
                                Some(c) => c.checked_mul(1000).ok_or_else(|| {
                                    anyhow!("config: tenants[].quota.cores too large")
                                })?,
                                None => u64::MAX,
                            };
                            let mem_bytes = match mem_gib {
                                Some(m) => m.checked_mul(gib(1)).ok_or_else(|| {
                                    anyhow!("config: tenants[].quota.mem_gib too large")
                                })?,
                                None => u64::MAX,
                            };
                            quotas.push((
                                TenantId(id as u32),
                                Resources::new(cores_milli, mem_bytes),
                            ));
                        }
                        other => bail!(
                            "config: tenants[].quota must be an object, got {other:?}"
                        ),
                    }
                }
            }
            other => bail!("config: \"tenants\" must be an array, got {other:?}"),
        }
        let explicit_workers = json.get("cluster").get("worker_nodes").as_u64();
        let worker_nodes = explicit_workers.unwrap_or(4) as usize;
        if worker_nodes == 0 {
            bail!("config: cluster.worker_nodes must be >= 1");
        }
        let mix = match json.get("cluster").get("mix").as_str() {
            Some(m) => Some(HeterogeneityMix::parse(m).ok_or_else(|| {
                anyhow!("config: unknown cluster.mix {m:?} (uniform | fat_thin | tiered)")
            })?),
            None => None,
        };
        let mut classes = Vec::new();
        match json.get("cluster").get("classes") {
            Json::Null => {}
            Json::Arr(entries) => {
                for e in entries {
                    let name = e.get("class").as_str().ok_or_else(|| {
                        anyhow!("config: cluster.classes[].class must be a string")
                    })?;
                    let count = e.get("count").as_u64().ok_or_else(|| {
                        anyhow!("config: cluster.classes[].count must be an integer")
                    })? as usize;
                    let class = NodeClass::parse(name, count).ok_or_else(|| {
                        anyhow!(
                            "config: unknown node class {name:?} (balanced | fat | thin)"
                        )
                    })?;
                    classes.push(class);
                }
                // An explicit empty array means "no classes" — keep the
                // mix/homogeneous shape, as the field docs promise.
                if !classes.is_empty() {
                    if mix.is_some() {
                        bail!(
                            "config: cluster.mix and cluster.classes are mutually exclusive"
                        );
                    }
                    // Validate the shape now so `cluster()` cannot fail
                    // later.
                    let spec = ClusterSpec::heterogeneous(&classes)
                        .map_err(|e| anyhow!("config: {e}"))?;
                    // Class-count mismatch: an explicit worker_nodes must
                    // agree with the classes' total.
                    if let Some(expected) = explicit_workers {
                        if spec.worker_count() != expected as usize {
                            bail!(
                                "config: cluster.classes total {} nodes but cluster.worker_nodes is {}",
                                spec.worker_count(),
                                expected
                            );
                        }
                    }
                }
            }
            other => bail!("config: \"cluster.classes\" must be an array, got {other:?}"),
        }
        let shards = match json.get("cluster").get("shards") {
            Json::Null => 1,
            s => {
                let n = s
                    .as_u64()
                    .ok_or_else(|| anyhow!("config: cluster.shards must be an integer"))?;
                if n == 0 {
                    bail!("config: cluster.shards must be >= 1");
                }
                n as usize
            }
        };

        let trace = match json.get("trace").get("kind").as_str().unwrap_or("exp2") {
            "exp1" => TraceConfig::Exp1,
            "exp2" => TraceConfig::Exp2,
            "uniform" => TraceConfig::Uniform {
                jobs: json.get("trace").get("jobs").as_u64().unwrap_or(20) as usize,
                mean_interval: json
                    .get("trace")
                    .get("mean_interval")
                    .as_f64()
                    .unwrap_or(60.0),
            },
            "two_tenant" => TraceConfig::TwoTenant {
                jobs: json.get("trace").get("jobs").as_u64().unwrap_or(200) as usize,
                mean_interval: json
                    .get("trace")
                    .get("mean_interval")
                    .as_f64()
                    .unwrap_or(60.0),
            },
            "elastic" => TraceConfig::Elastic {
                jobs: json.get("trace").get("jobs").as_u64().unwrap_or(40) as usize,
                mean_interval: json
                    .get("trace")
                    .get("mean_interval")
                    .as_f64()
                    .unwrap_or(30.0),
            },
            "serve" => {
                let horizon_hours = match json.get("trace").get("horizon_hours") {
                    Json::Null => crate::experiments::SERVE_HORIZON_HOURS,
                    h => {
                        let f = h.as_f64().ok_or_else(|| {
                            anyhow!("config: trace.horizon_hours must be a number")
                        })?;
                        if f <= 0.0 || !f.is_finite() {
                            bail!("config: trace.horizon_hours must be positive");
                        }
                        f
                    }
                };
                let multiplier = match json.get("trace").get("multiplier") {
                    Json::Null => 1.0,
                    m => {
                        let f = m.as_f64().ok_or_else(|| {
                            anyhow!("config: trace.multiplier must be a number")
                        })?;
                        if f <= 0.0 || !f.is_finite() {
                            bail!("config: trace.multiplier must be positive");
                        }
                        f
                    }
                };
                let elastic = match json.get("trace").get("elastic") {
                    Json::Null => false,
                    Json::Bool(b) => *b,
                    other => {
                        bail!("config: trace.elastic must be a bool, got {other:?}")
                    }
                };
                TraceConfig::Serve { horizon_hours, multiplier, elastic }
            }
            other => bail!("config: unknown trace.kind {other:?}"),
        };

        Ok(ExperimentConfig {
            scenario,
            seed,
            queue,
            preemption,
            preemption_policy,
            engine,
            walltime_error_factor,
            force_stepped_clock,
            pipeline,
            tenants,
            quotas,
            worker_nodes,
            mix,
            classes,
            shards,
            trace,
            gantt: matches!(json.get("output").get("gantt"), crate::util::Json::Bool(true)),
            csv: matches!(json.get("output").get("csv"), crate::util::Json::Bool(true)),
        })
    }

    pub fn load(path: &std::path::Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn cluster(&self) -> ClusterSpec {
        if !self.classes.is_empty() {
            return ClusterSpec::heterogeneous(&self.classes)
                .expect("classes validated at parse time");
        }
        match self.mix {
            // `Uniform` goes through the same constructor as the paper
            // clusters so homogeneous configs stay bit-identical.
            Some(HeterogeneityMix::Uniform) | None => {
                ClusterSpec::with_workers(self.worker_nodes)
            }
            Some(mix) => ClusterSpec::mixed(self.worker_nodes, mix),
        }
    }

    pub fn build_trace(&self) -> Vec<JobSpec> {
        match self.trace {
            TraceConfig::Exp1 => exp1_trace(),
            TraceConfig::Exp2 => exp2_trace(self.seed),
            TraceConfig::Uniform { jobs, mean_interval } => {
                uniform_trace(jobs, mean_interval, self.seed)
            }
            TraceConfig::TwoTenant { jobs, mean_interval } => {
                two_tenant_trace(jobs, mean_interval, self.seed)
            }
            TraceConfig::Elastic { jobs, mean_interval } => {
                elastic_trace(jobs, mean_interval, self.seed)
            }
            TraceConfig::Serve { horizon_hours, multiplier, elastic } => {
                if elastic {
                    serve_trace_elastic(horizon_hours * 3600.0, multiplier, self.seed)
                } else {
                    serve_trace(horizon_hours * 3600.0, multiplier, self.seed)
                }
            }
        }
    }

    /// Build the fully configured simulation this config describes
    /// (cluster size, queue, preemption policy, placement engine,
    /// walltime error, tenant weights + quotas).
    /// The [`RunSpec`] this config describes — the single run API the CLI
    /// `config` command executes (sharded when `cluster.shards > 1`).
    pub fn run_spec(&self) -> RunSpec {
        let mut spec = RunSpec::new(self.scenario)
            .seed(self.seed)
            .cluster(self.cluster())
            .queue(self.queue)
            .preemption(self.preemption)
            .preemption_policy(self.preemption_policy)
            .engine(self.engine)
            .walltime_error_factor(self.walltime_error_factor)
            .stepped_clock(self.force_stepped_clock)
            .pipeline(self.pipeline)
            .tenant_weights(&self.tenants)
            .shards(self.shards);
        for &(tenant, quota) in &self.quotas {
            spec = spec.tenant_quota(tenant, quota);
        }
        spec
    }

    /// Build the fully configured single-domain simulation (delegates to
    /// [`RunSpec::simulation`]; callers that want the sharded path go
    /// through [`ExperimentConfig::run_spec`]).
    pub fn build_simulation(&self) -> Simulation {
        self.run_spec().simulation()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "seed": 9,
              "cluster": { "worker_nodes": 8 },
              "trace": { "kind": "uniform", "jobs": 10, "mean_interval": 30 },
              "output": { "gantt": true }
            }"#,
        )
        .unwrap();
        assert_eq!(c.scenario, Scenario::CmGTg);
        assert_eq!(c.seed, 9);
        assert_eq!(c.queue, QueuePolicyKind::FifoSkip);
        assert_eq!(c.worker_nodes, 8);
        assert_eq!(c.trace, TraceConfig::Uniform { jobs: 10, mean_interval: 30.0 });
        assert!(c.gantt && !c.csv);
        assert_eq!(c.cluster().worker_count(), 8);
        assert_eq!(c.build_trace().len(), 10);
        assert_eq!(c.shards, 1, "shards defaults to the single scheduler");
    }

    #[test]
    fn parses_and_validates_cluster_shards() {
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "cluster": { "worker_nodes": 8, "mix": "tiered", "shards": 2 }
            }"#,
        )
        .unwrap();
        assert_eq!(c.shards, 2);
        let run = c.run_spec().run(&c.build_trace());
        assert!(run.is_sharded(), "tiered mix at shards=2 splits into domains");

        let err = ExperimentConfig::parse(
            r#"{"scenario": "CM_G_TG", "cluster": {"shards": 0}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("shards must be >= 1"), "{err}");
        let err = ExperimentConfig::parse(
            r#"{"scenario": "CM_G_TG", "cluster": {"shards": "two"}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("must be an integer"), "{err}");
    }

    #[test]
    fn defaults_fill_in() {
        let c = ExperimentConfig::parse(r#"{"scenario": "CM"}"#).unwrap();
        assert_eq!(c.seed, crate::experiments::DEFAULT_SEED);
        assert_eq!(c.worker_nodes, 4);
        assert_eq!(c.trace, TraceConfig::Exp2);
        assert_eq!(c.build_trace().len(), 20);
    }

    #[test]
    fn queue_key_parses_and_defaults_to_scenario_discipline() {
        let c = ExperimentConfig::parse(r#"{"scenario":"CM","queue":"easy_backfill"}"#)
            .unwrap();
        assert_eq!(c.queue, QueuePolicyKind::EasyBackfill);
        let d = ExperimentConfig::parse(r#"{"scenario":"CM_G_TG_SJF"}"#).unwrap();
        assert_eq!(d.queue, QueuePolicyKind::Sjf, "scenario's own discipline");
        assert!(ExperimentConfig::parse(r#"{"scenario":"CM","queue":"lifo"}"#).is_err());
        // Block/reserve disciplines are rejected for no-gang schedulers.
        assert!(
            ExperimentConfig::parse(r#"{"scenario":"Kubeflow","queue":"fifo_strict"}"#)
                .is_err()
        );
        assert!(
            ExperimentConfig::parse(r#"{"scenario":"Kubeflow","queue":"easy_backfill"}"#)
                .is_err()
        );
        assert!(ExperimentConfig::parse(r#"{"scenario":"Kubeflow","queue":"sjf"}"#).is_ok());
    }

    #[test]
    fn force_stepped_clock_parses_defaults_and_rejects_non_bool() {
        let c = ExperimentConfig::parse(
            r#"{"scenario":"CM_G_TG","force_stepped_clock":true}"#,
        )
        .unwrap();
        assert!(c.force_stepped_clock);
        let d = ExperimentConfig::parse(r#"{"scenario":"CM_G_TG"}"#).unwrap();
        assert!(!d.force_stepped_clock, "epoch clock is the default");
        let err = ExperimentConfig::parse(
            r#"{"scenario":"CM_G_TG","force_stepped_clock":"yes"}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("force_stepped_clock"), "{err}");
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ExperimentConfig::parse("[]").is_err());
        assert!(ExperimentConfig::parse(r#"{"scenario": "NOPE"}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"seed": 1}"#).is_err(), "scenario required");
        assert!(
            ExperimentConfig::parse(r#"{"scenario":"CM","trace":{"kind":"weird"}}"#).is_err()
        );
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"worker_nodes":0}}"#
        )
        .is_err());
    }

    #[test]
    fn cluster_shape_keys_parse_and_validate() {
        // Preset mix at a size.
        let c = ExperimentConfig::parse(
            r#"{"scenario":"CM_G_TG","cluster":{"worker_nodes":8,"mix":"fat_thin"}}"#,
        )
        .unwrap();
        assert_eq!(c.mix, Some(HeterogeneityMix::FatThin));
        let spec = c.cluster();
        assert_eq!(spec.worker_count(), 8);
        assert!(spec.is_heterogeneous());
        // Uniform mix keeps the paper's homogeneous builder.
        let u = ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"worker_nodes":4,"mix":"uniform"}}"#,
        )
        .unwrap();
        assert!(!u.cluster().is_heterogeneous());
        assert_eq!(u.cluster().node(crate::cluster::NodeId(1)).name, "node1");
        // Explicit classes.
        let e = ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"classes":[
                {"class":"fat","count":1},{"class":"thin","count":3}]}}"#,
        )
        .unwrap();
        assert_eq!(e.cluster().worker_count(), 4);
        assert_eq!(e.cluster().max_worker_cores(), 64);
        // worker_nodes must agree with the classes' total when given.
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"worker_nodes":8,"classes":[
                {"class":"fat","count":1},{"class":"thin","count":3}]}}"#,
        )
        .is_err());
        // mix and classes are mutually exclusive.
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"mix":"tiered","classes":[
                {"class":"fat","count":1}]}}"#,
        )
        .is_err());
        // Unknown names and degenerate shapes are rejected.
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"mix":"lopsided"}}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"classes":[{"class":"gpu","count":2}]}}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"classes":[{"class":"fat","count":0}]}}"#
        )
        .is_err());
        // An explicit empty array means "no classes": the homogeneous (or
        // mix) shape applies, even alongside a mix.
        let empty = ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"worker_nodes":4,"classes":[]}}"#,
        )
        .unwrap();
        assert!(!empty.cluster().is_heterogeneous());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","cluster":{"mix":"fat_thin","classes":[]}}"#
        )
        .is_ok());
    }

    #[test]
    fn placement_and_estimate_keys_parse_and_validate() {
        // Defaults: indexed engine, minimal-victim, factor 1.0.
        let d = ExperimentConfig::parse(r#"{"scenario":"CM"}"#).unwrap();
        assert_eq!(d.engine, PlacementEngineKind::Indexed);
        assert_eq!(d.preemption_policy, PreemptionPolicy::MinimalVictim);
        assert_eq!(d.walltime_error_factor, 1.0);
        // Explicit values.
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG_PRE",
              "engine": "linear",
              "preemption_policy": "least_work_lost",
              "walltime_error_factor": 2.5
            }"#,
        )
        .unwrap();
        assert_eq!(c.engine, PlacementEngineKind::Linear);
        assert_eq!(c.preemption_policy, PreemptionPolicy::LeastWorkLost);
        assert_eq!(c.walltime_error_factor, 2.5);
        // Rejections.
        assert!(ExperimentConfig::parse(r#"{"scenario":"CM","engine":"quantum"}"#).is_err());
        assert!(
            ExperimentConfig::parse(r#"{"scenario":"CM","preemption_policy":"greedy"}"#)
                .is_err()
        );
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","walltime_error_factor":0}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","walltime_error_factor":-1.5}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","walltime_error_factor":"fast"}"#
        )
        .is_err());
        // And the knobs run end-to-end.
        let run = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG_CBF",
              "engine": "linear",
              "walltime_error_factor": 1.5,
              "trace": { "kind": "uniform", "jobs": 5, "mean_interval": 20 }
            }"#,
        )
        .unwrap();
        assert_eq!(run.build_simulation().run(&run.build_trace()).records.len(), 5);
    }

    #[test]
    fn pipeline_key_parses_and_validates() {
        // Omitted: the legacy-equivalent default.
        let d = ExperimentConfig::parse(r#"{"scenario":"CM"}"#).unwrap();
        assert_eq!(d.pipeline, PipelineConfig::legacy_equivalent());
        // Explicit actions + plugins.
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG_PRE",
              "pipeline": {
                "actions": ["enqueue", "allocate", "preempt", "backfill"],
                "plugins": [
                  { "name": "aging", "threshold_secs": 300 },
                  { "name": "preemption_budget", "window_secs": 600, "max_evictions": 2 }
                ]
              }
            }"#,
        )
        .unwrap();
        assert_eq!(c.pipeline.actions.len(), 4);
        assert!(!c.pipeline.actions.contains(ActionKind::Reclaim));
        assert_eq!(c.pipeline.aging.map(|a| a.threshold_secs), Some(300.0));
        assert_eq!(c.pipeline.budget.map(|b| b.max_evictions), Some(2));
        // Rejections: unknown action, duplicate action, missing required
        // action, out-of-canonical-order list, unknown plugin, bad knobs.
        for bad in [
            r#"{"scenario":"CM","pipeline":{"actions":["enqueue","allocate","evict"]}}"#,
            r#"{"scenario":"CM","pipeline":{"actions":["enqueue","allocate","allocate"]}}"#,
            r#"{"scenario":"CM","pipeline":{"actions":["allocate","backfill"]}}"#,
            r#"{"scenario":"CM","pipeline":{"actions":["allocate","enqueue"]}}"#,
            r#"{"scenario":"CM","pipeline":{"plugins":[{"name":"gpu_packing"}]}}"#,
            r#"{"scenario":"CM","pipeline":{"plugins":[{"name":"aging"}]}}"#,
            r#"{"scenario":"CM","pipeline":{"plugins":[{"name":"aging","threshold_secs":-5}]}}"#,
            r#"{"scenario":"CM","pipeline":{"plugins":[
                {"name":"preemption_budget","window_secs":60,"max_evictions":0}]}}"#,
            r#"{"scenario":"CM","pipeline":[]}"#,
        ] {
            assert!(ExperimentConfig::parse(bad).is_err(), "should reject: {bad}");
        }
        // A pipelined config runs end-to-end.
        let run = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG_PRE",
              "pipeline": { "plugins": [ { "name": "aging", "threshold_secs": 600 } ] },
              "trace": { "kind": "two_tenant", "jobs": 8, "mean_interval": 30 }
            }"#,
        )
        .unwrap();
        assert_eq!(run.build_simulation().run(&run.build_trace()).records.len(), 8);
    }

    #[test]
    fn elasticity_keys_parse_and_validate() {
        // Explicit plugin + the elastic trace kind.
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG_PRE",
              "pipeline": { "plugins": [ { "name": "elasticity", "mode": "moldable" } ] },
              "trace": { "kind": "elastic", "jobs": 6, "mean_interval": 20 }
            }"#,
        )
        .unwrap();
        assert_eq!(c.pipeline.elasticity.map(|e| e.mode), Some(ElasticityMode::Moldable));
        assert_eq!(c.trace, TraceConfig::Elastic { jobs: 6, mean_interval: 20.0 });
        assert_eq!(c.build_trace().len(), 6);
        assert!(c.build_trace().iter().all(|j| j.elasticity.is_some()));
        // An omitted pipeline key keeps the scenario's own pipeline: the
        // EL_* scenarios carry their elasticity plugin into the config.
        let mall = ExperimentConfig::parse(r#"{"scenario":"EL_MALL"}"#).unwrap();
        assert_eq!(
            mall.pipeline.elasticity.map(|e| e.mode),
            Some(ElasticityMode::Malleable)
        );
        assert!(mall.preemption, "EL_* scenarios default preemption on");
        let rigid = ExperimentConfig::parse(r#"{"scenario":"EL_RIGID"}"#).unwrap();
        assert_eq!(rigid.pipeline, PipelineConfig::legacy_equivalent());
        // "resize" parses in the actions list.
        let acts = ExperimentConfig::parse(
            r#"{"scenario":"CM","pipeline":{"actions":["enqueue","allocate","resize"]}}"#,
        )
        .unwrap();
        assert!(acts.pipeline.actions.contains(ActionKind::Resize));
        // Rejections: missing mode, unknown mode, elasticity on a no-gang
        // scheduler, and an elasticity plugin whose action list omits
        // "resize". (Malformed min/max/preferred ranges are rejected at
        // the workload layer — `Elasticity::validate`.)
        for bad in [
            r#"{"scenario":"CM","pipeline":{"plugins":[{"name":"elasticity"}]}}"#,
            r#"{"scenario":"CM","pipeline":{"plugins":[
                {"name":"elasticity","mode":"liquid"}]}}"#,
            r#"{"scenario":"Kubeflow","pipeline":{"plugins":[
                {"name":"elasticity","mode":"moldable"}]}}"#,
            r#"{"scenario":"CM","pipeline":{"actions":["enqueue","allocate"],
                "plugins":[{"name":"elasticity","mode":"moldable"}]}}"#,
        ] {
            assert!(ExperimentConfig::parse(bad).is_err(), "should reject: {bad}");
        }
        // A malleable elastic config runs end-to-end.
        let run = ExperimentConfig::parse(
            r#"{
              "scenario": "EL_MALL",
              "trace": { "kind": "elastic", "jobs": 8, "mean_interval": 20 }
            }"#,
        )
        .unwrap();
        assert_eq!(run.build_simulation().run(&run.build_trace()).records.len(), 8);
    }

    #[test]
    fn serve_trace_keys_parse_and_validate() {
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "trace": { "kind": "serve", "horizon_hours": 2, "multiplier": 4, "elastic": false }
            }"#,
        )
        .unwrap();
        assert_eq!(
            c.trace,
            TraceConfig::Serve { horizon_hours: 2.0, multiplier: 4.0, elastic: false }
        );
        let trace = c.build_trace();
        assert!(!trace.is_empty(), "a 2 h serve horizon at 4x produces jobs");
        assert!(trace.iter().all(|j| j.elasticity.is_none()));
        // Defaults: the full sweep horizon at 1x, rigid gangs.
        let d = ExperimentConfig::parse(
            r#"{"scenario":"CM","trace":{"kind":"serve"}}"#,
        )
        .unwrap();
        assert_eq!(
            d.trace,
            TraceConfig::Serve {
                horizon_hours: crate::experiments::SERVE_HORIZON_HOURS,
                multiplier: 1.0,
                elastic: false
            }
        );
        // The elastic mix marks its gangs malleable.
        let e = ExperimentConfig::parse(
            r#"{
              "scenario": "EL_MALL",
              "trace": { "kind": "serve", "horizon_hours": 2, "elastic": true }
            }"#,
        )
        .unwrap();
        assert!(e.build_trace().iter().any(|j| j.elasticity.is_some()));
        // Rejections: non-positive / mistyped knobs.
        for bad in [
            r#"{"scenario":"CM","trace":{"kind":"serve","horizon_hours":0}}"#,
            r#"{"scenario":"CM","trace":{"kind":"serve","horizon_hours":-4}}"#,
            r#"{"scenario":"CM","trace":{"kind":"serve","horizon_hours":"long"}}"#,
            r#"{"scenario":"CM","trace":{"kind":"serve","multiplier":0}}"#,
            r#"{"scenario":"CM","trace":{"kind":"serve","multiplier":"heavy"}}"#,
            r#"{"scenario":"CM","trace":{"kind":"serve","elastic":"yes"}}"#,
        ] {
            assert!(ExperimentConfig::parse(bad).is_err(), "should reject: {bad}");
        }
        // A serve config runs end-to-end.
        let run = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "trace": { "kind": "serve", "horizon_hours": 1, "multiplier": 2 }
            }"#,
        )
        .unwrap();
        let out = run.build_simulation().run(&run.build_trace());
        assert_eq!(out.records.len(), run.build_trace().len());
    }

    #[test]
    fn tenant_quota_keys_parse_and_validate() {
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "tenants": [
                { "id": 0, "quota": { "cores": 32 } },
                { "id": 1, "weight": 2.0, "quota": { "cores": 64, "mem_gib": 128 } },
                { "id": 2 }
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(c.quotas.len(), 2, "tenant 2 has no quota");
        assert_eq!(c.quotas[0], (TenantId(0), Resources::new(32_000, u64::MAX)));
        assert_eq!(
            c.quotas[1],
            (TenantId(1), Resources::new(64_000, crate::cluster::gib(128)))
        );
        // An empty quota object and mistyped axes are rejected.
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","tenants":[{"id":0,"quota":{}}]}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","tenants":[{"id":0,"quota":{"cores":"many"}}]}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","tenants":[{"id":0,"quota":3}]}"#
        )
        .is_err());
        // Quota'd config runs end-to-end (jobs held Pending still finish
        // as the tenant's running jobs complete).
        let run = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "tenants": [ { "id": 0, "quota": { "cores": 16 } } ],
              "trace": { "kind": "uniform", "jobs": 6, "mean_interval": 10 }
            }"#,
        )
        .unwrap();
        assert_eq!(run.build_simulation().run(&run.build_trace()).records.len(), 6);
    }

    #[test]
    fn heterogeneous_config_runs_end_to_end() {
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "cluster": { "worker_nodes": 6, "mix": "tiered" },
              "trace": { "kind": "uniform", "jobs": 5, "mean_interval": 20 }
            }"#,
        )
        .unwrap();
        let out = c.build_simulation().run(&c.build_trace());
        assert_eq!(out.records.len(), 5);
    }

    #[test]
    fn config_runs_end_to_end() {
        let c = ExperimentConfig::parse(
            r#"{"scenario":"CM_S_TG","trace":{"kind":"uniform","jobs":4,"mean_interval":10}}"#,
        )
        .unwrap();
        let sim = c.build_simulation();
        let out = sim.run(&c.build_trace());
        assert_eq!(out.records.len(), 4);
    }

    #[test]
    fn multi_tenant_keys_parse_and_validate() {
        let c = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG",
              "queue": "fair_share",
              "preemption": true,
              "tenants": [ {"id": 0, "weight": 1.0}, {"id": 1, "weight": 3.0} ],
              "trace": { "kind": "two_tenant", "jobs": 12, "mean_interval": 30 }
            }"#,
        )
        .unwrap();
        assert_eq!(c.queue, QueuePolicyKind::FairShare);
        assert!(c.preemption);
        assert_eq!(c.tenants, vec![(TenantId(0), 1.0), (TenantId(1), 3.0)]);
        assert_eq!(c.trace, TraceConfig::TwoTenant { jobs: 12, mean_interval: 30.0 });
        assert_eq!(c.build_trace().len(), 12);
        // The PRE scenario defaults preemption on without the key.
        let pre = ExperimentConfig::parse(r#"{"scenario":"CM_G_TG_PRE"}"#).unwrap();
        assert!(pre.preemption);
        assert_eq!(pre.queue, QueuePolicyKind::FairShare);
        // Rejections: preemption without gang, bad tenant weight, and the
        // conservative discipline on a no-gang profile.
        assert!(ExperimentConfig::parse(r#"{"scenario":"Kubeflow","preemption":true}"#).is_err());
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","tenants":[{"id":0,"weight":0}]}"#
        )
        .is_err());
        // A mistyped weight must error, not silently fall back to 1.0.
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"CM","tenants":[{"id":0,"weight":"3.0"}]}"#
        )
        .is_err());
        // An omitted weight defaults to 1.0.
        let defaulted =
            ExperimentConfig::parse(r#"{"scenario":"CM","tenants":[{"id":2}]}"#).unwrap();
        assert_eq!(defaulted.tenants, vec![(TenantId(2), 1.0)]);
        assert!(ExperimentConfig::parse(
            r#"{"scenario":"Kubeflow","queue":"cons_backfill"}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(r#"{"scenario":"Kubeflow","queue":"fair_share"}"#)
            .is_ok());
        // A tenant-weighted preemptive config runs end-to-end.
        let run = ExperimentConfig::parse(
            r#"{
              "scenario": "CM_G_TG_PRE",
              "tenants": [ {"id": 1, "weight": 3.0} ],
              "trace": { "kind": "two_tenant", "jobs": 8, "mean_interval": 30 }
            }"#,
        )
        .unwrap();
        let out = run.build_simulation().run(&run.build_trace());
        assert_eq!(out.records.len(), 8);
    }
}
