//! MPI workload performance model — maps a placement (plus co-location) to
//! a per-job slowdown, the rate the discrete-event simulator integrates.
//!
//! In the paper's multi-layer design this is the physics layer: it is
//! what makes the planner's granularity choices and the scheduler's
//! placement decisions *matter*, by charging each mechanism the paper
//! measures on the real testbed. The same model also feeds forward into
//! scheduling itself: [`walltime_factor`] provides the pre-placement
//! walltime estimates the SJF ordering and both backfill disciplines
//! compare against.
//!
//! Mechanisms modelled (each anchored to a paper observation, DESIGN.md §1):
//! 1. Shared-pool scheduling: migrations/context switches under
//!    `cpu-manager-policy=none`, growing with node utilization, plus
//!    run-to-run variance (paper §V-C/§V-D on the NONE scenario).
//! 2. Intra-cgroup scheduling: multi-process containers pay a small
//!    per-process penalty even with exclusive cpusets; single-process
//!    containers behave like explicit pinning (paper §V-C on CM_G*).
//! 3. NUMA: remote-access penalty when a container spans sockets or floats
//!    over the node.
//! 4. Per-socket memory-bandwidth contention between co-resident
//!    containers (the STREAM story and the TG -33% result).
//! 5. Communication: per-benchmark comm fraction (Fig. 3) split between
//!    intra-container shared memory, cross-container/intra-node, and
//!    cross-node 1-GbE traffic (Hockney-style floor + per-byte cost).
//! 6. Gang lockstep: the job's compute rate is gated by its slowest worker
//!    (imbalance — what the task-group plugin fixes).

pub mod calib;
pub mod network;

pub use calib::Calibration;
pub use network::{
    job_nic_demands, nic_demands, nic_oversubscription, traffic_split, TrafficSplit,
};

use std::collections::BTreeMap;

use crate::apiserver::ApiServer;
use crate::cluster::{JobId, NodeId, Pod};
use crate::workload::Benchmark;

/// Slowdown decomposition for one job under the current cluster state.
#[derive(Debug, Clone)]
pub struct JobSlowdown {
    /// Per-worker compute slowdowns (sched × numa × membw).
    pub per_worker: Vec<f64>,
    /// max(per_worker) — the gang-lockstep compute factor.
    pub compute: f64,
    /// Communication-phase multiplier (1.0 = all shared-memory).
    pub comm: f64,
    /// Blended total: (1-cf)·compute + cf·comm·compute_overlap.
    pub total: f64,
}

/// Cluster-wide load snapshot, computed ONCE per rate recomputation and
/// shared across every running job's slowdown evaluation (the naive
/// per-job recomputation was the L3 hot path — see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct ClusterLoads {
    /// node -> socket -> memory-bandwidth demand (bytes/s).
    pub socket_demands: BTreeMap<NodeId, Vec<f64>>,
    /// node -> NIC demand (bytes/s) from cross-node traffic.
    pub nic_demands: BTreeMap<NodeId, f64>,
    /// node -> running MPI tasks (drives the shared-pool migration term).
    pub tasks_on_node: BTreeMap<NodeId, u32>,
}

impl ClusterLoads {
    pub fn snapshot(api: &ApiServer) -> ClusterLoads {
        let mut tasks_on_node: BTreeMap<NodeId, u32> = BTreeMap::new();
        for (&job_id, job) in &api.jobs {
            if job.phase != crate::apiserver::JobPhase::Running {
                continue;
            }
            for pod in api.worker_pods_of(job_id) {
                if let Some(node) = pod.node {
                    *tasks_on_node.entry(node).or_insert(0) += pod.ntasks;
                }
            }
        }
        ClusterLoads {
            socket_demands: socket_demands(api),
            nic_demands: network::nic_demands(api),
            tasks_on_node,
        }
    }
}

/// One running job's per-socket memory-bandwidth demand, by node. The
/// cluster-wide [`ClusterLoads`] snapshot is the sum of these over the
/// running set; the simulator's incremental rate maintenance adds/removes
/// exactly one job's contribution on placement events.
pub fn job_socket_demands(api: &ApiServer, job_id: JobId) -> BTreeMap<NodeId, Vec<f64>> {
    let mut demands: BTreeMap<NodeId, Vec<f64>> = BTreeMap::new();
    let bench = api.jobs[&job_id].planned.spec.benchmark;
    let per_task = bench.membw_demand_per_task();
    for pod in api.worker_pods_of(job_id) {
        let node = match pod.node {
            Some(n) => n,
            None => continue,
        };
        let spec = api.spec.node(node);
        let entry = demands
            .entry(node)
            .or_insert_with(|| vec![0.0; spec.sockets as usize]);
        distribute_demand(entry, pod, spec, per_task * pod.ntasks as f64);
    }
    demands
}

/// Per-socket memory-bandwidth demand on every node, derived from the
/// current running placements. Index: node -> socket -> bytes/s.
fn socket_demands(api: &ApiServer) -> BTreeMap<NodeId, Vec<f64>> {
    let mut demands: BTreeMap<NodeId, Vec<f64>> = BTreeMap::new();
    for (&job_id, job) in &api.jobs {
        if job.phase != crate::apiserver::JobPhase::Running {
            continue;
        }
        for (node, d) in job_socket_demands(api, job_id) {
            let entry = demands.entry(node).or_insert_with(|| vec![0.0; d.len()]);
            for (e, v) in entry.iter_mut().zip(&d) {
                *e += v;
            }
        }
    }
    demands
}

/// Spread one container's bandwidth demand over the sockets its processes
/// can run on: proportional to its cpuset split for exclusive containers,
/// evenly over all sockets for shared-pool containers.
fn distribute_demand(
    socket_demand: &mut [f64],
    pod: &Pod,
    spec: &crate::cluster::NodeSpec,
    total_demand: f64,
) {
    match &pod.cpuset {
        Some(cpuset) if !cpuset.is_empty() => {
            let mut per_socket = vec![0usize; socket_demand.len()];
            for cpu in cpuset.iter() {
                per_socket[spec.socket_of(cpu) as usize] += 1;
            }
            let total_cpus = cpuset.len() as f64;
            for (s, &count) in per_socket.iter().enumerate() {
                socket_demand[s] += total_demand * count as f64 / total_cpus;
            }
        }
        _ => {
            let n = socket_demand.len() as f64;
            for d in socket_demand.iter_mut() {
                *d += total_demand / n;
            }
        }
    }
}

/// Node CPU utilization from the *running tasks* perspective (drives the
/// shared-pool migration penalty).
fn node_task_utilization(api: &ApiServer, loads: &ClusterLoads, node: NodeId) -> f64 {
    let tasks = loads.tasks_on_node.get(&node).copied().unwrap_or(0);
    let cores = api.spec.node(node).allocatable_cores();
    (tasks as f64 / cores as f64).min(2.0)
}

/// Compute slowdown of one worker pod (mechanisms 1–4).
fn worker_slowdown(
    api: &ApiServer,
    pod: &Pod,
    bench: Benchmark,
    calib: &Calibration,
    loads: &ClusterLoads,
    noise: f64,
) -> f64 {
    let profile = bench.profile();
    let node = pod.node.expect("running worker without node");
    let spec = api.spec.node(node);

    // 1+2: scheduling.
    let f_sched = match &pod.cpuset {
        None => {
            let util = node_task_utilization(api, loads, node);
            (1.0 + calib.none_migration_base + calib.none_migration_load * util) * noise
        }
        Some(_) => 1.0 + calib.cgroup_sched_log_coef * (pod.ntasks.max(1) as f64).ln(),
    };

    // 3: NUMA. Shared-pool containers float over the whole node; exclusive
    // containers pay only if their cpuset spans sockets.
    let f_numa = if pod.spans_numa {
        1.0 + calib.numa_penalty(profile)
    } else {
        1.0
    };

    // 4: per-socket memory-bandwidth contention. A worker is gated by the
    // most oversubscribed socket it draws bandwidth from.
    let f_mem = {
        let node_demand = loads.socket_demands.get(&node);
        // Sustainable concurrent-stream bandwidth, not the spec peak.
        let capacity = spec.membw_per_socket * calib.membw_threshold;
        let mut worst: f64 = 1.0;
        if let Some(d) = node_demand {
            let sockets: Vec<usize> = match &pod.cpuset {
                Some(cpuset) => {
                    let mut v: Vec<usize> =
                        cpuset.iter().map(|c| spec.socket_of(c) as usize).collect();
                    v.sort_unstable();
                    v.dedup();
                    v
                }
                None => (0..d.len()).collect(),
            };
            for s in sockets {
                let oversub = d[s] / capacity;
                if oversub > 1.0 {
                    worst = worst.max(1.0 + calib.mem_sensitivity(profile) * (oversub - 1.0));
                }
            }
        }
        worst
    };

    f_sched * f_numa * f_mem
}

/// Communication-phase multiplier (mechanism 5) from the worker placement:
/// fraction of pairwise traffic that is intra-container (shared memory,
/// 1.0), cross-container within a node (+shm penalty), or cross-node
/// (Hockney floor + per-byte 1-GbE cost, scaled by NIC oversubscription
/// when co-scheduled jobs share the wire — see perfmodel::network).
fn comm_multiplier(
    api: &ApiServer,
    workers: &[&Pod],
    bench: Benchmark,
    calib: &Calibration,
    loads: &ClusterLoads,
) -> f64 {
    let split = network::traffic_split(workers);
    if split.cross_node <= 0.0 && split.cross_container_intra <= 0.0 {
        return 1.0;
    }

    let nic_factor = network::nic_oversubscription(
        api,
        &loads.nic_demands,
        workers.iter().filter_map(|p| p.node),
    );
    let eth_multiplier = calib.eth_latency_floor
        + bench.comm_bytes_per_task() * calib.eth_penalty_per_byte * nic_factor;

    split.same_container * 1.0
        + split.cross_container_intra * (1.0 + calib.cross_container_shm)
        + split.cross_node * eth_multiplier
}

/// Full slowdown decomposition of a running job (mechanisms 1–6).
///
/// `noise` is the job's shared-pool variance factor (drawn once per job by
/// the simulator; 1.0 under exclusive cpusets).
pub fn job_slowdown(
    api: &ApiServer,
    job_id: JobId,
    calib: &Calibration,
    noise: f64,
) -> JobSlowdown {
    job_slowdown_with(api, job_id, calib, noise, &ClusterLoads::snapshot(api))
}

/// Static walltime slowdown estimate for a job *before* placement — the
/// queue policies' walltime source (SJF ordering, EASY/conservative
/// backfill windows), replacing the raw base-runtime estimate with one
/// informed by the calibrated model. Placement-dependent terms (NUMA,
/// memory-bandwidth contention, NIC sharing) are unknown ahead of time and
/// left out; what remains is the part determined by the job's own shape:
///
/// - intra-cgroup scheduling: `1 + coef·ln(tasks)` of the *largest* worker
///   (gang lockstep gates on the slowest container);
/// - communication: the pairwise traffic fraction that leaves a container
///   (from the planned worker split) priced at the cross-node Hockney cost
///   — pessimistic for splits the scheduler manages to co-locate, which
///   keeps backfill guarantees conservative.
pub fn walltime_factor(bench: Benchmark, worker_tasks: &[u32], calib: &Calibration) -> f64 {
    if worker_tasks.is_empty() {
        return 1.0;
    }
    let total: u32 = worker_tasks.iter().sum();
    let max_tasks = worker_tasks.iter().copied().max().unwrap_or(1).max(1);
    let f_sched = 1.0 + calib.cgroup_sched_log_coef * (max_tasks as f64).ln();

    let t = total as f64;
    let same_container = if total > 1 {
        worker_tasks
            .iter()
            .map(|&ti| {
                let ti = ti as f64;
                ti * (ti - 1.0)
            })
            .sum::<f64>()
            / (t * (t - 1.0))
    } else {
        1.0
    };
    let cross = (1.0 - same_container).max(0.0);
    let eth = calib.eth_latency_floor + bench.comm_bytes_per_task() * calib.eth_penalty_per_byte;
    let comm = same_container + cross * eth;

    let cf = bench.mpi_profile().comm_fraction;
    (1.0 - cf) * f_sched + cf * comm
}

/// [`job_slowdown`] against a pre-computed load snapshot — the simulator
/// calls this once per running job per state change, amortizing the
/// cluster-wide scans across the whole recomputation.
pub fn job_slowdown_with(
    api: &ApiServer,
    job_id: JobId,
    calib: &Calibration,
    noise: f64,
    loads: &ClusterLoads,
) -> JobSlowdown {
    let job = &api.jobs[&job_id];
    let bench = job.planned.spec.benchmark;
    let workers = api.worker_pods_of(job_id);

    let per_worker: Vec<f64> = workers
        .iter()
        .map(|pod| worker_slowdown(api, pod, bench, calib, loads, noise))
        .collect();
    // 6: gang lockstep — slowest worker gates the compute phase.
    let compute = per_worker.iter().copied().fold(1.0_f64, f64::max);
    let comm = comm_multiplier(api, &workers, bench, calib, loads);

    let cf = bench.mpi_profile().comm_fraction;
    let total = (1.0 - cf) * compute + cf * comm;
    JobSlowdown { per_worker, compute, comm, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apiserver::ApiServer;
    use crate::cluster::{gib, ClusterSpec, NodeId, Pod, PodRole, Resources};
    use crate::kubelet::KubeletConfig;
    use crate::workload::{Benchmark, Granularity, JobSpec, PlannedJob};

    /// Build an ApiServer with one running job whose workers are laid out
    /// as (node, ntasks) tuples.
    fn setup(
        kubelet: KubeletConfig,
        bench: Benchmark,
        layout: &[(usize, u32)],
    ) -> (ApiServer, JobId) {
        let mut api = ApiServer::new(ClusterSpec::paper(), kubelet);
        let spec = JobSpec::paper_job(1, bench, 0.0);
        let job_id = spec.id;
        let planned = PlannedJob {
            spec,
            granularity: Granularity {
                n_nodes: layout.len() as u32,
                n_workers: layout.len() as u32,
                n_groups: 1,
            },
        };
        let mut pods = Vec::new();
        for (i, &(_, ntasks)) in layout.iter().enumerate() {
            let id = api.fresh_pod_id();
            let mut p = Pod::new(id, job_id, format!("w{i}"), PodRole::Worker { index: i as u32 });
            p.ntasks = ntasks;
            p.requests = Resources::new(ntasks as u64 * 1000, ntasks as u64 * gib(2));
            p.limits = p.requests;
            pods.push(p);
        }
        let ids: Vec<_> = pods.iter().map(|p| p.id).collect();
        api.create_job(planned, pods, vec![], 0.0);
        for (pid, &(node, _)) in ids.iter().zip(layout.iter()) {
            assert!(api.bind_pod(*pid, NodeId(node), 0.0));
        }
        api.start_job(job_id, 0.0);
        (api, job_id)
    }

    #[test]
    fn pinned_single_process_containers_have_unit_compute_slowdown() {
        // CM_G-style: 16 × 1-task workers, 4 per node.
        let layout: Vec<(usize, u32)> = (0..16).map(|i| (1 + i % 4, 1)).collect();
        let (api, job) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::EpDgemm, &layout);
        let s = job_slowdown(&api, job, &Calibration::default(), 1.0);
        assert!((s.compute - 1.0).abs() < 1e-9, "compute={}", s.compute);
    }

    #[test]
    fn shared_pool_is_slower_than_affinity() {
        let layout = [(1usize, 16u32)];
        let (api_none, j1) = setup(KubeletConfig::default_policy(), Benchmark::EpDgemm, &layout);
        let (api_cm, j2) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::EpDgemm, &layout);
        let c = Calibration::default();
        let none = job_slowdown(&api_none, j1, &c, 1.0);
        let cm = job_slowdown(&api_cm, j2, &c, 1.0);
        assert!(none.total > cm.total, "NONE {} !> CM {}", none.total, cm.total);
        assert!(cm.total > 1.0, "multi-process cgroup still pays a little");
    }

    #[test]
    fn stream_oversubscribes_one_socket_under_cm() {
        // 16 STREAM tasks pinned to one socket: demand 96 GB/s > 76.8 GB/s.
        let (api, job) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::EpStream, &[(1, 16)]);
        let c = Calibration::default();
        let s = job_slowdown(&api, job, &c, 1.0);
        assert!(s.compute > 1.2, "membw contention expected, got {}", s.compute);
    }

    #[test]
    fn stream_split_across_nodes_avoids_contention() {
        let layout: Vec<(usize, u32)> = (0..4).map(|i| (1 + i, 4)).collect();
        let (api, job) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::EpStream, &layout);
        let c = Calibration::default();
        let s = job_slowdown(&api, job, &c, 1.0);
        // 4 tasks/socket = 24 GB/s < capacity: no membw contention — only
        // the 4-process cgroup penalty (1 + 0.054*ln 4 ~ 1.075) remains.
        assert!(s.compute < 1.10, "compute={}", s.compute);
        assert!(s.total < 1.2, "total={}", s.total);
    }

    #[test]
    fn network_job_scattered_is_catastrophic() {
        // Native-Volcano-style: 16 × 1-task containers over 4 nodes.
        let layout: Vec<(usize, u32)> = (0..16).map(|i| (1 + i % 4, 1)).collect();
        let (api, job) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::GRandomRing, &layout);
        let s = job_slowdown(&api, job, &Calibration::default(), 1.0);
        assert!(s.total > 20.0, "scattered ring should be tens of x, got {}", s.total);

        // Same benchmark in a single container: no penalty.
        let (api1, job1) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::GRandomRing, &[(1, 16)]);
        let s1 = job_slowdown(&api1, job1, &Calibration::default(), 1.0);
        assert!(s1.total < 1.2, "single-container ring {}", s1.total);
    }

    #[test]
    fn cpu_job_tolerates_scatter() {
        let layout: Vec<(usize, u32)> = (0..16).map(|i| (1 + i % 4, 1)).collect();
        let (api, job) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::EpDgemm, &layout);
        let s = job_slowdown(&api, job, &Calibration::default(), 1.0);
        assert!(s.total < 1.6, "DGEMM scatter should be mild, got {}", s.total);
    }

    #[test]
    fn imbalanced_layout_gated_by_slowest_worker() {
        // 12 tasks on node1 + 4 on node2 (imbalance) vs 8+8 (balanced), with
        // a co-located STREAM job loading node1's sockets.
        let c = Calibration::default();
        let (mut api, job) = setup(
            KubeletConfig::cpu_mem_affinity(),
            Benchmark::EpStream,
            &[(1, 12), (2, 4)],
        );
        // Co-locate a second STREAM job on node 1 to create contention.
        let spec2 = JobSpec::paper_job(2, Benchmark::EpStream, 0.0);
        let j2 = spec2.id;
        let planned2 = PlannedJob {
            spec: spec2,
            granularity: Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 },
        };
        let pid = api.fresh_pod_id();
        let mut p = Pod::new(pid, j2, "j2-w0".into(), PodRole::Worker { index: 0 });
        p.ntasks = 16;
        p.requests = Resources::new(16_000, 16 * gib(2));
        p.limits = p.requests;
        api.create_job(planned2, vec![p], vec![], 0.0);
        assert!(api.bind_pod(pid, NodeId(1), 0.0));
        api.start_job(j2, 0.0);

        // The kubelet packed job1's 12-core worker on socket 0 (72 GB/s
        // demand, under capacity) and job2's 16-core worker on socket 1
        // (96 GB/s, oversubscribed): job2 pays, job1 does not — NUMA
        // isolation works as on the real testbed.
        let s2 = job_slowdown(&api, j2, &c, 1.0);
        assert!(
            s2.compute > 1.2,
            "16 STREAM tasks on one socket must hit membw contention: {s2:?}"
        );
        // Gang lockstep: each job's compute factor is its slowest worker.
        let s = job_slowdown(&api, job, &c, 1.0);
        let max = s.per_worker.iter().copied().fold(0.0_f64, f64::max);
        assert_eq!(s.compute, max);
        assert!(s.per_worker[0] > s.per_worker[1], "12-task cgroup > 4-task cgroup");
    }

    #[test]
    fn walltime_factor_shapes() {
        let c = Calibration::default();
        // Single container, single task: no penalty at all.
        assert!((walltime_factor(Benchmark::EpDgemm, &[1], &c) - 1.0).abs() < 1e-12);
        // Single 16-task container: only the intra-cgroup term, weighted by
        // the compute fraction.
        let single = walltime_factor(Benchmark::EpDgemm, &[16], &c);
        assert!(single > 1.0 && single < 1.2, "{single}");
        // A fully scattered network job is estimated far slower than the
        // same job in one container.
        let whole = walltime_factor(Benchmark::GRandomRing, &[16], &c);
        let scattered = walltime_factor(Benchmark::GRandomRing, &[1; 16], &c);
        assert!(scattered > 5.0 * whole, "whole={whole} scattered={scattered}");
        // A scattered CPU job barely pays (tiny comm fraction).
        let dgemm_split = walltime_factor(Benchmark::EpDgemm, &[1; 16], &c);
        assert!(dgemm_split < 1.1, "{dgemm_split}");
        // Estimates never fall below the ideal runtime.
        for b in crate::workload::ALL_BENCHMARKS {
            for tasks in [vec![16u32], vec![4; 4], vec![1; 16], vec![]] {
                assert!(walltime_factor(b, &tasks, &c) >= 1.0 - 1e-12, "{b} {tasks:?}");
            }
        }
    }

    #[test]
    fn noise_only_applies_to_shared_pool() {
        let layout = [(1usize, 16u32)];
        let c = Calibration::default();
        let (api_cm, j) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::EpDgemm, &layout);
        let a = job_slowdown(&api_cm, j, &c, 1.0).total;
        let b = job_slowdown(&api_cm, j, &c, 1.3).total;
        assert!((a - b).abs() < 1e-12, "noise must not affect pinned jobs");

        let (api_none, j2) = setup(KubeletConfig::default_policy(), Benchmark::EpDgemm, &layout);
        let a = job_slowdown(&api_none, j2, &c, 1.0).total;
        let b = job_slowdown(&api_none, j2, &c, 1.3).total;
        assert!(b > a, "noise must slow shared-pool jobs");
    }

    #[test]
    fn more_colocation_never_speeds_up() {
        // Monotonicity: adding a co-located job never *increases* another
        // job's rate.
        let c = Calibration::default();
        let (mut api, job) = setup(KubeletConfig::cpu_mem_affinity(), Benchmark::MiniFe, &[(1, 16)]);
        let before = job_slowdown(&api, job, &c, 1.0).total;
        let spec2 = JobSpec::paper_job(2, Benchmark::EpStream, 0.0);
        let j2 = spec2.id;
        let planned2 = PlannedJob {
            spec: spec2,
            granularity: Granularity { n_nodes: 1, n_workers: 1, n_groups: 1 },
        };
        let pid = api.fresh_pod_id();
        let mut p = Pod::new(pid, j2, "j2-w0".into(), PodRole::Worker { index: 0 });
        p.ntasks = 16;
        p.requests = Resources::new(16_000, 16 * gib(2));
        p.limits = p.requests;
        api.create_job(planned2, vec![p], vec![], 0.0);
        assert!(api.bind_pod(pid, NodeId(1), 0.0));
        api.start_job(j2, 0.0);
        let after = job_slowdown(&api, job, &c, 1.0).total;
        assert!(after >= before, "before={before} after={after}");
    }
}
