//! Calibration constants for the MPI performance model, each anchored to a
//! mechanism the paper measures (§V-C/§V-D observations) — see DESIGN.md §7.
//!
//! The paper reports *relative* results; these constants are set so the
//! shape of Figs. 4–9 and Table III holds (who wins, by roughly what
//! factor). Every constant documents its paper anchor. EXPERIMENTS.md
//! records the calibrated-vs-paper deltas.

use crate::workload::Profile;

#[derive(Debug, Clone)]
pub struct Calibration {
    // --- scheduling / pinning (paper: "less process migrations and
    // context-switches", "exploit processor affinity better") ---
    /// Shared-pool (cpu-manager=none) baseline penalty: process migrations
    /// + context switches even on an idle node.
    pub none_migration_base: f64,
    /// Additional shared-pool penalty proportional to node CPU utilization
    /// (more co-runners => more migrations/preemptions).
    pub none_migration_load: f64,
    /// Log-normal sigma of run-to-run variance under the shared pool
    /// (paper: "randomness of these processes movement can incur a
    /// variable performance between different executions").
    pub none_variance_sigma: f64,
    /// Intra-cgroup scheduling penalty coefficient: a container running
    /// `n` processes on an n-core exclusive cpuset pays
    /// `coef * ln(n)` (the kernel still load-balances within the cgroup;
    /// the effect grows sub-linearly with the process count). Single-
    /// process containers pay nothing — "essentially a single-level
    /// scheduling ... similar to when processes are pinned explicitly".
    pub cgroup_sched_log_coef: f64,

    // --- NUMA (paper: "more local memory accesses, less remote memory
    // accesses" under CM) ---
    /// Remote-access penalty when a container spans NUMA domains (or floats
    /// over the whole node), by profile.
    pub numa_penalty_cpu: f64,
    pub numa_penalty_memory: f64,
    pub numa_penalty_cpumem: f64,
    pub numa_penalty_network: f64,

    // --- per-socket memory-bandwidth contention (paper: "CM ... introduces
    // more memory contention for memory-intensive applications";
    // TG "reduce[s] a 33% the running time of STREAM") ---
    /// Fraction of peak socket bandwidth that is actually sustainable by
    /// concurrent triad-like streams (co-running streams interfere well
    /// before the spec peak; STREAM on 2697v4 sustains ~75% of peak).
    /// Contention starts when demand exceeds `threshold * capacity`.
    pub membw_threshold: f64,
    /// Sensitivity of each profile to bandwidth oversubscription: slowdown
    /// = 1 + sens * (demand/(threshold*capacity) - 1) past the threshold.
    pub mem_sens_cpu: f64,
    pub mem_sens_memory: f64,
    pub mem_sens_cpumem: f64,
    pub mem_sens_network: f64,

    // --- communication (paper: network-intensive workloads "face very
    // important performance degradation" when scattered; 1-GbE testbed) ---
    /// Penalty for crossing container boundaries within one node (shared
    /// memory becomes per-pod loopback/CMA).
    pub cross_container_shm: f64,
    /// Slowdown multiplier of the communication phase for traffic crossing
    /// nodes over 1 GbE, relative to intra-node shared memory, per
    /// benchmark class: proportional to bytes on the wire.
    pub eth_penalty_per_byte: f64,
    /// Floor multiplier for any cross-node communication (latency term of
    /// the Hockney model; collectives pay it even with small payloads).
    pub eth_latency_floor: f64,

    // --- preemption / checkpoint-restart (multi-tenant queues) ---
    /// Sustained checkpoint+restore bandwidth to the shared GPFS mount,
    /// bytes/s. A preempted job's restart cost is dominated by writing and
    /// re-reading its memory image (CRIU-style), so the cost scales with
    /// the job's memory footprint over this bandwidth.
    pub checkpoint_bw: f64,
    /// Fixed restart overhead: container re-creation plus MPI re-wireup,
    /// seconds, paid once per preemption regardless of image size.
    pub restart_fixed_secs: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            none_migration_base: 0.16,
            none_migration_load: 0.22,
            none_variance_sigma: 0.07,
            cgroup_sched_log_coef: 0.054,

            numa_penalty_cpu: 0.06,
            numa_penalty_memory: 0.28,
            numa_penalty_cpumem: 0.16,
            numa_penalty_network: 0.03,

            membw_threshold: 0.75,
            mem_sens_cpu: 0.12,
            mem_sens_memory: 1.0,
            mem_sens_cpumem: 0.55,
            mem_sens_network: 0.05,

            cross_container_shm: 0.05,
            // Calibrated so a 16-task G-RandomRing scattered one-task-per-
            // container across 4 nodes degrades by hundreds of x (the
            // mechanism behind Table III's Volcano makespan blow-up,
            // 123055 s vs 2520 s): per-rank ring traffic 3e8 B/s over a
            // shared 1-GbE NIC vs intra-node shared memory.
            eth_penalty_per_byte: 1.2e-7,
            eth_latency_floor: 1.5,

            // ~2 GB/s sustained to the shared filesystem + 5 s of container
            // and MPI re-wireup: a paper-standard 32 GiB job restarts in
            // ~22 s — small next to its ~600 s runtime, so preemption pays
            // off whenever a high-priority job would otherwise queue.
            checkpoint_bw: 2.0e9,
            restart_fixed_secs: 5.0,
        }
    }
}

impl Calibration {
    pub fn numa_penalty(&self, profile: Profile) -> f64 {
        match profile {
            Profile::Cpu => self.numa_penalty_cpu,
            Profile::Memory => self.numa_penalty_memory,
            Profile::CpuMemory => self.numa_penalty_cpumem,
            Profile::Network => self.numa_penalty_network,
        }
    }

    pub fn mem_sensitivity(&self, profile: Profile) -> f64 {
        match profile {
            Profile::Cpu => self.mem_sens_cpu,
            Profile::Memory => self.mem_sens_memory,
            Profile::CpuMemory => self.mem_sens_cpumem,
            Profile::Network => self.mem_sens_network,
        }
    }

    /// Checkpoint-restart cost (seconds) of preempting a job with the given
    /// memory footprint: fixed re-wireup plus image write+read over the
    /// shared filesystem. The simulator adds this to the preempted job's
    /// remaining work when it restarts.
    pub fn restart_cost_secs(&self, mem_bytes: u64) -> f64 {
        self.restart_fixed_secs + mem_bytes as f64 / self.checkpoint_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Calibration::default();
        assert!(c.none_migration_base > 0.0 && c.none_migration_base < 1.0);
        assert!(c.numa_penalty(Profile::Memory) > c.numa_penalty(Profile::Cpu));
        assert!(c.mem_sensitivity(Profile::Memory) > c.mem_sensitivity(Profile::Network));
    }

    #[test]
    fn restart_cost_scales_with_memory_footprint() {
        let c = Calibration::default();
        let small = c.restart_cost_secs(1 << 30);
        let paper = c.restart_cost_secs(32 << 30);
        assert!(small >= c.restart_fixed_secs);
        assert!(paper > small);
        // A paper-standard 32 GiB job restarts in well under a tenth of its
        // ~600 s base runtime — preemption must be worth paying for.
        assert!(paper < 60.0, "restart cost {paper} too large");
    }

    #[test]
    fn scattered_ring_penalty_matches_table3_scale() {
        // 16-task RandomRing spread 1-task-per-container over 4 nodes:
        // cross fraction 0.75, comm multiplier ~ floor + bytes*penalty.
        let c = Calibration::default();
        // Solo job: NIC oversubscription ~4.7x on its own traffic.
        let m = c.eth_latency_floor + 3.0e8 * c.eth_penalty_per_byte * 4.7;
        let cf = 0.65; // RandomRing comm fraction
        let total = (1.0 - cf) + cf * (1.0 + 0.05 + 0.75 * (m - 1.0));
        assert!(
            (50.0..200.0).contains(&total),
            "scattered ring slowdown {total} should be ~100x"
        );
    }
}
