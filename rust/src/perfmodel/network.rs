//! Network model: pairwise traffic decomposition + 1-GbE NIC contention.
//!
//! The paper's testbed interconnect is 1-Gigabit Ethernet; when several
//! scattered jobs communicate across nodes simultaneously (the native-
//! Volcano scenario in §V-E) they share each node's NIC, which is exactly
//! what turns "slow" into "catastrophic" (Table III). This module
//! decomposes each job's traffic by locality (same container / same node /
//! cross node, under a uniform pairwise pattern) and derives per-node NIC
//! demand so co-scheduled network-intensive jobs degrade each other.

use std::collections::BTreeMap;

use crate::apiserver::{ApiServer, JobPhase};
use crate::cluster::{NodeId, Pod};

/// Locality split of a job's pairwise communication.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficSplit {
    /// Fraction of pairs inside one container (shared memory).
    pub same_container: f64,
    /// Fraction crossing containers within one node.
    pub cross_container_intra: f64,
    /// Fraction crossing nodes (on the wire).
    pub cross_node: f64,
}

impl TrafficSplit {
    pub fn single_container() -> TrafficSplit {
        TrafficSplit { same_container: 1.0, cross_container_intra: 0.0, cross_node: 0.0 }
    }
}

/// Decompose a worker placement into the traffic split under a uniform
/// (all-to-all-ish) pairwise pattern: P(same container) = Σ share_i²,
/// P(same node) = Σ_node (Σ_{i∈node} share_i)².
pub fn traffic_split(workers: &[&Pod]) -> TrafficSplit {
    let ntasks_total: u32 = workers.iter().map(|p| p.ntasks).sum();
    if ntasks_total == 0 || workers.len() <= 1 {
        return TrafficSplit::single_container();
    }
    let n = ntasks_total as f64;
    let mut same_container = 0.0;
    let mut tasks_per_node: BTreeMap<NodeId, f64> = BTreeMap::new();
    for pod in workers {
        let share = pod.ntasks as f64 / n;
        same_container += share * share;
        *tasks_per_node.entry(pod.node.expect("unbound worker")).or_insert(0.0) += share;
    }
    let same_node: f64 = tasks_per_node.values().map(|s| s * s).sum();
    TrafficSplit {
        same_container,
        cross_container_intra: (same_node - same_container).max(0.0),
        cross_node: 1.0 - same_node,
    }
}

/// One running job's per-node NIC demand (bytes/s) from its cross-node
/// traffic: each node's share of the job's wire traffic is proportional to
/// the tasks it hosts, weighted by the job's communication fraction (a job
/// that spends 65% of its time communicating loads the NIC 65% of the
/// time). The cluster-wide [`nic_demands`] view sums these; the
/// simulator's incremental rate maintenance adds/removes one job's
/// contribution on placement events.
pub fn job_nic_demands(api: &ApiServer, job_id: crate::cluster::JobId) -> BTreeMap<NodeId, f64> {
    let mut demand: BTreeMap<NodeId, f64> = BTreeMap::new();
    let bench = api.jobs[&job_id].planned.spec.benchmark;
    let workers = api.worker_pods_of(job_id);
    let split = traffic_split(&workers);
    if split.cross_node <= 0.0 {
        return demand;
    }
    let cf = bench.mpi_profile().comm_fraction;
    for pod in &workers {
        let node = pod.node.expect("unbound worker");
        // Each task sends comm_bytes_per_task during comm phases; the
        // cross-node share of it hits this node's NIC, duty-cycled by
        // the communication fraction.
        let bytes = pod.ntasks as f64 * bench.comm_bytes_per_task();
        *demand.entry(node).or_insert(0.0) += bytes * split.cross_node * cf;
    }
    demand
}

/// Per-node NIC demand (bytes/s) from every *running* job's cross-node
/// traffic.
pub fn nic_demands(api: &ApiServer) -> BTreeMap<NodeId, f64> {
    let mut demand: BTreeMap<NodeId, f64> = BTreeMap::new();
    for (&job_id, job) in &api.jobs {
        if job.phase != JobPhase::Running {
            continue;
        }
        for (node, d) in job_nic_demands(api, job_id) {
            *demand.entry(node).or_insert(0.0) += d;
        }
    }
    demand
}

/// NIC oversubscription factor for a set of nodes: how much slower wire
/// transfers go because co-resident jobs share the NIC. 1.0 when total
/// demand fits the NIC.
pub fn nic_oversubscription(
    api: &ApiServer,
    demands: &BTreeMap<NodeId, f64>,
    nodes: impl Iterator<Item = NodeId>,
) -> f64 {
    let mut worst = 1.0_f64;
    for node in nodes {
        let nic = api.spec.node(node).nic_bw;
        if let Some(&d) = demands.get(&node) {
            worst = worst.max(d / nic);
        }
    }
    worst.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{JobId, PodId, PodRole};

    fn worker(id: u64, node: usize, ntasks: u32) -> Pod {
        let mut p = Pod::new(
            PodId(id),
            JobId(1),
            format!("w{id}"),
            PodRole::Worker { index: id as u32 },
        );
        p.ntasks = ntasks;
        p.node = Some(NodeId(node));
        p
    }

    #[test]
    fn single_container_is_all_shared_memory() {
        let w = worker(1, 1, 16);
        let split = traffic_split(&[&w]);
        assert_eq!(split, TrafficSplit::single_container());
    }

    #[test]
    fn split_fractions_sum_to_one() {
        let pods: Vec<Pod> = (0..16).map(|i| worker(i, 1 + (i % 4) as usize, 1)).collect();
        let refs: Vec<&Pod> = pods.iter().collect();
        let s = traffic_split(&refs);
        let sum = s.same_container + s.cross_container_intra + s.cross_node;
        assert!((sum - 1.0).abs() < 1e-12);
        // 16 × 1-task containers over 4 nodes: P(same node) = 4(4/16)² = ¼.
        assert!((s.cross_node - 0.75).abs() < 1e-12);
        assert!((s.same_container - 1.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn two_containers_same_node_have_no_wire_traffic() {
        let a = worker(1, 2, 8);
        let b = worker(2, 2, 8);
        let s = traffic_split(&[&a, &b]);
        assert_eq!(s.cross_node, 0.0);
        assert!((s.same_container - 0.5).abs() < 1e-12);
        assert!((s.cross_container_intra - 0.5).abs() < 1e-12);
    }

    #[test]
    fn uneven_placement_has_less_cross_traffic_than_even() {
        // 12+4 split keeps more pairs local than 8+8.
        let a = [worker(1, 1, 12), worker(2, 2, 4)];
        let b = [worker(3, 1, 8), worker(4, 2, 8)];
        let sa = traffic_split(&[&a[0], &a[1]]);
        let sb = traffic_split(&[&b[0], &b[1]]);
        assert!(sa.cross_node < sb.cross_node);
    }
}
