//! PJRT runtime — loads the AOT-compiled benchmark payloads
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them on the XLA CPU client. This is the only place the rust coordinator
//! touches XLA; Python never runs on this path.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* -> HloModuleProto
//! text parser -> XlaComputation -> PjRtClient::compile -> execute.
//!
//! The `xla` crate only exists in the offline HPC toolchain registry, so
//! the execution path is gated behind the off-by-default `pjrt` feature
//! (Cargo.toml): without it, manifest parsing still works and
//! [`Runtime::load`] returns a descriptive error, so the CLI, examples,
//! and tier-1 tests build and run on a bare checkout.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;
use crate::workload::{Benchmark, Profile};

/// One entry-point argument's shape/dtype from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Manifest entry for one compiled benchmark payload.
#[derive(Debug, Clone)]
pub struct PayloadSpec {
    pub benchmark: Benchmark,
    pub hlo_path: PathBuf,
    pub args: Vec<ArgSpec>,
    pub profile: Profile,
    pub flops_per_step: u64,
    pub bytes_per_step: u64,
}

/// Parse `artifacts/manifest.json` (written by python/compile/aot.py).
pub fn load_manifest(artifacts_dir: &Path) -> Result<Vec<PayloadSpec>> {
    let path = artifacts_dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
    let json = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
    let obj = json.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
    let mut specs = Vec::new();
    for (name, entry) in obj {
        let benchmark = Benchmark::from_artifact(name)
            .ok_or_else(|| anyhow!("unknown benchmark {name} in manifest"))?;
        let hlo = entry
            .get("hlo")
            .as_str()
            .ok_or_else(|| anyhow!("{name}: missing hlo"))?;
        let profile_str = entry
            .get("profile")
            .as_str()
            .ok_or_else(|| anyhow!("{name}: missing profile"))?;
        let profile = Profile::parse(profile_str)
            .ok_or_else(|| anyhow!("{name}: bad profile {profile_str}"))?;
        let mut args = Vec::new();
        for a in entry.get("args").as_arr().unwrap_or(&[]) {
            let shape = a
                .get("shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_u64().unwrap_or(0) as usize)
                .collect();
            let dtype = a.get("dtype").as_str().unwrap_or("float32").to_string();
            args.push(ArgSpec { shape, dtype });
        }
        specs.push(PayloadSpec {
            benchmark,
            hlo_path: artifacts_dir.join(hlo),
            args,
            profile,
            flops_per_step: entry.get("flops_per_step").as_u64().unwrap_or(0),
            bytes_per_step: entry.get("bytes_per_step").as_u64().unwrap_or(0),
        });
    }
    if specs.is_empty() {
        bail!("empty manifest at {}", path.display());
    }
    Ok(specs)
}

/// Real PJRT execution path — compiled only with the `pjrt` feature.
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::BTreeMap;
    use std::path::Path;
    use std::time::Instant;

    use anyhow::{anyhow, bail, Context, Result};

    use super::{load_manifest, ArgSpec, PayloadSpec};
    use crate::workload::Benchmark;

    /// Build a deterministic input literal for an argument spec. Values are
    /// small random floats (not zeros — keeps the numerics non-degenerate);
    /// int32 args are treated as the ring permutation.
    fn make_literal(arg: &ArgSpec, rng: &mut crate::util::Rng) -> Result<xla::Literal> {
        let n = arg.elements();
        let dims: Vec<i64> = arg.shape.iter().map(|&d| d as i64).collect();
        let lit = match arg.dtype.as_str() {
            "float32" => {
                let data: Vec<f32> =
                    (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect();
                xla::Literal::vec1(&data)
            }
            "int32" => {
                // Ring permutation: rotate by one (a valid random-ring order).
                let p = n as i32;
                let data: Vec<i32> = (0..p).map(|i| (i + 1) % p).collect();
                xla::Literal::vec1(&data)
            }
            other => bail!("unsupported dtype {other}"),
        };
        Ok(if dims.len() == 1 && dims[0] as usize == n {
            lit
        } else {
            lit.reshape(&dims)?
        })
    }

    /// A compiled benchmark payload, ready to execute.
    pub struct Payload {
        pub spec: PayloadSpec,
        exe: xla::PjRtLoadedExecutable,
        inputs: Vec<xla::Literal>,
    }

    impl Payload {
        /// Execute one step; returns wall-clock seconds.
        pub fn step(&self) -> Result<f64> {
            let t0 = Instant::now();
            let result = self.exe.execute::<xla::Literal>(&self.inputs)?;
            // Force completion by materializing the first output.
            let _ = result[0][0].to_literal_sync()?;
            Ok(t0.elapsed().as_secs_f64())
        }

        /// Execute one step and return the flattened f32 outputs (used by the
        /// e2e driver to sanity-check numerics, e.g. MiniFE residual norms).
        pub fn step_outputs(&self) -> Result<Vec<Vec<f32>>> {
            let result = self.exe.execute::<xla::Literal>(&self.inputs)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            let mut outs = Vec::new();
            for lit in tuple {
                outs.push(lit.to_vec::<f32>().unwrap_or_default());
            }
            Ok(outs)
        }
    }

    /// The PJRT runtime: one CPU client + all compiled payloads.
    pub struct Runtime {
        pub client_platform: String,
        pub payloads: BTreeMap<Benchmark, Payload>,
    }

    impl Runtime {
        /// Load every artifact in the manifest and compile it on the CPU
        /// PJRT client.
        pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let specs = load_manifest(artifacts_dir)?;
            let mut rng = crate::util::Rng::seed_from_u64(0x9e37_79b9_7f4a_7c15);
            let mut payloads = BTreeMap::new();
            for spec in specs {
                let proto = xla::HloModuleProto::from_text_file(
                    spec.hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .with_context(|| format!("parsing {}", spec.hlo_path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", spec.benchmark))?;
                let inputs = spec
                    .args
                    .iter()
                    .map(|a| make_literal(a, &mut rng))
                    .collect::<Result<Vec<_>>>()?;
                payloads.insert(spec.benchmark, Payload { spec, exe, inputs });
            }
            Ok(Runtime { client_platform: client.platform_name(), payloads })
        }

        pub fn payload(&self, bench: Benchmark) -> Option<&Payload> {
            self.payloads.get(&bench)
        }

        /// Measure mean per-step wall time of one benchmark payload.
        pub fn measure(&self, bench: Benchmark, warmup: usize, iters: usize) -> Result<f64> {
            let payload =
                self.payload(bench).ok_or_else(|| anyhow!("no payload for {bench}"))?;
            for _ in 0..warmup {
                payload.step()?;
            }
            let mut total = 0.0;
            for _ in 0..iters.max(1) {
                total += payload.step()?;
            }
            Ok(total / iters.max(1) as f64)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Payload, Runtime};

/// Stub execution path — same public surface as the PJRT runtime, but
/// [`Runtime::load`] fails with a descriptive error. Keeps the CLI's `e2e`
/// subcommand and the `e2e_serve` / `profile_benchmarks` examples
/// compiling on a checkout without the offline `xla` registry.
#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::collections::BTreeMap;
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::PayloadSpec;
    use crate::workload::Benchmark;

    /// Placeholder for a compiled benchmark payload (never constructed).
    pub struct Payload {
        pub spec: PayloadSpec,
    }

    impl Payload {
        pub fn step(&self) -> Result<f64> {
            bail!("kube-fgs was built without the `pjrt` feature")
        }

        pub fn step_outputs(&self) -> Result<Vec<Vec<f32>>> {
            bail!("kube-fgs was built without the `pjrt` feature")
        }
    }

    /// Placeholder runtime: `load` always fails.
    pub struct Runtime {
        pub client_platform: String,
        pub payloads: BTreeMap<Benchmark, Payload>,
    }

    impl Runtime {
        pub fn load(_artifacts_dir: &Path) -> Result<Runtime> {
            bail!(
                "PJRT execution requires the `pjrt` feature (and the `xla` \
                 crate from the offline toolchain registry); rebuild with \
                 `cargo build --features pjrt`"
            )
        }

        pub fn payload(&self, bench: Benchmark) -> Option<&Payload> {
            self.payloads.get(&bench)
        }

        pub fn measure(&self, _bench: Benchmark, _warmup: usize, _iters: usize) -> Result<f64> {
            bail!("kube-fgs was built without the `pjrt` feature")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{Payload, Runtime};

/// Default artifacts directory: `$CARGO_MANIFEST_DIR/artifacts` at build
/// time, overridable with `KUBE_FGS_ARTIFACTS`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("KUBE_FGS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses_all_benchmarks() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let specs = load_manifest(&default_artifacts_dir()).unwrap();
        assert_eq!(specs.len(), 5);
        for s in &specs {
            assert!(!s.args.is_empty(), "{}", s.benchmark);
            assert!(s.flops_per_step > 0);
            assert!(s.hlo_path.exists());
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn runtime_loads_and_executes_every_payload() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::load(&default_artifacts_dir()).unwrap();
        assert_eq!(rt.payloads.len(), 5);
        for (bench, payload) in &rt.payloads {
            let secs = payload.step().unwrap_or_else(|e| panic!("{bench}: {e}"));
            assert!(secs > 0.0 && secs < 60.0, "{bench}: {secs}s");
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_with_clear_error() {
        let err = Runtime::load(&default_artifacts_dir()).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err:#}");
    }

    #[test]
    fn arg_spec_elements() {
        let a = ArgSpec { shape: vec![4, 8], dtype: "float32".into() };
        assert_eq!(a.elements(), 32);
        let scalar = ArgSpec { shape: vec![], dtype: "float32".into() };
        assert_eq!(scalar.elements(), 1);
    }
}
