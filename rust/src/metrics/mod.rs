//! Metrics registry + the paper's evaluation metrics (§V-B).
//!
//! [`ExperimentMetrics`] aggregates a [`SimOutput`] into the four paper
//! metrics (job running time, job response time, overall response time,
//! makespan). [`Registry`] is a small Prometheus-style counter/gauge
//! surface — the "system information" endpoint the planner agent senses.

use std::collections::BTreeMap;

use crate::simulator::{JobRecord, SimOutput};
use crate::util::stats::percentile;
use crate::workload::{Benchmark, ServeClass, ALL_BENCHMARKS, ALL_SERVE_CLASSES};

/// Aggregated metrics of one experiment run.
#[derive(Debug, Clone)]
pub struct ExperimentMetrics {
    pub per_job: Vec<JobRecord>,
    pub overall_response: f64,
    pub makespan: f64,
    pub avg_running: BTreeMap<Benchmark, f64>,
    pub avg_wait: f64,
}

impl ExperimentMetrics {
    pub fn from(out: &SimOutput) -> ExperimentMetrics {
        let mut per_job = out.records.clone();
        per_job.sort_by_key(|r| r.id);
        let avg_running = ALL_BENCHMARKS
            .iter()
            .filter(|b| per_job.iter().any(|r| r.benchmark == **b))
            .map(|&b| (b, out.avg_running(b)))
            .collect();
        let avg_wait = if per_job.is_empty() {
            0.0
        } else {
            per_job.iter().map(JobRecord::wait).sum::<f64>() / per_job.len() as f64
        };
        ExperimentMetrics {
            overall_response: out.overall_response(),
            makespan: out.makespan(),
            avg_running,
            avg_wait,
            per_job,
        }
    }

    /// Aggregate a bare record set — a sharded run's merged records,
    /// where there is no single `SimOutput` to read the metrics from.
    /// Same definitions as [`ExperimentMetrics::from`], over the union.
    pub fn from_records(records: &[JobRecord]) -> ExperimentMetrics {
        let mut per_job = records.to_vec();
        per_job.sort_by_key(|r| r.id);
        let avg_running = ALL_BENCHMARKS
            .iter()
            .filter(|b| per_job.iter().any(|r| r.benchmark == **b))
            .map(|&b| {
                let xs: Vec<f64> = per_job
                    .iter()
                    .filter(|r| r.benchmark == b)
                    .map(JobRecord::running)
                    .collect();
                (b, xs.iter().sum::<f64>() / xs.len() as f64)
            })
            .collect();
        let avg_wait = if per_job.is_empty() {
            0.0
        } else {
            per_job.iter().map(JobRecord::wait).sum::<f64>() / per_job.len() as f64
        };
        let overall_response = per_job.iter().map(JobRecord::response).sum();
        let makespan = if per_job.is_empty() {
            0.0
        } else {
            let first =
                per_job.iter().map(|r| r.submit_time).fold(f64::INFINITY, f64::min);
            let last = per_job.iter().map(|r| r.finish_time).fold(0.0, f64::max);
            last - first
        };
        ExperimentMetrics { per_job, overall_response, makespan, avg_running, avg_wait }
    }

    /// Relative improvement of `self` over `baseline` for a metric
    /// extractor (positive = this run is better/smaller).
    pub fn improvement_over(
        &self,
        baseline: &ExperimentMetrics,
        metric: fn(&ExperimentMetrics) -> f64,
    ) -> f64 {
        let b = metric(baseline);
        let s = metric(self);
        if b == 0.0 {
            0.0
        } else {
            (b - s) / b
        }
    }
}

pub fn overall_response(m: &ExperimentMetrics) -> f64 {
    m.overall_response
}

pub fn makespan(m: &ExperimentMetrics) -> f64 {
    m.makespan
}

/// Response-time percentiles of a record set (submit → finish seconds).
/// Empty record sets yield all-zero percentiles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResponsePercentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl ResponsePercentiles {
    pub fn from_records(records: &[JobRecord]) -> ResponsePercentiles {
        let mut responses: Vec<f64> = records.iter().map(JobRecord::response).collect();
        responses.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ResponsePercentiles {
            p50: percentile(&responses, 0.50),
            p95: percentile(&responses, 0.95),
            p99: percentile(&responses, 0.99),
        }
    }
}

/// Latency accounting for one serving class (`ServeClass`): response
/// percentiles plus SLO-violation counts against the class target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassSlo {
    pub class: ServeClass,
    pub slo_secs: f64,
    pub jobs: usize,
    pub violations: usize,
    pub percentiles: ResponsePercentiles,
}

/// Per-class + overall SLO report over a run's job records, keyed by the
/// class↔tenant mapping of the serving mix ([`ServeClass::of_tenant`]).
/// Records of tenants outside the serving mix are counted in the overall
/// percentiles but belong to no class row.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub per_class: Vec<ClassSlo>,
    pub overall: ResponsePercentiles,
    pub jobs: usize,
    pub violations: usize,
}

impl SloReport {
    pub fn from_records(records: &[JobRecord]) -> SloReport {
        let per_class: Vec<ClassSlo> = ALL_SERVE_CLASSES
            .iter()
            .map(|&class| {
                let of_class: Vec<JobRecord> = records
                    .iter()
                    .filter(|r| ServeClass::of_tenant(r.tenant) == Some(class))
                    .cloned()
                    .collect();
                let slo = class.slo_secs();
                ClassSlo {
                    class,
                    slo_secs: slo,
                    jobs: of_class.len(),
                    violations: of_class.iter().filter(|r| r.response() > slo).count(),
                    percentiles: ResponsePercentiles::from_records(&of_class),
                }
            })
            .collect();
        SloReport {
            overall: ResponsePercentiles::from_records(records),
            jobs: records.len(),
            violations: per_class.iter().map(|c| c.violations).sum(),
            per_class,
        }
    }

    /// Fraction of serving-class jobs violating their SLO (0.0 when the
    /// trace has no serving-class jobs at all).
    pub fn violation_fraction(&self) -> f64 {
        let class_jobs: usize = self.per_class.iter().map(|c| c.jobs).sum();
        if class_jobs == 0 {
            0.0
        } else {
            self.violations as f64 / class_jobs as f64
        }
    }
}

/// Minimal Prometheus-style metrics registry (gauge/counter with labels),
/// standing in for the Prometheus deployment the planner agent queries.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    values: BTreeMap<String, f64>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.values.insert(name.to_string(), value);
    }

    pub fn inc_counter(&mut self, name: &str, by: f64) {
        *self.values.entry(name.to_string()).or_insert(0.0) += by;
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.get(name).copied()
    }

    /// Prometheus text exposition format (subset).
    pub fn expose(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.values {
            out.push_str(&format!("{k} {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::JobId;

    fn record(id: u64, bench: Benchmark, submit: f64, start: f64, finish: f64) -> JobRecord {
        JobRecord {
            id: JobId(id),
            benchmark: bench,
            tenant: crate::workload::DEFAULT_TENANT,
            priority: 0,
            submit_time: submit,
            start_time: start,
            finish_time: finish,
            running_secs: finish - start,
        }
    }

    fn fake_output() -> SimOutput {
        use crate::apiserver::ApiServer;
        use crate::cluster::ClusterSpec;
        use crate::kubelet::KubeletConfig;
        SimOutput {
            records: vec![
                record(1, Benchmark::EpDgemm, 0.0, 0.0, 100.0),
                record(2, Benchmark::EpDgemm, 10.0, 20.0, 150.0),
                record(3, Benchmark::GFft, 20.0, 20.0, 120.0),
            ],
            unschedulable: vec![],
            api: ApiServer::new(ClusterSpec::paper(), KubeletConfig::default_policy()),
            sched_stats: Default::default(),
            core_stats: Default::default(),
        }
    }

    #[test]
    fn metrics_match_paper_definitions() {
        let m = ExperimentMetrics::from(&fake_output());
        // T = sum of responses: 100 + 140 + 100.
        assert!((m.overall_response - 340.0).abs() < 1e-9);
        // Makespan: last finish (150) - first submit (0).
        assert!((m.makespan - 150.0).abs() < 1e-9);
        // avg running of DGEMM: (100 + 130) / 2.
        assert!((m.avg_running[&Benchmark::EpDgemm] - 115.0).abs() < 1e-9);
        // avg wait: (0 + 10 + 0)/3.
        assert!((m.avg_wait - 10.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn improvement_is_relative() {
        let base = ExperimentMetrics::from(&fake_output());
        let mut better = base.clone();
        better.overall_response = base.overall_response * 0.65;
        let imp = better.improvement_over(&base, overall_response);
        assert!((imp - 0.35).abs() < 1e-9);
    }

    #[test]
    fn response_percentiles_interpolate_and_handle_empty() {
        let records: Vec<JobRecord> =
            (0..=100).map(|i| record(i, Benchmark::GFft, 0.0, 0.0, i as f64)).collect();
        let p = ResponsePercentiles::from_records(&records);
        assert!((p.p50 - 50.0).abs() < 1e-9);
        assert!((p.p95 - 95.0).abs() < 1e-9);
        assert!((p.p99 - 99.0).abs() < 1e-9);
        let empty = ResponsePercentiles::from_records(&[]);
        assert_eq!(empty, ResponsePercentiles { p50: 0.0, p95: 0.0, p99: 0.0 });
    }

    #[test]
    fn slo_report_counts_violations_per_class() {
        use crate::workload::{ServeClass, TenantId};
        let mk = |id, tenant: TenantId, finish: f64| {
            let mut r = record(id, Benchmark::MiniFe, 0.0, 0.0, finish);
            r.tenant = tenant;
            r
        };
        let gang = ServeClass::HpcGang.tenant();
        let micro = ServeClass::Microservice.tenant();
        let records = vec![
            mk(1, gang, 1000.0),  // within the 3600 s gang SLO
            mk(2, gang, 4000.0),  // violation
            mk(3, micro, 100.0),  // within the 900 s microservice SLO
            mk(4, micro, 1000.0), // violation
            mk(5, micro, 200.0),
        ];
        let rep = SloReport::from_records(&records);
        assert_eq!(rep.jobs, 5);
        assert_eq!(rep.violations, 2);
        assert!((rep.violation_fraction() - 0.4).abs() < 1e-12);
        let of = |class: ServeClass| {
            rep.per_class.iter().find(|c| c.class == class).copied().unwrap()
        };
        assert_eq!(of(ServeClass::HpcGang).jobs, 2);
        assert_eq!(of(ServeClass::HpcGang).violations, 1);
        assert_eq!(of(ServeClass::Microservice).violations, 1);
        // Absent class: zero jobs, zero percentiles, no panic.
        let ai = of(ServeClass::AiInference);
        assert_eq!(ai.jobs, 0);
        assert_eq!(ai.percentiles.p99, 0.0);
        // No serving-class jobs at all ⇒ fraction 0.
        assert_eq!(
            SloReport::from_records(&[mk(9, TenantId(7), 1e6)]).violation_fraction(),
            0.0
        );
    }

    #[test]
    fn registry_gauges_and_counters() {
        let mut r = Registry::new();
        r.set_gauge("kube_node_available", 4.0);
        r.inc_counter("jobs_submitted_total", 1.0);
        r.inc_counter("jobs_submitted_total", 1.0);
        assert_eq!(r.get("kube_node_available"), Some(4.0));
        assert_eq!(r.get("jobs_submitted_total"), Some(2.0));
        assert!(r.expose().contains("jobs_submitted_total 2"));
        assert_eq!(r.get("missing"), None);
    }
}
