//! Self-built substrate utilities (the offline registry carries only the
//! `xla` closure, so RNG, JSON, stats/bench live here — see DESIGN.md).

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
pub use stats::{jain_index, BenchTimer, Summary};
