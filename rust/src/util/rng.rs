//! Deterministic, seedable PRNG (splitmix64 seeding + xoshiro256**).
//!
//! The offline vendored registry has no `rand` crate, so the simulator's
//! randomness substrate is built here. Every experiment takes an explicit
//! seed; results are bit-reproducible across runs (a requirement for the
//! paper's seeded exp-2/exp-3 traces and the NONE-scenario variance model).

/// xoshiro256** by Blackman & Vigna — public domain reference algorithm.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named sub-component. Used to give
    /// each job / node / plugin its own stream so adding one consumer does
    /// not perturb the others (important for calibration stability).
    pub fn derive(&self, tag: u64) -> Rng {
        Rng::seed_from_u64(self.s[0] ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative noise with median 1.0 and the given sigma
    /// (the NONE-scenario run-to-run variance model).
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from_u64(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..100 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::seed_from_u64(11);
        for n in [1usize, 2, 5, 16, 100] {
            let mut p = r.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_noise_median_near_one() {
        let mut r = Rng::seed_from_u64(17);
        let mut xs: Vec<f64> = (0..10_001).map(|_| r.lognormal_noise(0.2)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[5_000];
        assert!((0.95..1.05).contains(&median), "median={median}");
    }

    #[test]
    fn derive_streams_are_independent() {
        let root = Rng::seed_from_u64(5);
        let mut a = root.derive(1);
        let mut b = root.derive(2);
        assert_ne!(a.next_u64(), b.next_u64());
        // Deriving again with the same tag reproduces the stream.
        let mut a2 = root.derive(1);
        let mut a3 = Rng::seed_from_u64(5).derive(1);
        assert_eq!(a2.next_u64(), a3.next_u64());
    }
}
