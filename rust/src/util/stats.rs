//! Small statistics helpers shared by the metrics module and the bench
//! harness (the vendored registry has no `criterion`; rust/benches uses
//! [`BenchTimer`] instead — same warmup/measure/report discipline).

use std::time::Instant;

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::from(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Jain's fairness index over a set of per-entity allocations:
/// `(Σx)² / (n · Σx²)`, in (0, 1]; 1.0 means perfectly even. Empty or
/// all-zero inputs count as perfectly fair (no one is disadvantaged).
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum_sq == 0.0 {
        1.0
    } else {
        sum * sum / (xs.len() as f64 * sum_sq)
    }
}

/// Linear-interpolated percentile over a pre-sorted slice, q in [0, 1].
/// An empty slice yields 0.0 (a zero-sample tail has no latency), so SLO
/// pipelines over filtered job classes never panic on an absent class.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Minimal bench harness: warmup, timed iterations, Summary of per-iter
/// seconds. Used by every target in rust/benches (harness = false).
pub struct BenchTimer {
    pub name: String,
    pub warmup_iters: usize,
    pub iters: usize,
}

impl BenchTimer {
    pub fn new(name: &str) -> Self {
        BenchTimer { name: name.to_string(), warmup_iters: 2, iters: 10 }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup_iters = warmup;
        self.iters = iters;
        self
    }

    /// Run `f` warmup+measured times; returns per-iteration seconds summary
    /// and prints one criterion-style line.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::from(&samples);
        println!(
            "bench {:<40} mean {:>12} p50 {:>12} p95 {:>12} p99 {:>12} (n={})",
            self.name,
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            fmt_secs(s.p99),
            s.n
        );
        s
    }
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 1.0), 30.0);
        assert!((percentile(&xs, 0.5) - 20.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.25) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[5.0], 0.95), 5.0);
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[], 0.99), 0.0);
    }

    #[test]
    fn tail_percentiles_closed_form_uniform() {
        // Uniform grid 0..=100: percentile(q) = 100q exactly under linear
        // interpolation (pos = q * 100 lands between integer samples).
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let s = Summary::from(&xs);
        assert!((s.p50 - 50.0).abs() < 1e-9);
        assert!((s.p95 - 95.0).abs() < 1e-9);
        assert!((s.p99 - 99.0).abs() < 1e-9);
        assert!((percentile(&xs, 0.975) - 97.5).abs() < 1e-9);
    }

    #[test]
    fn tail_percentiles_closed_form_two_point() {
        // Two-point distribution {0, 10}: pos = q, so percentile(q) = 10q.
        let xs = [0.0, 10.0];
        let s = Summary::from(&xs);
        assert!((s.p50 - 5.0).abs() < 1e-12);
        assert!((s.p95 - 9.5).abs() < 1e-12);
        assert!((s.p99 - 9.9).abs() < 1e-12);
    }

    #[test]
    fn jain_index_bounds_and_known_values() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // One of three gets everything: index = 1/3.
        assert!((jain_index(&[9.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
        // More even is fairer.
        assert!(jain_index(&[2.0, 3.0]) > jain_index(&[1.0, 4.0]));
    }

    #[test]
    fn bench_timer_runs() {
        let mut count = 0;
        let s = BenchTimer::new("noop").with_iters(1, 3).run(|| count += 1);
        assert_eq!(count, 4);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with(" s"));
    }
}
