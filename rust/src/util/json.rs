//! Minimal JSON parser for `artifacts/manifest.json` and experiment configs.
//!
//! The offline vendored registry has no `serde`/`serde_json`, so this small
//! recursive-descent parser is part of the substrate we build ourselves.
//! It supports the full JSON grammar the AOT manifest uses (objects, arrays,
//! strings with escapes, numbers, booleans, null) and precise error offsets.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after top-level value"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let start = self.pos - 1;
                    let len = utf8_len(b).ok_or_else(|| self.err("invalid utf-8"))?;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0x00..=0x7F => Some(1),
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ✓");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"\\x\"", "{a:1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "dgemm": {
            "hlo": "dgemm.hlo.txt",
            "args": [{"shape": [256, 256], "dtype": "float32"}],
            "profile": "cpu",
            "flops_per_step": 33554432,
            "bytes_per_step": 786432,
            "sha256": "abc"
          }
        }"#;
        let v = Json::parse(text).unwrap();
        let d = v.get("dgemm");
        assert_eq!(d.get("profile").as_str(), Some("cpu"));
        assert_eq!(d.get("flops_per_step").as_u64(), Some(33554432));
        let args = d.get("args").as_arr().unwrap();
        assert_eq!(args[0].get("shape").as_arr().unwrap().len(), 2);
    }
}
