//! Kubelet substrate: per-node pod admission under the paper's two node
//! settings (default vs CPU/memory affinity).

pub mod cpu_manager;
pub mod topology_manager;

pub use cpu_manager::{CpuAssignment, CpuManagerPolicy, CpuManagerState};
pub use topology_manager::{numa_hint, NumaHint, TopologyPolicy};

use crate::cluster::{NodeSpec, Pod};

/// Node-level Kubelet configuration (paper Table II "Kubelet" column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KubeletConfig {
    pub cpu_policy: CpuManagerPolicy,
    pub topology_policy: TopologyPolicy,
}

impl KubeletConfig {
    /// `default`: shared resources under limits.
    pub fn default_policy() -> Self {
        KubeletConfig {
            cpu_policy: CpuManagerPolicy::None,
            topology_policy: TopologyPolicy::None,
        }
    }

    /// `cpu/memory affinity`: `--cpu-manager-policy=static`
    /// `--topology-manager-policy=best-effort`.
    pub fn cpu_mem_affinity() -> Self {
        KubeletConfig {
            cpu_policy: CpuManagerPolicy::Static,
            topology_policy: TopologyPolicy::BestEffort,
        }
    }
}

/// One node's Kubelet: admits pods bound to this node and maintains the
/// exclusive-CPU bookkeeping.
#[derive(Debug, Clone)]
pub struct Kubelet {
    pub spec: NodeSpec,
    pub cpus: CpuManagerState,
}

impl Kubelet {
    pub fn new(spec: NodeSpec, config: KubeletConfig) -> Kubelet {
        let cpus = CpuManagerState::new(&spec, config.cpu_policy, config.topology_policy);
        Kubelet { spec, cpus }
    }

    /// Start a pod on this node: grant its cpuset per policy and record the
    /// NUMA-spanning flag the performance model reads. Returns false if the
    /// exclusive allocation is impossible (scheduler/kubelet race — callers
    /// treat it as an admission failure).
    pub fn admit(&mut self, pod: &mut Pod) -> bool {
        // Only integer-CPU ("guaranteed" QoS) containers get exclusive
        // cpusets; everything else floats on the shared pool.
        let cores = if pod.requests.is_integer_cpu() {
            pod.requests.whole_cores()
        } else {
            0
        };
        match self.cpus.allocate(cores) {
            Some(assignment) => {
                pod.spans_numa = assignment.spans_numa();
                pod.cpuset = assignment.cpuset().cloned();
                true
            }
            None => false,
        }
    }

    /// Terminate a pod: release its exclusive CPUs back to the pool. The
    /// pod keeps its (now historical) cpuset for post-mortem reporting;
    /// the API server's phase machine guarantees single termination.
    pub fn terminate(&mut self, pod: &Pod) {
        if let Some(cpuset) = &pod.cpuset {
            self.cpus.release(&self.spec, cpuset);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{gib, JobId, Pod, PodId, PodRole, Resources};

    fn worker_pod(id: u64, cores: u64) -> Pod {
        let mut p = Pod::new(
            PodId(id),
            JobId(1),
            format!("w{id}"),
            PodRole::Worker { index: id as u32 },
        );
        p.requests = Resources::new(cores * 1000, gib(2) * cores);
        p.limits = p.requests;
        p
    }

    #[test]
    fn affinity_kubelet_grants_exclusive_cpuset() {
        let mut k = Kubelet::new(NodeSpec::paper_worker("w"), KubeletConfig::cpu_mem_affinity());
        let mut p = worker_pod(1, 16);
        assert!(k.admit(&mut p));
        assert_eq!(p.cpuset.as_ref().unwrap().len(), 16);
        assert!(!p.spans_numa);
    }

    #[test]
    fn default_kubelet_shares_pool() {
        let mut k = Kubelet::new(NodeSpec::paper_worker("w"), KubeletConfig::default_policy());
        let mut p = worker_pod(1, 16);
        assert!(k.admit(&mut p));
        assert!(p.cpuset.is_none());
        assert!(p.spans_numa, "shared pool spans the node");
    }

    #[test]
    fn admission_fails_when_full_then_recovers() {
        let mut k = Kubelet::new(NodeSpec::paper_worker("w"), KubeletConfig::cpu_mem_affinity());
        let mut a = worker_pod(1, 32);
        let mut b = worker_pod(2, 1);
        assert!(k.admit(&mut a));
        assert!(!k.admit(&mut b));
        k.terminate(&a);
        assert!(a.cpuset.is_some(), "historical cpuset kept for reporting");
        assert!(k.admit(&mut b));
    }

    #[test]
    fn two_16core_pods_get_disjoint_sockets() {
        let mut k = Kubelet::new(NodeSpec::paper_worker("w"), KubeletConfig::cpu_mem_affinity());
        let mut a = worker_pod(1, 16);
        let mut b = worker_pod(2, 16);
        assert!(k.admit(&mut a) && k.admit(&mut b));
        assert!(!a.spans_numa && !b.spans_numa);
        assert!(a.cpuset.as_ref().unwrap().is_disjoint(b.cpuset.as_ref().unwrap()));
    }

    #[test]
    fn fractional_cpu_pod_is_shared_even_under_static() {
        let mut k = Kubelet::new(NodeSpec::paper_worker("w"), KubeletConfig::cpu_mem_affinity());
        let mut p = worker_pod(1, 16);
        p.requests.cpu_milli = 500; // launcher-style burstable pod
        assert!(k.admit(&mut p));
        assert!(p.cpuset.is_none());
    }
}
