//! Kubelet CPU manager — `--cpu-manager-policy={none,static}`.
//!
//! Reimplements the upstream allocation behaviour the paper relies on
//! (§III, §IV-C): under `static`, a guaranteed pod requesting an integer
//! number of CPUs receives an *exclusive* cpuset carved out of the node's
//! shared pool; under `none`, all pods float over the shared pool (the
//! container may migrate across all allocatable CPUs — the perf model
//! charges this).

use crate::cluster::{CpuSet, NodeSpec};

use super::topology_manager::TopologyPolicy;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuManagerPolicy {
    None,
    Static,
}

/// Result of admitting a container on a node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CpuAssignment {
    /// `cpu-manager-policy=none`: container floats on the shared pool.
    SharedPool,
    /// `static`: exclusive cpuset; `spans_numa` records whether the
    /// topology manager had to cross a NUMA boundary.
    Exclusive { cpuset: CpuSet, spans_numa: bool },
}

impl CpuAssignment {
    pub fn spans_numa(&self) -> bool {
        match self {
            CpuAssignment::SharedPool => true, // shared pool spans the node
            CpuAssignment::Exclusive { spans_numa, .. } => *spans_numa,
        }
    }

    pub fn cpuset(&self) -> Option<&CpuSet> {
        match self {
            CpuAssignment::SharedPool => None,
            CpuAssignment::Exclusive { cpuset, .. } => Some(cpuset),
        }
    }
}

/// Per-node CPU-manager state: the free CPUs of each socket.
#[derive(Debug, Clone)]
pub struct CpuManagerState {
    pub policy: CpuManagerPolicy,
    pub topology: TopologyPolicy,
    /// Free allocatable CPUs, per socket.
    free: Vec<CpuSet>,
}

impl CpuManagerState {
    pub fn new(spec: &NodeSpec, policy: CpuManagerPolicy, topology: TopologyPolicy) -> Self {
        let free = (0..spec.sockets)
            .map(|s| spec.allocatable_cpus_of_socket(s))
            .collect();
        CpuManagerState { policy, topology, free }
    }

    pub fn free_total(&self) -> usize {
        self.free.iter().map(CpuSet::len).sum()
    }

    pub fn free_of_socket(&self, socket: usize) -> usize {
        self.free[socket].len()
    }

    /// Admit a container requesting `cores` exclusive CPUs.
    ///
    /// Under the `none` policy every container lands on the shared pool.
    /// Under `static` + `best-effort` topology, the allocation prefers a
    /// single NUMA domain (bin-packing: the *fullest* socket that still
    /// fits, to preserve large holes for later pods — upstream
    /// `takeByTopology` behaviour); if no socket fits, it spills across
    /// domains, taking from the socket with the most free CPUs first.
    /// Under `static` + topology `none`, CPUs are taken lowest-id-first
    /// with no NUMA awareness.
    pub fn allocate(&mut self, cores: u32) -> Option<CpuAssignment> {
        if self.policy == CpuManagerPolicy::None {
            return Some(CpuAssignment::SharedPool);
        }
        let want = cores as usize;
        if want == 0 {
            return Some(CpuAssignment::SharedPool); // non-guaranteed QoS
        }
        if self.free_total() < want {
            return None;
        }
        match self.topology {
            TopologyPolicy::BestEffort => {
                // Single-domain fit: fullest (least-free) socket that fits.
                let candidate = (0..self.free.len())
                    .filter(|&s| self.free[s].len() >= want)
                    .min_by_key(|&s| self.free[s].len());
                if let Some(s) = candidate {
                    let cpuset = self.free[s].take(want);
                    return Some(CpuAssignment::Exclusive { cpuset, spans_numa: false });
                }
                // Spill: biggest sockets first (fewest crossings).
                let mut remaining = want;
                let mut cpuset = CpuSet::empty();
                let mut order: Vec<usize> = (0..self.free.len()).collect();
                order.sort_by_key(|&s| std::cmp::Reverse(self.free[s].len()));
                for s in order {
                    if remaining == 0 {
                        break;
                    }
                    let take = remaining.min(self.free[s].len());
                    cpuset = cpuset.union(&self.free[s].take(take));
                    remaining -= take;
                }
                debug_assert_eq!(remaining, 0);
                Some(CpuAssignment::Exclusive { cpuset, spans_numa: true })
            }
            TopologyPolicy::None => {
                // Lowest-id-first across the whole node.
                let mut remaining = want;
                let mut cpuset = CpuSet::empty();
                let mut sockets_touched = Vec::new();
                for s in 0..self.free.len() {
                    if remaining == 0 {
                        break;
                    }
                    let take = remaining.min(self.free[s].len());
                    if take > 0 {
                        cpuset = cpuset.union(&self.free[s].take(take));
                        sockets_touched.push(s);
                        remaining -= take;
                    }
                }
                debug_assert_eq!(remaining, 0);
                Some(CpuAssignment::Exclusive {
                    cpuset,
                    spans_numa: sockets_touched.len() > 1,
                })
            }
        }
    }

    /// Return an exclusive cpuset to the free pools.
    pub fn release(&mut self, spec: &NodeSpec, cpuset: &CpuSet) {
        for cpu in cpuset.iter() {
            let s = spec.socket_of(cpu) as usize;
            let inserted = self.free[s].insert(cpu);
            debug_assert!(inserted, "double release of cpu {cpu}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeSpec;

    fn state(policy: CpuManagerPolicy, topo: TopologyPolicy) -> (NodeSpec, CpuManagerState) {
        let spec = NodeSpec::paper_worker("w0");
        let st = CpuManagerState::new(&spec, policy, topo);
        (spec, st)
    }

    #[test]
    fn none_policy_always_shared() {
        let (_, mut st) = state(CpuManagerPolicy::None, TopologyPolicy::None);
        assert_eq!(st.allocate(16), Some(CpuAssignment::SharedPool));
        assert_eq!(st.free_total(), 32, "shared pool is not carved up");
    }

    #[test]
    fn static_best_effort_prefers_single_socket() {
        let (_, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        let a = st.allocate(16).unwrap();
        assert!(!a.spans_numa(), "16 cores fit in one socket");
        assert_eq!(a.cpuset().unwrap().len(), 16);
        // Second 16-core pod gets the other socket, still single-NUMA.
        let b = st.allocate(16).unwrap();
        assert!(!b.spans_numa());
        assert!(a.cpuset().unwrap().is_disjoint(b.cpuset().unwrap()));
        assert_eq!(st.free_total(), 0);
    }

    #[test]
    fn static_best_effort_binpacks_small_pods() {
        let (_, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        let a = st.allocate(4).unwrap(); // socket 0 (both equal, min index wins)
        let s0_after = st.free_of_socket(0);
        let s1_after = st.free_of_socket(1);
        assert_eq!(s0_after + s1_after, 28);
        // Next 12-core pod should pack into the *fuller* socket (the one
        // with 12 free) if it fits, preserving the 16-free socket.
        let b = st.allocate(12).unwrap();
        assert!(!b.spans_numa());
        assert!(a.cpuset().unwrap().is_disjoint(b.cpuset().unwrap()));
        assert_eq!(st.free_of_socket(0).min(st.free_of_socket(1)), 0);
        assert_eq!(st.free_of_socket(0).max(st.free_of_socket(1)), 16);
    }

    #[test]
    fn static_best_effort_spills_when_no_socket_fits() {
        let (_, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        st.allocate(8).unwrap(); // socket now 8 free / 16 free
        let big = st.allocate(20).unwrap(); // no single socket has 20
        assert!(big.spans_numa());
        assert_eq!(big.cpuset().unwrap().len(), 20);
        assert_eq!(st.free_total(), 4);
    }

    #[test]
    fn static_topology_none_ignores_sockets() {
        let (_, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::None);
        st.allocate(10).unwrap(); // takes socket-0 cpus 2..12
        let a = st.allocate(10).unwrap(); // 6 from socket 0 + 4 from socket 1
        assert!(a.spans_numa());
    }

    #[test]
    fn thin_single_socket_class_never_spans_numa() {
        // 1-socket thin nodes: every exclusive allocation is single-NUMA
        // by construction, and capacity is the class's 16 cores.
        let spec = crate::cluster::NodeClass::thin(1).node_spec("t");
        let mut st = CpuManagerState::new(&spec, CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        assert_eq!(st.free_total(), 16);
        let a = st.allocate(10).unwrap();
        assert!(!a.spans_numa());
        let b = st.allocate(6).unwrap();
        assert!(!b.spans_numa());
        assert!(st.allocate(1).is_none(), "class capacity enforced");
    }

    #[test]
    fn fat_four_socket_class_prefers_single_socket_and_spills() {
        // 4-socket fat nodes: 16 allocatable per socket; a 16-core pod
        // packs one socket, a 20-core pod must span.
        let spec = crate::cluster::NodeClass::fat(1).node_spec("f");
        let mut st = CpuManagerState::new(&spec, CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        assert_eq!(st.free_total(), 64);
        let a = st.allocate(16).unwrap();
        assert!(!a.spans_numa());
        let big = st.allocate(20).unwrap();
        assert!(big.spans_numa());
        assert_eq!(big.cpuset().unwrap().len(), 20);
        // Remaining capacity still admits single-socket pods.
        let c = st.allocate(12).unwrap();
        assert!(!c.spans_numa());
        assert_eq!(st.free_total(), 64 - 16 - 20 - 12);
    }

    #[test]
    fn allocate_fails_when_exhausted() {
        let (_, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        assert!(st.allocate(32).is_some());
        assert!(st.allocate(1).is_none());
    }

    #[test]
    fn release_restores_capacity() {
        let (spec, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        let a = st.allocate(16).unwrap();
        let cpuset = a.cpuset().unwrap().clone();
        assert_eq!(st.free_total(), 16);
        st.release(&spec, &cpuset);
        assert_eq!(st.free_total(), 32);
        // And the freed cores are reusable as a single-NUMA block again.
        let b = st.allocate(16).unwrap();
        assert!(!b.spans_numa());
    }

    #[test]
    fn zero_core_request_is_shared() {
        let (_, mut st) = state(CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        assert_eq!(st.allocate(0), Some(CpuAssignment::SharedPool));
    }
}
