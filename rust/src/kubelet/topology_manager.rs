//! Kubelet topology manager — `--topology-manager-policy={none,best-effort}`.
//!
//! The paper's two Kubelet settings (§III): default (`none`, shared
//! resources) vs CPU/memory affinity (`static` CPU manager + `best-effort`
//! topology manager, i.e. exclusive CPUs preferring a single NUMA node).
//! The admission logic itself lives in [`super::cpu_manager`]; this module
//! holds the policy type and the NUMA-hint helper used by tests and the
//! perf model.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyPolicy {
    /// No NUMA alignment between CPU allocations.
    None,
    /// Prefer a single NUMA node; admit anyway if impossible (the
    /// `best-effort` upstream policy — never rejects).
    BestEffort,
}

/// A NUMA affinity hint: which single domain could satisfy `cores`, if any.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumaHint {
    /// A single domain fits the request.
    Preferred { socket: u32 },
    /// The request must span domains.
    CrossNuma,
}

/// Compute the hint the topology manager would merge for a CPU request,
/// given per-socket free counts.
pub fn numa_hint(free_per_socket: &[usize], cores: u32) -> NumaHint {
    let want = cores as usize;
    free_per_socket
        .iter()
        .enumerate()
        .filter(|(_, &f)| f >= want)
        .min_by_key(|(_, &f)| f)
        .map(|(s, _)| NumaHint::Preferred { socket: s as u32 })
        .unwrap_or(NumaHint::CrossNuma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hint_prefers_tightest_fit() {
        assert_eq!(numa_hint(&[16, 8], 8), NumaHint::Preferred { socket: 1 });
        assert_eq!(numa_hint(&[16, 8], 12), NumaHint::Preferred { socket: 0 });
    }

    #[test]
    fn hint_cross_numa_when_fragmented() {
        assert_eq!(numa_hint(&[10, 10], 16), NumaHint::CrossNuma);
    }

    #[test]
    fn hint_exact_fit() {
        assert_eq!(numa_hint(&[16, 16], 16), NumaHint::Preferred { socket: 0 });
    }
}
