//! Scenario matrix — the paper's Table II plus the §V-E framework
//! baselines, each mapping to a fully configured [`Simulation`].

use crate::cluster::ClusterSpec;
use crate::controller::{
    JobController, KubeflowController, NativeVolcanoController, VolcanoMpiController,
};
use crate::kubelet::KubeletConfig;
use crate::perfmodel::Calibration;
use crate::planner::GranularityPolicy;
use crate::scheduler::SchedulerConfig;
use crate::simulator::Simulation;

/// All evaluated scenarios: six from Table II + two framework baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// Kubelet default, stock Volcano gang.
    None_,
    /// CPU/memory affinity, stock Volcano gang.
    Cm,
    /// Affinity + planner 'scale'.
    CmS,
    /// Affinity + planner 'granularity'.
    CmG,
    /// Affinity + 'scale' + task-group scheduling.
    CmSTg,
    /// Affinity + 'granularity' + task-group scheduling.
    CmGTg,
    /// Kubeflow MPI operator on the default scheduler (affinity kubelet).
    Kubeflow,
    /// Stock Volcano MPI example: one task per container (affinity kubelet).
    VolcanoNative,
}

/// The six Table-II scenarios, in the paper's column order.
pub const TABLE2_SCENARIOS: [Scenario; 6] = [
    Scenario::None_,
    Scenario::Cm,
    Scenario::CmS,
    Scenario::CmG,
    Scenario::CmSTg,
    Scenario::CmGTg,
];

/// The §V-E framework-comparison scenarios (Table III / Figs. 8–9 order).
pub const EXP3_SCENARIOS: [Scenario; 5] = [
    Scenario::Kubeflow,
    Scenario::VolcanoNative,
    Scenario::Cm,
    Scenario::CmSTg,
    Scenario::CmGTg,
];

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::None_ => "NONE",
            Scenario::Cm => "CM",
            Scenario::CmS => "CM_S",
            Scenario::CmG => "CM_G",
            Scenario::CmSTg => "CM_S_TG",
            Scenario::CmGTg => "CM_G_TG",
            Scenario::Kubeflow => "Kubeflow",
            Scenario::VolcanoNative => "Volcano",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        let all = [
            Scenario::None_,
            Scenario::Cm,
            Scenario::CmS,
            Scenario::CmG,
            Scenario::CmSTg,
            Scenario::CmGTg,
            Scenario::Kubeflow,
            Scenario::VolcanoNative,
        ];
        all.iter().copied().find(|sc| sc.name().eq_ignore_ascii_case(s))
    }

    pub fn kubelet(&self) -> KubeletConfig {
        match self {
            Scenario::None_ => KubeletConfig::default_policy(),
            _ => KubeletConfig::cpu_mem_affinity(),
        }
    }

    pub fn policy(&self) -> GranularityPolicy {
        match self {
            Scenario::CmS | Scenario::CmSTg => GranularityPolicy::Scale,
            Scenario::CmG | Scenario::CmGTg => GranularityPolicy::Granularity,
            _ => GranularityPolicy::None,
        }
    }

    pub fn controller(&self) -> Box<dyn JobController> {
        match self {
            Scenario::Kubeflow => Box::new(KubeflowController),
            Scenario::VolcanoNative => Box::new(NativeVolcanoController),
            _ => Box::new(VolcanoMpiController),
        }
    }

    pub fn scheduler(&self, seed: u64) -> SchedulerConfig {
        match self {
            Scenario::CmSTg | Scenario::CmGTg => SchedulerConfig::fine_grained(seed),
            Scenario::Kubeflow => SchedulerConfig::kube_default(seed),
            _ => SchedulerConfig::volcano_default(seed),
        }
    }

    /// Build a fully configured simulation for this scenario.
    pub fn simulation(&self, seed: u64) -> Simulation {
        self.simulation_on(ClusterSpec::paper(), seed)
    }

    pub fn simulation_on(&self, cluster: ClusterSpec, seed: u64) -> Simulation {
        Simulation::new(
            cluster,
            self.kubelet(),
            self.policy(),
            self.controller(),
            self.scheduler(seed),
            Calibration::default(),
            seed,
        )
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kubelet::CpuManagerPolicy;

    #[test]
    fn table2_matrix_matches_paper() {
        // NONE is the only default-kubelet scenario.
        assert_eq!(Scenario::None_.kubelet().cpu_policy, CpuManagerPolicy::None);
        for s in &TABLE2_SCENARIOS[1..] {
            assert_eq!(s.kubelet().cpu_policy, CpuManagerPolicy::Static, "{s}");
        }
        // TG only in the _TG scenarios.
        assert!(Scenario::CmSTg.scheduler(0).taskgroup);
        assert!(Scenario::CmGTg.scheduler(0).taskgroup);
        assert!(!Scenario::CmS.scheduler(0).taskgroup);
        // Gang everywhere except Kubeflow.
        assert!(!Scenario::Kubeflow.scheduler(0).gang);
        assert!(Scenario::VolcanoNative.scheduler(0).gang);
    }

    #[test]
    fn names_round_trip() {
        for s in TABLE2_SCENARIOS.iter().chain(EXP3_SCENARIOS.iter()) {
            assert_eq!(Scenario::parse(s.name()), Some(*s));
        }
        assert_eq!(Scenario::parse("cm_g_tg"), Some(Scenario::CmGTg));
        assert_eq!(Scenario::parse("bogus"), None);
    }

    #[test]
    fn controllers_match_frameworks() {
        assert_eq!(Scenario::Kubeflow.controller().name(), "kubeflow-mpi-operator");
        assert_eq!(Scenario::VolcanoNative.controller().name(), "volcano-native");
        assert_eq!(Scenario::CmGTg.controller().name(), "volcano+mpi-aware");
    }
}
