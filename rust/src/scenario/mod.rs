//! Scenario matrix — the paper's Table II plus the §V-E framework
//! baselines and the queue-policy variants, each mapping to a fully
//! configured [`Simulation`].
//!
//! A scenario is the experiment space's coordinate system: one name pins
//! all six knobs of the multi-layer design — (kubelet, planner,
//! controller, scheduler, queue, preemption) — so every CLI surface,
//! example, and bench reproduces identical numbers for a given seed. The
//! cluster *shape* (size, heterogeneity mix) is deliberately orthogonal:
//! any scenario runs on any [`ClusterSpec`] via
//! [`Scenario::simulation_on`], which is what the scaling sweeps iterate
//! over.

use crate::cluster::ClusterSpec;
use crate::controller::{
    JobController, KubeflowController, NativeVolcanoController, VolcanoMpiController,
};
use crate::kubelet::KubeletConfig;
use crate::perfmodel::Calibration;
use crate::planner::GranularityPolicy;
use crate::scheduler::{ElasticityMode, PipelineConfig, QueuePolicyKind, SchedulerConfig};
use crate::simulator::Simulation;

/// All evaluated scenarios: six from Table II + two framework baselines
/// + four queue-policy variants (the `*_SJF` / `*_BF` axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// Kubelet default, stock Volcano gang.
    None_,
    /// CPU/memory affinity, stock Volcano gang.
    Cm,
    /// Affinity + planner 'scale'.
    CmS,
    /// Affinity + planner 'granularity'.
    CmG,
    /// Affinity + 'scale' + task-group scheduling.
    CmSTg,
    /// Affinity + 'granularity' + task-group scheduling.
    CmGTg,
    /// Kubeflow MPI operator on the default scheduler (affinity kubelet).
    Kubeflow,
    /// Stock Volcano MPI example: one task per container (affinity kubelet).
    VolcanoNative,
    /// CM with a shortest-job-first queue.
    CmSjf,
    /// CM with EASY backfilling.
    CmBf,
    /// The paper's fine-grained scheduler with a shortest-job-first queue.
    CmGTgSjf,
    /// The paper's fine-grained scheduler with EASY backfilling.
    CmGTgBf,
    /// CM with multi-tenant fair-share queues.
    CmFs,
    /// CM with conservative backfilling.
    CmCbf,
    /// The paper's fine-grained scheduler with fair-share queues.
    CmGTgFs,
    /// The paper's fine-grained scheduler with conservative backfilling.
    CmGTgCbf,
    /// The paper's fine-grained scheduler with fair-share queues AND
    /// priority preemption (the full multi-tenant configuration).
    CmGTgPre,
    /// Elasticity baseline: fine-grained scheduler + preemption, but no
    /// elasticity plugin — elastic jobs are treated rigidly (their full
    /// preferred-width gang must fit or they wait).
    ElRigid,
    /// Moldable admission: the `resize` action may narrow a gang-blocked
    /// elastic job down to its minimum width at start; no runtime resizes.
    ElMold,
    /// Fully malleable: moldable admission plus shrink-before-preempt and
    /// expand-into-drain at runtime.
    ElMall,
}

/// Every scenario code, in declaration order — the full matrix axis the
/// differential golden-trace harness iterates (× placement engines ×
/// cluster mixes).
pub const ALL_SCENARIOS: [Scenario; 20] = [
    Scenario::None_,
    Scenario::Cm,
    Scenario::CmS,
    Scenario::CmG,
    Scenario::CmSTg,
    Scenario::CmGTg,
    Scenario::Kubeflow,
    Scenario::VolcanoNative,
    Scenario::CmSjf,
    Scenario::CmBf,
    Scenario::CmGTgSjf,
    Scenario::CmGTgBf,
    Scenario::CmFs,
    Scenario::CmCbf,
    Scenario::CmGTgFs,
    Scenario::CmGTgCbf,
    Scenario::CmGTgPre,
    Scenario::ElRigid,
    Scenario::ElMold,
    Scenario::ElMall,
];

/// The elasticity ablation's axis, in dominance order (rigid is the
/// baseline the malleable configuration must strictly beat on the
/// elastic trace).
pub const ELASTIC_SCENARIOS: [Scenario; 3] =
    [Scenario::ElRigid, Scenario::ElMold, Scenario::ElMall];

/// The six Table-II scenarios, in the paper's column order.
pub const TABLE2_SCENARIOS: [Scenario; 6] = [
    Scenario::None_,
    Scenario::Cm,
    Scenario::CmS,
    Scenario::CmG,
    Scenario::CmSTg,
    Scenario::CmGTg,
];

/// The §V-E framework-comparison scenarios (Table III / Figs. 8–9 order).
pub const EXP3_SCENARIOS: [Scenario; 5] = [
    Scenario::Kubeflow,
    Scenario::VolcanoNative,
    Scenario::Cm,
    Scenario::CmSTg,
    Scenario::CmGTg,
];

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::None_ => "NONE",
            Scenario::Cm => "CM",
            Scenario::CmS => "CM_S",
            Scenario::CmG => "CM_G",
            Scenario::CmSTg => "CM_S_TG",
            Scenario::CmGTg => "CM_G_TG",
            Scenario::Kubeflow => "Kubeflow",
            Scenario::VolcanoNative => "Volcano",
            Scenario::CmSjf => "CM_SJF",
            Scenario::CmBf => "CM_BF",
            Scenario::CmGTgSjf => "CM_G_TG_SJF",
            Scenario::CmGTgBf => "CM_G_TG_BF",
            Scenario::CmFs => "CM_FS",
            Scenario::CmCbf => "CM_CBF",
            Scenario::CmGTgFs => "CM_G_TG_FS",
            Scenario::CmGTgCbf => "CM_G_TG_CBF",
            Scenario::CmGTgPre => "CM_G_TG_PRE",
            Scenario::ElRigid => "EL_RIGID",
            Scenario::ElMold => "EL_MOLD",
            Scenario::ElMall => "EL_MALL",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        ALL_SCENARIOS.iter().copied().find(|sc| sc.name().eq_ignore_ascii_case(s))
    }

    pub fn kubelet(&self) -> KubeletConfig {
        match self {
            Scenario::None_ => KubeletConfig::default_policy(),
            _ => KubeletConfig::cpu_mem_affinity(),
        }
    }

    pub fn policy(&self) -> GranularityPolicy {
        match self {
            Scenario::CmS | Scenario::CmSTg => GranularityPolicy::Scale,
            Scenario::CmG
            | Scenario::CmGTg
            | Scenario::CmGTgSjf
            | Scenario::CmGTgBf
            | Scenario::CmGTgFs
            | Scenario::CmGTgCbf
            | Scenario::CmGTgPre
            | Scenario::ElRigid
            | Scenario::ElMold
            | Scenario::ElMall => GranularityPolicy::Granularity,
            _ => GranularityPolicy::None,
        }
    }

    /// Queue discipline of this scenario (the fifth matrix knob).
    pub fn queue(&self) -> QueuePolicyKind {
        match self {
            Scenario::CmSjf | Scenario::CmGTgSjf => QueuePolicyKind::Sjf,
            Scenario::CmBf | Scenario::CmGTgBf => QueuePolicyKind::EasyBackfill,
            Scenario::CmCbf | Scenario::CmGTgCbf => QueuePolicyKind::ConservativeBackfill,
            Scenario::CmFs | Scenario::CmGTgFs | Scenario::CmGTgPre => {
                QueuePolicyKind::FairShare
            }
            _ => QueuePolicyKind::FifoSkip,
        }
    }

    /// Whether this scenario enables priority preemption (the sixth knob).
    pub fn preemption(&self) -> bool {
        matches!(
            self,
            Scenario::CmGTgPre | Scenario::ElRigid | Scenario::ElMold | Scenario::ElMall
        )
    }

    /// Elasticity mode of this scenario's pipeline (`None` = no
    /// elasticity plugin; elastic job specs are scheduled rigidly).
    pub fn elasticity(&self) -> Option<ElasticityMode> {
        match self {
            Scenario::ElMold => Some(ElasticityMode::Moldable),
            Scenario::ElMall => Some(ElasticityMode::Malleable),
            _ => None,
        }
    }

    pub fn controller(&self) -> Box<dyn JobController> {
        match self {
            Scenario::Kubeflow => Box::new(KubeflowController),
            Scenario::VolcanoNative => Box::new(NativeVolcanoController),
            _ => Box::new(VolcanoMpiController),
        }
    }

    pub fn scheduler(&self, seed: u64) -> SchedulerConfig {
        let base = match self {
            Scenario::CmSTg
            | Scenario::CmGTg
            | Scenario::CmGTgSjf
            | Scenario::CmGTgBf
            | Scenario::CmGTgFs
            | Scenario::CmGTgCbf
            | Scenario::CmGTgPre
            | Scenario::ElRigid
            | Scenario::ElMold
            | Scenario::ElMall => SchedulerConfig::fine_grained(seed),
            Scenario::Kubeflow => SchedulerConfig::kube_default(seed),
            _ => SchedulerConfig::volcano_default(seed),
        };
        let base = base.with_queue(self.queue()).with_preemption(self.preemption());
        match self.elasticity() {
            Some(mode) => base
                .with_pipeline(PipelineConfig::legacy_equivalent().with_elasticity(mode)),
            None => base,
        }
    }

    /// Build a fully configured simulation for this scenario.
    pub fn simulation(&self, seed: u64) -> Simulation {
        self.simulation_on(ClusterSpec::paper(), seed)
    }

    pub fn simulation_on(&self, cluster: ClusterSpec, seed: u64) -> Simulation {
        self.simulation_on_queue(cluster, seed, self.queue())
    }

    /// Same scenario with its queue discipline overridden (the CLI
    /// `--queue` flag and the queue-policy ablation use this).
    pub fn simulation_with_queue(&self, seed: u64, queue: QueuePolicyKind) -> Simulation {
        self.simulation_on_queue(ClusterSpec::paper(), seed, queue)
    }

    pub fn simulation_on_queue(
        &self,
        cluster: ClusterSpec,
        seed: u64,
        queue: QueuePolicyKind,
    ) -> Simulation {
        self.simulation_configured(cluster, seed, queue, self.preemption())
    }

    /// Fully custom build: queue discipline and preemption both
    /// overridden (the fairness ablation, `run --preempt`, and the config
    /// file use this).
    pub fn simulation_configured(
        &self,
        cluster: ClusterSpec,
        seed: u64,
        queue: QueuePolicyKind,
        preemption: bool,
    ) -> Simulation {
        Simulation::new(
            cluster,
            self.kubelet(),
            self.policy(),
            self.controller(),
            self.scheduler(seed).with_queue(queue).with_preemption(preemption),
            Calibration::default(),
            seed,
        )
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kubelet::CpuManagerPolicy;

    #[test]
    fn table2_matrix_matches_paper() {
        // NONE is the only default-kubelet scenario.
        assert_eq!(Scenario::None_.kubelet().cpu_policy, CpuManagerPolicy::None);
        for s in &TABLE2_SCENARIOS[1..] {
            assert_eq!(s.kubelet().cpu_policy, CpuManagerPolicy::Static, "{s}");
        }
        // TG only in the _TG scenarios.
        assert!(Scenario::CmSTg.scheduler(0).taskgroup);
        assert!(Scenario::CmGTg.scheduler(0).taskgroup);
        assert!(!Scenario::CmS.scheduler(0).taskgroup);
        // Gang everywhere except Kubeflow.
        assert!(!Scenario::Kubeflow.scheduler(0).gang);
        assert!(Scenario::VolcanoNative.scheduler(0).gang);
    }

    #[test]
    fn all_scenarios_is_complete_and_duplicate_free() {
        // Every code round-trips through its own name, and no two share
        // one — so the differential harness's matrix axis covers the enum.
        for s in ALL_SCENARIOS {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
        let mut names: Vec<&str> = ALL_SCENARIOS.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ALL_SCENARIOS.len());
        for s in TABLE2_SCENARIOS.iter().chain(EXP3_SCENARIOS.iter()) {
            assert!(ALL_SCENARIOS.contains(s), "{s}");
        }
    }

    #[test]
    fn names_round_trip() {
        for s in TABLE2_SCENARIOS.iter().chain(EXP3_SCENARIOS.iter()) {
            assert_eq!(Scenario::parse(s.name()), Some(*s));
        }
        assert_eq!(Scenario::parse("cm_g_tg"), Some(Scenario::CmGTg));
        assert_eq!(Scenario::parse("cm_g_tg_bf"), Some(Scenario::CmGTgBf));
        assert_eq!(Scenario::parse("CM_SJF"), Some(Scenario::CmSjf));
        assert_eq!(Scenario::parse("cm_fs"), Some(Scenario::CmFs));
        assert_eq!(Scenario::parse("CM_G_TG_CBF"), Some(Scenario::CmGTgCbf));
        assert_eq!(Scenario::parse("cm_g_tg_pre"), Some(Scenario::CmGTgPre));
        assert_eq!(Scenario::parse("bogus"), None);
    }

    #[test]
    fn queue_variants_only_change_the_queue_knob() {
        use crate::scheduler::QueuePolicyKind;
        for (base, variant, queue) in [
            (Scenario::Cm, Scenario::CmSjf, QueuePolicyKind::Sjf),
            (Scenario::Cm, Scenario::CmBf, QueuePolicyKind::EasyBackfill),
            (Scenario::Cm, Scenario::CmFs, QueuePolicyKind::FairShare),
            (Scenario::Cm, Scenario::CmCbf, QueuePolicyKind::ConservativeBackfill),
            (Scenario::CmGTg, Scenario::CmGTgSjf, QueuePolicyKind::Sjf),
            (Scenario::CmGTg, Scenario::CmGTgBf, QueuePolicyKind::EasyBackfill),
            (Scenario::CmGTg, Scenario::CmGTgFs, QueuePolicyKind::FairShare),
            (Scenario::CmGTg, Scenario::CmGTgCbf, QueuePolicyKind::ConservativeBackfill),
        ] {
            assert_eq!(variant.queue(), queue);
            assert_eq!(variant.scheduler(0), base.scheduler(0).with_queue(queue));
            assert_eq!(variant.policy(), base.policy());
            assert_eq!(variant.kubelet().cpu_policy, base.kubelet().cpu_policy);
            assert_eq!(variant.controller().name(), base.controller().name());
            assert!(!variant.preemption());
        }
        assert_eq!(Scenario::CmGTg.queue(), QueuePolicyKind::FifoSkip);
    }

    #[test]
    fn pre_variant_enables_fair_share_and_preemption() {
        use crate::scheduler::QueuePolicyKind;
        let pre = Scenario::CmGTgPre;
        assert!(pre.preemption());
        assert_eq!(pre.queue(), QueuePolicyKind::FairShare);
        assert_eq!(
            pre.scheduler(0),
            Scenario::CmGTg
                .scheduler(0)
                .with_queue(QueuePolicyKind::FairShare)
                .with_preemption(true)
        );
        assert_eq!(pre.policy(), Scenario::CmGTg.policy());
        // Preemption needs gang all-or-nothing.
        assert!(pre.scheduler(0).gang);
    }

    #[test]
    fn elastic_variants_differ_only_in_the_elasticity_plugin() {
        assert_eq!(Scenario::ElRigid.elasticity(), None);
        assert_eq!(Scenario::ElMold.elasticity(), Some(ElasticityMode::Moldable));
        assert_eq!(Scenario::ElMall.elasticity(), Some(ElasticityMode::Malleable));
        for s in ELASTIC_SCENARIOS {
            assert!(ALL_SCENARIOS.contains(&s), "{s}");
            assert!(s.preemption(), "{s}: the ablation compares against eviction");
            assert_eq!(s.policy(), GranularityPolicy::Granularity, "{s}");
            assert_eq!(s.queue(), QueuePolicyKind::FifoSkip, "{s}");
            let cfg = s.scheduler(0);
            assert!(cfg.gang && cfg.taskgroup, "{s}: fine-grained base");
            assert_eq!(cfg.pipeline.elasticity.map(|e| e.mode), s.elasticity(), "{s}");
        }
        // The rigid baseline runs the stock legacy-equivalent pipeline.
        assert_eq!(Scenario::ElRigid.scheduler(0).pipeline, PipelineConfig::legacy_equivalent());
        assert_eq!(Scenario::parse("el_mall"), Some(Scenario::ElMall));
    }

    #[test]
    fn controllers_match_frameworks() {
        assert_eq!(Scenario::Kubeflow.controller().name(), "kubeflow-mpi-operator");
        assert_eq!(Scenario::VolcanoNative.controller().name(), "volcano-native");
        assert_eq!(Scenario::CmGTg.controller().name(), "volcano+mpi-aware");
    }
}
