//! Minimal, dependency-free `anyhow` stand-in (see Cargo.toml for why).
//!
//! API subset implemented — enough for every call site in kube-fgs:
//! - [`Error`]: an erased error with a context chain. `Display` prints the
//!   outermost message; the alternate form (`{:#}`) and `Debug` print the
//!   full `outer: cause: root` chain, matching `anyhow`'s behaviour.
//! - [`Result<T>`] with `E = Error`, usable with `?` over any
//!   `std::error::Error` (blanket `From`).
//! - [`anyhow!`], [`bail!`], [`ensure!`] format-string macros.
//! - [`Context`]: `.context(..)` / `.with_context(|| ..)` on `Result` and
//!   `Option`.

use std::error::Error as StdError;
use std::fmt;

/// Erased error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as the real
// `anyhow`).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        assert_eq!(format!("{e:?}"), "reading config: missing");
        assert_eq!(e.root_cause(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn fails() -> Result<()> {
            bail!("bad value {}", 7);
        }
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "bad value 7");

        fn checks(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(checks(1).is_ok());
        assert!(checks(-1).is_err());
        let m = anyhow!("plain {}", "message");
        assert_eq!(m.to_string(), "plain message");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: missing");
        let o: Option<u32> = None;
        assert_eq!(o.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(5u32).context("never").unwrap(), 5);
    }
}
