//! Bench: regenerate Fig. 3 (benchmark MPI profiling analysis) and time
//! the live PJRT payload measurements behind it.
//!
//! Run: cargo bench --bench fig3_profiles

use kube_fgs::experiments;
use kube_fgs::runtime::{default_artifacts_dir, Runtime};
use kube_fgs::util::BenchTimer;
use kube_fgs::workload::ALL_BENCHMARKS;

fn main() {
    println!("=== Fig. 3 — Benchmarks MPI profiling analysis ===\n");
    print!("{}", experiments::fig3_table());

    match Runtime::load(&default_artifacts_dir()) {
        Ok(rt) => {
            println!("\nper-payload PJRT step time:");
            for &b in &ALL_BENCHMARKS {
                let payload = rt.payload(b).unwrap();
                BenchTimer::new(&format!("payload/{}", b.artifact()))
                    .with_iters(2, 8)
                    .run(|| {
                        payload.step().unwrap();
                    });
            }
        }
        Err(e) => println!("\n(payload timing skipped: {e})"),
    }
}
