//! Bench: regenerate Fig. 9 (per-job response time across frameworks).
//!
//! Run: cargo bench --bench fig9_framework_response

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::simulator::JobRecord;
use kube_fgs::util::BenchTimer;

fn main() {
    println!("=== Fig. 9 — per-job response time across frameworks ===\n");
    let results = experiments::exp3_all_scenarios(DEFAULT_SEED);
    print!(
        "{}",
        experiments::per_job_table(&results, JobRecord::response, "")
    );

    // Paper: CM_G_TG improves (or at least equals) the response of jobs
    // overall; Volcano is the worst case.
    let sum = |name: &str| {
        results
            .iter()
            .find(|(s, _)| s.name() == name)
            .map(|(_, m)| m.overall_response)
            .unwrap()
    };
    println!("\noverall response: Volcano {:.0} s, CM {:.0} s, CM_G_TG {:.0} s", sum("Volcano"), sum("CM"), sum("CM_G_TG"));
    assert!(sum("Volcano") > sum("CM"));
    assert!(sum("CM_G_TG") < sum("CM"));

    println!();
    BenchTimer::new("exp3/fig9-pipeline").with_iters(1, 3).run(|| {
        experiments::exp3_all_scenarios(DEFAULT_SEED);
    });
}
