//! Bench: regenerate Fig. 7 (makespan of the 20-job mixed workload, plus
//! the per-scenario scheduling-process Gantt).
//!
//! Run: cargo bench --bench fig7_makespan

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::report;
use kube_fgs::util::BenchTimer;
use kube_fgs::workload::exp2_trace;

fn main() {
    println!("=== Fig. 7 — makespan, 20 mixed jobs ===\n");
    let results = experiments::exp2_all_scenarios(DEFAULT_SEED);
    print!("{}", experiments::fig7_table(&results));

    println!("\nscheduling process (CM vs CM_G_TG):");
    for name in ["CM", "CM_G_TG"] {
        let s = kube_fgs::scenario::Scenario::parse(name).unwrap();
        let out = experiments::run_scenario(s, &exp2_trace(DEFAULT_SEED), DEFAULT_SEED, None);
        println!("\n-- {name} --");
        print!("{}", report::gantt(&out, 90));
    }

    println!();
    BenchTimer::new("exp2/makespan-pipeline").with_iters(1, 3).run(|| {
        experiments::exp2_all_scenarios(DEFAULT_SEED);
    });
}
