//! Microbenchmarks of the L3 hot paths: one scheduling session (filter +
//! score + gang trial), task-group construction, Algorithm-4 scoring, rate
//! recomputation, and a full simulation step loop.
//!
//! These are the targets the §Perf optimization pass iterates against
//! (EXPERIMENTS.md §Perf records before/after).
//!
//! Run: cargo bench --bench scheduler_micro

use kube_fgs::apiserver::ApiServer;
use kube_fgs::cluster::ClusterSpec;
use kube_fgs::controller::{JobController, VolcanoMpiController};
use kube_fgs::kubelet::KubeletConfig;
use kube_fgs::perfmodel::{job_slowdown, job_slowdown_with, Calibration, ClusterLoads};
use kube_fgs::planner::{plan, GranularityPolicy, SystemInfo};
use kube_fgs::scheduler::{PlacementEngineKind, Scheduler, SchedulerConfig, ALL_PLACEMENT_ENGINES};
use kube_fgs::util::BenchTimer;
use kube_fgs::workload::{exp2_trace, uniform_trace, Benchmark, JobSpec};

/// API server with `n` pending granularity jobs (16 pods each).
fn pending_cluster(n: u64, workers: usize) -> ApiServer {
    let mut api = ApiServer::new(
        ClusterSpec::with_workers(workers),
        KubeletConfig::cpu_mem_affinity(),
    );
    let info = SystemInfo::homogeneous(workers as u32);
    for i in 1..=n {
        let spec = JobSpec::paper_job(i, Benchmark::EpDgemm, 0.0);
        let planned = plan(&spec, GranularityPolicy::Granularity, info);
        let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
        api.create_job(planned, pods, hostfile, 0.0);
    }
    api
}

/// Placement-engine, persistent-timeline, and earliest-fit before/after
/// sections: the linear scan vs the indexed buckets, the per-session
/// rebuild vs the event-driven cache, and the linear hole search vs the
/// range-minimum segment tree, at 32 and 128 workers. Returns (name,
/// mean seconds) timing rows plus (name, per-second) scheduler
/// throughput rows for the CI artifact (`--json PATH`).
fn placement_sections() -> (Vec<(String, f64)>, Vec<(String, f64)>) {
    let mut rows = Vec::new();

    // Placement engine: scheduling sessions over a congested queue. Same
    // seeds, same queue — selections are bit-identical (property-pinned);
    // only the per-pod feasibility enumeration cost differs, and it is
    // the O(nodes)-per-pod term that dominates 128-node sessions.
    for workers in [32usize, 128] {
        let jobs = 2 * workers as u64;
        for engine in ALL_PLACEMENT_ENGINES {
            let tag = match engine {
                PlacementEngineKind::Linear => "(before)",
                PlacementEngineKind::Indexed => "(after)",
            };
            let s = BenchTimer::new(&format!(
                "placement-engine/session-{workers}w-{jobs}j-{engine} {tag}"
            ))
            .with_iters(1, 5)
            .run(|| {
                let mut api = pending_cluster(jobs, workers);
                let mut sched =
                    Scheduler::new(SchedulerConfig::fine_grained(1).with_engine(engine));
                let started = sched.cycle(&mut api, 0.0);
                assert!(!started.is_empty());
            });
            rows.push((format!("placement/session-{workers}w-{engine}"), s.mean));
        }
    }

    // Persistent timeline: the cost of acquiring one conservative
    // session's availability profile on a loaded cluster where one
    // projection moved since the last session — the rebuild pays the full
    // O(running x nodes) cumulative clone chain plus a pod walk per
    // running job every session; the cache folds in the one delta and
    // hands out a flat clone.
    for workers in [32usize, 128] {
        use std::collections::BTreeMap;
        use kube_fgs::cluster::{JobId, Resources};
        use kube_fgs::scheduler::{QueueContext, ResourceTimeline, TimelineCache};
        // Cap at 240 running jobs: each launcher holds 1 GiB on the
        // control plane (248 GiB allocatable), and the fill must start
        // every job so the release profile covers the whole running set.
        let jobs = (2 * workers as u64).min(240);
        let mut api = pending_cluster(jobs, workers);
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        let started = sched.cycle(&mut api, 0.0);
        assert_eq!(started.len(), jobs as usize, "fill session starts every job");
        let mut projected: BTreeMap<JobId, f64> = started
            .iter()
            .enumerate()
            .map(|(i, &j)| (j, 1000.0 + i as f64))
            .collect();
        let free: Vec<Resources> = api.spec.node_ids().map(|n| api.free_on(n)).collect();
        let s = BenchTimer::new(&format!(
            "timeline/session-profile-{workers}w-{jobs}j-rebuild (before)"
        ))
        .with_iters(2, 20)
        .run(|| {
            let ctx = QueueContext {
                api: &api,
                now: 1.0,
                projected_completion: &projected,
                free: &free,
                walltime_factor: 1.0,
            };
            let tl = ResourceTimeline::new(&ctx);
            std::hint::black_box(&tl);
        });
        rows.push((format!("timeline/session-profile-{workers}w-rebuild"), s.mean));
        let ctx0 = QueueContext {
            api: &api,
            now: 1.0,
            projected_completion: &projected,
            free: &free,
            walltime_factor: 1.0,
        };
        let mut cache = TimelineCache::new(&ctx0);
        let mut step = 0u64;
        let s = BenchTimer::new(&format!(
            "timeline/session-profile-{workers}w-{jobs}j-cache (after)"
        ))
        .with_iters(2, 20)
        .run(|| {
            step += 1;
            let moved = started[step as usize % started.len()];
            projected.insert(moved, 1500.0 + step as f64 * 0.5);
            let ctx = QueueContext {
                api: &api,
                now: 1.0,
                projected_completion: &projected,
                free: &free,
                walltime_factor: 1.0,
            };
            cache.refresh(&ctx);
            let tl = cache.session_profile();
            std::hint::black_box(&tl);
        });
        rows.push((format!("timeline/session-profile-{workers}w-cache"), s.mean));
    }

    // Earliest-fit hole search: the retained linear scan vs the
    // range-minimum segment tree, on synthetic release profiles at
    // conservative-queue scale. Both return bit-identical placements
    // (debug-asserted per window, property-pinned over whole sims); only
    // the per-candidate window-minimum cost differs — O(points x nodes)
    // against O(log points + nodes).
    {
        use kube_fgs::cluster::{JobId, Resources};
        use kube_fgs::scheduler::ResourceTimeline;
        let workers = 32usize;
        let api = pending_cluster(1, workers);
        let alloc: Vec<Resources> =
            api.spec.node_ids().map(|n| api.spec.node(n).allocatable()).collect();
        for n_points in [128usize, 1024] {
            // Free capacity ramps from empty to the full cluster across
            // the profile, so the search walks deep into the points.
            let den = (n_points - 1) as u64;
            let tl = ResourceTimeline::from_points(
                (0..n_points)
                    .map(|i| {
                        let free = alloc
                            .iter()
                            .map(|a| {
                                Resources::new(
                                    a.cpu_milli * i as u64 / den,
                                    a.mem_bytes * i as u64 / den,
                                )
                            })
                            .collect();
                        (i as f64 * 5.0, free)
                    })
                    .collect(),
            );
            let s = BenchTimer::new(&format!(
                "earliest-fit/{n_points}p-{workers}w-linear (before)"
            ))
            .with_iters(1, 5)
            .run(|| {
                assert!(tl.earliest_fit_linear(&api, JobId(1), 10.0).is_some());
            });
            rows.push((format!("earliest_fit/{n_points}p-linear"), s.mean));
            let s = BenchTimer::new(&format!(
                "earliest-fit/{n_points}p-{workers}w-tree (after)"
            ))
            .with_iters(1, 20)
            .run(|| {
                assert!(tl.earliest_fit(&api, JobId(1), 10.0).is_some());
            });
            rows.push((format!("earliest_fit/{n_points}p-tree"), s.mean));
        }
    }

    // Scheduler throughput counters: sessions/sec and decisions/sec over
    // full simulated runs — the same SchedulerStats the sharded scale-out
    // sums across domains (RunOutput::sched_stats). Rates rather than
    // per-iteration means, so they land in their own JSON section.
    let mut rates = Vec::new();
    {
        use kube_fgs::experiments::RunSpec;
        use kube_fgs::scenario::Scenario;
        for workers in [32usize, 128] {
            let jobs = 2 * workers;
            let interval = 60.0 * 8.0 / workers as f64;
            let trace = uniform_trace(jobs, interval, 2);
            let spec = RunSpec::new(Scenario::CmGTg)
                .seed(2)
                .cluster(ClusterSpec::with_workers(workers));
            let wall = std::time::Instant::now();
            let run = spec.run(&trace);
            let secs = wall.elapsed().as_secs_f64().max(1e-9);
            let stats = run.sched_stats();
            assert_eq!(run.records().len(), jobs);
            println!(
                "throughput/sim-{workers}w-{jobs}j: {:.1} sessions/s, {:.1} decisions/s \
                 ({} sessions, {} decisions in {:.3}s)",
                stats.sessions as f64 / secs,
                stats.decisions as f64 / secs,
                stats.sessions,
                stats.decisions,
                secs
            );
            rates.push((
                format!("throughput/sessions_per_sec-{workers}w"),
                stats.sessions as f64 / secs,
            ));
            rates.push((
                format!("throughput/decisions_per_sec-{workers}w"),
                stats.decisions as f64 / secs,
            ));
        }
    }

    // Simulator-core before/after: the retired per-event stepped clock
    // (O(events x running)) vs the epoch-based progress ledger
    // ((events + running) log running) on a dense serve-style trace.
    // ns/event comes from SimCoreStats, which times only the clock
    // sections (next_completion, advance, completion harvest), so the
    // ratio isolates the sim core from scheduler cost.
    {
        use kube_fgs::experiments::RunSpec;
        use kube_fgs::scenario::Scenario;
        use kube_fgs::workload::serve_trace;
        for workers in [128usize, 1024] {
            // Traffic scales with the cluster so the running set stays
            // dense; half-hour horizon bounds bench wall time.
            let multiplier = workers as f64 / 4.0;
            let trace = serve_trace(1800.0, multiplier, 2);
            let mut ns_per_event = [0.0f64; 2];
            for (slot, stepped) in [(0usize, true), (1usize, false)] {
                let clock = if stepped { "stepped" } else { "epoch" };
                let tag = if stepped { "(before)" } else { "(after)" };
                let spec = RunSpec::new(Scenario::CmGTg)
                    .seed(2)
                    .cluster(ClusterSpec::with_workers(workers))
                    .stepped_clock(stepped);
                let wall = std::time::Instant::now();
                let run = spec.run(&trace);
                let secs = wall.elapsed().as_secs_f64().max(1e-9);
                let stats = run.core_stats();
                assert!(!run.records().is_empty(), "serve trace produced completions");
                ns_per_event[slot] = stats.nanos_per_event();
                println!(
                    "sim_core/{workers}w-{clock} {tag}: {:.0} ns/event, {:.0} events/s \
                     ({} events, {} resyncs, run {:.3}s)",
                    stats.nanos_per_event(),
                    stats.events as f64 / secs,
                    stats.events,
                    stats.resyncs,
                    secs
                );
                rows.push((format!("sim_core/run-{workers}w-{clock}"), secs));
                rates.push((
                    format!("sim_core/ns_per_event-{workers}w-{clock}"),
                    stats.nanos_per_event(),
                ));
                if !stepped {
                    rates.push((
                        format!("sim_core/events_per_sec-{workers}w"),
                        stats.events as f64 / secs,
                    ));
                }
            }
            println!(
                "sim_core/{workers}w: stepped/epoch ns-per-event ratio {:.1}x",
                ns_per_event[0] / ns_per_event[1].max(1e-9)
            );
        }
    }
    (rows, rates)
}

/// Hand-rendered JSON artifact (the substrate has no serde): the CI
/// perf-trajectory data point for the placement/timeline/earliest-fit
/// hot paths, plus the scheduler sessions/sec + decisions/sec rates and
/// the sim-core ns/event + events/sec before/after counters.
fn placement_json(rows: &[(String, f64)], rates: &[(String, f64)]) -> String {
    let mut out = String::from("{\n  \"bench\": \"placement\", \"entries\": [\n");
    for (i, (name, mean)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{name}\", \"mean_s\": {mean:.6}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"throughput\": [\n");
    for (i, (name, per_sec)) in rates.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{name}\", \"per_sec\": {per_sec:.1}}}{}\n",
            if i + 1 < rates.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let placement_only = args.iter().any(|a| a == "--placement-only");

    println!("=== L3 scheduler microbenchmarks ===\n");

    if placement_only {
        let (rows, rates) = placement_sections();
        if let Some(path) = json_path {
            std::fs::write(&path, placement_json(&rows, &rates)).expect("writing bench json");
            println!("\nwrote {path}");
        }
        return;
    }

    // One full scheduling session over 8 pending fine-grained jobs
    // (8 jobs x 17 pods, task-group plugin on).
    BenchTimer::new("session/8-jobs-taskgroup-4-nodes").with_iters(3, 20).run(|| {
        let mut api = pending_cluster(8, 4);
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        let started = sched.cycle(&mut api, 0.0);
        assert!(!started.is_empty());
    });

    // Same at 16 nodes / 32 jobs — the scalability ablation point.
    BenchTimer::new("session/32-jobs-taskgroup-16-nodes").with_iters(1, 10).run(|| {
        let mut api = pending_cluster(32, 16);
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        sched.cycle(&mut api, 0.0);
    });

    // Rate recomputation: job_slowdown over a loaded cluster.
    {
        let mut api = pending_cluster(8, 4);
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        sched.cycle(&mut api, 0.0);
        let running = api.running_jobs();
        let calib = Calibration::default();
        // Naive per-job recomputation (the pre-optimization hot path).
        BenchTimer::new("perfmodel/rate-recompute-naive").with_iters(3, 50).run(|| {
            for &j in &running {
                job_slowdown(&api, j, &calib, 1.0);
            }
        });
        // Snapshot-amortized recomputation (what the simulator runs).
        BenchTimer::new("perfmodel/rate-recompute-snapshot").with_iters(3, 50).run(|| {
            let loads = ClusterLoads::snapshot(&api);
            for &j in &running {
                job_slowdown_with(&api, j, &calib, 1.0, &loads);
            }
        });
    }

    // Queue-policy session cost: one cycle over a 64-job congested queue
    // per discipline (see benches/queue_policies.rs for the 1k-job scale).
    for kind in kube_fgs::scheduler::ALL_QUEUE_POLICIES {
        BenchTimer::new(&format!("session/64-jobs-queue-{}", kind.name()))
            .with_iters(1, 10)
            .run(|| {
                let mut api = pending_cluster(64, 4);
                let mut sched =
                    Scheduler::new(SchedulerConfig::fine_grained(1).with_queue(kind));
                sched.cycle(&mut api, 0.0);
            });
    }

    // Rate maintenance over a whole run: the incremental placement-delta
    // path (contention-set dirty tracking + per-node rebuild) vs forcing
    // the pre-optimization full rescan on every event. Same seeds, same
    // trace — the outputs are bit-identical (pinned by a property test);
    // only the bookkeeping cost differs, and it grows with cluster size.
    for workers in [16usize, 64] {
        let jobs = 3 * workers;
        let interval = 60.0 * 8.0 / workers as f64;
        let mk = |force: bool| {
            let cluster = kube_fgs::cluster::ClusterSpec::with_workers(workers);
            let mut sim = kube_fgs::scenario::Scenario::CmGTg
                .simulation_on_queue(cluster, 2, kube_fgs::scheduler::QueuePolicyKind::FifoSkip);
            sim.force_full_recompute = force;
            sim
        };
        let trace = uniform_trace(jobs, interval, 2);
        BenchTimer::new(&format!("rates/full-rescan-{workers}w-{jobs}j (before)"))
            .with_iters(1, 5)
            .run(|| {
                let out = mk(true).run(&trace);
                assert_eq!(out.records.len(), jobs);
            });
        BenchTimer::new(&format!("rates/incremental-{workers}w-{jobs}j (after)"))
            .with_iters(1, 5)
            .run(|| {
                let out = mk(false).run(&trace);
                assert_eq!(out.records.len(), jobs);
            });
    }

    // Tenant-usage accounting: the maintained O(tenants) ledgers vs the
    // full job-map recompute the fair-share ordering used to run on every
    // session.
    {
        let sim = kube_fgs::scenario::Scenario::CmGTgFs.simulation(2);
        let out = sim.run(&kube_fgs::workload::two_tenant_trace(300, 20.0, 2));
        let api = out.api;
        BenchTimer::new("tenant-usage/full-scan-300j (before)").with_iters(5, 500).run(|| {
            let u = api.tenant_usage_reference(1e7);
            std::hint::black_box(&u);
        });
        BenchTimer::new("tenant-usage/ledgers-300j (after)").with_iters(5, 500).run(|| {
            let u = api.tenant_usage(1e7);
            std::hint::black_box(&u);
        });
    }

    // Running-set view: the old full job-map scan (reference, kept as
    // ApiServer::running_jobs_reference) vs the maintained index the
    // preemption and elasticity passes now read on every cycle. The gap
    // grows with schedule history — after a 300-job trace the scan walks
    // every completed job in the map to find the handful still running.
    {
        let sim = kube_fgs::scenario::Scenario::CmGTg.simulation(2);
        let out = sim.run(&uniform_trace(300, 30.0, 2));
        let mut api = out.api;
        let info = SystemInfo::homogeneous(4);
        for i in 1..=8u64 {
            let spec = JobSpec::paper_job(10_000 + i, Benchmark::EpDgemm, 0.0);
            let planned = plan(&spec, GranularityPolicy::Granularity, info);
            let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
            api.create_job(planned, pods, hostfile, 0.0);
        }
        let mut sched = Scheduler::new(SchedulerConfig::fine_grained(1));
        let started = sched.cycle(&mut api, 0.0);
        assert!(!started.is_empty());
        assert_eq!(api.running_jobs(), api.running_jobs_reference());
        BenchTimer::new("running-set/full-scan-300j (before)").with_iters(5, 500).run(|| {
            let r = api.running_jobs_reference();
            std::hint::black_box(&r);
        });
        BenchTimer::new("running-set/index-300j (after)").with_iters(5, 500).run(|| {
            let r = api.running_jobs();
            std::hint::black_box(&r);
        });
    }

    // Group-placement session view: the old full pod scan (reference,
    // kept as Scheduler::rebuild_placement) vs the API server's
    // incrementally maintained view that sessions now clone. The gap grows
    // with schedule history — after a 200-job trace the scan walks ~3.4k
    // mostly-succeeded pods while the incremental view is near-empty.
    {
        let sim = kube_fgs::scenario::Scenario::CmGTg.simulation(2);
        let out = sim.run(&uniform_trace(200, 60.0, 2));
        let api = out.api;
        BenchTimer::new("placement/full-pod-scan (before)").with_iters(5, 200).run(|| {
            let p = Scheduler::rebuild_placement(&api);
            std::hint::black_box(&p);
        });
        BenchTimer::new("placement/incremental-clone (after)").with_iters(5, 200).run(|| {
            let p = api.group_placement().clone();
            std::hint::black_box(&p);
        });
    }

    // Placement engine + persistent timeline + earliest-fit before/after
    // (32 and 128 workers) — the CI placement_bench.json artifact rows.
    let (rows, rates) = placement_sections();

    // Full experiment-2 simulation, one scenario.
    BenchTimer::new("simulate/exp2-CM_G_TG").with_iters(1, 10).run(|| {
        let sim = kube_fgs::scenario::Scenario::CmGTg.simulation(2);
        let out = sim.run(&exp2_trace(2));
        assert_eq!(out.records.len(), 20);
    });

    // Full experiment-2, all six scenarios (the figure-regeneration cost).
    BenchTimer::new("simulate/exp2-all-scenarios").with_iters(1, 5).run(|| {
        kube_fgs::experiments::exp2_all_scenarios(2);
    });

    if let Some(path) = json_path {
        std::fs::write(&path, placement_json(&rows, &rates)).expect("writing bench json");
        println!("\nwrote {path}");
    }
}
