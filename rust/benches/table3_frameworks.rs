//! Bench: regenerate Table III (makespan under Kubeflow / native Volcano /
//! CM / CM_S_TG / CM_G_TG) in the paper's exact format.
//!
//! Run: cargo bench --bench table3_frameworks

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::util::BenchTimer;

fn main() {
    println!("=== Table III — makespan comparison ===\n");
    let results = experiments::exp3_all_scenarios(DEFAULT_SEED);
    print!("{}", experiments::table3(&results));

    let get = |name: &str| {
        results.iter().find(|(s, _)| s.name() == name).map(|(_, m)| m.makespan).unwrap()
    };
    println!("\nshape checks:");
    println!(
        "  Volcano / CM slowdown: {:.1}x (paper: 123055/2529 = 48.7x)",
        get("Volcano") / get("CM")
    );
    println!(
        "  Kubeflow ~= CM: {:+.1}% (paper: 2520 vs 2529 = -0.4%)",
        (get("Kubeflow") / get("CM") - 1.0) * 100.0
    );
    assert!(get("Volcano") > 10.0 * get("CM"), "Volcano must blow up");
    assert!(get("CM_G_TG") < get("CM"));

    println!();
    BenchTimer::new("exp3/frameworks-pipeline").with_iters(1, 3).run(|| {
        experiments::exp3_all_scenarios(DEFAULT_SEED);
    });
}
