//! Bench: regenerate Fig. 5 (overall response time of 10 EP-DGEMM jobs)
//! and check the paper's headline deltas hold in shape.
//!
//! Run: cargo bench --bench fig5_dgemm_response

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::util::BenchTimer;

fn main() {
    println!("=== Fig. 5 — overall response time, 10 EP-DGEMM jobs ===\n");
    let results = experiments::exp1_all_scenarios(DEFAULT_SEED);
    print!("{}", experiments::fig5_table(&results));

    let get = |name: &str| {
        results.iter().find(|(s, _)| s.name() == name).map(|(_, m)| m.overall_response).unwrap()
    };
    println!("\nshape checks (paper: CM_S* +5%/+26%, CM_G* +15%/+34% vs CM/NONE):");
    for s in ["CM_S", "CM_G", "CM_S_TG", "CM_G_TG"] {
        println!(
            "  {:<8} vs CM {:+.0}%   vs NONE {:+.0}%",
            s,
            (1.0 - get(s) / get("CM")) * 100.0,
            (1.0 - get(s) / get("NONE")) * 100.0
        );
    }
    assert!(get("CM_G") < get("CM") && get("CM") < get("NONE"));

    println!();
    BenchTimer::new("exp1/response-pipeline").with_iters(1, 5).run(|| {
        experiments::exp1_all_scenarios(DEFAULT_SEED);
    });
}
