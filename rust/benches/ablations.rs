//! Ablation benches for the design choices DESIGN.md calls out:
//!  A. task-group plugin on/off at fixed granularity (isolates Alg. 3-4);
//!  B. granularity policy sweep at fixed scheduler;
//!  C. cluster-size scaling (4 -> 16 worker nodes, future-work §VI);
//!  D. arrival-intensity sweep (queueing sensitivity).
//!
//! Run: cargo bench --bench ablations

use kube_fgs::experiments::{run_metrics, DEFAULT_SEED};
use kube_fgs::metrics::ExperimentMetrics;
use kube_fgs::report;
use kube_fgs::scenario::Scenario;
use kube_fgs::simulator::Simulation;
use kube_fgs::util::BenchTimer;
use kube_fgs::workload::{exp2_trace, uniform_trace};

fn main() {
    let seed = DEFAULT_SEED;
    let trace = exp2_trace(seed);

    println!("=== Ablation A/B — planner policy x task-group plugin ===\n");
    let mut rows = Vec::new();
    for s in kube_fgs::scenario::TABLE2_SCENARIOS {
        let m = run_metrics(s, &trace, seed);
        rows.push(vec![
            s.name().to_string(),
            format!("{:?}", s.policy()),
            s.scheduler(0).taskgroup.to_string(),
            format!("{:.0}", m.overall_response),
            format!("{:.0}", m.makespan),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["scenario", "planner", "taskgroup", "response (s)", "makespan (s)"],
            &rows
        )
    );

    println!("\n=== Ablation C — cluster-size scaling (CM_G_TG) ===\n");
    let mut rows = Vec::new();
    for workers in [4usize, 8, 16] {
        let scenario = Scenario::CmGTg;
        let sim = scenario.simulation_on(
            kube_fgs::cluster::ClusterSpec::with_workers(workers),
            seed,
        );
        let out = sim.run(&trace);
        let m = ExperimentMetrics::from(&out);
        rows.push(vec![
            workers.to_string(),
            format!("{:.0}", m.overall_response),
            format!("{:.0}", m.makespan),
            format!("{:.1}", m.avg_wait),
        ]);
    }
    print!(
        "{}",
        report::table(&["workers", "response (s)", "makespan (s)", "avg wait (s)"], &rows)
    );

    println!("\n=== Ablation D — arrival intensity (CM vs CM_G_TG) ===\n");
    let mut rows = Vec::new();
    for interval in [30u64, 60, 120] {
        let t = uniform_trace(20, interval as f64, seed);
        let cm = run_metrics(Scenario::Cm, &t, seed);
        let fg = run_metrics(Scenario::CmGTg, &t, seed);
        rows.push(vec![
            format!("{interval}s"),
            format!("{:.0}", cm.overall_response),
            format!("{:.0}", fg.overall_response),
            format!("{:+.0}%", (1.0 - fg.overall_response / cm.overall_response) * 100.0),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["mean interval", "CM response", "CM_G_TG response", "improvement"],
            &rows
        )
    );

    println!();
    let mut simulate = || {
        let sim: Simulation = Scenario::CmGTg.simulation(seed);
        sim.run(&trace);
    };
    BenchTimer::new("ablation/simulation-cost").with_iters(1, 5).run(&mut simulate);
}
