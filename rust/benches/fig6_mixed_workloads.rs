//! Bench: regenerate Fig. 6 (per-benchmark avg running time + overall
//! response, 20 mixed jobs, six scenarios).
//!
//! Run: cargo bench --bench fig6_mixed_workloads

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::util::BenchTimer;
use kube_fgs::workload::Benchmark;

fn main() {
    println!("=== Fig. 6 — 20 mixed jobs, six scenarios ===\n");
    let results = experiments::exp2_all_scenarios(DEFAULT_SEED);
    print!("{}", experiments::fig6_table(&results));

    let get = |name: &str| results.iter().find(|(s, _)| s.name() == name).unwrap();
    let (_, cm_s) = get("CM_S");
    let (_, cm_s_tg) = get("CM_S_TG");
    println!(
        "\nTG effect on EP-STREAM (paper: -33% CM_S_TG vs CM_S): {:+.0}%",
        (cm_s_tg.avg_running[&Benchmark::EpStream] / cm_s.avg_running[&Benchmark::EpStream] - 1.0)
            * 100.0
    );

    println!();
    BenchTimer::new("exp2/all-six-scenarios").with_iters(1, 3).run(|| {
        experiments::exp2_all_scenarios(DEFAULT_SEED);
    });
}
