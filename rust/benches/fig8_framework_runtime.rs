//! Bench: regenerate Fig. 8 (per-job running time across frameworks).
//!
//! Run: cargo bench --bench fig8_framework_runtime

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::simulator::JobRecord;
use kube_fgs::util::BenchTimer;

fn main() {
    println!("=== Fig. 8 — per-job running time across frameworks ===\n");
    let results = experiments::exp3_all_scenarios(DEFAULT_SEED);
    print!(
        "{}",
        experiments::per_job_table(&results, JobRecord::running, "")
    );

    // Paper: network-intensive jobs degrade catastrophically under native
    // Volcano; CM_G_TG improves or equals every job vs CM.
    let volcano = &results.iter().find(|(s, _)| s.name() == "Volcano").unwrap().1;
    let worst = volcano
        .per_job
        .iter()
        .map(|r| (r.benchmark, r.running()))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\nworst Volcano job: {} at {:.0} s (network-intensive scatter)",
        worst.0.name(),
        worst.1
    );
    assert!(worst.0.profile().is_network());

    println!();
    BenchTimer::new("exp3/fig8-pipeline").with_iters(1, 3).run(|| {
        experiments::exp3_all_scenarios(DEFAULT_SEED);
    });
}
