//! Bench: regenerate Fig. 4 (average running time of 10 EP-DGEMM jobs
//! across the six Table-II scenarios) and time the full simulation.
//!
//! Run: cargo bench --bench fig4_dgemm_runtime

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::util::BenchTimer;

fn main() {
    println!("=== Fig. 4 — avg running time, 10 EP-DGEMM jobs ===\n");
    let results = experiments::exp1_all_scenarios(DEFAULT_SEED);
    print!("{}", experiments::fig4_table(&results));

    println!();
    BenchTimer::new("exp1/all-six-scenarios").with_iters(1, 5).run(|| {
        let r = experiments::exp1_all_scenarios(DEFAULT_SEED);
        assert_eq!(r.len(), 6);
    });
}
