//! Queue-policy microbenchmarks: the cost of one scheduling session per
//! queue discipline at 1k-job `uniform_trace` scale, plus full-trace
//! simulations per policy (the queue-policy ablation's runtime envelope).
//!
//! Run: cargo bench --bench queue_policies

use kube_fgs::apiserver::ApiServer;
use kube_fgs::cluster::ClusterSpec;
use kube_fgs::controller::{JobController, VolcanoMpiController};
use kube_fgs::kubelet::KubeletConfig;
use kube_fgs::planner::{plan, GranularityPolicy, SystemInfo};
use kube_fgs::scheduler::{Scheduler, SchedulerConfig, ALL_QUEUE_POLICIES};
use kube_fgs::util::BenchTimer;
use kube_fgs::workload::uniform_trace;

/// API server with every job of a 1k uniform trace pending at t=0.
fn pending_uniform_cluster(n: usize, workers: usize) -> ApiServer {
    let mut api = ApiServer::new(
        ClusterSpec::with_workers(workers),
        KubeletConfig::cpu_mem_affinity(),
    );
    let info = SystemInfo::homogeneous(workers as u32);
    for spec in uniform_trace(n, 60.0, 7) {
        let planned = plan(&spec, GranularityPolicy::Granularity, info);
        let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
        api.create_job(planned, pods, hostfile, 0.0);
    }
    api
}

fn main() {
    println!("=== Queue-policy benchmarks ===\n");

    // One scheduling session over a 1000-job pending queue, per policy:
    // the per-cycle cost of ordering + gang trials + (for EASY) the
    // shadow-time computation.
    for kind in ALL_QUEUE_POLICIES {
        BenchTimer::new(&format!("session/1k-pending/{}", kind.name()))
            .with_iters(1, 5)
            .run(|| {
                let mut api = pending_uniform_cluster(1000, 16);
                let mut sched =
                    Scheduler::new(SchedulerConfig::fine_grained(1).with_queue(kind));
                let started = sched.cycle(&mut api, 0.0);
                assert!(!started.is_empty());
            });
    }

    // Full 200-job ablation trace, per policy (what `kube-fgs queues`
    // runs once per policy).
    let trace = uniform_trace(200, 60.0, 2);
    for kind in ALL_QUEUE_POLICIES {
        BenchTimer::new(&format!("simulate/uniform-200/{}", kind.name()))
            .with_iters(0, 2)
            .run(|| {
                let sim =
                    kube_fgs::scenario::Scenario::CmGTg.simulation_with_queue(2, kind);
                let out = sim.run(&trace);
                assert_eq!(out.records.len() + out.unschedulable.len(), 200);
            });
    }
}
