//! Integration tests: the full pipeline (planner -> controller ->
//! scheduler -> kubelet -> simulator -> metrics) across scenarios, plus
//! regression checks on the paper's headline results at the default seed.

use kube_fgs::apiserver::JobPhase;
use kube_fgs::cluster::PodPhase;
use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::metrics::ExperimentMetrics;
use kube_fgs::scenario::{Scenario, EXP3_SCENARIOS, TABLE2_SCENARIOS};
use kube_fgs::workload::{exp1_trace, exp2_trace, Benchmark, ALL_BENCHMARKS};

#[test]
fn every_scenario_completes_exp2_and_conserves_resources() {
    let trace = exp2_trace(DEFAULT_SEED);
    for scenario in TABLE2_SCENARIOS.iter().chain(EXP3_SCENARIOS.iter()) {
        let out = experiments::run_scenario(*scenario, &trace, DEFAULT_SEED, None);
        assert_eq!(out.records.len(), 20, "{scenario}");
        // Every job succeeded, every pod succeeded, all resources returned.
        for job in out.api.jobs.values() {
            assert_eq!(job.phase, JobPhase::Succeeded, "{scenario}");
        }
        for pod in out.api.pods.values() {
            assert_eq!(pod.phase, PodPhase::Succeeded, "{scenario}");
            assert!(pod.node.is_some(), "{scenario}");
        }
        for n in out.api.spec.node_ids() {
            assert_eq!(
                out.api.free_on(n),
                out.api.spec.node(n).allocatable(),
                "{scenario}: node {n:?} leaked resources"
            );
        }
        // Time identities.
        for r in &out.records {
            assert!(r.start_time >= r.submit_time - 1e-9, "{scenario}");
            assert!(r.finish_time > r.start_time, "{scenario}");
        }
    }
}

#[test]
fn paper_headline_shape_exp1() {
    // Fig. 5: fine-grained policies beat the baselines; granularity beats
    // scale; everything beats NONE.
    let results = experiments::exp1_all_scenarios(DEFAULT_SEED);
    let get = |name: &str| {
        results
            .iter()
            .find(|(s, _)| s.name() == name)
            .map(|(_, m)| m.overall_response)
            .unwrap()
    };
    assert!(get("CM") < get("NONE"));
    assert!(get("CM_S") < get("CM"));
    assert!(get("CM_G") < get("CM_S"));
    // TG does not help DGEMM-only workloads (paper: "TG incurs no
    // significant benefit for DGEMM") — within 3%.
    assert!((get("CM_S_TG") / get("CM_S") - 1.0).abs() < 0.03);
    assert!((get("CM_G_TG") / get("CM_G") - 1.0).abs() < 0.03);
}

#[test]
fn paper_headline_shape_exp2() {
    let results = experiments::exp2_all_scenarios(DEFAULT_SEED);
    let get = |name: &str| results.iter().find(|(s, _)| s.name() == name).unwrap();
    let resp = |name: &str| get(name).1.overall_response;
    let mk = |name: &str| get(name).1.makespan;

    // Overall response: CM_G_TG reduces vs NONE by ~35% and vs CM by
    // 10-25% (paper: 35% / 19%).
    let vs_none = 1.0 - resp("CM_G_TG") / resp("NONE");
    let vs_cm = 1.0 - resp("CM_G_TG") / resp("CM");
    assert!((0.25..0.45).contains(&vs_none), "vs NONE: {vs_none}");
    assert!((0.05..0.30).contains(&vs_cm), "vs CM: {vs_cm}");

    // Makespan: CM_G_TG improves vs NONE (paper 34%) and vs CM (paper 11%).
    assert!(mk("CM_G_TG") < mk("CM"), "TG must improve makespan over CM");
    assert!(mk("CM_G_TG") < mk("NONE"));

    // Granularity policies help CPU- and memory-intensive benchmarks...
    for bench in [Benchmark::EpDgemm, Benchmark::EpStream] {
        let cm = get("CM").1.avg_running[&bench];
        let cm_g = get("CM_G").1.avg_running[&bench];
        assert!(cm_g < cm, "{bench}: CM_G {cm_g} !< CM {cm}");
    }
    // ... but have no significant effect on network-intensive ones.
    for bench in [Benchmark::GFft, Benchmark::GRandomRing] {
        let cm = get("CM").1.avg_running[&bench];
        let cm_g = get("CM_G").1.avg_running[&bench];
        assert!((cm_g / cm - 1.0).abs() < 0.05, "{bench}: {cm} vs {cm_g}");
    }
}

#[test]
fn paper_headline_shape_exp3() {
    let results = experiments::exp3_all_scenarios(DEFAULT_SEED);
    let get = |name: &str| results.iter().find(|(s, _)| s.name() == name).unwrap();
    // Kubeflow ~ CM (both: affinity + default-ish scheduling, no split).
    let kubeflow = get("Kubeflow").1.makespan;
    let cm = get("CM").1.makespan;
    assert!((kubeflow / cm - 1.0).abs() < 0.10, "{kubeflow} vs {cm}");
    // Native Volcano blows up by an order of magnitude+ (paper: 48.7x).
    let volcano = get("Volcano").1.makespan;
    assert!(volcano > 10.0 * cm, "Volcano {volcano} vs CM {cm}");
    // The blow-up comes from network-intensive jobs.
    let vol_metrics = &get("Volcano").1;
    let worst = vol_metrics
        .per_job
        .iter()
        .max_by(|a, b| a.running().partial_cmp(&b.running()).unwrap())
        .unwrap();
    assert!(worst.benchmark.profile().is_network());
    // Fine-grained wins overall.
    assert!(get("CM_G_TG").1.makespan < cm);
}

#[test]
fn fair_share_with_preemption_improves_high_priority_response() {
    // Acceptance (ISSUE 3): on the 200-job two-tenant trace, the
    // fair-share + preemption configuration must strictly improve the
    // high-priority (prod) tenant's mean response time over FIFO-skip.
    let rows = experiments::fairness_ablation(
        DEFAULT_SEED,
        experiments::FAIRNESS_JOBS,
        experiments::FAIRNESS_INTERVAL,
    );
    let get = |label: &str| rows.iter().find(|r| r.label == label).unwrap();
    let fifo = get("fifo");
    let fsp = get("fair_share+preempt");
    // Every configuration completes the whole trace.
    for r in &rows {
        assert_eq!(r.metrics.per_job.len(), experiments::FAIRNESS_JOBS, "{}", r.label);
    }
    let prod = kube_fgs::workload::PROD_TENANT;
    let fifo_prod = fifo.tenant(prod).expect("prod tenant in fifo run").mean_response;
    let fsp_prod = fsp.tenant(prod).expect("prod tenant in fs+p run").mean_response;
    assert!(
        fsp_prod < fifo_prod,
        "fair_share+preempt prod mean response {fsp_prod} must beat fifo {fifo_prod}"
    );
    // Preemption actually fired, and only in the preemption config.
    assert!(fsp.preemptions > 0, "expected preemptions under fair_share+preempt");
    assert_eq!(fifo.preemptions, 0);
}

#[test]
fn malleable_elasticity_dominates_rigid() {
    // Acceptance (ISSUE 7): on the elastic trace, the malleable
    // configuration — expand-into-drain + shrink-before-preempt — must
    // strictly beat the rigid baseline on BOTH overall response time and
    // makespan, at the default ablation size.
    let rows = experiments::elasticity_ablation(
        DEFAULT_SEED,
        experiments::ELASTICITY_JOBS,
        experiments::ELASTICITY_INTERVAL,
    );
    let get = |label: &str| rows.iter().find(|r| r.label == label).unwrap();
    // Every mode completes the whole trace.
    for r in &rows {
        assert_eq!(r.metrics.per_job.len(), experiments::ELASTICITY_JOBS, "{}", r.label);
    }
    let rigid = get("rigid");
    let malleable = get("malleable");
    assert!(
        malleable.metrics.overall_response < rigid.metrics.overall_response,
        "malleable overall response {} must beat rigid {}",
        malleable.metrics.overall_response,
        rigid.metrics.overall_response
    );
    assert!(
        malleable.metrics.makespan < rigid.metrics.makespan,
        "malleable makespan {} must beat rigid {}",
        malleable.metrics.makespan,
        rigid.metrics.makespan
    );
    // The resize verb actually fired, and only where the plugin runs:
    // rigid has no elasticity plugin, so its resize action is a no-op.
    assert!(malleable.resizes > 0, "expected resizes under malleable");
    assert_eq!(rigid.resizes, 0);
}

#[test]
fn serve_slo_violations_and_p99_grow_with_traffic() {
    // Acceptance (ISSUE 9): under the open-loop serving mix, pushing the
    // traffic multiplier up can only hurt — the baseline policy's SLO
    // violation count and p99 response must be monotonically
    // non-decreasing in the multiplier.
    let points = experiments::serve_sweep(
        DEFAULT_SEED,
        &[Scenario::Cm],
        &[1.0, 4.0, 10.0],
        2.0 * 3600.0,
        1,
        None,
        false,
    );
    assert_eq!(points.len(), 3);
    for p in &points {
        assert!(p.jobs > 0, "multiplier {} produced an empty trace", p.multiplier);
        assert_eq!(
            p.slo.jobs + p.unschedulable,
            p.jobs,
            "multiplier {}: every job scored or reported unschedulable",
            p.multiplier
        );
    }
    for w in points.windows(2) {
        assert!(
            w[1].slo.violations >= w[0].slo.violations,
            "violations fell from {} at {}x to {} at {}x",
            w[0].slo.violations,
            w[0].multiplier,
            w[1].slo.violations,
            w[1].multiplier
        );
        assert!(
            w[1].slo.overall.p99 >= w[0].slo.overall.p99,
            "p99 fell from {} at {}x to {} at {}x",
            w[0].slo.overall.p99,
            w[0].multiplier,
            w[1].slo.overall.p99,
            w[1].multiplier
        );
    }
    // The sweep actually saturates the baseline within the swept range.
    assert!(
        points.last().unwrap().slo.violation_fraction()
            >= experiments::SERVE_KNEE_THRESHOLD,
        "10x traffic must push CM past the knee threshold"
    );
}

#[test]
fn malleable_knee_beats_rigid_on_elastic_serve_mix() {
    // Acceptance (ISSUE 9): on the elastic serving mix, the malleable
    // policy must sustain strictly more traffic before saturating — its
    // knee (the multiplier where the violation fraction crosses 0.5) sits
    // at a strictly higher multiplier than the rigid baseline's. A knee
    // that is never reached counts as infinite.
    let rigid = Scenario::parse("EL_RIGID").unwrap();
    let mall = Scenario::parse("EL_MALL").unwrap();
    let points = experiments::serve_sweep(
        DEFAULT_SEED,
        &[rigid, mall],
        &[1.0, 2.0, 3.0, 4.0, 6.0, 9.0],
        2.0 * 3600.0,
        1,
        None,
        true,
    );
    let knee = |s| {
        experiments::serve_knee(&points, s).unwrap_or(f64::INFINITY)
    };
    let (k_rigid, k_mall) = (knee(rigid), knee(mall));
    assert!(
        k_rigid.is_finite(),
        "rigid must saturate within the swept multipliers (fractions: {:?})",
        points
            .iter()
            .filter(|p| p.scenario == rigid)
            .map(|p| (p.multiplier, p.slo.violation_fraction()))
            .collect::<Vec<_>>()
    );
    assert!(
        k_mall > k_rigid,
        "malleable knee {k_mall} must sit strictly above rigid {k_rigid}"
    );
}

#[test]
fn preemptive_runs_conserve_resources_and_complete() {
    // CM_G_TG_PRE over the two-tenant trace: every job completes despite
    // evictions + restarts, and all bookkeeping returns to zero.
    let trace = kube_fgs::workload::two_tenant_trace(60, 60.0, DEFAULT_SEED);
    let out = experiments::run_scenario(Scenario::parse("CM_G_TG_PRE").unwrap(), &trace, DEFAULT_SEED, None);
    assert_eq!(out.records.len(), 60);
    for job in out.api.jobs.values() {
        assert_eq!(job.phase, JobPhase::Succeeded);
    }
    for n in out.api.spec.node_ids() {
        assert_eq!(out.api.free_on(n), out.api.spec.node(n).allocatable());
    }
    for r in &out.records {
        assert!(r.start_time >= r.submit_time - 1e-9);
        assert!(r.finish_time > r.start_time);
    }
}

#[test]
fn exp1_trace_queueing_is_visible_in_waits() {
    // 10 jobs, 60 s apart, ~600 s each, 8 slots: later jobs must queue.
    let out = experiments::run_scenario(Scenario::Cm, &exp1_trace(), DEFAULT_SEED, None);
    let m = ExperimentMetrics::from(&out);
    assert!(m.avg_wait > 0.0, "expected queueing in exp1");
}

#[test]
fn reproducible_across_identical_runs() {
    let a = experiments::run_scenario(Scenario::CmGTg, &exp2_trace(7), 7, None);
    let b = experiments::run_scenario(Scenario::CmGTg, &exp2_trace(7), 7, None);
    let key = |o: &kube_fgs::simulator::SimOutput| {
        o.records
            .iter()
            .map(|r| (r.id, r.finish_time.to_bits()))
            .collect::<Vec<_>>()
    };
    assert_eq!(key(&a), key(&b));
}

#[test]
fn granularity_scenarios_place_single_task_containers() {
    let out = experiments::run_scenario(Scenario::CmGTg, &exp2_trace(3), 3, None);
    for job in out.api.jobs.values() {
        let bench = job.planned.spec.benchmark;
        let workers: Vec<_> = job
            .pods
            .iter()
            .map(|p| &out.api.pods[p])
            .filter(|p| p.is_worker())
            .collect();
        if bench.profile().is_network() {
            assert_eq!(workers.len(), 1, "network job stays whole");
            assert_eq!(workers[0].ntasks, 16);
        } else {
            assert_eq!(workers.len(), 16, "cpu/mem job fully split");
            assert!(workers.iter().all(|w| w.ntasks == 1));
            // Task-group: 16 workers in 4 cohesive groups of 4. Each
            // group's workers stay on one node (affinity); groups prefer
            // distinct nodes but may share one under capacity pressure
            // from co-located jobs (anti-affinity is a score, not a hard
            // constraint).
            let mut group_nodes = std::collections::BTreeMap::new();
            for w in &workers {
                group_nodes
                    .entry(w.group.expect("worker without group"))
                    .or_insert_with(std::collections::BTreeSet::new)
                    .insert(w.node.unwrap());
            }
            assert_eq!(group_nodes.len(), 4, "{}", job.planned.spec.name);
            for (g, nodes) in &group_nodes {
                assert_eq!(
                    nodes.len(),
                    1,
                    "{}: group {g} split across {nodes:?}",
                    job.planned.spec.name
                );
            }
        }
    }
}

#[test]
fn all_benchmarks_appear_in_fig6() {
    let results = experiments::exp2_all_scenarios(DEFAULT_SEED);
    for (_, m) in &results {
        for b in ALL_BENCHMARKS {
            assert!(m.avg_running.contains_key(&b));
            assert!(m.avg_running[&b] > 0.0);
        }
    }
}
