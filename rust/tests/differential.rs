//! Differential golden-trace harness for the action/plugin pipeline.
//!
//! The scheduler's legacy monolithic cycle is kept verbatim behind
//! `force_legacy_scheduler` as a pinned reference; these tests drive whole
//! simulations through both paths over every scenario in the matrix ×
//! both placement engines × homogeneous and fat/thin cluster mixes, and
//! require bit-identical `SimOutput`s — record-for-record f64 bit
//! equality plus FNV-1a digest equality over the full event trace. Any
//! behavioural drift introduced while refactoring actions or plugins
//! fails here with the first diverging job, not as a silent golden-digest
//! change.

use kube_fgs::cluster::{ClusterSpec, HeterogeneityMix};
use kube_fgs::scenario::{Scenario, ALL_SCENARIOS};
use kube_fgs::scheduler::PlacementEngineKind;
use kube_fgs::simulator::{SimDigest, SimOutput};
use kube_fgs::workload::two_tenant_trace;

const SEED: u64 = 11;
const JOBS: usize = 12;
const MEAN_INTERVAL: f64 = 30.0;

#[derive(Clone, Copy)]
enum Mix {
    Uniform,
    FatThin,
}

impl Mix {
    fn cluster(self) -> ClusterSpec {
        match self {
            // Same worker count both ways so only the node shapes differ.
            Mix::Uniform => ClusterSpec::with_workers(4),
            Mix::FatThin => ClusterSpec::mixed(4, HeterogeneityMix::FatThin),
        }
    }

    fn name(self) -> &'static str {
        match self {
            Mix::Uniform => "uniform",
            Mix::FatThin => "fat_thin",
        }
    }
}

fn run(
    scenario: Scenario,
    mix: Mix,
    engine: PlacementEngineKind,
    force_legacy: bool,
) -> SimOutput {
    let mut sim = scenario.simulation_on(mix.cluster(), SEED);
    sim.set_placement_engine(engine);
    sim.set_force_legacy_scheduler(force_legacy);
    sim.run(&two_tenant_trace(JOBS, MEAN_INTERVAL, SEED))
}

/// The core differential assertion: pipeline vs legacy, bit-for-bit.
fn assert_pipeline_matches_legacy(mix: Mix, engine: PlacementEngineKind) {
    for scenario in ALL_SCENARIOS {
        let ctx = format!("{scenario} / {} / {engine:?}", mix.name());
        let pipeline = run(scenario, mix, engine, false);
        let legacy = run(scenario, mix, engine, true);
        // Record-level comparison first, so a divergence names the first
        // differing job instead of two opaque hashes.
        assert_eq!(pipeline.records.len(), legacy.records.len(), "{ctx}: record count");
        for (p, l) in pipeline.records.iter().zip(legacy.records.iter()) {
            assert_eq!(p.id, l.id, "{ctx}: record order");
            assert_eq!(
                p.start_time.to_bits(),
                l.start_time.to_bits(),
                "{ctx}: job {:?} start {} vs {}",
                p.id,
                p.start_time,
                l.start_time
            );
            assert_eq!(
                p.finish_time.to_bits(),
                l.finish_time.to_bits(),
                "{ctx}: job {:?} finish {} vs {}",
                p.id,
                p.finish_time,
                l.finish_time
            );
        }
        assert_eq!(pipeline.unschedulable, legacy.unschedulable, "{ctx}: unschedulable");
        // Then the full trace digest (events, placements, all records).
        assert_eq!(
            SimDigest::of(&pipeline),
            SimDigest::of(&legacy),
            "{ctx}: event-trace digest"
        );
    }
}

#[test]
fn pipeline_matches_legacy_uniform_linear() {
    assert_pipeline_matches_legacy(Mix::Uniform, PlacementEngineKind::Linear);
}

#[test]
fn pipeline_matches_legacy_uniform_indexed() {
    assert_pipeline_matches_legacy(Mix::Uniform, PlacementEngineKind::Indexed);
}

#[test]
fn pipeline_matches_legacy_fat_thin_linear() {
    assert_pipeline_matches_legacy(Mix::FatThin, PlacementEngineKind::Linear);
}

#[test]
fn pipeline_matches_legacy_fat_thin_indexed() {
    assert_pipeline_matches_legacy(Mix::FatThin, PlacementEngineKind::Indexed);
}

/// Elastic traces without an elasticity plugin: the `resize` action in
/// the default pipeline must stay a provable no-op even when every job
/// carries an `elasticity` range — the verb only activates through the
/// plugin, so these schedules are still bit-identical to the legacy
/// cycle (which has no resize path at all). EL_RIGID is the ablation
/// baseline; CM_G_TG and CM_G_TG_PRE cover the no-preemption and
/// fair-share-preemption variants.
#[test]
fn pipeline_matches_legacy_on_elastic_traces_without_plugin() {
    use kube_fgs::workload::elastic_trace;
    let trace = elastic_trace(JOBS, MEAN_INTERVAL, SEED);
    for scenario in [Scenario::ElRigid, Scenario::CmGTg, Scenario::CmGTgPre] {
        assert!(scenario.elasticity().is_none());
        let mk = |force_legacy: bool| {
            let mut sim = scenario.simulation_on(Mix::Uniform.cluster(), SEED);
            sim.set_force_legacy_scheduler(force_legacy);
            sim.run(&trace)
        };
        let pipeline = mk(false);
        let legacy = mk(true);
        assert_eq!(pipeline.resize_count(), 0, "{scenario}: resize must not fire");
        assert_eq!(
            SimDigest::of(&pipeline),
            SimDigest::of(&legacy),
            "{scenario}: elastic trace without plugin must match legacy"
        );
    }
}

/// The digest itself is a stable serialization surface: equal outputs hash
/// equal, the JSON form round-trips losslessly, and perturbing the run
/// (different seed) actually changes the hash — a digest that never
/// changes would pin nothing.
#[test]
fn digest_round_trips_and_discriminates() {
    let a = run(Scenario::CmGTg, Mix::Uniform, PlacementEngineKind::Indexed, false);
    let d = SimDigest::of(&a);
    let parsed = SimDigest::from_json(&d.to_json()).expect("round trip");
    assert_eq!(d, parsed);

    let mut sim = Scenario::CmGTg.simulation_on(Mix::Uniform.cluster(), SEED + 1);
    sim.set_placement_engine(PlacementEngineKind::Indexed);
    let b = sim.run(&two_tenant_trace(JOBS, MEAN_INTERVAL, SEED + 1));
    assert_ne!(d, SimDigest::of(&b), "different seed must change the digest");
}
