//! Golden snapshot tests for the paper-figure scenarios.
//!
//! Each case runs a full simulation and compares its [`SimDigest`] — an
//! FNV-1a hash over the complete event trace, placements, and job records
//! — against a JSON snapshot under `tests/golden/`. The snapshots pin the
//! exact schedules behind every paper figure: a refactor that perturbs so
//! much as one f64 bit of one start time fails here.
//!
//! Blessing:
//!   * `KUBE_FGS_BLESS=1 cargo test --test golden` rewrites every
//!     snapshot from the current behaviour (inspect the diff before
//!     committing!).
//!   * On a developer machine a *missing* snapshot is blessed on first
//!     run rather than failing, so a fresh checkout (or a deliberately
//!     deleted file) regenerates itself.
//!   * In CI (the `CI` env var is set, as on every GitHub runner) a
//!     missing snapshot FAILS: CI compares against the committed record,
//!     it never manufactures one — a snapshot that self-blesses in CI
//!     would pin whatever the broken build produced. Bless locally and
//!     commit the file instead. Drift against an *existing* snapshot
//!     always fails everywhere.

use std::path::PathBuf;

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::scenario::{Scenario, ELASTIC_SCENARIOS, EXP3_SCENARIOS, TABLE2_SCENARIOS};
use kube_fgs::simulator::{SimDigest, SimOutput};
use kube_fgs::workload::{elastic_trace, exp2_trace, two_tenant_trace};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn bless_requested() -> bool {
    ["KUBE_FGS_BLESS", "BLESS"]
        .iter()
        .any(|k| std::env::var(k).map(|v| v == "1").unwrap_or(false))
}

/// Compare `out` against the named snapshot, blessing it when asked to
/// (or when it does not exist yet).
fn check_golden(name: &str, out: &SimOutput) {
    let digest = SimDigest::of(out);
    let path = golden_dir().join(format!("{name}.json"));
    let in_ci = std::env::var_os("CI").is_some();
    if !bless_requested() && !path.exists() && in_ci {
        panic!(
            "golden: {} is missing and this is CI. CI never blesses snapshots — it would \
             pin whatever this build produced instead of the committed record. Run \
             `cargo test --test golden` locally (a missing file self-blesses there) and \
             commit tests/golden/{name}.json.",
            path.display()
        );
    }
    if bless_requested() || !path.exists() {
        std::fs::create_dir_all(golden_dir()).expect("create tests/golden");
        std::fs::write(&path, format!("{}\n", digest.to_json()))
            .unwrap_or_else(|e| panic!("golden: writing {}: {e}", path.display()));
        eprintln!(
            "golden: blessed {}\ngolden: to commit it, run:\n    git add rust/tests/golden/{name}.json",
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden: reading {}: {e}", path.display()));
    let want = SimDigest::from_json(&text)
        .unwrap_or_else(|e| panic!("golden: parsing {}: {e}", path.display()));
    assert_eq!(
        digest, want,
        "golden digest drift for {name} ({}). If the behaviour change is \
         intended, re-bless with KUBE_FGS_BLESS=1 and commit the diff.",
        path.display()
    );
}

/// Table II / Figs. 6-7: the six fine-grained scenarios on the exp2 trace.
#[test]
fn golden_exp2_table2_scenarios() {
    let trace = exp2_trace(DEFAULT_SEED);
    for s in TABLE2_SCENARIOS {
        let out = experiments::run_scenario(s, &trace, DEFAULT_SEED, None);
        check_golden(&format!("exp2_{}", s.name()), &out);
    }
}

/// Table III / Figs. 8-9: the framework-comparison scenarios on the same
/// trace (separate snapshots so the two experiments can drift — and be
/// re-blessed — independently).
#[test]
fn golden_exp3_framework_scenarios() {
    let trace = exp2_trace(DEFAULT_SEED);
    for s in EXP3_SCENARIOS {
        let out = experiments::run_scenario(s, &trace, DEFAULT_SEED, None);
        check_golden(&format!("exp3_{}", s.name()), &out);
    }
}

/// The multi-tenant preemptive schedule (fair-share + priority
/// preemption) on the two-tenant trace — the schedule with the most
/// internal churn (evict, requeue, re-place), so the most sensitive pin.
#[test]
fn golden_two_tenant_preemption() {
    let trace = two_tenant_trace(30, 45.0, DEFAULT_SEED);
    let out = experiments::run_scenario(Scenario::CmGTgPre, &trace, DEFAULT_SEED, None);
    check_golden("two_tenant_CM_G_TG_PRE", &out);
}

/// The elasticity modes on the elastic trace — rigid, moldable, and
/// malleable each get their own snapshot (the resize verb's schedules:
/// mold/shrink/expand events are part of the digest, so a change to any
/// resize path fails the corresponding pin).
#[test]
fn golden_elastic_modes() {
    let trace = elastic_trace(24, 25.0, DEFAULT_SEED);
    for s in ELASTIC_SCENARIOS {
        let out = experiments::run_scenario(s, &trace, DEFAULT_SEED, None);
        if s.elasticity().is_none() {
            assert_eq!(
                out.resize_count(),
                0,
                "{s}: no elasticity plugin, so the resize action must be a no-op"
            );
        }
        check_golden(&format!("elastic_{}", s.name()), &out);
    }
}
