//! Property-based tests on coordinator invariants.
//!
//! The offline registry has no `proptest`, so these are randomized-input
//! property tests driven by the crate's own seeded RNG: each property is
//! checked over hundreds of generated cases, and any failure prints the
//! case seed for replay (the substitute for proptest shrinking).

use kube_fgs::cluster::{gib, ClusterSpec, JobId, NodeSpec, Pod, PodId, PodRole, Resources};
use kube_fgs::controller::mpi_aware::allocate_tasks;
use kube_fgs::controller::{JobController, VolcanoMpiController};
use kube_fgs::kubelet::{CpuManagerPolicy, CpuManagerState, TopologyPolicy};
use kube_fgs::perfmodel::{job_slowdown, Calibration};
use kube_fgs::planner::{plan, GranularityPolicy, SystemInfo};
use kube_fgs::scheduler::taskgroup::build_groups;
use kube_fgs::scenario::Scenario;
use kube_fgs::util::Rng;
use kube_fgs::workload::{uniform_trace, Benchmark, JobSpec, ALL_BENCHMARKS};

const CASES: usize = 300;

/// Property: RoundRobin task allocation conserves N_t and balances within 1.
#[test]
fn prop_allocate_tasks_conserves_and_balances() {
    let mut rng = Rng::seed_from_u64(101);
    for case in 0..CASES {
        let nt = rng.range_usize(1, 129) as u32;
        let nw = rng.range_usize(1, 65) as u32;
        let counts = allocate_tasks(nt, nw);
        assert_eq!(counts.iter().sum::<u32>(), nt, "case {case}: nt={nt} nw={nw}");
        let (max, min) = (counts.iter().max().unwrap(), counts.iter().min().unwrap());
        assert!(max - min <= 1, "case {case}: {counts:?}");
    }
}

/// Property: task-group construction is balanced (sizes differ by <= 1 for
/// equal workers) and covers every worker exactly once.
#[test]
fn prop_taskgroups_balanced_partition() {
    let mut rng = Rng::seed_from_u64(202);
    for case in 0..CASES {
        let n = rng.range_usize(1, 65);
        let k = rng.range_usize(1, 17);
        let pods: Vec<Pod> = (0..n)
            .map(|i| {
                let mut p = Pod::new(
                    PodId(i as u64),
                    JobId(1),
                    format!("w{i}"),
                    PodRole::Worker { index: i as u32 },
                );
                p.requests = Resources::new(1000, gib(2));
                p
            })
            .collect();
        let refs: Vec<&Pod> = pods.iter().collect();
        let groups = build_groups(&refs, k);
        let sizes: Vec<usize> = groups.iter().map(|g| g.workers.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), n, "case {case}");
        let max = sizes.iter().max().unwrap();
        let min = sizes.iter().min().unwrap();
        assert!(max - min <= 1, "case {case}: n={n} k={k} {sizes:?}");
        let mut all: Vec<u64> = groups.iter().flat_map(|g| g.workers.iter().map(|p| p.0)).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "case {case}: duplicate/missing workers");
    }
}

/// Property: the static CPU manager never double-allocates a CPU, never
/// exceeds capacity, and release restores the exact free count.
#[test]
fn prop_cpu_manager_exclusive_and_conserving() {
    let mut rng = Rng::seed_from_u64(303);
    for case in 0..CASES {
        let spec = NodeSpec::paper_worker("w");
        let topo = if rng.f64() < 0.5 { TopologyPolicy::BestEffort } else { TopologyPolicy::None };
        let mut st = CpuManagerState::new(&spec, CpuManagerPolicy::Static, topo);
        let mut granted: Vec<kube_fgs::cluster::CpuSet> = Vec::new();
        // Random allocate/release churn.
        for _ in 0..rng.range_usize(1, 40) {
            if granted.is_empty() || rng.f64() < 0.6 {
                let want = rng.range_usize(1, 17) as u32;
                if let Some(a) = st.allocate(want) {
                    if let Some(cs) = a.cpuset() {
                        // Exclusivity: disjoint from every live grant.
                        for g in &granted {
                            assert!(cs.is_disjoint(g), "case {case}: overlap");
                        }
                        granted.push(cs.clone());
                    }
                }
            } else {
                let i = rng.range_usize(0, granted.len());
                let cs = granted.swap_remove(i);
                st.release(&spec, &cs);
            }
            let live: usize = granted.iter().map(|g| g.len()).sum();
            assert_eq!(st.free_total() + live, 32, "case {case}: leak");
        }
    }
}

/// Property: Algorithm 1 always yields a feasible granularity — workers
/// within [1, N_t], nodes within [1, available], groups <= workers, and
/// network jobs are never split.
#[test]
fn prop_planner_feasible_granularity() {
    let mut rng = Rng::seed_from_u64(404);
    let policies = [GranularityPolicy::None, GranularityPolicy::Scale, GranularityPolicy::Granularity];
    for case in 0..CASES {
        let bench = ALL_BENCHMARKS[rng.range_usize(0, 5)];
        let mut spec = JobSpec::paper_job(1, bench, 0.0);
        spec.ntasks = rng.range_usize(1, 65) as u32;
        spec.default_workers = rng.range_usize(1, 17) as u32;
        let info = SystemInfo::homogeneous(rng.range_usize(0, 17) as u32);
        let policy = policies[rng.range_usize(0, 3)];
        let g = plan(&spec, policy, info).granularity;
        assert!(g.n_workers >= 1 && g.n_workers <= spec.ntasks.max(spec.default_workers), "case {case}: {g:?}");
        assert!(g.n_nodes >= 1, "case {case}");
        assert!(g.n_groups >= 1 && g.n_groups <= g.n_workers.max(g.n_nodes), "case {case}: {g:?}");
        if bench.profile().is_network() && policy != GranularityPolicy::None {
            assert_eq!(g.n_workers, 1, "case {case}: network job split");
        }
    }
}

/// Property: simulation conservation — every submitted job finishes exactly
/// once, response = wait + run, resources fully returned — across random
/// scenarios, traces, and seeds.
#[test]
fn prop_simulation_conservation() {
    let scenarios = [
        Scenario::None_,
        Scenario::Cm,
        Scenario::CmS,
        Scenario::CmG,
        Scenario::CmSTg,
        Scenario::CmGTg,
        Scenario::Kubeflow,
        Scenario::VolcanoNative,
    ];
    let mut rng = Rng::seed_from_u64(505);
    for case in 0..40 {
        let scenario = scenarios[rng.range_usize(0, scenarios.len())];
        let n_jobs = rng.range_usize(1, 25);
        let interval = rng.range_f64(10.0, 200.0);
        let seed = rng.next_u64();
        let trace = uniform_trace(n_jobs, interval, seed);
        let out = experiments_run(scenario, &trace, seed);
        assert_eq!(out.records.len(), n_jobs, "case {case} {scenario}");
        let mut seen = std::collections::BTreeSet::new();
        for r in &out.records {
            assert!(seen.insert(r.id), "case {case}: duplicate record");
            assert!(r.finish_time > r.submit_time, "case {case}");
            assert!((r.response() - (r.wait() + r.running())).abs() < 1e-9);
        }
        for n in out.api.spec.node_ids() {
            assert_eq!(out.api.free_on(n), out.api.spec.node(n).allocatable(), "case {case}");
        }
    }
}

fn experiments_run(
    scenario: Scenario,
    trace: &[JobSpec],
    seed: u64,
) -> kube_fgs::simulator::SimOutput {
    kube_fgs::experiments::run_scenario(scenario, trace, seed, None)
}

/// Property: every queue policy completes every feasible job — no
/// discipline (including strict head-blocking and EASY reservations)
/// starves a job forever — and resources are fully returned.
#[test]
fn prop_queue_policies_complete_all_jobs() {
    let mut rng = Rng::seed_from_u64(808);
    for case in 0..12 {
        let n_jobs = rng.range_usize(5, 30);
        let interval = rng.range_f64(20.0, 120.0);
        let seed = rng.next_u64();
        let trace = uniform_trace(n_jobs, interval, seed);
        for kind in kube_fgs::scheduler::ALL_QUEUE_POLICIES {
            let out = kube_fgs::experiments::run_scenario_with_queue(
                Scenario::CmGTg,
                kind,
                &trace,
                seed,
            );
            assert_eq!(out.records.len(), n_jobs, "case {case} {kind}");
            assert!(out.unschedulable.is_empty(), "case {case} {kind}");
            for n in out.api.spec.node_ids() {
                assert_eq!(
                    out.api.free_on(n),
                    out.api.spec.node(n).allocatable(),
                    "case {case} {kind}: leaked resources"
                );
            }
        }
    }
}

/// Property: preemption rollback — across randomized two-tenant traces
/// under fair-share + priority preemption, preempt → re-place → complete
/// leaves the bookkeeping identical to a never-preempted run's end state:
/// every job completes exactly once, all node allocations return to the
/// full allocatable capacity, and the incrementally maintained
/// group-placement view equals the full pod-scan rebuild (both empty).
#[test]
fn prop_preempt_replace_complete_restores_bookkeeping() {
    use kube_fgs::scheduler::{QueuePolicyKind, Scheduler};
    use kube_fgs::workload::{two_tenant_trace, PROD_TENANT};
    let mut rng = Rng::seed_from_u64(909);
    for case in 0..10 {
        let n_jobs = rng.range_usize(8, 30);
        let interval = rng.range_f64(20.0, 100.0);
        let seed = rng.next_u64();
        let trace = two_tenant_trace(n_jobs, interval, seed);
        let mut sim = Scenario::CmGTg.simulation_configured(
            ClusterSpec::paper(),
            seed,
            QueuePolicyKind::FairShare,
            true,
        );
        sim.api.set_tenant_weight(PROD_TENANT, 3.0);
        let out = sim.run(&trace);
        assert_eq!(out.records.len(), n_jobs, "case {case}: every job completes");
        let mut seen = std::collections::BTreeSet::new();
        for r in &out.records {
            assert!(seen.insert(r.id), "case {case}: duplicate record");
            assert!(r.finish_time > r.start_time, "case {case}");
            assert!(r.start_time >= r.submit_time - 1e-9, "case {case}");
        }
        for n in out.api.spec.node_ids() {
            assert_eq!(
                out.api.free_on(n),
                out.api.spec.node(n).allocatable(),
                "case {case}: node {n:?} leaked resources after preemption churn"
            );
        }
        assert_eq!(
            out.api.group_placement(),
            &Scheduler::rebuild_placement(&out.api),
            "case {case}: incremental placement drifted from rebuild"
        );
        assert!(
            out.api.group_placement().bound_nodes.is_empty(),
            "case {case}: placement not empty after completion"
        );
    }
}

/// Property: perf-model monotonicity — a job's slowdown is never below 1,
/// and network jobs never beat their single-container placement when
/// scattered.
#[test]
fn prop_perfmodel_slowdown_at_least_one() {
    let mut rng = Rng::seed_from_u64(606);
    for case in 0..60 {
        let scenario = [Scenario::Cm, Scenario::CmGTg, Scenario::VolcanoNative]
            [rng.range_usize(0, 3)];
        let n_jobs = rng.range_usize(1, 9);
        let seed = rng.next_u64();
        let trace = uniform_trace(n_jobs, 1.0, seed);
        // Build a running cluster snapshot by driving a simulation's first
        // scheduling cycle manually.
        let mut sim_api = kube_fgs::apiserver::ApiServer::new(
            ClusterSpec::paper(),
            scenario.kubelet(),
        );
        let controller = scenario.controller();
        let info = SystemInfo::homogeneous(4);
        for spec in &trace {
            let planned = plan(spec, scenario.policy(), info);
            let (pods, hostfile) = controller.build(&planned, &mut sim_api);
            sim_api.create_job(planned, pods, hostfile, 0.0);
        }
        let mut sched = kube_fgs::scheduler::Scheduler::new(scenario.scheduler(seed));
        let started = sched.cycle(&mut sim_api, 0.0);
        let calib = Calibration::default();
        for job in started {
            let s = job_slowdown(&sim_api, job, &calib, 1.0);
            assert!(s.total >= 1.0 - 1e-9, "case {case}: slowdown {s:?}");
            assert!(s.compute >= 1.0 - 1e-9, "case {case}");
            assert!(s.comm >= 1.0 - 1e-9, "case {case}");
        }
    }
}

/// Property: a benchmark's running time under CM_G_TG is never worse than
/// under NONE for isolated single-job traces (the paper's core claim in
/// the small).
#[test]
fn prop_fine_grained_never_loses_isolated() {
    for (i, &bench) in ALL_BENCHMARKS.iter().enumerate() {
        let trace = vec![JobSpec::paper_job(1, bench, 0.0)];
        let none = experiments_run(Scenario::None_, &trace, i as u64 + 1);
        let fg = experiments_run(Scenario::CmGTg, &trace, i as u64 + 1);
        let t_none = none.records[0].running();
        let t_fg = fg.records[0].running();
        assert!(
            t_fg <= t_none * 1.001,
            "{bench}: CM_G_TG {t_fg} vs NONE {t_none}"
        );
    }
}

/// Property: Kubelet admission under the affinity config grants
/// single-NUMA cpusets whenever a socket can fit the request.
#[test]
fn prop_best_effort_single_numa_when_possible() {
    let mut rng = Rng::seed_from_u64(707);
    for case in 0..CASES {
        let spec = NodeSpec::paper_worker("w");
        let mut st = CpuManagerState::new(&spec, CpuManagerPolicy::Static, TopologyPolicy::BestEffort);
        loop {
            let want = rng.range_usize(1, 17) as u32;
            let fits_single = (0..2).any(|s| st.free_of_socket(s) >= want as usize);
            match st.allocate(want) {
                Some(a) => {
                    if fits_single {
                        assert!(!a.spans_numa(), "case {case}: spanned despite fit");
                    }
                }
                None => break,
            }
            if st.free_total() == 0 {
                break;
            }
        }
    }
}

/// Property: scheduling on a heterogeneous cluster never places a pod
/// exceeding its node class's capacity, and no node class is ever
/// overcommitted — across random fat/thin/balanced mixes, job shapes
/// (including 32-core single workers that only fit fat nodes), planner
/// policies, and scheduling/finish churn.
#[test]
fn prop_heterogeneous_scheduling_respects_class_capacity() {
    use kube_fgs::cluster::{HeterogeneityMix, PodPhase};
    let mixes = [HeterogeneityMix::FatThin, HeterogeneityMix::Tiered];
    let policies =
        [GranularityPolicy::None, GranularityPolicy::Scale, GranularityPolicy::Granularity];
    let mut rng = Rng::seed_from_u64(1111);
    for case in 0..20u64 {
        let workers = rng.range_usize(2, 12);
        let mix = mixes[rng.range_usize(0, mixes.len())];
        let cluster = ClusterSpec::mixed(workers, mix);
        let mut api = kube_fgs::apiserver::ApiServer::new(
            cluster,
            kube_fgs::kubelet::KubeletConfig::cpu_mem_affinity(),
        );
        let info = SystemInfo::of(&api.spec);
        let n = rng.range_usize(2, 10);
        for i in 1..=n {
            let bench = ALL_BENCHMARKS[rng.range_usize(0, 5)];
            let mut spec = JobSpec::paper_job(i as u64, bench, 0.0);
            spec.ntasks = [4u32, 8, 16, 32][rng.range_usize(0, 4)];
            spec.resources =
                Resources::new(spec.ntasks as u64 * 1000, spec.ntasks as u64 * gib(2));
            let planned = plan(&spec, policies[rng.range_usize(0, 3)], info);
            let (pods, hostfile) = VolcanoMpiController.build(&planned, &mut api);
            api.create_job(planned, pods, hostfile, 0.0);
        }
        let mut sched = kube_fgs::scheduler::Scheduler::new(
            kube_fgs::scheduler::SchedulerConfig::fine_grained(case),
        );
        for step in 0..4 {
            let t = step as f64;
            sched.cycle(&mut api, t);
            // Every bound/running pod fits its node's class capacity, and
            // the per-node sum of bound requests never overcommits.
            let mut used: Vec<Resources> = vec![Resources::ZERO; api.spec.nodes.len()];
            for pod in api.pods.values() {
                if let (Some(node), PodPhase::Bound | PodPhase::Running) =
                    (pod.node, pod.phase)
                {
                    assert!(
                        pod.requests.fits_within(&api.spec.node(node).allocatable()),
                        "case {case} step {step}: pod {:?} wider than node class {:?}",
                        pod.id,
                        api.spec.node(node).name
                    );
                    used[node.0] += pod.requests;
                }
            }
            for node in api.spec.node_ids() {
                assert!(
                    used[node.0].fits_within(&api.spec.node(node).allocatable()),
                    "case {case} step {step}: node {:?} overcommitted",
                    api.spec.node(node).name
                );
            }
            // Free capacity and retry the stragglers next session.
            for id in api.running_jobs().into_iter().take(2) {
                api.finish_job(id, t + 0.5);
            }
        }
    }
}

/// Property: the indexed placement engine is bit-identical to the linear
/// reference scan across cluster shapes (homogeneous + heterogeneity
/// mixes), queue policies, and preemption churn — whole simulations, not
/// just single sessions. (Debug builds additionally assert the feasible
/// set per pod and the index's free view per session.)
#[test]
fn prop_indexed_engine_matches_linear_reference_bitwise() {
    use kube_fgs::cluster::HeterogeneityMix;
    use kube_fgs::scheduler::{PlacementEngineKind, QueuePolicyKind};
    use kube_fgs::workload::two_tenant_trace;
    let queues = [
        QueuePolicyKind::FifoSkip,
        QueuePolicyKind::Sjf,
        QueuePolicyKind::EasyBackfill,
        QueuePolicyKind::ConservativeBackfill,
        QueuePolicyKind::FairShare,
    ];
    for case in 0..8u64 {
        let cluster = || match case % 3 {
            0 => ClusterSpec::paper(),
            1 => ClusterSpec::mixed(6, HeterogeneityMix::FatThin),
            _ => ClusterSpec::mixed(6, HeterogeneityMix::Tiered),
        };
        let queue = queues[case as usize % queues.len()];
        let preempt = case % 2 == 1;
        let mk = |engine: PlacementEngineKind| {
            let mut sim = Scenario::CmGTg.simulation_configured(
                cluster(),
                case,
                queue,
                preempt,
            );
            sim.set_placement_engine(engine);
            sim
        };
        let trace = two_tenant_trace(14, 35.0, case);
        let key = |o: &kube_fgs::simulator::SimOutput| {
            o.records
                .iter()
                .map(|r| (r.id, r.start_time.to_bits(), r.finish_time.to_bits()))
                .collect::<Vec<_>>()
        };
        let linear = mk(PlacementEngineKind::Linear).run(&trace);
        let indexed = mk(PlacementEngineKind::Indexed).run(&trace);
        assert_eq!(key(&linear), key(&indexed), "case {case} ({queue}, preempt={preempt})");
        assert_eq!(linear.unschedulable, indexed.unschedulable, "case {case}");
    }
}

/// Property: the persistent conservative-backfill timeline (event-driven
/// refresh) produces bit-identical simulations to the per-session rebuild
/// reference, across cluster shapes and preemption churn. (Debug builds
/// additionally assert cache == rebuild at every conservative session.)
#[test]
fn prop_persistent_timeline_matches_rebuild_bitwise() {
    use kube_fgs::cluster::HeterogeneityMix;
    use kube_fgs::scheduler::QueuePolicyKind;
    use kube_fgs::workload::two_tenant_trace;
    for case in 0..6u64 {
        let cluster = || match case % 3 {
            0 => ClusterSpec::paper(),
            1 => ClusterSpec::mixed(6, HeterogeneityMix::FatThin),
            _ => ClusterSpec::mixed(6, HeterogeneityMix::Tiered),
        };
        let preempt = case % 2 == 1;
        let mk = |force_rebuild: bool| {
            let mut sim = Scenario::CmGTg.simulation_configured(
                cluster(),
                case,
                QueuePolicyKind::ConservativeBackfill,
                preempt,
            );
            sim.set_force_timeline_rebuild(force_rebuild);
            sim
        };
        let trace = two_tenant_trace(14, 30.0, case);
        let key = |o: &kube_fgs::simulator::SimOutput| {
            o.records
                .iter()
                .map(|r| (r.id, r.start_time.to_bits(), r.finish_time.to_bits()))
                .collect::<Vec<_>>()
        };
        let persistent = mk(false).run(&trace);
        let rebuilt = mk(true).run(&trace);
        assert_eq!(key(&persistent), key(&rebuilt), "case {case} (preempt={preempt})");
        assert_eq!(persistent.unschedulable, rebuilt.unschedulable, "case {case}");
    }
}

/// Property: the action/plugin pipeline is bit-identical to the pinned
/// legacy scheduler cycle — same event-trace digest — and conserves its
/// bookkeeping (every job accounted for, no pod left bound, all node
/// resources returned, tenant ledgers equal to the now-empty running
/// set), across 200 fuzzed (scenario, engine, cluster mix, trace shape,
/// seed) tuples.
#[test]
fn prop_pipeline_differential_fuzz() {
    use kube_fgs::cluster::{HeterogeneityMix, PodPhase};
    use kube_fgs::scenario::ALL_SCENARIOS;
    use kube_fgs::scheduler::PlacementEngineKind;
    use kube_fgs::simulator::SimDigest;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1212);
    for case in 0..200 {
        let scenario = ALL_SCENARIOS[rng.range_usize(0, ALL_SCENARIOS.len())];
        let engine = if rng.f64() < 0.5 {
            PlacementEngineKind::Linear
        } else {
            PlacementEngineKind::Indexed
        };
        let workers = rng.range_usize(2, 9);
        let mix = rng.range_usize(0, 3);
        let cluster = || match mix {
            0 => ClusterSpec::with_workers(workers),
            1 => ClusterSpec::mixed(workers, HeterogeneityMix::FatThin),
            _ => ClusterSpec::mixed(workers, HeterogeneityMix::Tiered),
        };
        let n_jobs = rng.range_usize(3, 10);
        let interval = rng.range_f64(15.0, 90.0);
        let seed = rng.next_u64();
        let trace = if rng.f64() < 0.5 {
            uniform_trace(n_jobs, interval, seed)
        } else {
            two_tenant_trace(n_jobs, interval, seed)
        };
        let mk = |force_legacy: bool| {
            let mut sim = scenario.simulation_on(cluster(), seed);
            sim.set_placement_engine(engine);
            sim.set_force_legacy_scheduler(force_legacy);
            sim.run(&trace)
        };
        let pipeline = mk(false);
        let legacy = mk(true);
        assert_eq!(
            SimDigest::of(&pipeline),
            SimDigest::of(&legacy),
            "case {case}: {scenario} {engine:?} mix {mix} x{workers} seed {seed} diverged"
        );
        // Bookkeeping conservation on the pipeline path.
        assert_eq!(
            pipeline.records.len() + pipeline.unschedulable.len(),
            n_jobs,
            "case {case}: job leaked"
        );
        for n in pipeline.api.spec.node_ids() {
            assert_eq!(
                pipeline.api.free_on(n),
                pipeline.api.spec.node(n).allocatable(),
                "case {case}: leaked resources"
            );
        }
        for pod in pipeline.api.pods.values() {
            assert!(
                !matches!(pod.phase, PodPhase::Bound | PodPhase::Running),
                "case {case}: pod {:?} leaked in {:?}",
                pod.id,
                pod.phase
            );
        }
        // Tenant ledgers must sum to the running set, which is empty.
        let tenants: std::collections::BTreeSet<_> =
            pipeline.records.iter().map(|r| r.tenant).collect();
        for t in tenants {
            assert_eq!(
                pipeline.api.tenant_running_requests(t),
                Resources::ZERO,
                "case {case}: tenant {t:?} ledger out of balance"
            );
        }
    }
}

/// Property: elastic resize churn conserves the bookkeeping — across
/// random elastic traces under every elasticity mode (rigid baseline,
/// moldable, malleable), the mold/shrink/expand churn returns every node
/// to full allocatable capacity, leaks no Bound/Running pod, keeps the
/// tenant ledgers exact, and reports truthful per-job metrics (start >=
/// submit, finish > start, response = wait + running, service time
/// positive).
#[test]
fn prop_elastic_resize_churn_conserves_bookkeeping() {
    use kube_fgs::cluster::PodPhase;
    use kube_fgs::scenario::ELASTIC_SCENARIOS;
    use kube_fgs::workload::elastic_trace;
    let mut rng = Rng::seed_from_u64(1313);
    for case in 0..12 {
        let n_jobs = rng.range_usize(6, 24);
        let interval = rng.range_f64(15.0, 60.0);
        let seed = rng.next_u64();
        let trace = elastic_trace(n_jobs, interval, seed);
        for scenario in ELASTIC_SCENARIOS {
            let out = experiments_run(scenario, &trace, seed);
            assert_eq!(
                out.records.len() + out.unschedulable.len(),
                n_jobs,
                "case {case} {scenario}: job leaked"
            );
            let mut seen = std::collections::BTreeSet::new();
            for r in &out.records {
                assert!(seen.insert(r.id), "case {case} {scenario}: duplicate record");
                assert!(r.start_time >= r.submit_time - 1e-9, "case {case} {scenario}");
                assert!(r.finish_time > r.start_time, "case {case} {scenario}");
                assert!(r.running() > 0.0, "case {case} {scenario}: empty service");
                assert!(
                    (r.response() - (r.wait() + r.running())).abs() < 1e-9,
                    "case {case} {scenario}: response != wait + running"
                );
            }
            for n in out.api.spec.node_ids() {
                assert_eq!(
                    out.api.free_on(n),
                    out.api.spec.node(n).allocatable(),
                    "case {case} {scenario}: node {n:?} leaked resources after resize churn"
                );
            }
            for pod in out.api.pods.values() {
                assert!(
                    !matches!(pod.phase, PodPhase::Bound | PodPhase::Running),
                    "case {case} {scenario}: pod {:?} leaked in {:?}",
                    pod.id,
                    pod.phase
                );
            }
            // Tenant ledgers must sum to the running set, which is empty.
            let tenants: std::collections::BTreeSet<_> =
                out.records.iter().map(|r| r.tenant).collect();
            for t in tenants {
                assert_eq!(
                    out.api.tenant_running_requests(t),
                    Resources::ZERO,
                    "case {case} {scenario}: tenant {t:?} ledger out of balance"
                );
            }
        }
    }
}

/// Property: per-benchmark base work overrides scale running times
/// proportionally for isolated jobs.
#[test]
fn prop_base_work_scales_runtime() {
    let trace = vec![JobSpec::paper_job(1, Benchmark::EpDgemm, 0.0)];
    let mut bw = std::collections::BTreeMap::new();
    bw.insert(Benchmark::EpDgemm, 100.0);
    let out100 = kube_fgs::experiments::run_scenario(Scenario::CmGTg, &trace, 1, Some(&bw));
    bw.insert(Benchmark::EpDgemm, 200.0);
    let out200 = kube_fgs::experiments::run_scenario(Scenario::CmGTg, &trace, 1, Some(&bw));
    let r = out200.records[0].running() / out100.records[0].running();
    assert!((r - 2.0).abs() < 1e-6, "ratio {r}");
}

/// Property: the segment-tree `earliest_fit` is bit-identical to the
/// retained linear scan over whole simulations — same event-trace digest
/// with `linear_earliest_fit(true)` forced as with the tree (the
/// default) — across fuzzed (queue policy, cluster mix, trace shape,
/// seed) tuples. Backfill queues exercise the hole-finding path hardest,
/// so they get half the draws.
#[test]
fn prop_segment_tree_earliest_fit_matches_linear() {
    use kube_fgs::cluster::HeterogeneityMix;
    use kube_fgs::experiments::RunSpec;
    use kube_fgs::scheduler::{QueuePolicyKind, ALL_QUEUE_POLICIES};
    use kube_fgs::simulator::SimDigest;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1414);
    for case in 0..60 {
        let queue = if rng.f64() < 0.5 {
            if rng.f64() < 0.5 {
                QueuePolicyKind::ConservativeBackfill
            } else {
                QueuePolicyKind::EasyBackfill
            }
        } else {
            ALL_QUEUE_POLICIES[rng.range_usize(0, ALL_QUEUE_POLICIES.len())]
        };
        let workers = rng.range_usize(2, 9);
        let mix = rng.range_usize(0, 3);
        let cluster = match mix {
            0 => ClusterSpec::with_workers(workers),
            1 => ClusterSpec::mixed(workers, HeterogeneityMix::FatThin),
            _ => ClusterSpec::mixed(workers, HeterogeneityMix::Tiered),
        };
        let n_jobs = rng.range_usize(4, 13);
        let interval = rng.range_f64(15.0, 60.0);
        let seed = rng.next_u64();
        let trace = if rng.f64() < 0.5 {
            uniform_trace(n_jobs, interval, seed)
        } else {
            two_tenant_trace(n_jobs, interval, seed)
        };
        let mk = |linear: bool| {
            RunSpec::new(Scenario::CmGTg)
                .seed(seed)
                .cluster(cluster.clone())
                .queue(queue)
                .linear_earliest_fit(linear)
                .run(&trace)
                .single()
        };
        let tree = mk(false);
        let linear = mk(true);
        assert_eq!(
            SimDigest::of(&tree),
            SimDigest::of(&linear),
            "case {case}: {queue:?} mix {mix} x{workers} seed {seed}: segment tree diverged from linear scan"
        );
    }
}

/// Property: on shard-invariant configs — uniform clusters, whose single
/// worker capacity class can never be split across domains — requesting
/// any shard count is bit-identical to `shards = 1`: same digest, same
/// merged metrics to the last f64 bit.
#[test]
fn prop_sharded_digest_matches_unsharded_on_uniform() {
    use kube_fgs::experiments::RunSpec;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1515);
    for case in 0..40 {
        let workers = rng.range_usize(2, 13);
        let shards = rng.range_usize(2, 9);
        let n_jobs = rng.range_usize(4, 16);
        let interval = rng.range_f64(15.0, 60.0);
        let seed = rng.next_u64();
        let trace = if rng.f64() < 0.5 {
            uniform_trace(n_jobs, interval, seed)
        } else {
            two_tenant_trace(n_jobs, interval, seed)
        };
        let mk = |shards: usize| {
            RunSpec::new(Scenario::CmGTg)
                .seed(seed)
                .cluster(ClusterSpec::with_workers(workers))
                .shards(shards)
                .run(&trace)
        };
        let one = mk(1);
        let many = mk(shards);
        assert!(
            !many.is_sharded(),
            "case {case}: uniform cluster must collapse to a single domain"
        );
        assert_eq!(
            one.digests(),
            many.digests(),
            "case {case}: x{workers} shards {shards} seed {seed} diverged"
        );
        assert_eq!(
            one.overall_response().to_bits(),
            many.overall_response().to_bits(),
            "case {case}: overall response drifted"
        );
        assert_eq!(
            one.makespan().to_bits(),
            many.makespan().to_bits(),
            "case {case}: makespan drifted"
        );
    }
}

/// Property: a sharded run's result is a pure function of (spec, seed) —
/// independent of the worker thread count. The dispatcher assigns jobs
/// before any thread starts and each domain owns a fixed RNG stream, so
/// threads 1, 2, and 8 must produce identical per-shard digest vectors
/// and the same combined digest.
#[test]
fn prop_sharded_thread_count_invariance() {
    use kube_fgs::cluster::HeterogeneityMix;
    use kube_fgs::experiments::RunSpec;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1616);
    for case in 0..20 {
        let workers = rng.range_usize(4, 13);
        let mix = if rng.f64() < 0.5 {
            HeterogeneityMix::FatThin
        } else {
            HeterogeneityMix::Tiered
        };
        let shards = rng.range_usize(2, 5);
        let n_jobs = rng.range_usize(6, 20);
        let interval = rng.range_f64(15.0, 60.0);
        let seed = rng.next_u64();
        let trace = two_tenant_trace(n_jobs, interval, seed);
        let mk = |threads: usize| {
            RunSpec::new(Scenario::CmGTg)
                .seed(seed)
                .cluster(ClusterSpec::mixed(workers, mix))
                .shards(shards)
                .threads(threads)
                .run(&trace)
        };
        let t1 = mk(1);
        assert!(t1.is_sharded(), "case {case}: {mix:?} x{workers} must shard");
        for threads in [2usize, 8] {
            let tn = mk(threads);
            assert_eq!(
                t1.digests(),
                tn.digests(),
                "case {case}: {mix:?} x{workers} shards {shards} seed {seed}: \
                 {threads} threads diverged from 1"
            );
            assert_eq!(
                t1.combined_digest(),
                tn.combined_digest(),
                "case {case}: combined digest drifted at {threads} threads"
            );
        }
    }
}

/// Property: the open-loop serve trace is shard-invariant on the paper
/// cluster — replaying the production mix at nominal traffic through
/// `RunSpec` with `shards = 4` is bit-identical to `shards = 1` (the
/// homogeneous cluster collapses to a single domain), so the serving
/// sweep composes with the scale-out axis without perturbing results.
/// The fixed-trace paths (goldens, differential matrix, fuzz) never see
/// the generator; this pins the generated path to the same guarantee.
#[test]
fn prop_serve_trace_shard_invariant_at_nominal_traffic() {
    use kube_fgs::experiments::RunSpec;
    use kube_fgs::workload::serve_trace;

    let trace = serve_trace(2.0 * 3600.0, 1.0, 2024);
    assert!(!trace.is_empty(), "a 2 h serve horizon produces jobs");
    let mk = |shards: usize| {
        RunSpec::new(Scenario::CmGTg)
            .seed(2024)
            .cluster(ClusterSpec::paper())
            .shards(shards)
            .run(&trace)
    };
    let one = mk(1);
    let four = mk(4);
    assert!(!four.is_sharded(), "the paper cluster must collapse to one domain");
    assert_eq!(one.digests(), four.digests(), "serve trace diverged across shard counts");
    assert_eq!(
        one.combined_digest(),
        four.combined_digest(),
        "combined digest drifted for the serve trace"
    );
    assert_eq!(
        one.overall_response().to_bits(),
        four.overall_response().to_bits(),
        "overall response drifted for the serve trace"
    );
}

/// Property: sharded runs are deterministic — the same `RunSpec` run
/// twice yields identical per-shard digests and an identically merged
/// record stream (every job exactly once, ids strictly ascending).
#[test]
fn prop_sharded_run_is_deterministic_and_merges_completely() {
    use kube_fgs::cluster::HeterogeneityMix;
    use kube_fgs::experiments::RunSpec;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1717);
    for case in 0..20 {
        let workers = rng.range_usize(4, 13);
        let shards = rng.range_usize(2, 5);
        let n_jobs = rng.range_usize(6, 20);
        let interval = rng.range_f64(15.0, 60.0);
        let seed = rng.next_u64();
        let trace = two_tenant_trace(n_jobs, interval, seed);
        let mk = || {
            RunSpec::new(Scenario::CmGTg)
                .seed(seed)
                .cluster(ClusterSpec::mixed(workers, HeterogeneityMix::Tiered))
                .shards(shards)
                .run(&trace)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.digests(), b.digests(), "case {case}: rerun diverged (seed {seed})");
        let records = a.records();
        let unschedulable = a.unschedulable();
        assert_eq!(
            records.len() + unschedulable.len(),
            n_jobs,
            "case {case}: merged output lost a job"
        );
        for w in records.windows(2) {
            assert!(
                w[0].id < w[1].id,
                "case {case}: merged records not strictly ascending by id"
            );
        }
    }
}

/// Property: the epoch-based progress ledger is a faithful replacement
/// for the retired per-event stepped clock. Across scenarios, cluster
/// mixes, traces, and seeds, both clocks complete the same job set,
/// mark the same jobs unschedulable, and agree on every start/finish
/// time to within 1e-6 s (the clocks round differently — the stepped
/// path decrements remaining work per event while the epoch ledger
/// evaluates the closed form — so bit-identity is not the contract;
/// bounded divergence is).
#[test]
fn prop_epoch_clock_matches_stepped_reference_within_tolerance() {
    use kube_fgs::cluster::HeterogeneityMix;
    use kube_fgs::scenario::ALL_SCENARIOS;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1818);
    for case in 0..60 {
        let scenario = ALL_SCENARIOS[rng.range_usize(0, ALL_SCENARIOS.len())];
        let workers = rng.range_usize(2, 9);
        let mix = rng.range_usize(0, 3);
        let cluster = || match mix {
            0 => ClusterSpec::with_workers(workers),
            1 => ClusterSpec::mixed(workers, HeterogeneityMix::FatThin),
            _ => ClusterSpec::mixed(workers, HeterogeneityMix::Tiered),
        };
        let n_jobs = rng.range_usize(3, 12);
        let interval = rng.range_f64(15.0, 90.0);
        let seed = rng.next_u64();
        let trace = if rng.f64() < 0.5 {
            uniform_trace(n_jobs, interval, seed)
        } else {
            two_tenant_trace(n_jobs, interval, seed)
        };
        let mk = |stepped: bool| {
            let mut sim = scenario.simulation_on(cluster(), seed);
            sim.set_force_stepped_clock(stepped);
            sim.run(&trace)
        };
        let epoch = mk(false);
        let stepped = mk(true);
        assert_eq!(
            epoch.unschedulable, stepped.unschedulable,
            "case {case}: {scenario} mix {mix} x{workers} seed {seed}: unschedulable sets differ"
        );
        assert_eq!(
            epoch.records.len(),
            stepped.records.len(),
            "case {case}: {scenario} mix {mix} x{workers} seed {seed}: record counts differ"
        );
        let by_id: std::collections::BTreeMap<_, _> = stepped
            .records
            .iter()
            .map(|r| (r.id, (r.start_time, r.finish_time)))
            .collect();
        for r in &epoch.records {
            let (s, f) = by_id[&r.id];
            assert!(
                (r.start_time - s).abs() < 1e-6 && (r.finish_time - f).abs() < 1e-6,
                "case {case}: {scenario} mix {mix} x{workers} seed {seed}: job {:?} \
                 diverged beyond tolerance (start {} vs {}, finish {} vs {})",
                r.id,
                r.start_time,
                s,
                r.finish_time,
                f
            );
        }
        assert!(
            epoch.core_stats.events > 0,
            "case {case}: epoch clock counted no events"
        );
        assert_eq!(
            stepped.core_stats.resyncs, 0,
            "case {case}: stepped clock must never resync the ledger"
        );
    }
}

/// Property: the pipeline-vs-legacy bit-identity guarantee survives on
/// the pinned stepped clock — forcing `force_stepped_clock` on both
/// sides of the differential reproduces the exact digests the retired
/// clock produced, so the reference path stays verifiable verbatim.
#[test]
fn prop_stepped_clock_pipeline_matches_legacy_bitwise() {
    use kube_fgs::scenario::ALL_SCENARIOS;
    use kube_fgs::simulator::SimDigest;
    use kube_fgs::workload::two_tenant_trace;

    let mut rng = Rng::seed_from_u64(1919);
    for case in 0..40 {
        let scenario = ALL_SCENARIOS[rng.range_usize(0, ALL_SCENARIOS.len())];
        let workers = rng.range_usize(2, 9);
        let n_jobs = rng.range_usize(3, 10);
        let interval = rng.range_f64(15.0, 90.0);
        let seed = rng.next_u64();
        let trace = if rng.f64() < 0.5 {
            uniform_trace(n_jobs, interval, seed)
        } else {
            two_tenant_trace(n_jobs, interval, seed)
        };
        let mk = |force_legacy: bool| {
            let mut sim = scenario.simulation_on(ClusterSpec::with_workers(workers), seed);
            sim.set_force_stepped_clock(true);
            sim.set_force_legacy_scheduler(force_legacy);
            sim.run(&trace)
        };
        let pipeline = mk(false);
        let legacy = mk(true);
        assert_eq!(
            SimDigest::of(&pipeline),
            SimDigest::of(&legacy),
            "case {case}: {scenario} x{workers} seed {seed}: stepped-clock differential diverged"
        );
    }
}

/// Property: the serve-trace shard invariance holds on the pinned
/// stepped clock too — the clock swap is orthogonal to the scale-out
/// axis, so `shards = 4` stays bit-identical to `shards = 1` whichever
/// clock drives the run.
#[test]
fn prop_serve_trace_shard_invariant_on_stepped_clock() {
    use kube_fgs::experiments::RunSpec;
    use kube_fgs::workload::serve_trace;

    let trace = serve_trace(2.0 * 3600.0, 1.0, 2024);
    assert!(!trace.is_empty(), "a 2 h serve horizon produces jobs");
    let mk = |shards: usize| {
        RunSpec::new(Scenario::CmGTg)
            .seed(2024)
            .cluster(ClusterSpec::paper())
            .shards(shards)
            .stepped_clock(true)
            .run(&trace)
    };
    let one = mk(1);
    let four = mk(4);
    assert_eq!(
        one.digests(),
        four.digests(),
        "stepped-clock serve trace diverged across shard counts"
    );
    assert_eq!(
        one.combined_digest(),
        four.combined_digest(),
        "stepped-clock combined digest drifted for the serve trace"
    );
}
