//! Future-work demo (paper §VI): scheduling mixed HPC-AI workloads plus
//! I/O-profile applications with the fine-grained policies.
//!
//! Uses the extended catalogue (workload::extensions): AI-training jobs
//! split like CPU-intensive HPC jobs; IOR-like jobs map to the network/I-O
//! profile and stay coarse-grained.
//!
//! Run: cargo run --release --example mixed_hpc_ai

use kube_fgs::experiments::RunSpec;
use kube_fgs::metrics::ExperimentMetrics;
use kube_fgs::report;
use kube_fgs::scenario::Scenario;
use kube_fgs::workload::mixed_hpc_ai_trace;

fn main() {
    let trace = mixed_hpc_ai_trace(3, 400.0);
    println!("mixed HPC-AI trace: {} jobs (3 waves of DGEMM / AI-training / STREAM / IOR)\n", trace.len());

    let mut rows = Vec::new();
    for scenario in [Scenario::None_, Scenario::Cm, Scenario::CmSTg, Scenario::CmGTg] {
        let out = RunSpec::new(scenario).seed(11).run(&trace).single();
        let m = ExperimentMetrics::from(&out);
        rows.push(vec![
            scenario.name().to_string(),
            format!("{:.0}", m.overall_response),
            format!("{:.0}", m.makespan),
            format!("{:.1}", m.avg_wait),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["scenario", "overall response (s)", "makespan (s)", "avg wait (s)"],
            &rows
        )
    );

    let cm: f64 = rows[1][1].parse().unwrap();
    let fg: f64 = rows[3][1].parse().unwrap();
    println!(
        "\nfine-grained scheduling carries over to the mixed HPC-AI workload: \
         CM_G_TG improves overall response by {:.0}% vs CM",
        (1.0 - fg / cm) * 100.0
    );
}
