//! Future-work demo (paper §VI): scheduling mixed HPC-AI workloads plus
//! I/O-profile applications with the fine-grained policies.
//!
//! Part 1 uses the extended catalogue (workload::extensions): AI-training
//! jobs split like CPU-intensive HPC jobs; IOR-like jobs map to the
//! network/I-O profile and stay coarse-grained.
//!
//! Part 2 grounds the mix in the open-loop production-traffic generator
//! (workload::arrivals): diurnal HPC gangs, bursty MMPP AI-inference jobs,
//! and steady microservices arrive over a six-hour horizon, and the
//! policies are compared on tail latency and per-class SLO violations.
//!
//! Run: cargo run --release --example mixed_hpc_ai

use kube_fgs::experiments::RunSpec;
use kube_fgs::metrics::{ExperimentMetrics, SloReport};
use kube_fgs::report;
use kube_fgs::scenario::Scenario;
use kube_fgs::workload::{mixed_hpc_ai_trace, serve_trace, ALL_SERVE_CLASSES};

const SCENARIOS: [Scenario; 4] =
    [Scenario::None_, Scenario::Cm, Scenario::CmSTg, Scenario::CmGTg];

fn main() {
    let trace = mixed_hpc_ai_trace(3, 400.0);
    println!(
        "mixed HPC-AI trace: {} jobs (3 waves of DGEMM / AI-training / STREAM / IOR)\n",
        trace.len()
    );

    let mut rows = Vec::new();
    for scenario in SCENARIOS {
        let out = RunSpec::new(scenario).seed(11).run(&trace).single();
        let m = ExperimentMetrics::from(&out);
        rows.push(vec![
            scenario.name().to_string(),
            format!("{:.0}", m.overall_response),
            format!("{:.0}", m.makespan),
            format!("{:.1}", m.avg_wait),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["scenario", "overall response (s)", "makespan (s)", "avg wait (s)"],
            &rows
        )
    );

    let cm: f64 = rows[1][1].parse().unwrap();
    let fg: f64 = rows[3][1].parse().unwrap();
    println!(
        "\nfine-grained scheduling carries over to the mixed HPC-AI workload: \
         CM_G_TG improves overall response by {:.0}% vs CM",
        (1.0 - fg / cm) * 100.0
    );

    // Part 2: the same HPC + AI + microservice blend, but arriving through
    // the open-loop production-traffic generator at 2x nominal load.
    let serve = serve_trace(6.0 * 3600.0, 2.0, 11);
    println!(
        "\nproduction serving mix: {} jobs over 6 h at 2x nominal traffic \
         ({} tenant classes)\n",
        serve.len(),
        ALL_SERVE_CLASSES.len()
    );
    let mut slo_rows = Vec::new();
    for scenario in SCENARIOS {
        let out = RunSpec::new(scenario).seed(11).run(&serve).single();
        let slo = SloReport::from_records(&out.records);
        slo_rows.push(vec![
            scenario.name().to_string(),
            format!("{:.0}", slo.overall.p50),
            format!("{:.0}", slo.overall.p95),
            format!("{:.0}", slo.overall.p99),
            slo.violations.to_string(),
            format!("{:.1}", slo.violation_fraction() * 100.0),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["scenario", "p50 (s)", "p95 (s)", "p99 (s)", "SLO viol", "viol %"],
            &slo_rows
        )
    );
    let cm_viol: usize = slo_rows[1][4].parse().unwrap();
    let fg_viol: usize = slo_rows[3][4].parse().unwrap();
    println!(
        "\nunder open-loop production traffic, CM_G_TG violates {fg_viol} SLOs \
         vs CM's {cm_viol}"
    );
}
