//! Experiment 2 end-to-end: 20 mixed MPI jobs (5 benchmarks × 4) submitted
//! in a random sequence over [0, 1200] s, run under all six Table-II
//! scenarios. Reproduces Figs. 6–7.
//!
//! Run: cargo run --release --example mixed_workloads [-- <seed>]

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::report;
use kube_fgs::workload::exp2_trace;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    println!("Experiment 2 — 20 mixed jobs, seed {seed}\n");

    let trace = exp2_trace(seed);
    println!("trace:");
    for j in &trace {
        println!("  t={:>6.1}s  {}", j.submit_time, j.name);
    }

    let results = experiments::exp2_all_scenarios(seed);
    println!("\nFig. 6 — per-benchmark avg running time + overall response:");
    print!("{}", experiments::fig6_table(&results));
    println!("\nFig. 7 — makespan:");
    print!("{}", experiments::fig7_table(&results));

    // The scheduling-process panels of Fig. 7 for the two extremes.
    for name in ["CM", "CM_G_TG"] {
        let scenario = kube_fgs::scenario::Scenario::parse(name).unwrap();
        let out = experiments::RunSpec::new(scenario).seed(seed).run(&trace).single();
        println!("\nFig. 7 — scheduling process, {name}:");
        print!("{}", report::gantt(&out, 90));
    }
}
