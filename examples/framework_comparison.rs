//! Experiment 3: compare the fine-grained scheduler against the Kubeflow
//! MPI operator and native Volcano on the Experiment-2 workload.
//! Reproduces Table III and Figs. 8–9.
//!
//! Run: cargo run --release --example framework_comparison [-- <seed>]

use kube_fgs::experiments::{self, DEFAULT_SEED};
use kube_fgs::simulator::JobRecord;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    println!("Experiment 3 — frameworks (seed {seed})\n");

    let results = experiments::exp3_all_scenarios(seed);

    println!("Table III — makespan comparison:");
    print!("{}", experiments::table3(&results));

    println!();
    print!(
        "{}",
        experiments::per_job_table(&results, JobRecord::running, "Fig. 8 — job running time (s):")
    );
    println!();
    print!(
        "{}",
        experiments::per_job_table(&results, JobRecord::response, "Fig. 9 — job response time (s):")
    );

    // The paper's §V-E observations, checked programmatically:
    let get = |name: &str| results.iter().find(|(s, _)| s.name() == name).unwrap();
    let (_, kubeflow) = get("Kubeflow");
    let (_, volcano) = get("Volcano");
    let (_, cm) = get("CM");
    let (_, cm_g_tg) = get("CM_G_TG");
    println!("\nchecks:");
    println!(
        "  Kubeflow ~= CM makespan:        {:>8.0} vs {:>8.0}  ({:+.1}%)",
        kubeflow.makespan,
        cm.makespan,
        (kubeflow.makespan / cm.makespan - 1.0) * 100.0
    );
    println!(
        "  Volcano slowdown vs CM:         {:>8.1}x   (paper: ~48.7x)",
        volcano.makespan / cm.makespan
    );
    println!(
        "  CM_G_TG best makespan:          {:>8.0} s  (improves CM by {:.0}%)",
        cm_g_tg.makespan,
        (1.0 - cm_g_tg.makespan / cm.makespan) * 100.0
    );
}
