//! Quickstart: build the paper's five-node cluster, submit one MiniFE job
//! under the fine-grained CM_G_TG scenario, and walk through what each
//! layer decided (planner granularity -> MPI-aware controller pods ->
//! task-group placement -> kubelet cpusets -> simulated runtime).
//!
//! Run: cargo run --release --example quickstart

use kube_fgs::metrics::ExperimentMetrics;
use kube_fgs::report;
use kube_fgs::scenario::Scenario;
use kube_fgs::workload::{Benchmark, JobSpec};

fn main() {
    let scenario = Scenario::CmGTg;
    println!("scenario: {scenario} (cpu/memory affinity + 'granularity' planner + task-group scheduling)\n");

    // One MiniFE job, 16 MPI tasks, submitted at t=0.
    let job = JobSpec::paper_job(1, Benchmark::MiniFe, 0.0);
    println!(
        "job: {} — {} tasks, {} total, profile {}",
        job.name,
        job.ntasks,
        job.resources,
        job.benchmark.profile().as_str()
    );

    // What the planner agent (Algorithm 1) decides:
    let planned = kube_fgs::planner::plan(
        &job,
        scenario.policy(),
        kube_fgs::planner::SystemInfo::homogeneous(4),
    );
    println!(
        "planner (Algorithm 1): N_n={} nodes, N_w={} workers, N_g={} groups",
        planned.granularity.n_nodes, planned.granularity.n_workers, planned.granularity.n_groups
    );

    // Run the full stack (RunSpec is the one run API; `.single()`
    // unwraps the sole scheduler domain of an unsharded run).
    let out = kube_fgs::experiments::RunSpec::new(scenario).seed(7).run(&[job]).single();

    // What the MPI-aware controller (Algorithm 2) + task-group plugin
    // (Algorithms 3-4) + kubelet produced:
    println!("\npods (controller Algorithm 2 + scheduler Algorithms 3-4 + kubelet):");
    for pod in out.api.pods.values() {
        let node = pod.node.map(|n| out.api.spec.nodes[n.0].name.clone()).unwrap_or_default();
        let cpuset = pod
            .cpuset
            .as_ref()
            .map(|c| format!("cpuset {c}"))
            .unwrap_or_else(|| "shared pool".into());
        println!(
            "  {:<22} node {:<7} tasks {}  group {:?}  {}{}",
            pod.name,
            node,
            pod.ntasks,
            pod.group,
            cpuset,
            if pod.spans_numa { "  [spans NUMA]" } else { "" }
        );
    }

    println!("\nhostfile:");
    for line in &out.api.jobs.values().next().unwrap().hostfile {
        println!("  {line}");
    }

    let m = ExperimentMetrics::from(&out);
    println!();
    print!("{}", report::scenario_summary(scenario.name(), &m));
    println!("\ntimeline:");
    print!("{}", report::gantt(&out, 80));
}
