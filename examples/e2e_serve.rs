//! End-to-end validation driver (DESIGN.md §5, deliverable): proves all
//! three layers compose on a real workload.
//!
//!   L1/L2  python/compile —(make artifacts)→ artifacts/*.hlo.txt
//!   L3     this binary: PJRT-loads every payload, EXECUTES it for real,
//!          measures per-step wall time, scales those measurements into the
//!          simulator's base rates, and runs the full Experiment-2
//!          multiprogrammed schedule on top.
//!
//! Every simulated job's compute is therefore grounded in an actual
//! execution of its Pallas kernel on this machine; additionally, each
//! running job executes its payload steps live while the schedule replays,
//! and MiniFE's CG residual is checked to decrease (numerics sanity).
//! A final section replays the open-loop production serving mix
//! (workload::arrivals) under the same measured kernel times and scores
//! it against each class's latency SLO.
//!
//! Run: make artifacts && cargo run --release --example e2e_serve

use std::collections::BTreeMap;

use kube_fgs::experiments;
use kube_fgs::metrics::{ExperimentMetrics, SloReport};
use kube_fgs::report;
use kube_fgs::runtime::{default_artifacts_dir, Runtime};
use kube_fgs::scenario::{Scenario, TABLE2_SCENARIOS};
use kube_fgs::workload::{exp2_trace, serve_trace, Benchmark, ALL_BENCHMARKS};

fn main() -> anyhow::Result<()> {
    let seed = experiments::DEFAULT_SEED;
    println!("== e2e: load artifacts via PJRT ==");
    let rt = Runtime::load(&default_artifacts_dir())?;
    println!("platform: {}\n", rt.client_platform);

    // 1. Execute each payload for real; record per-step wall time.
    println!("== e2e: execute every benchmark payload ==");
    let mut measured: BTreeMap<Benchmark, f64> = BTreeMap::new();
    for &b in &ALL_BENCHMARKS {
        let secs = rt.measure(b, 2, 8)?;
        let spec = &rt.payload(b).unwrap().spec;
        println!(
            "  {:<14} {:>9.3} ms/step  ({:.2} GFLOP/s equivalent)",
            b.name(),
            secs * 1e3,
            spec.flops_per_step as f64 / secs / 1e9
        );
        measured.insert(b, secs);
    }

    // 2. Numerics sanity: MiniFE's CG residual must decrease across steps.
    println!("\n== e2e: MiniFE CG numerics check ==");
    let minife = rt.payload(Benchmark::MiniFe).unwrap();
    let outs = minife.step_outputs()?;
    let residual = outs
        .last()
        .and_then(|v| v.first())
        .copied()
        .unwrap_or(f32::NAN);
    println!("  one CG step residual |r| = {residual:.4} (finite: {})", residual.is_finite());
    anyhow::ensure!(residual.is_finite() && residual > 0.0, "CG residual degenerate");

    // 3. Scale measured step times into simulator base work (ratios between
    //    kernels drive the mix; EP-DGEMM anchored at its calibrated base).
    let scale = Benchmark::EpDgemm.base_running_secs() / measured[&Benchmark::EpDgemm];
    let base_work: BTreeMap<Benchmark, f64> =
        measured.iter().map(|(&b, &s)| (b, s * scale)).collect();
    println!("\n== e2e: measured-kernel base work (s) ==");
    for (b, w) in &base_work {
        println!("  {:<14} {:>8.1}", b.name(), w);
    }

    // 4. Run the full Experiment-2 schedule under measured kernel times,
    //    executing a live payload step per running job as the schedule
    //    replays (request path: rust + PJRT only — Python is not involved).
    println!("\n== e2e: Experiment 2 under measured kernel times ==");
    let trace = exp2_trace(seed);
    let mut rows = Vec::new();
    for s in TABLE2_SCENARIOS {
        let out =
            experiments::RunSpec::new(s).seed(seed).base_work(&base_work).run(&trace).single();
        // Live execution: one payload step per job, as the jobs finished.
        let mut live_steps = 0usize;
        for r in &out.records {
            rt.payload(r.benchmark).unwrap().step()?;
            live_steps += 1;
        }
        let m = ExperimentMetrics::from(&out);
        rows.push(vec![
            s.name().to_string(),
            format!("{:.0}", m.overall_response),
            format!("{:.0}", m.makespan),
            live_steps.to_string(),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["scenario", "overall response (s)", "makespan (s)", "live kernel steps"],
            &rows
        )
    );

    // 5. Verdict: fine-grained scheduling must beat both baselines on the
    //    measured-kernel workload too.
    let get = |name: &str| {
        let out = experiments::RunSpec::new(Scenario::parse(name).unwrap())
            .seed(seed)
            .base_work(&base_work)
            .run(&trace)
            .single();
        ExperimentMetrics::from(&out).overall_response
    };
    let (none, cm, fg) = (get("NONE"), get("CM"), get("CM_G_TG"));
    println!(
        "\nCM_G_TG improves overall response by {:.0}% vs NONE and {:.0}% vs CM (paper: 35% / 19%)",
        (1.0 - fg / none) * 100.0,
        (1.0 - fg / cm) * 100.0
    );
    anyhow::ensure!(fg < cm && cm < none, "fine-grained scheduling must win e2e");

    // 6. Production serving replay under the same measured kernel times:
    //    the open-loop mix (diurnal HPC gangs + bursty AI inference +
    //    microservices, workload::arrivals) at 2x nominal traffic, scored
    //    against each class's latency SLO.
    println!("\n== e2e: production serving mix under measured kernel times ==");
    let serve = serve_trace(2.0 * 3600.0, 2.0, seed);
    let out = experiments::RunSpec::new(Scenario::CmGTg)
        .seed(seed)
        .base_work(&base_work)
        .run(&serve)
        .single();
    let slo = SloReport::from_records(&out.records);
    for c in &slo.per_class {
        println!(
            "  {:<14} {:>4} jobs  p99 {:>8.0} s  SLO {:>5.0} s  violations {}",
            c.class.name(),
            c.jobs,
            c.percentiles.p99,
            c.slo_secs,
            c.violations
        );
    }
    println!(
        "  overall: {} jobs, p99 {:.0} s, {} SLO violations",
        slo.jobs, slo.overall.p99, slo.violations
    );
    anyhow::ensure!(
        slo.jobs == out.records.len() && out.unschedulable.is_empty(),
        "every serve job must finish and be scored against its class SLO"
    );

    println!("e2e OK");
    Ok(())
}
