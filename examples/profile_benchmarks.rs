//! Fig. 3 — Benchmarks MPI profiling analysis, plus live kernel roofline
//! numbers from the PJRT payloads when artifacts are present.
//!
//! Run: cargo run --release --example profile_benchmarks

use kube_fgs::experiments;
use kube_fgs::report;
use kube_fgs::runtime::{default_artifacts_dir, Runtime};
use kube_fgs::workload::ALL_BENCHMARKS;

fn main() {
    println!("Fig. 3 — Benchmarks MPI profiling analysis\n");
    print!("{}", experiments::fig3_table());

    // Live payload measurements (skipped gracefully without artifacts).
    match Runtime::load(&default_artifacts_dir()) {
        Ok(rt) => {
            println!("\nAOT payload characteristics (PJRT {}):", rt.client_platform);
            let mut rows = Vec::new();
            for &b in &ALL_BENCHMARKS {
                let p = rt.payload(b).unwrap();
                let secs = rt.measure(b, 1, 5).unwrap();
                rows.push(vec![
                    b.name().to_string(),
                    format!("{:.3}", secs * 1e3),
                    format!("{:.2}", p.spec.flops_per_step as f64 / secs / 1e9),
                    format!("{:.2}", p.spec.bytes_per_step as f64 / secs / 1e9),
                    format!(
                        "{:.2}",
                        p.spec.flops_per_step as f64 / p.spec.bytes_per_step as f64
                    ),
                ]);
            }
            print!(
                "{}",
                report::table(
                    &["benchmark", "ms/step", "GFLOP/s", "GB/s", "flops/byte"],
                    &rows
                )
            );
        }
        Err(e) => println!("\n(skipping live payload profile: {e})"),
    }
}
