"""AOT lowering: JAX step functions -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the XLA
the published ``xla`` 0.1.6 rust crate links) rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--only dgemm,stream]

Outputs one ``<name>.hlo.txt`` per benchmark plus ``manifest.json``
describing entry-point shapes/dtypes/profiles for the rust loader.
This is the ONLY Python that must run before the rust binary is
self-contained; ``make artifacts`` skips it when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import SPECS


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(name: str) -> str:
    """Lower one benchmark step function to HLO text."""
    spec = SPECS[name]
    lowered = jax.jit(spec.fn).lower(*spec.args)
    return to_hlo_text(lowered)


def arg_manifest(spec) -> list[dict]:
    return [
        {"shape": list(a.shape), "dtype": a.dtype.name} for a in spec.args
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated benchmark subset")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = [n for n in args.only.split(",") if n] or list(SPECS)

    manifest = {}
    for name in names:
        spec = SPECS[name]
        text = lower_spec(name)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = {
            "hlo": path.name,
            "args": arg_manifest(spec),
            "profile": spec.profile,
            "flops_per_step": spec.flops,
            "bytes_per_step": spec.bytes,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote manifest for {len(manifest)} benchmarks")


if __name__ == "__main__":
    main()
