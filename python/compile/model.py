"""L2: per-benchmark JAX step functions calling the L1 Pallas kernels.

Each of the paper's five MPI benchmarks gets one *step* function — the unit
of compute one simulated job iteration performs.  These are the functions
``aot.py`` lowers to HLO text; the rust runtime (rust/src/runtime) loads the
artifacts and executes steps on the request path (Python never runs there).

Shapes are fixed at lowering time (one compiled executable per benchmark);
the canonical shapes live in ``SPECS`` and are also emitted into
``artifacts/manifest.json`` for the rust side.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import dgemm as dgemm_k
from .kernels import fft as fft_k
from .kernels import ring as ring_k
from .kernels import stencil as stencil_k
from .kernels import stream as stream_k

# ---------------------------------------------------------------------------
# EP-DGEMM: CPU-intensive dense matmul throughput.
# ---------------------------------------------------------------------------

DGEMM_N = 256


def dgemm_step(a: jax.Array, b: jax.Array) -> jax.Array:
    """One EP-DGEMM iteration: C = A @ B via the blocked Pallas kernel."""
    return dgemm_k.dgemm(a, b)


# ---------------------------------------------------------------------------
# EP-STREAM: memory-bandwidth-bound triad.
# ---------------------------------------------------------------------------

STREAM_SHAPE = (64, 4096)  # 256 K fp32 elements per operand, 3 MiB triad traffic


def stream_step(b: jax.Array, c: jax.Array, scalar: jax.Array) -> jax.Array:
    """One EP-STREAM iteration: a = b + s*c via the Pallas triad kernel."""
    return stream_k.triad(b, c, scalar)


# ---------------------------------------------------------------------------
# MiniFE: CG iteration on the 7-point stencil operator (CPU+memory).
# ---------------------------------------------------------------------------

MINIFE_GRID = (32, 32, 32)


def minife_step(
    x: jax.Array, r: jax.Array, p: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One conjugate-gradient iteration for ``A x = b``.

    ``A`` is the Pallas 7-point stencil operator.  Returns the updated
    ``(x, r, p)`` state plus the new residual norm (a scalar the runtime can
    log as the convergence signal).
    """
    ap = stencil_k.stencil_matvec(p)
    rs_old = jnp.vdot(r, r)
    denom = jnp.vdot(p, ap)
    alpha = rs_old / jnp.where(denom == 0, 1.0, denom)
    x = x + alpha * p
    r = r - alpha * ap
    rs_new = jnp.vdot(r, r)
    beta = rs_new / jnp.where(rs_old == 0, 1.0, rs_old)
    p = r + beta * p
    return x, r, p, jnp.sqrt(rs_new)


# ---------------------------------------------------------------------------
# G-RandomRing: network-intensive ring exchange.
# ---------------------------------------------------------------------------

RING_SHAPE = (16, 4096)  # 16 logical ranks, 16 KiB message per rank


def ring_step(buf: jax.Array, perm: jax.Array) -> jax.Array:
    """One random-ring exchange+combine over all ranks."""
    return ring_k.ring_exchange(buf, perm)


# ---------------------------------------------------------------------------
# G-FFT: network-intensive distributed FFT (local butterflies via Pallas).
# ---------------------------------------------------------------------------

FFT_N = 1024


def fft_step(x_re: jax.Array, x_im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full radix-2 DIT FFT of a length-n signal, n a power of two.

    Stockham-style composition: each of the ``log2 n`` stages calls the
    Pallas butterfly kernel on (half, M) operands and interleaves the two
    output halves along the trailing axis — layout work only, so all flops
    run in the kernel.  Matches ``jnp.fft.fft`` (see tests).
    """
    (n,) = x_re.shape
    stages = int(math.log2(n))
    if 1 << stages != n:
        raise ValueError(f"n={n} is not a power of two")
    # Stage s: operands viewed as (half, m) with half = n/2^(s+1) ... we use
    # the recursive DIT split: even/odd decimation done via reshape.
    re = x_re.reshape(1, n)
    im = x_im.reshape(1, n)
    for _ in range(stages):
        rows, cols = re.shape
        half = cols // 2
        # Decimate: evens -> a, odds -> b (per row).
        a_re, b_re = re[:, 0::2], re[:, 1::2]
        a_im, b_im = im[:, 0::2], im[:, 1::2]
        # Recurse by doubling the row count (each row an independent sub-FFT).
        re = jnp.concatenate([a_re, b_re], axis=0)
        im = jnp.concatenate([a_im, b_im], axis=0)
    # Now re/im are (n, 1): single points, already their own FFTs.  Rebuild
    # upward: at each level, combine pairs of sub-FFTs with the butterfly.
    size = 1
    while size < n:
        rows = re.shape[0]
        half_rows = rows // 2
        a_re, b_re = re[:half_rows, :], re[half_rows:, :]
        a_im, b_im = im[:half_rows, :], im[half_rows:, :]
        # Twiddles for combining sub-FFTs of length ``size``: w^k, k < size,
        # broadcast across the rows of each sub-FFT pair.  Operands are
        # (half_rows, size); the butterfly kernel wants per-row twiddles, so
        # we transpose k into the trailing axis: reshape to planar (h*size).
        k = jnp.arange(size, dtype=x_re.dtype)
        ang = -2.0 * jnp.pi * k / (2 * size)
        w_re = jnp.cos(ang)[None, :] * jnp.ones((half_rows, 1), x_re.dtype)
        w_im = jnp.sin(ang)[None, :] * jnp.ones((half_rows, 1), x_re.dtype)
        # Butterfly kernel expects (H, 1) twiddles; flatten (row, k) pairs so
        # each flattened row has a scalar twiddle.
        hh = half_rows * size
        t_re, t_im, u_re, u_im = fft_k.butterfly(
            a_re.reshape(hh, 1),
            a_im.reshape(hh, 1),
            b_re.reshape(hh, 1),
            b_im.reshape(hh, 1),
            w_re.reshape(hh, 1),
            w_im.reshape(hh, 1),
        )
        re = jnp.concatenate(
            [t_re.reshape(half_rows, size), u_re.reshape(half_rows, size)], axis=1
        )
        im = jnp.concatenate(
            [t_im.reshape(half_rows, size), u_im.reshape(half_rows, size)], axis=1
        )
        size *= 2
    return re.reshape(n), im.reshape(n)


# ---------------------------------------------------------------------------
# AOT spec table — consumed by aot.py and mirrored into artifacts/manifest.json
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepSpec:
    """Lowering spec for one benchmark step function."""

    name: str
    fn: Callable
    args: tuple  # jax.ShapeDtypeStruct example args
    profile: str  # paper classification: cpu | memory | network | cpu+memory
    flops: int  # useful flops per step (for perf accounting)
    bytes: int  # HBM traffic per step


f32 = jnp.float32
i32 = jnp.int32


def _sds(shape, dtype=f32):
    return jax.ShapeDtypeStruct(shape, dtype)


SPECS: dict[str, StepSpec] = {
    "dgemm": StepSpec(
        "dgemm",
        dgemm_step,
        (_sds((DGEMM_N, DGEMM_N)), _sds((DGEMM_N, DGEMM_N))),
        "cpu",
        2 * DGEMM_N**3,
        3 * DGEMM_N * DGEMM_N * 4,
    ),
    "stream": StepSpec(
        "stream",
        stream_step,
        (_sds(STREAM_SHAPE), _sds(STREAM_SHAPE), _sds((1, 1))),
        "memory",
        2 * STREAM_SHAPE[0] * STREAM_SHAPE[1],
        stream_k.bytes_moved(STREAM_SHAPE),
    ),
    "minife": StepSpec(
        "minife",
        minife_step,
        (_sds(MINIFE_GRID), _sds(MINIFE_GRID), _sds(MINIFE_GRID)),
        "cpu+memory",
        stencil_k.flops(MINIFE_GRID) + 10 * MINIFE_GRID[0] * MINIFE_GRID[1] * MINIFE_GRID[2],
        8 * MINIFE_GRID[0] * MINIFE_GRID[1] * MINIFE_GRID[2] * 4,
    ),
    "ring": StepSpec(
        "ring",
        ring_step,
        (_sds(RING_SHAPE), _sds((RING_SHAPE[0],), i32)),
        "network",
        2 * RING_SHAPE[0] * RING_SHAPE[1],
        ring_k.bytes_on_wire(RING_SHAPE),
    ),
    "fft": StepSpec(
        "fft",
        fft_step,
        (_sds((FFT_N,)), _sds((FFT_N,))),
        "network",
        fft_k.flops(FFT_N),
        4 * FFT_N * 4,
    ),
}
