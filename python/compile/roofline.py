"""Real-TPU performance estimation for the L1 kernels (DESIGN.md §8).

Pallas runs here under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls), so wall-clock numbers are not a TPU proxy. This module
instead estimates each kernel's real-TPU standing analytically from its
BlockSpec structure: VMEM residency, MXU/VPU utilization, arithmetic
intensity, and the roofline-implied bound (compute- vs HBM-bound) for a
TPU v4-like core (275 TFLOP/s fp32-equivalent MXU path at bf16 inputs,
1.2 TB/s HBM, 16 MiB VMEM, 128x128 MXU, 8x128 VPU).

Used by the perf pass (EXPERIMENTS.md §Perf) and tested in
python/tests/test_roofline.py.
"""

from __future__ import annotations

from dataclasses import dataclass

# TPU v4-like core parameters.
PEAK_FLOPS = 137.5e12  # fp32-accumulate MXU path, one core
HBM_BW = 1.2e12  # bytes/s
VMEM_BYTES = 16 * 2**20
MXU_DIM = 128
VPU_LANES = (8, 128)


@dataclass(frozen=True)
class KernelEstimate:
    name: str
    vmem_bytes: int
    vmem_frac: float
    flops_per_step: int
    hbm_bytes_per_step: int
    arithmetic_intensity: float
    mxu_utilization: float  # fraction of MXU issue slots doing useful work
    bound: str  # "compute" | "memory"
    est_step_seconds: float


def _roofline(flops: int, hbm_bytes: int, mxu_util: float) -> tuple[str, float]:
    t_compute = flops / (PEAK_FLOPS * max(mxu_util, 1e-9))
    t_memory = hbm_bytes / HBM_BW
    if t_compute >= t_memory:
        return "compute", t_compute
    return "memory", t_memory


def dgemm_estimate(m: int, n: int, k: int, bm: int = 128, bn: int = 128, bk: int = 128) -> KernelEstimate:
    """Blocked matmul: double-buffered A/B tiles + resident fp32 out tile."""
    vmem = 2 * (bm * bk + bk * bn) * 4 + bm * bn * 4
    flops = 2 * m * n * k
    # Each A tile read n/bn times, each B tile read m/bm times, C written once.
    hbm = (m * k * (n // bn) + k * n * (m // bm) + m * n) * 4
    # MXU utilization: fraction of the 128x128 systolic array covered by the
    # tile (full tiles -> 1.0), degraded by pipeline drain at small K.
    cover = min(bm, MXU_DIM) * min(bn, MXU_DIM) / (MXU_DIM * MXU_DIM)
    drain = bk / (bk + MXU_DIM)
    util = cover * drain
    bound, secs = _roofline(flops, hbm, util)
    return KernelEstimate(
        "dgemm", vmem, vmem / VMEM_BYTES, flops, hbm, flops / hbm, util, bound, secs
    )


def stream_estimate(rows: int, lanes: int, brows: int = 8, blanes: int = 1024) -> KernelEstimate:
    """Triad: pure streaming, no reuse — memory-bound by construction."""
    vmem = 3 * brows * blanes * 4 * 2  # double-buffered b, c, a blocks
    n = rows * lanes
    flops = 2 * n
    hbm = 3 * n * 4
    # VPU op every cycle while data is resident: utilization is the block's
    # lane alignment.
    util = min(blanes, VPU_LANES[1]) / VPU_LANES[1] * min(brows, VPU_LANES[0]) / VPU_LANES[0]
    bound, secs = _roofline(flops, hbm, util)
    return KernelEstimate(
        "stream", vmem, vmem / VMEM_BYTES, flops, hbm, flops / hbm, util, bound, secs
    )


def stencil_estimate(nz: int, ny: int, nx: int, bz: int = 4) -> KernelEstimate:
    """7-point stencil: slab + halo resident; each point read ~once with
    halo overlap along z."""
    slab = (bz + 2) * (ny + 2) * (nx + 2) * 4
    vmem = 2 * slab + bz * ny * nx * 4
    n = nz * ny * nx
    flops = 13 * n
    # z-halo rows re-read once per neighbouring slab.
    hbm = (n + 2 * (nz // bz) * ny * nx + n) * 4
    util = 0.35  # elementwise VPU work, no MXU
    bound, secs = _roofline(flops, hbm, util)
    return KernelEstimate(
        "minife", vmem, vmem / VMEM_BYTES, flops, hbm, flops / hbm, util, bound, secs
    )


def fft_estimate(n: int) -> KernelEstimate:
    """Radix-2 butterflies: 10 flops/point/stage, log2 n stages."""
    import math

    stages = int(math.log2(n))
    flops = 10 * n * stages
    # Ping-pong through VMEM when the signal fits (it does at our sizes).
    vmem = 4 * n * 4 * 2
    hbm = 4 * n * 4  # one read + one write of planar re/im
    util = 0.25
    bound, secs = _roofline(flops, hbm, util)
    return KernelEstimate(
        "fft", vmem, vmem / VMEM_BYTES, flops, hbm, flops / hbm, util, bound, secs
    )


def ring_estimate(p: int, n: int) -> KernelEstimate:
    """Ring exchange: bandwidth-bound combine (ICI-bound on a real pod)."""
    vmem = 2 * n * 4 * 2
    flops = 2 * p * n
    hbm = 3 * p * n * 4
    util = 0.25
    bound, secs = _roofline(flops, hbm, util)
    return KernelEstimate(
        "ring", vmem, vmem / VMEM_BYTES, flops, hbm, flops / hbm, util, bound, secs
    )


def all_estimates() -> list[KernelEstimate]:
    from .model import DGEMM_N, FFT_N, MINIFE_GRID, RING_SHAPE, STREAM_SHAPE

    return [
        dgemm_estimate(DGEMM_N, DGEMM_N, DGEMM_N),
        stream_estimate(*STREAM_SHAPE),
        stencil_estimate(*MINIFE_GRID),
        fft_estimate(FFT_N),
        ring_estimate(*RING_SHAPE),
    ]


def report() -> str:
    lines = [
        f"{'kernel':<8} {'VMEM':>9} {'%VMEM':>6} {'AI':>7} {'MXU/VPU':>8} "
        f"{'bound':>8} {'est step':>10}"
    ]
    for e in all_estimates():
        lines.append(
            f"{e.name:<8} {e.vmem_bytes / 1024:>7.0f}Ki {e.vmem_frac * 100:>5.1f}% "
            f"{e.arithmetic_intensity:>7.2f} {e.mxu_utilization:>8.2f} "
            f"{e.bound:>8} {e.est_step_seconds * 1e6:>8.1f}us"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
