"""EP-DGEMM payload kernel (L1, Pallas).

The HPCC EP-DGEMM benchmark measures per-process dense matmul throughput —
the paper classifies it as *CPU intensive*.  On TPU the analogous hot loop is
an MXU-targeted blocked matmul: tiles sized so that one (BM, BK) A-tile, one
(BK, BN) B-tile and one (BM, BN) fp32 output/accumulator tile fit comfortably
in VMEM, with the K reduction carried across the innermost grid dimension and
accumulated in place in the revisited output block (fp32, MXU-style).

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpec structure is nevertheless authored for
the real-TPU HBM->VMEM schedule (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shapes: 128x128 output tiles feed the 128x128 MXU; BK=128
# keeps the A/B/out working set at 3 * 128*128*4 B = 192 KiB << 16 MiB VMEM,
# leaving headroom for double buffering.
BM = 128
BN = 128
BK = 128


def _dgemm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: ``o[i, j] += A[i, k] @ B[k, j]``.

    The output tile is revisited along the K grid dimension (its index map
    ignores ``k``), so it doubles as the fp32 accumulator: initialised on the
    first K step, accumulated on every step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def dgemm(
    a: jax.Array, b: jax.Array, *, bm: int = BM, bn: int = BN, bk: int = BK
) -> jax.Array:
    """Blocked ``a @ b`` with fp32 accumulation.

    Shapes must tile exactly: ``a: (M, K)``, ``b: (K, N)`` with
    ``M % bm == K % bk == N % bn == 0``.  Returns fp32.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shape ({m},{k})x({k},{n}) does not tile by ({bm},{bn},{bk})"
        )
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_dgemm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK, itemsize: int = 4) -> int:
    """Per-step VMEM working set (A-tile + B-tile + fp32 out tile).

    Used by the perf pass (DESIGN.md §Perf) to estimate real-TPU residency;
    with double buffering the steady-state footprint is 2x the input tiles
    plus one accumulator tile.
    """
    a_tile = bm * bk * itemsize
    b_tile = bk * bn * itemsize
    o_tile = bm * bn * 4
    return 2 * (a_tile + b_tile) + o_tile
